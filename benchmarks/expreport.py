"""Generate the data-driven sections of EXPERIMENTS.md from artifacts.

    PYTHONPATH=src python -m benchmarks.expreport > experiments/report.md

Pulls: experiments/dryrun/<mesh>/*.json (dry-run + variants) and
experiments/bench/suite_*.json (agent suite).  The narrative sections of
EXPERIMENTS.md are hand-written; this produces the tables they reference.
"""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.roofline import DRYRUN_DIR, roofline

GiB = 2 ** 30


def _cells(mesh: str, variant: str = "baseline"):
    d = DRYRUN_DIR / mesh
    return [json.loads(f.read_text())
            for f in sorted(d.glob(f"*__{variant}.json"))]


def dryrun_section() -> list[str]:
    out = ["### Dry-run results (both meshes)", ""]
    for mesh, chips in (("single", 256), ("multi", 512)):
        ok = sum(1 for c in _cells(mesh) if c["status"] == "ok")
        sk = sum(1 for c in _cells(mesh) if c["status"] == "skipped")
        out.append(f"**{mesh}-pod ({chips} chips)**: {ok} compiled OK, "
                   f"{sk} documented skips, {40 - ok - sk} errors.")
        out.append("")
        out.append("| arch | shape | status | compile s | params/dev | "
                   "state/dev | CPU-temp* |")
        out.append("|---|---|---|---|---|---|---|")
        for c in _cells(mesh):
            if c["status"] == "skipped":
                out.append(f"| {c['arch']} | {c['shape']} | SKIP: "
                           f"{c['reason'][:48]} | | | | |")
                continue
            if c["status"] != "ok":
                out.append(f"| {c['arch']} | {c['shape']} | ERROR | | | | |")
                continue
            ma = c.get("memory_analytic", {})
            state = (ma.get("opt_per_device", 0)
                     + ma.get("cache_per_device", 0))
            out.append(
                f"| {c['arch']} | {c['shape']} | ok | {c['compile_s']:.0f} "
                f"| {ma.get('params_per_device', 0)/GiB:.2f} GiB "
                f"| {state/GiB:.2f} GiB "
                f"| {c['memory']['temp_size_in_bytes']/GiB:.1f} GiB |")
        out.append("")
    out.append("*CPU-temp: XLA CPU-backend temp allocation — inflated by "
               "f32 weight-conversion copies (no host bf16 FMA); the "
               "analytic columns are the TPU-credible persistent state. "
               "See §Dry-run notes.*")
    out.append("")
    return out


def roofline_section(mesh: str = "single") -> list[str]:
    out = [f"### Roofline table ({mesh}-pod, baseline)", "",
           "| arch | shape | t_compute (s) | t_memory (s) | t_collective (s)"
           " | dominant | MODEL/HLO | roofline frac |",
           "|---|---|---|---|---|---|---|---|"]
    for c in _cells(mesh):
        if c["status"] == "skipped":
            out.append(f"| {c['arch']} | {c['shape']} | — | — | — | N/A | — "
                       f"| — |")
            continue
        r = roofline(c)
        if r is None:
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute']:.3e} "
            f"| {r['t_memory']:.3e} | {r['t_collective']:.3e} "
            f"| {r['dominant']} | {r['useful_ratio']:.3f} "
            f"| {r['roofline_fraction']:.3f} |")
    out.append("")
    return out


def variant_rows(arch: str, shape: str, variants: list[str],
                 mesh: str = "single") -> list[str]:
    out = [f"| variant | flops/dev | bytes/dev | coll bytes/dev | t_dominant |",
           "|---|---|---|---|---|"]
    for v in ["baseline"] + variants:
        f = DRYRUN_DIR / mesh / f"{arch}__{shape}__{v}.json"
        if not f.exists():
            out.append(f"| {v} | (missing) | | | |")
            continue
        c = json.loads(f.read_text())
        if c["status"] != "ok":
            out.append(f"| {v} | ERROR | | | |")
            continue
        r = roofline(c)
        dom = max(("compute", r["t_compute"]), ("memory", r["t_memory"]),
                  ("collective", r["t_collective"]), key=lambda kv: kv[1])
        out.append(
            f"| {v} | {c['flops_per_device']:.3e} "
            f"| {c['bytes_per_device']:.3e} "
            f"| {c['collective_bytes_per_device'].get('total', 0):.3e} "
            f"| {dom[0]} {dom[1]:.3e}s |")
    return out


def agents_section() -> list[str]:
    caches = sorted((Path(__file__).resolve().parent.parent / "experiments"
                     / "bench").glob("suite_*.json"))
    if not caches:
        return ["(run `python -m benchmarks.run` first)"]
    raw = json.loads(caches[-1].read_text())
    out = ["### Agent suite (seq vs par; response time in decode steps)", "",
           "| task | coupling? | seq steps | par steps | Δ raw | seq tok "
           "| par tok | Δ vol | steps/1k seq | steps/1k par | inval(par) "
           "| conflicts(par) | converged |",
           "|---|---|---|---|---|---|---|---|---|---|---|---|---|"]
    from repro.agents.tasks import TASKS
    for t, modes in raw.items():
        sq = modes["sequential"]
        pr = modes["parallel"]
        m = lambda rs, k: sum(r[k] for r in rs) / len(rs)
        s_steps, p_steps = m(sq, "steps"), m(pr, "steps")
        s_tok, p_tok = m(sq, "gen_tokens"), m(pr, "gen_tokens")
        conv = all(r["converged"] for r in sq + pr)
        out.append(
            f"| {t} | {TASKS[t].coupling} | {s_steps:.0f} | {p_steps:.0f} "
            f"| {100*(p_steps-s_steps)/s_steps:+.1f}% "
            f"| {s_tok:.0f} | {p_tok:.0f} "
            f"| {100*(p_tok-s_tok)/s_tok:+.1f}% "
            f"| {1000*s_steps/s_tok:.0f} | {1000*p_steps/p_tok:.0f} "
            f"| {m(pr, 'invalidations'):.1f} "
            f"| {m(pr, 'semantic_conflicts'):.1f} | {conv} |")
    out.append("")
    return out


def schedule_section() -> list[str]:
    """Per-op collective schedule for representative cells (§Dry-run)."""
    out = ["### Collective schedule (bytes/device/step, representative cells)",
           "", "| cell | all-gather | all-reduce | reduce-scatter | "
           "all-to-all | collective-permute |", "|---|---|---|---|---|---|"]
    picks = [("command-r-plus-104b", "train_4k"),
             ("deepseek-moe-16b", "train_4k"),
             ("command-r-plus-104b", "decode_32k"),
             ("olmo-1b", "decode_32k"),
             ("recurrentgemma-2b", "long_500k")]
    for arch, shape in picks:
        f = DRYRUN_DIR / "single" / f"{arch}__{shape}__baseline.json"
        if not f.exists():
            continue
        c = json.loads(f.read_text())
        if c["status"] != "ok":
            continue
        coll = c["collective_bytes_per_device"]
        row = [f"{arch} × {shape}"]
        for op in ("all-gather", "all-reduce", "reduce-scatter",
                   "all-to-all", "collective-permute"):
            v = coll.get(op, 0.0)
            row.append(f"{v:.2e}" if v else "—")
        out.append("| " + " | ".join(row) + " |")
    out.append("")
    return out


def perf_variants_section() -> list[str]:
    out = ["### §Perf variant tables (raw numbers)", ""]
    cells = [
        ("deepseek-moe-16b", "train_4k",
         ["dense_dispatch", "no_remat", "cap_1.0", "cap1_noremat"]),
        ("deepseek-v2-lite-16b", "decode_32k", ["mla_repl", "mla_seq"]),
        ("olmo-1b", "decode_32k",
         ["fused_allgather", "fused_pmax", "fused_pmax_every4"]),
        ("xlstm-125m", "train_4k", ["serial_tscan"]),
        ("recurrentgemma-2b", "long_500k", ["ring_cache"]),
        ("recurrentgemma-2b", "decode_32k", ["ring_cache"]),
    ]
    for arch, shape, variants in cells:
        out.append(f"**{arch} × {shape}**")
        out.extend(variant_rows(arch, shape, variants))
        out.append("")
    return out


def main():
    print("\n".join(dryrun_section()))
    print("\n".join(schedule_section()))
    print("\n".join(roofline_section("single")))
    print("\n".join(perf_variants_section()))
    print("\n".join(agents_section()))


if __name__ == "__main__":
    main()
