"""Serving benchmark: dense vs paged KV cache under continuous batching,
the chunked-vs-stalled admission sweep of the token-budget mixed step, the
replicated page-table sweep (N engines gossiping one CRDT page table:
sync bytes per step + cross-replica shared-prefix resolution), and the
speculative-decoding sweep (off vs prompt-lookup vs CRDT-doc drafting:
accept rate, committed tokens/step, µs/accepted-token, stream identity),
the quantized page-pool sweep (off vs int8 vs fp8: resident-capacity gain,
analytic read bytes/step, logit-error report, greedy-stream identity), and
the tiered-memory sweep (host-swap preemption vs recompute-from-scratch).

Sweeps batch × context-length skew × cache layout and reports, per config:

  us_per_token            median step wall time / mean active rows
  write_bytes_per_step    cache bytes *written* per decode step (analytic)
  read_bytes_per_step     cache bytes *read* per decode step (analytic)
  resident_cache_mb       KV bytes pinned at the live-token watermark
  decode_stall_steps      steps where a decode-ready lane got no budget
  ttft_steps / ttft_ms    admission → first token
  itl_p50 / itl_p99       inter-token latency across all requests

The write accounting is the point of the original exercise: the dense
path's one-hot ``jnp.where`` rewrites the full [B, Hkv, S, D] cache per
layer per step (O(B·max_len)), while the paged path writes one page slot
per row (O(page)).  The ``chunked_admission`` sweep is the mixed step's
headline: stalled (whole-prompt, decode lanes idle — the old bucketed
admission) vs chunked (≤ chunk-size prompt slices interleaved with decode
spans) — chunked holds decode_stall_steps at zero while the stalled
baseline idles every in-flight lane per admission.

  PYTHONPATH=src python -m benchmarks.bench_serving [--quick] [--out PATH]

Prints ``name,us_per_call,derived`` CSV rows (the harness contract).
"""
from __future__ import annotations

import argparse
import json
import statistics
import time
from pathlib import Path

import numpy as np


def _abstract_cache(cfg, *, batch: int, max_len: int, page_size: int,
                    paged: bool, kv_quant: str = "off"):
    """Shape-only cache tree (no allocation) for byte accounting."""
    import jax

    from repro.models import lm

    return jax.eval_shape(lambda: lm.init_cache(
        cfg, batch, max_len, paged=paged, page_size=page_size,
        kv_quant=kv_quant))


def analytic_step_bytes(cfg, *, batch: int, max_len: int, page_size: int,
                        live_lens: list[int], paged: bool,
                        kv_quant: str = "off") -> tuple[int, int]:
    """(write_bytes, read_bytes) of KV-cache traffic for ONE decode step.

    Dense: the one-hot masked select produces a full new cache value per
    attention layer (write = |cache|) after streaming the old one (read =
    |cache|).  Paged: one slot write per row; reads walk only live pages.

    ONE code path for dense / paged / quantized: every byte count is
    derived from the cache tree's own leaf shapes+dtypes via the same
    helpers roofline.py uses (kv_slot_bytes / kv_page_bytes /
    dense_kv_bytes), so a quantized pool automatically counts its int8/fp8
    payload plus the f32 per-row scale leaves, and the bench agrees with
    the roofline model by construction.
    """
    from benchmarks import roofline

    cache = _abstract_cache(cfg, batch=batch, max_len=max_len,
                            page_size=page_size, paged=paged,
                            kv_quant=kv_quant)
    if not paged:
        total = roofline.dense_kv_bytes(cache)
        return total, total
    write = batch * roofline.kv_slot_bytes(cache)
    read = sum(-(-(l + 1) // page_size) for l in live_lens) \
        * roofline.kv_page_bytes(cache)
    return write, read


def analytic_slot_bytes(cfg, *, batch: int, max_len: int, page_size: int,
                        kv_quant: str = "off") -> int:
    """Bytes one cached token pins across all paged layers (pool + scales)."""
    from benchmarks import roofline

    return roofline.kv_slot_bytes(_abstract_cache(
        cfg, batch=batch, max_len=max_len, page_size=page_size, paged=True,
        kv_quant=kv_quant))


def _quantile(xs: list[float], q: float) -> float:
    if not xs:
        return 0.0
    xs = sorted(xs)
    i = min(len(xs) - 1, int(round(q * (len(xs) - 1))))
    return xs[i]


def run_config(cfg, params, *, batch: int, max_len: int, page_size: int,
               skew: str, paged: bool, n_requests: int, prompt_hi: int,
               max_new: int, seed: int = 0, chunk_size: int = 32,
               interleave: bool = True, stagger: bool = False,
               kv_quant: str = "off") -> dict:
    from repro.serving.scheduler import ContinuousBatchingEngine, Request

    rng = np.random.default_rng(seed)
    if skew == "uniform":
        plens = [prompt_hi] * n_requests
    else:                                       # ragged: log-uniform spread
        plens = [int(x) for x in np.exp(rng.uniform(
            np.log(4), np.log(prompt_hi), n_requests)).astype(int)]
    # ``stagger`` varies generation lengths so completions (and therefore
    # admissions) interleave with decode — the regime where stalled
    # admission actually stalls lanes.
    news = [max(1, max_new // 2 + (i * 3) % max_new) if stagger else max_new
            for i in range(n_requests)]
    requests = [Request(rid=i,
                        prompt=[int(t) for t in
                                rng.integers(2, cfg.vocab_size, p)],
                        max_new_tokens=news[i])
                for i, p in enumerate(plens)]

    eng = ContinuousBatchingEngine(cfg, params, batch=batch, max_len=max_len,
                                   paged=paged, page_size=page_size,
                                   chunk_size=chunk_size,
                                   prefill_interleave=interleave,
                                   kv_quant=kv_quant)
    for r in requests:
        eng.submit(r)
    step_times: list[float] = []
    step_stamps: list[float] = [time.perf_counter()]
    active_counts: list[int] = []
    live_len_samples: list[list[int]] = []
    resident_peak = 0
    tok_stamp: dict[int, list[float]] = {r.rid: [] for r in requests}
    tok_seen = {r.rid: 0 for r in requests}
    while True:
        live = [len(r.prompt) + len(r.tokens)
                for r in eng.rows if r is not None]
        t0 = time.perf_counter()
        more = eng.step()
        now = time.perf_counter()
        step_times.append(now - t0)
        step_stamps.append(now)
        for r in requests:                      # per-token arrival stamps
            while tok_seen[r.rid] < len(r.tokens):
                tok_stamp[r.rid].append(now)
                tok_seen[r.rid] += 1
        if live:
            active_counts.append(len(live))
            live_len_samples.append(live)
        resident_peak = max(resident_peak, eng.resident_cache_bytes())
        if not more:
            break
        if eng.stats["steps"] > 50_000:
            raise RuntimeError("bench runaway")

    # Median step time strips compile outliers (first call per bucket/shape).
    med_step = statistics.median(step_times)
    mean_active = statistics.fmean(active_counts) if active_counts else 0.0
    mid_lens = live_len_samples[len(live_len_samples) // 2] \
        if live_len_samples else []
    wb, rb = analytic_step_bytes(cfg, batch=batch, max_len=max_len,
                                 page_size=page_size, live_lens=mid_lens,
                                 paged=paged, kv_quant=kv_quant)
    admitted_mid_flight = sum(1 for r in requests if r.admitted_step > 0)
    # TTFT in steps is deterministic (greedy, fixed seeds); wall TTFT rides
    # the step timestamps.  Inter-token latency pools per-request diffs.
    ttft_steps = [r.first_token_step - r.admitted_step for r in requests
                  if r.first_token_step >= 0]
    ttft_wall = [step_stamps[min(r.first_token_step, len(step_stamps) - 1)]
                 - step_stamps[min(r.admitted_step, len(step_stamps) - 1)]
                 for r in requests if r.first_token_step >= 0]
    itl = [b - a for stamps in tok_stamp.values()
           for a, b in zip(stamps, stamps[1:])]
    return {
        "batch": batch, "skew": skew, "mode": "paged" if paged else "dense",
        "kv_quant": kv_quant,
        "max_len": max_len, "page_size": page_size,
        "chunk_size": chunk_size, "interleave": interleave,
        "n_requests": n_requests, "gen_tokens": eng.stats["gen_tokens"],
        "steps": eng.stats["steps"], "prefills": eng.stats["prefills"],
        "prefill_chunks": eng.stats["prefill_chunks"],
        "us_per_token": 1e6 * med_step / max(mean_active, 1e-9),
        "us_per_step": 1e6 * med_step,
        "mean_active_rows": mean_active,
        "write_bytes_per_step": wb,
        "read_bytes_per_step": rb,
        "resident_cache_mb": resident_peak / 2**20,
        "peak_pages": eng.stats["peak_pages"],
        "admitted_mid_flight": admitted_mid_flight,
        "completed": eng.stats["completed"],
        "decode_stall_steps": eng.stats["decode_stall_steps"],
        "stalled_lane_steps": eng.stats["stalled_lane_steps"],
        "ttft_steps_mean": (statistics.fmean(ttft_steps)
                            if ttft_steps else 0.0),
        "ttft_steps_max": max(ttft_steps, default=0),
        "ttft_ms_mean": 1e3 * (statistics.fmean(ttft_wall)
                               if ttft_wall else 0.0),
        "itl_p50_us": 1e6 * _quantile(itl, 0.50),
        "itl_p99_us": 1e6 * _quantile(itl, 0.99),
    }


def run_chunked_admission(cfg, params, *, batch: int, max_len: int,
                          page_size: int, n_requests: int, prompt_hi: int,
                          max_new: int, chunks: tuple[int, ...]) -> list[dict]:
    """Chunked vs stalled admission sweep (the mixed-step headline).

    ``stalled`` emulates the old bucketed-admission scheduler: prompts land
    whole and decode lanes idle while any admission is in flight.  Each
    ``chunked`` row interleaves ≤ chunk-size prompt slices with decode spans
    — decode_stall_steps drops to zero and inter-token latency flattens,
    at the cost of more (smaller) steps per admission.
    """
    rows = []
    base = dict(batch=batch, max_len=max_len, page_size=page_size,
                skew="ragged", paged=True, n_requests=n_requests,
                prompt_hi=prompt_hi, max_new=max_new, stagger=True)
    row = run_config(cfg, params, interleave=False, chunk_size=max_len,
                     **base)
    row["admission"] = "stalled"
    rows.append(row)
    for chunk in chunks:
        row = run_config(cfg, params, interleave=True, chunk_size=chunk,
                         **base)
        row["admission"] = "chunked"
        rows.append(row)
    return rows


def run_prefix_share(cfg, params, *, max_len: int, page_size: int,
                     fanout: int, prompt_len: int, max_new: int,
                     share: bool, seed: int = 0) -> dict:
    """Fan-out of ``fanout`` agents forked from ONE shared prompt.

    With ``share=True`` the scheduler's copy-on-write prefix sharing is on:
    the clones' prompt pages are refcounted aliases of the first admission's
    pages, duplicated only when a row is about to write into one.  Reports
    peak resident KV MB and per-admission µs for the with/without-COW
    comparison column.
    """
    from repro.serving.scheduler import ContinuousBatchingEngine, Request

    rng = np.random.default_rng(seed)
    prompt = [int(t) for t in rng.integers(2, cfg.vocab_size, prompt_len)]
    requests = [Request(rid=i, prompt=list(prompt), max_new_tokens=max_new)
                for i in range(fanout)]
    eng = ContinuousBatchingEngine(cfg, params, batch=fanout,
                                   max_len=max_len, paged=True,
                                   page_size=page_size, prefix_sharing=share)
    # Warm the prefill bucket / decode shapes so admission_us measures the
    # steady-state admission path, not the jit compile.
    eng.run([Request(rid=-1, prompt=list(prompt), max_new_tokens=max_new)])
    eng.stats.update(admit_s=0.0, prefills=0, peak_pages=0,
                     shared_pages=0, cow_copies=0, completed=0)
    for r in requests:
        eng.submit(r)
    resident_peak = 0
    while True:
        more = eng.step()
        resident_peak = max(resident_peak, eng.resident_cache_bytes())
        if not more:
            break
        if eng.stats["steps"] > 50_000:
            raise RuntimeError("prefix-share bench runaway")
    s = eng.stats
    return {
        "fanout": fanout, "prompt_len": prompt_len, "max_new": max_new,
        "page_size": page_size, "cow": share,
        "resident_cache_mb": resident_peak / 2**20,
        "peak_pages": s["peak_pages"],
        "admission_us": 1e6 * s["admit_s"] / max(s["prefills"], 1),
        "shared_pages": s["shared_pages"], "cow_copies": s["cow_copies"],
        "completed": s["completed"],
    }


def run_replicated(cfg, params, *, replicas: int, batch: int, max_len: int,
                   page_size: int, prompt_len: int, max_new: int,
                   sync_every: int = 1, seed: int = 0) -> dict:
    """Staggered shared-prefix fan-out across ``replicas`` engine replicas.

    Requests arrive in an ``A A B B ...`` pattern over two distinct prompts,
    sized so round-robin dispatch lands BOTH prompts on EVERY replica.  The
    first admitter of each prompt publishes its immutable full prefix pages
    into the replicated CRDT map; later admissions of the same prompt on
    *other* replicas then resolve those pages through the gossip'd metadata
    (``cross_replica_hits`` — the coordination-layer signal this sweep
    gates on) while local re-admissions hit the ordinary COW prefix cache.
    Also reports the anti-entropy wire cost (``sync_bytes_per_step``) and
    asserts bitwise page-table convergence across replicas at drain.
    """
    from repro.serving.replicated import MultiEngineServer
    from repro.serving.scheduler import Request

    rng = np.random.default_rng(seed)
    prompts = [[int(t) for t in rng.integers(2, cfg.vocab_size, prompt_len)]
               for _ in range(2)]
    # Round-robin sends request i to replica i % R.  Wave w = i // R gives
    # the first half of the replicas prompt A and the rest prompt B, then
    # SWAPS every wave — so each replica's queue alternates prompts and its
    # later admissions land after a peer has published that prompt's pages.
    n_requests = 4 * replicas
    def _prompt_idx(i: int) -> int:
        half = 0 if 2 * (i % replicas) < replicas else 1
        return (half + i // replicas) % 2
    requests = [Request(rid=i, prompt=list(prompts[_prompt_idx(i)]),
                        max_new_tokens=max_new)
                for i in range(n_requests)]
    server = MultiEngineServer(cfg, params, replicas=replicas, batch=batch,
                               max_len=max_len, page_size=page_size,
                               sync_every=sync_every, chunk_size=page_size)
    for r in requests:
        server.submit(r)
    step_times: list[float] = []
    while True:
        t0 = time.perf_counter()
        more = server.step()
        step_times.append(time.perf_counter() - t0)
        if not more:
            break
        if server.clock > 50_000:
            raise RuntimeError("replicated bench runaway")
    server.sync()                           # final round: frontiers settle
    s = server.stats()
    med_step = statistics.median(step_times)
    return {
        "replicas": replicas, "batch": batch, "page_size": page_size,
        "sync_every": sync_every, "n_requests": n_requests,
        "prompt_len": prompt_len,
        "us_per_step": 1e6 * med_step,
        "steps": s["steps"], "syncs": s["syncs"],
        "gen_tokens": s["gen_tokens"], "completed": s["completed"],
        "sync_bytes": s["sync_bytes"],
        "sync_bytes_per_step": s["sync_bytes_per_step"],
        "cross_replica_hits": s["cross_replica_hits"],
        "published_prefix_pages": s["published_prefix_pages"],
        "shared_pages": s["shared_pages"],
        "converged": server.converged(),
    }


def run_disagg(cfg, params, *, replicas: int, batch: int, max_len: int,
               page_size: int, prompt_len: int, max_new: int,
               adopt: bool, seed: int = 0) -> tuple[dict, dict]:
    """Disaggregated prefill/decode sweep: one prefill replica + decode
    replicas over the CRDT page table, staggered shared-prefix arrivals.

    The first ``batch`` requests arrive at t=0 (cold — routed to the
    prefill replica); the rest arrive one per step, so same-prompt
    followers land after the prefill replica has published its filled
    pages and routing steers them to the decode tier.  With
    ``adopt=True`` the decode replicas' adoption hooks physically transfer
    the published pages (rule-3 commit) and admission skips the covered
    prefill chunks; ``adopt=False`` is the local-prefill baseline —
    identical topology, routing, and publication, but every decode
    admission recomputes its prompt.  Returns ``(row, streams)`` where
    ``streams`` maps rid -> generated tokens (the acceptance section
    checks the two sweeps match token-for-token: adoption is bitwise).
    """
    from repro.serving.replicated import MultiEngineServer
    from repro.serving.scheduler import Request

    rng = np.random.default_rng(seed)
    prompts = [[int(t) for t in rng.integers(2, cfg.vocab_size, prompt_len)]
               for _ in range(2)]
    n_requests = 4 * replicas
    requests = [Request(rid=i, prompt=list(prompts[(i // 2) % 2]),
                        max_new_tokens=max_new)
                for i in range(n_requests)]
    roles = ["prefill"] + ["decode"] * (replicas - 1)
    server = MultiEngineServer(cfg, params, replicas=replicas, batch=batch,
                               max_len=max_len, page_size=page_size,
                               sync_every=1, chunk_size=page_size,
                               roles=roles, adopt_pages=adopt)
    pending = list(requests)
    for req in pending[:batch]:
        server.submit(req)
    pending = pending[batch:]
    step_times: list[float] = []
    while True:
        t0 = time.perf_counter()
        more = server.step()
        step_times.append(time.perf_counter() - t0)
        if pending:
            server.submit(pending.pop(0))
            continue
        if not more:
            break
        if server.clock > 50_000:
            raise RuntimeError("disagg bench runaway")
    server.sync()                           # final round: frontiers settle
    s = server.stats()
    ttft = [r.first_token_step - r.admitted_step for r in requests
            if r.first_token_step >= 0]
    row = {
        "adoption": "on" if adopt else "off",
        "replicas": replicas, "batch": batch, "page_size": page_size,
        "n_requests": n_requests, "prompt_len": prompt_len,
        "us_per_step": 1e6 * statistics.median(step_times),
        "steps": s["steps"],
        "gen_tokens": s["gen_tokens"], "completed": s["completed"],
        "ttft_steps_mean": (statistics.fmean(ttft) if ttft else 0.0),
        "ttft_steps_max": max(ttft, default=0),
        "adopted_pages": s["adopted_pages"],
        "adopted_tokens": s["adopted_tokens"],
        "prefill_steps_avoided": s["prefill_steps_avoided"],
        "transferred_pages": s["transferred_pages"],
        "transfer_bytes": s["transfer_bytes"],
        "transfer_bytes_per_step": (s["transfer_bytes"] // s["steps"]
                                    if s["steps"] else 0),
        "adopt_aborts": s["adopt_aborts"],
        "cross_replica_hits": s["cross_replica_hits"],
        "published_prefix_pages": s["published_prefix_pages"],
        "sync_bytes_per_step": s["sync_bytes_per_step"],
        "converged": server.converged(),
    }
    streams = {r.rid: list(r.tokens) for r in requests}
    return row, streams


def _fault_row(trace: dict, base_steps: int) -> dict:
    srv = trace["server"]
    return {
        "schedule": trace["schedule"],
        # -1 marks the fault-free reference run of the same workload.
        "crash_at": -1 if trace["crash_replica"] is None
        else trace["crash_at"],
        "steps": trace["steps"],
        "recovery_step_overhead": trace["steps"] - base_steps,
        "completed": srv["completed"],
        "gen_tokens": srv["gen_tokens"],
        "goodput_tokens_per_step": srv["gen_tokens"] / max(trace["steps"], 1),
        "recovered": srv["recovered_requests"],
        "retried": srv["retried"],
        "shed": srv["shed"],
        "expired": srv["expired"],
        "lost": srv["lost_requests"],
        "failed": srv["failed_requests"],
        "ok": trace["ok"],
    }


def run_fault_sweep(cfg, params, *, schedules: tuple[str, ...],
                    crash_ats: tuple[int, ...], seed: int = 0) -> list[dict]:
    """Crash-failover sweep over the REAL multi-engine server (chaos
    harness): each row is one seeded (fault schedule x crash step) trial
    plus one fault-free reference of the same workload.  Greedy decoding
    and a seeded channel make every counter bit-identical across reruns of
    the same commit, so the regression gate holds them to the strict
    threshold; ``recovery_step_overhead`` (extra steps vs the fault-free
    reference — a TTFT/latency penalty in step units) is the headline
    recovery-cost number."""
    from repro.serving import chaos

    clean = chaos.run_chaos(cfg, params, schedule="lossy", seed=seed,
                            crash_replica=None)
    rows = [_fault_row(clean, clean["steps"])]
    for schedule in schedules:
        for crash_at in crash_ats:
            trace = chaos.run_chaos(cfg, params, schedule=schedule,
                                    seed=seed, crash_at=crash_at)
            rows.append(_fault_row(trace, clean["steps"]))
    return rows


def run_spec_decode(cfg, params, *, batch: int, max_len: int, page_size: int,
                    n_requests: int, prompt_hi: int, max_new: int,
                    spec_k: int = 4, chunk_size: int = 16,
                    seed: int = 0) -> list[dict]:
    """Speculative-decoding sweep: off vs prompt-lookup vs CRDT-doc drafting.

    One shared workload of motif-repeating prompts (the code-generation
    regime prompt lookup targets: trailing n-grams recur upstream).  The
    ``off`` row is the greedy reference; every spec row must reproduce its
    token streams exactly (``streams_match``) while finishing in fewer
    steps.  The ``doc`` row seeds the drafter with the reference run's
    converged streams — standing in for CRDT document content the system
    already agreed on, the case where doc-lookup beats own-history n-gram.

    ``us_per_accepted_token`` is the headline: median step wall time over
    committed tokens per step (accepted draft + bonus), the spec-decode
    analogue of µs/token.
    """
    from repro.serving import draft as draft_mod
    from repro.serving.scheduler import ContinuousBatchingEngine, Request

    rng = np.random.default_rng(seed)
    prompts = []
    for _ in range(n_requests):
        m = 4 + int(rng.integers(0, 4))
        motif = [int(t) for t in rng.integers(2, cfg.vocab_size, m)]
        tail = [int(t) for t in rng.integers(2, cfg.vocab_size, m)]
        reps = -(-prompt_hi // m)
        prompts.append((motif * reps)[: prompt_hi - len(tail)] + tail)

    def run_mode(mode: str, drafter=None):
        reqs = [Request(rid=i, prompt=list(p), max_new_tokens=max_new)
                for i, p in enumerate(prompts)]
        eng = ContinuousBatchingEngine(
            cfg, params, batch=batch, max_len=max_len, paged=True,
            page_size=page_size, chunk_size=chunk_size,
            spec_decode=mode, spec_k=spec_k, drafter=drafter)
        for r in reqs:
            eng.submit(r)
        times = []
        while True:
            t0 = time.perf_counter()
            more = eng.step()
            times.append(time.perf_counter() - t0)
            if not more:
                break
            if eng.stats["steps"] > 50_000:
                raise RuntimeError("spec-decode bench runaway")
        return eng, reqs, statistics.median(times)

    eng0, reqs0, med0 = run_mode("off")
    streams0 = {r.rid: list(r.tokens) for r in reqs0}
    rows = []
    for mode in ("off", "ngram", "doc"):
        if mode == "off":
            eng, reqs, med = eng0, reqs0, med0
        else:
            drafter = None
            if mode == "doc":
                drafter = draft_mod.DocDrafter()
                drafter.set_docs([list(p) + streams0[i]
                                  for i, p in enumerate(prompts)])
            eng, reqs, med = run_mode(mode, drafter=drafter)
        s = eng.stats
        tps = s["gen_tokens"] / max(s["steps"], 1)
        rows.append({
            "spec": mode, "batch": batch, "spec_k": spec_k,
            "chunk_size": chunk_size, "n_requests": n_requests,
            "steps": s["steps"], "gen_tokens": s["gen_tokens"],
            "draft_tokens": s["draft_tokens"],
            "accepted_tokens": s["accepted_tokens"],
            "rollback_tokens": s["rollback_tokens"],
            "spec_steps": s["spec_steps"],
            "spec_rollbacks": s["spec_rollbacks"],
            "accept_rate": eng.spec_accept_rate,
            "tokens_per_step": tps,
            "us_per_step": 1e6 * med,
            "us_per_accepted_token": 1e6 * med / max(tps, 1e-9),
            "completed": s["completed"],
            "streams_match": all(list(r.tokens) == streams0[r.rid]
                                 for r in reqs),
        })
    return rows


def run_spec_agents(cfg, params, *, spec_k: int = 4, max_len: int = 256,
                    page_size: int = 16, chunk_size: int = 16,
                    seed: int = 0) -> list[dict]:
    """End-to-end agent trial, speculative vs baseline.

    One sequential CodeCRDT task (single writer: no cross-agent
    observation timing, so the whole-trial document digest must match the
    non-speculative run bit-for-bit) run off vs doc-drafted.  Wall clock
    and step count are the e2e speedup numbers; digest equality is the
    e2e correctness gate.
    """
    from repro.agents.orchestrator import run_task
    from repro.agents.tasks import TASKS

    task = TASKS["tic_tac_toe"]
    rows = []
    base = None
    for mode in ("off", "doc"):
        r = run_task(cfg, params, task, mode="sequential", seed=seed,
                     max_len=max_len, kv="paged", prefill="chunked",
                     page_size=page_size, chunk_size=chunk_size,
                     spec_decode=mode, spec_k=spec_k)
        if base is None:
            base = r
        rows.append({
            "spec": mode, "task": task.name, "wall_s": r.wall_s,
            "steps": r.steps, "gen_tokens": r.gen_tokens,
            "draft_tokens": r.draft_tokens,
            "accepted_tokens": r.accepted_tokens,
            "rollback_tokens": r.rollback_tokens,
            "accept_rate": r.accept_rate,
            "digest_match": r.digest == base.digest,
        })
    return rows


# Documented quant-error budget (model-level logit error vs the bf16-pool
# reference, teacher-forced): int8 per-page-row scales bound element error
# by scale/2 ≈ amax/254; fp8 e4m3 has ~3 mantissa bits, so its budget is
# looser.  Greedy argmax must survive either way.
QUANT_LOGIT_TOL = {"int8": 0.25, "fp8": 0.5}


def _quant_modes():
    from repro.models import cache as cache_mod

    return tuple(m for m in cache_mod.KV_QUANT_MODES
                 if m != "fp8" or cache_mod.FP8_DTYPE is not None)


def run_quant_sweep(cfg, params, *, batch: int, max_len: int, page_size: int,
                    n_requests: int, prompt_hi: int, max_new: int,
                    chunk_size: int = 16, seed: int = 0) -> list[dict]:
    """Quantized page-pool sweep: off vs int8 (vs fp8 when the jax build
    has ``float8_e4m3fn``).

    One shared ragged workload through the full engine per mode.  The
    ``off`` row is the bf16-pool reference; the ``int8`` row must
    reproduce its greedy token streams exactly (``streams_match`` — fp8's
    ~3 mantissa bits may flip near-tie argmaxes, so fp8 is held to the
    logit-error budget instead) while reading fewer analytic bytes per
    step and pinning fewer resident MB at the live-token watermark.  ``resident_capacity_gain`` is the headline:
    bytes one cached token pins under bf16 over the same under the quant
    layout (pool + scale leaves) — how many MORE tokens the same pool MB
    can hold.
    """
    from repro.serving.scheduler import ContinuousBatchingEngine, Request

    rng = np.random.default_rng(seed)
    plens = [int(x) for x in np.exp(rng.uniform(
        np.log(4), np.log(prompt_hi), n_requests)).astype(int)]
    prompts = [[int(t) for t in rng.integers(2, cfg.vocab_size, p)]
               for p in plens]
    base_slot = analytic_slot_bytes(cfg, batch=batch, max_len=max_len,
                                    page_size=page_size, kv_quant="off")
    rows: list[dict] = []
    streams0 = None
    for mode in _quant_modes():
        reqs = [Request(rid=i, prompt=list(p), max_new_tokens=max_new)
                for i, p in enumerate(prompts)]
        eng = ContinuousBatchingEngine(cfg, params, batch=batch,
                                       max_len=max_len, paged=True,
                                       page_size=page_size,
                                       chunk_size=chunk_size, kv_quant=mode)
        for r in reqs:
            eng.submit(r)
        times: list[float] = []
        active: list[int] = []
        live_samples: list[list[int]] = []
        resident_peak = 0
        while True:
            live = [len(r.prompt) + len(r.tokens)
                    for r in eng.rows if r is not None]
            t0 = time.perf_counter()
            more = eng.step()
            times.append(time.perf_counter() - t0)
            if live:
                active.append(len(live))
                live_samples.append(live)
            resident_peak = max(resident_peak, eng.resident_cache_bytes())
            if not more:
                break
            if eng.stats["steps"] > 50_000:
                raise RuntimeError("quant bench runaway")
        streams = {r.rid: list(r.tokens) for r in reqs}
        if streams0 is None:
            streams0 = streams
        mid = live_samples[len(live_samples) // 2] if live_samples else []
        wb, rb = analytic_step_bytes(cfg, batch=batch, max_len=max_len,
                                     page_size=page_size, live_lens=mid,
                                     paged=True, kv_quant=mode)
        slot = analytic_slot_bytes(cfg, batch=batch, max_len=max_len,
                                   page_size=page_size, kv_quant=mode)
        med = statistics.median(times)
        mean_active = statistics.fmean(active) if active else 0.0
        rows.append({
            "kv_quant": mode, "batch": batch, "page_size": page_size,
            "n_requests": n_requests, "steps": eng.stats["steps"],
            "gen_tokens": eng.stats["gen_tokens"],
            "completed": eng.stats["completed"],
            "us_per_token": 1e6 * med / max(mean_active, 1e-9),
            "us_per_step": 1e6 * med,
            "write_bytes_per_step": wb,
            "read_bytes_per_step": rb,
            "resident_cache_mb": resident_peak / 2**20,
            "slot_bytes": slot,
            "resident_capacity_gain": base_slot / slot,
            "streams_match": streams == streams0,
        })
    return rows


def quant_error_report(cfg, params, *, max_len: int = 64, page_size: int = 8,
                       prompt_len: int = 12, decode_steps: int = 12,
                       seed: int = 0) -> dict:
    """Model-level logit-error report for quantized KV pools (CI artifact).

    Teacher-forces the bf16-pool greedy stream through each quant mode so
    per-step logits are directly comparable, then reports logit MSE,
    max-abs error, and whether the quant run's own greedy argmax matches
    the reference at every step.  Gated against QUANT_LOGIT_TOL.
    """
    import jax.numpy as jnp

    from repro.models import lm

    rng = np.random.default_rng(seed)
    batch = 2
    tokens = jnp.asarray(rng.integers(2, cfg.vocab_size,
                                      (batch, prompt_len)), jnp.int32)
    maxp = -(-max_len // page_size)
    bt = jnp.arange(batch * maxp, dtype=jnp.int32).reshape(batch, maxp)

    def run(mode: str, inputs=None):
        cache = lm.init_cache(cfg, batch, max_len, paged=True,
                              page_size=page_size, kv_quant=mode)
        cache = lm.set_block_tables(cache, bt)
        logits, cache = lm.prefill(params, cfg, tokens, cache)
        outs = [np.asarray(logits, np.float32)]
        fed = []
        for t in range(decode_steps):
            nxt = (jnp.asarray(np.argmax(outs[-1], -1), jnp.int32)
                   if inputs is None else inputs[t])
            fed.append(nxt)
            pos = jnp.full((batch,), prompt_len + t, jnp.int32)
            logits, cache = lm.decode_step(params, cfg, nxt, cache, pos)
            outs.append(np.asarray(logits, np.float32))
        return outs, fed

    ref_outs, ref_inputs = run("off")
    modes = {}
    for mode in _quant_modes():
        if mode == "off":
            continue
        outs, _ = run(mode, inputs=ref_inputs)
        diffs = [q - r for q, r in zip(outs, ref_outs)]
        max_abs = float(max(np.max(np.abs(d)) for d in diffs))
        greedy = all(np.array_equal(np.argmax(q, -1), np.argmax(r, -1))
                     for q, r in zip(outs, ref_outs))
        modes[mode] = {
            "logit_mse": float(np.mean([np.mean(d ** 2) for d in diffs])),
            "logit_max_abs": max_abs,
            "greedy_match": bool(greedy),
            "tolerance": QUANT_LOGIT_TOL[mode],
            "within_tol": bool(max_abs <= QUANT_LOGIT_TOL[mode]),
        }
    return {
        "batch": batch, "prompt_len": prompt_len,
        "decode_steps": decode_steps, "page_size": page_size,
        "modes": modes,
        # Greedy identity is an int8 guarantee: fp8 e4m3 (~3 mantissa bits)
        # may legitimately flip near-tie argmaxes and is held only to the
        # logit-error budget.
        "greedy_match_int8": modes["int8"]["greedy_match"],
        "all_within_tol": all(m["within_tol"] for m in modes.values()),
    }


def run_swap_sweep(cfg, params, *, max_len: int = 64, page_size: int = 8,
                   num_pages: int = 6, chunk_size: int = 8,
                   prompt_lens: tuple[int, ...] = (24, 6), max_new: int = 16,
                   swap_tier_pages: int = 8, kv_quant: str = "off",
                   seed: int = 0) -> list[dict]:
    """Tiered host-swap page memory vs recompute-from-scratch preemption.

    A deliberately undersized pool (``num_pages`` < both rows' peak) forces
    LRU preemption of the long-context row mid-decode.  The ``recompute``
    reference (swap tier disabled) re-admits the victim by re-prefilling
    its whole context in chunk-size slices; the ``swap`` run copies the
    victim's private pages to a host swap pool at eviction and streams
    them back on re-admission, so only the context *tail* re-prefills.
    Gate: same token streams, strictly fewer steps, and the swap run's
    swap/preempt counters prove the tier actually engaged.
    """
    from repro.serving.scheduler import ContinuousBatchingEngine, Request

    rng = np.random.default_rng(seed)
    prompts = [[int(t) for t in rng.integers(2, cfg.vocab_size, p)]
               for p in prompt_lens]
    rows: list[dict] = []
    streams0 = None
    for tier in (0, swap_tier_pages):
        reqs = [Request(rid=i, prompt=list(p), max_new_tokens=max_new)
                for i, p in enumerate(prompts)]
        eng = ContinuousBatchingEngine(
            cfg, params, batch=len(prompts), max_len=max_len, paged=True,
            page_size=page_size, num_pages=num_pages, chunk_size=chunk_size,
            kv_quant=kv_quant, swap_tier_pages=tier,
            swap_min_tokens=2 * page_size)
        for r in reqs:
            eng.submit(r)
        times: list[float] = []
        while True:
            t0 = time.perf_counter()
            more = eng.step()
            times.append(time.perf_counter() - t0)
            if not more:
                break
            if eng.stats["steps"] > 50_000:
                raise RuntimeError("swap bench runaway")
        streams = {r.rid: list(r.tokens) for r in reqs}
        if streams0 is None:
            streams0 = streams
        s = eng.stats
        rows.append({
            "tier": "swap" if tier else "recompute",
            "swap_tier_pages": tier, "num_pages": num_pages,
            "page_size": page_size, "kv_quant": kv_quant,
            "steps": s["steps"], "completed": s["completed"],
            "gen_tokens": s["gen_tokens"],
            "preempt_swap": s["preempt_swap"],
            "preempt_recompute": s["preempt_recompute"],
            "swap_outs": s["swap_outs"], "swap_ins": s["swap_ins"],
            "us_per_step": 1e6 * statistics.median(times),
            "streams_match": streams == streams0,
        })
    return rows


def run_bench(quick: bool = False, out: str | Path = "BENCH_serving.json",
              emit_csv=print, swap_tier_pages: int = 8) -> dict:
    from repro.agents.orchestrator import make_sim_llm

    cfg, params = make_sim_llm()
    max_len = 128 if quick else 256
    page_size = 16
    max_new = 8 if quick else 16
    batches = (4,) if quick else (4, 8)
    prompt_hi = max_len - max_new - 1
    rows = []
    for batch in batches:
        n_requests = 2 * batch + 2              # forces mid-flight admission
        for skew in ("uniform", "ragged"):
            for paged in (False, True):
                rows.append(run_config(
                    cfg, params, batch=batch, max_len=max_len,
                    page_size=page_size, skew=skew, paged=paged,
                    n_requests=n_requests, prompt_hi=prompt_hi,
                    max_new=max_new))

    # Chunked-vs-stalled admission sweep (TTFT, decode-stall steps, p50/p99
    # inter-token latency) — the token-budget mixed step's headline.
    chunk_rows = run_chunked_admission(
        cfg, params, batch=batches[0], max_len=max_len,
        page_size=page_size, n_requests=2 * batches[0] + 2,
        prompt_hi=prompt_hi, max_new=max_new,
        chunks=(page_size, 2 * page_size) if quick
        else (page_size // 2, page_size, 2 * page_size))

    # Prefix-share sweep: shared-prompt fan-out, with/without COW sharing.
    share_rows = []
    fanouts = (4,) if quick else (2, 4, 8)
    for fanout in fanouts:
        for share in (False, True):
            # Prompt deliberately NOT page-aligned: the partial boundary
            # page is shared too and every sharer copy-on-writes it at its
            # first generated token.
            share_rows.append(run_prefix_share(
                cfg, params, max_len=max_len, page_size=page_size,
                fanout=fanout, prompt_len=3 * page_size + 5,
                max_new=max_new, share=share))

    # Replicated sweep: N engines on one CRDT page table, staggered
    # shared-prefix fan-out (gossip cost + cross-replica prefix reuse).
    repl_rows = []
    for replicas in ((2,) if quick else (2, 4)):
        repl_rows.append(run_replicated(
            cfg, params, replicas=replicas, batch=2, max_len=max_len,
            page_size=page_size, prompt_len=3 * page_size + 5,
            max_new=max_new))

    # Disaggregation sweep: prefill/decode roles over the CRDT page table,
    # physical page adoption ON vs OFF on the identical workload (see
    # run_disagg) — the coordination-vs-data-plane comparison.
    disagg_rows = []
    disagg_streams = {}
    for adopt in (False, True):
        row, streams = run_disagg(
            cfg, params, replicas=2, batch=2, max_len=max_len,
            page_size=page_size, prompt_len=3 * page_size + 5,
            max_new=max_new, adopt=adopt)
        disagg_rows.append(row)
        disagg_streams[adopt] = streams

    # Fault sweep: crash failover + load shedding on the real server over
    # seeded faulty gossip (deterministic counters; see run_fault_sweep).
    fault_rows = run_fault_sweep(
        cfg, params,
        schedules=("lossy",) if quick else ("lossy", "reorder_delay"),
        crash_ats=(4,) if quick else (4, 8))

    # Speculative-decoding sweep: off / prompt-lookup / CRDT-doc drafting
    # through the mixed step, plus an end-to-end agent trial (off vs doc).
    spec_rows = run_spec_decode(
        cfg, params, batch=batches[0], max_len=max_len, page_size=page_size,
        n_requests=batches[0] + 2, prompt_hi=prompt_hi // 2,
        max_new=2 * max_new, spec_k=4)
    spec_agent_rows = run_spec_agents(cfg, params, spec_k=4)

    # Quantized page-pool sweep on a dedicated head_dim=64 single-head
    # config: the capacity gain is head_dim-bound (scales amortize over the
    # feature axis — bf16→int8 gain is 2D/(D+4)), and the sim-llm's 16-wide
    # heads would cap it at 1.6× regardless of how good the layout is.
    import jax

    from repro.models import lm

    qcfg = cfg.replace(num_heads=1, num_kv_heads=1, head_dim=64)
    qparams = lm.init(jax.random.PRNGKey(0), qcfg)
    quant_rows = run_quant_sweep(
        qcfg, qparams, batch=batches[0], max_len=max_len,
        page_size=page_size, n_requests=batches[0] + 2,
        prompt_hi=prompt_hi // 2, max_new=max_new)
    quant_err = quant_error_report(qcfg, qparams)

    # Tiered-memory sweep: host-swap preemption vs recompute on an
    # undersized pool (see run_swap_sweep).
    swap_rows = run_swap_sweep(cfg, params, swap_tier_pages=swap_tier_pages)

    ratios = []
    for d in rows:
        if d["mode"] != "dense":
            continue
        p = next(r for r in rows
                 if r["mode"] == "paged" and r["batch"] == d["batch"]
                 and r["skew"] == d["skew"])
        ratios.append(d["write_bytes_per_step"] / p["write_bytes_per_step"])
    stalled = next(r for r in chunk_rows if r["admission"] == "stalled")
    report = {
        "config": {"model": cfg.name, "d_model": cfg.d_model,
                   "num_layers": cfg.num_layers, "max_len": max_len,
                   "page_size": page_size, "quick": quick},
        "rows": rows,
        "chunked_admission": chunk_rows,
        "prefix_share": share_rows,
        "replicated": repl_rows,
        "fault": fault_rows,
        "fault_tolerance": {
            # Acceptance: every trial upholds the chaos invariants
            # (exactly-once, bitwise convergence, lane conservation), no
            # accepted request is ever lost, and every crash trial actually
            # exercised failover (recovered at least one orphan).
            "all_invariants_ok": all(r["ok"] for r in fault_rows),
            "no_lost_requests": all(r["lost"] == 0 for r in fault_rows),
            "crash_runs_recovered": all(
                r["recovered"] > 0 for r in fault_rows if r["crash_at"] >= 0),
        },
        "replication": {
            # Every replica pair landed bitwise-identical page tables after
            # the drain sync, and the fan-out workload produced at least one
            # cross-replica shared-prefix resolution per config.
            "all_converged": all(r["converged"] for r in repl_rows),
            "cross_replica_hits_positive": all(
                r["cross_replica_hits"] > 0 for r in repl_rows),
            "all_completed": all(r["completed"] == r["n_requests"]
                                 for r in repl_rows),
        },
        "disagg": disagg_rows,
        "disaggregation": {
            # Acceptance: adoption moved real pages and skipped real prefill
            # chunks, never made TTFT worse than the local-prefill baseline
            # on the identical workload, produced token streams identical
            # to it (transfers are bitwise), and the baseline run proves
            # the OFF switch truly never moved a byte.
            "adopted_pages_positive":
                disagg_rows[1]["adopted_pages"] > 0,
            "prefill_steps_avoided_positive":
                disagg_rows[1]["prefill_steps_avoided"] > 0,
            "ttft_adopt_not_worse": (disagg_rows[1]["ttft_steps_mean"]
                                     <= disagg_rows[0]["ttft_steps_mean"]),
            "streams_match": disagg_streams[True] == disagg_streams[False],
            "baseline_never_adopts": (
                disagg_rows[0]["adopted_pages"] == 0
                and disagg_rows[0]["transfer_bytes"] == 0),
            "all_completed": all(r["completed"] == r["n_requests"]
                                 for r in disagg_rows),
            "all_converged": all(r["converged"] for r in disagg_rows),
        },
        "spec_decode": {"engine": spec_rows, "agents": spec_agent_rows},
        "quant": quant_rows,
        "quant_error": quant_err,
        "swap": swap_rows,
        "quantization": {
            # Acceptance: int8 greedy streams are bit-identical to the
            # bf16-pool reference (fp8 is held only to the logit-error
            # budget — ~3 mantissa bits may flip near-tie argmaxes), one
            # cached token pins ≥1.8× fewer bytes (pool + scales, analytic
            # from the CacheSpec leaves), and each quant step reads fewer
            # bytes and pins fewer resident MB than bf16 paged.
            "streams_match_int8": all(
                r["streams_match"] for r in quant_rows
                if r["kv_quant"] != "fp8"),
            "resident_capacity_gain_ok": all(
                r["resident_capacity_gain"] >= 1.8 for r in quant_rows
                if r["kv_quant"] != "off"),
            "read_bytes_below_fp32": all(
                r["read_bytes_per_step"] < quant_rows[0][
                    "read_bytes_per_step"]
                for r in quant_rows if r["kv_quant"] != "off"),
            "resident_mb_below_fp32": all(
                r["resident_cache_mb"] < quant_rows[0]["resident_cache_mb"]
                for r in quant_rows if r["kv_quant"] != "off"),
            "greedy_match_int8": quant_err["greedy_match_int8"],
            "error_within_tol": quant_err["all_within_tol"],
        },
        "memory_tiers": {
            # Acceptance: the swap tier recovers the preempted long-context
            # victim in strictly fewer steps than recompute-from-scratch,
            # with identical token streams, and its counters prove pages
            # actually moved through the host tier (while the recompute
            # reference never swapped).
            "swap_beats_recompute": (
                swap_rows[1]["steps"] < swap_rows[0]["steps"]),
            "streams_match": all(r["streams_match"] for r in swap_rows),
            "swap_counters_positive": (
                swap_rows[1]["swap_outs"] > 0
                and swap_rows[1]["swap_ins"] > 0
                and swap_rows[1]["preempt_swap"] > 0),
            "recompute_reference_unswapped": (
                swap_rows[0]["swap_outs"] == 0
                and swap_rows[0]["preempt_swap"] == 0),
            "all_completed": all(r["completed"] == 2 for r in swap_rows),
        },
        "speculation": {
            # Acceptance: every speculative engine run reproduces the
            # greedy reference streams token-for-token, drafts something
            # (accept_rate > 0), and the e2e agent trial matches the
            # baseline document digest while finishing in fewer steps.
            "streams_match": all(r["streams_match"] for r in spec_rows),
            "accept_rate_positive": all(
                r["accept_rate"] > 0 for r in spec_rows
                if r["spec"] != "off"),
            "agents_digest_match": all(
                r["digest_match"] for r in spec_agent_rows),
            "agents_steps_reduced": all(
                r["steps"] < spec_agent_rows[0]["steps"]
                for r in spec_agent_rows if r["spec"] != "off"),
        },
        "write_bytes_ratio_dense_over_paged": min(ratios),
        "admission": {
            "mid_flight_admissions": sum(r["admitted_mid_flight"]
                                         for r in rows if r["mode"] == "paged"),
            "all_completed": all(r["completed"] == r["n_requests"]
                                 for r in rows),
            # Acceptance headline: every chunked config stalls strictly
            # fewer decode steps than the bucketed-admission baseline.
            "chunked_stalls_below_baseline": all(
                r["decode_stall_steps"] < stalled["decode_stall_steps"]
                for r in chunk_rows if r["admission"] == "chunked"),
        },
    }
    Path(out).write_text(json.dumps(report, indent=2))
    # Quant-error report doubles as a standalone CI artifact next to the
    # main report (uploaded by the bench-smoke job).
    Path(out).with_name("BENCH_quant_error.json").write_text(
        json.dumps(quant_err, indent=2))
    for r in rows:
        name = f"serving/{r['mode']}_b{r['batch']}_{r['skew']}"
        derived = (f"writeB/step={r['write_bytes_per_step']}"
                   f";readB/step={r['read_bytes_per_step']}"
                   f";residentMB={r['resident_cache_mb']:.2f}")
        emit_csv(f"{name},{r['us_per_token']:.1f},{derived}")
    emit_csv(f"serving/write_ratio,0.0,dense_over_paged="
             f"{report['write_bytes_ratio_dense_over_paged']:.1f}x")
    for r in chunk_rows:
        name = (f"serving/admit_{r['admission']}"
                + (f"_c{r['chunk_size']}" if r["admission"] == "chunked"
                   else ""))
        derived = (f"stallSteps={r['decode_stall_steps']}"
                   f";ttftSteps={r['ttft_steps_mean']:.1f}"
                   f";itlP50us={r['itl_p50_us']:.0f}"
                   f";itlP99us={r['itl_p99_us']:.0f}")
        emit_csv(f"{name},{r['us_per_step']:.1f},{derived}")
    for r in share_rows:
        name = (f"serving/prefix_f{r['fanout']}_"
                f"{'cow' if r['cow'] else 'nocow'}")
        derived = (f"residentMB={r['resident_cache_mb']:.2f}"
                   f";sharedPages={r['shared_pages']}"
                   f";cowCopies={r['cow_copies']}")
        emit_csv(f"{name},{r['admission_us']:.1f},{derived}")
    for r in repl_rows:
        derived = (f"syncB/step={r['sync_bytes_per_step']}"
                   f";xReplicaHits={r['cross_replica_hits']}"
                   f";publishedPages={r['published_prefix_pages']}"
                   f";converged={int(r['converged'])}")
        emit_csv(f"serving/repl_r{r['replicas']},{r['us_per_step']:.1f},"
                 f"{derived}")
    for r in disagg_rows:
        derived = (f"adoptedPages={r['adopted_pages']}"
                   f";prefillStepsAvoided={r['prefill_steps_avoided']}"
                   f";xferB/step={r['transfer_bytes_per_step']}"
                   f";ttftSteps={r['ttft_steps_mean']:.1f}"
                   f";aborts={r['adopt_aborts']}"
                   f";converged={int(r['converged'])}")
        emit_csv(f"serving/disagg_{r['adoption']},{r['us_per_step']:.1f},"
                 f"{derived}")
    for r in fault_rows:
        name = (f"serving/fault_{r['schedule']}"
                + ("_clean" if r["crash_at"] < 0 else f"_c{r['crash_at']}"))
        derived = (f"recovered={r['recovered']};retried={r['retried']}"
                   f";shed={r['shed']};lost={r['lost']}"
                   f";overheadSteps={r['recovery_step_overhead']}"
                   f";goodput={r['goodput_tokens_per_step']:.3f}"
                   f";ok={int(r['ok'])}")
        emit_csv(f"{name},0.0,{derived}")
    for r in spec_rows:
        derived = (f"acceptRate={r['accept_rate']:.2f}"
                   f";tokPerStep={r['tokens_per_step']:.2f}"
                   f";usPerAccTok={r['us_per_accepted_token']:.1f}"
                   f";draft={r['draft_tokens']};roll={r['rollback_tokens']}"
                   f";steps={r['steps']};match={int(r['streams_match'])}")
        emit_csv(f"serving/spec_{r['spec']},{r['us_per_step']:.1f},{derived}")
    for r in spec_agent_rows:
        derived = (f"steps={r['steps']};acceptRate={r['accept_rate']:.2f}"
                   f";roll={r['rollback_tokens']}"
                   f";digestMatch={int(r['digest_match'])}")
        emit_csv(f"serving/spec_agents_{r['spec']},"
                 f"{1e6 * r['wall_s']:.0f},{derived}")
    for r in quant_rows:
        derived = (f"readB/step={r['read_bytes_per_step']}"
                   f";residentMB={r['resident_cache_mb']:.3f}"
                   f";slotB={r['slot_bytes']}"
                   f";capGain={r['resident_capacity_gain']:.2f}"
                   f";match={int(r['streams_match'])}")
        emit_csv(f"serving/quant_{r['kv_quant']},"
                 f"{r['us_per_token']:.1f},{derived}")
    for mode, e in quant_err["modes"].items():
        emit_csv(f"serving/quant_err_{mode},0.0,"
                 f"mse={e['logit_mse']:.2e}"
                 f";maxAbs={e['logit_max_abs']:.4f}"
                 f";greedy={int(e['greedy_match'])}"
                 f";withinTol={int(e['within_tol'])}")
    for r in swap_rows:
        derived = (f"steps={r['steps']};swapOuts={r['swap_outs']}"
                   f";swapIns={r['swap_ins']}"
                   f";preemptSwap={r['preempt_swap']}"
                   f";preemptRecompute={r['preempt_recompute']}"
                   f";match={int(r['streams_match'])}")
        emit_csv(f"serving/swap_{r['tier']},{r['us_per_step']:.1f},{derived}")
    return report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="BENCH_serving.json")
    ap.add_argument("--swap-tier-pages", type=int, default=8,
                    help="host swap-pool slots for the memory-tier sweep "
                         "(0 disables the swap row's tier)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run_bench(quick=args.quick, out=args.out,
              swap_tier_pages=args.swap_tier_pages)


if __name__ == "__main__":
    main()
