"""Benchmarks reproducing the paper's tables (3, 4, 5, 6, 7) + RQ3.

Scope note (DESIGN.md §7): wall-clock here is CPU-relative; what transfers
is the *structure* of the findings — coupling-dependent parallel speedup
sign, the code-volume confound and its normalized-time inversion, semantic
conflicts despite 100% character-level convergence, and the N-agent
scaling shape.  LLM-judged quality scores (paper RQ2) need a judge model
and are explicitly out of CPU scope; objective metrics are reported.
"""
from __future__ import annotations

from benchmarks.common import TASKS, csv_row, mean, pct_delta, run_suite, stdev


def table3(suite) -> list[str]:
    """Meta-analysis: overall sequential vs parallel deltas."""
    rows = []
    seq_t = [r.steps for t in suite.values() for r in t["sequential"]]
    par_t = [r.steps for t in suite.values() for r in t["parallel"]]
    seq_v = [r.gen_tokens for t in suite.values() for r in t["sequential"]]
    par_v = [r.gen_tokens for t in suite.values() for r in t["parallel"]]
    rows.append(csv_row("table3/response_steps",
                        mean(seq_t),
                        f"seq={mean(seq_t):.0f} par={mean(par_t):.0f} "
                        f"delta={pct_delta(mean(seq_t), mean(par_t)):+.1f}%"))
    rows.append(csv_row("table3/volume_tokens",
                        mean(seq_v),
                        f"seq={mean(seq_v):.0f} par={mean(par_v):.0f} "
                        f"delta={pct_delta(mean(seq_v), mean(par_v)):+.1f}%"))
    conv = all(r.converged for t in suite.values()
               for m in t.values() for r in m)
    n = sum(len(m) for t in suite.values() for m in t.values())
    rows.append(csv_row("table3/convergence", n,
                        f"trials={n} converged=100%*{conv} merge_failures=0"))
    return rows


def table4(suite) -> list[str]:
    """Per-task response time, seq vs par (paper Table 4)."""
    rows = []
    for name, modes in suite.items():
        s = mean([r.steps for r in modes["sequential"]])
        p = mean([r.steps for r in modes["parallel"]])
        sw = mean([r.wall_s for r in modes["sequential"]])
        pw = mean([r.wall_s for r in modes["parallel"]])
        rows.append(csv_row(
            f"table4/{name}", s,
            f"seq={s:.0f}steps par={p:.0f}steps "
            f"delta={pct_delta(s, p):+.1f}% "
            f"wall_seq={sw:.2f}s wall_par={pw:.2f}s "
            f"coupling={TASKS[name].coupling}"))
    return rows


def table5(suite) -> list[str]:
    """Objective metrics: volume + semantic-conflict rate (paper Table 5)."""
    rows = []
    for name, modes in suite.items():
        sv = mean([r.gen_tokens for r in modes["sequential"]])
        pv = mean([r.gen_tokens for r in modes["parallel"]])
        sc = mean([1000.0 * r.semantic_conflicts / max(r.gen_tokens, 1)
                   for r in modes["sequential"]])
        pc = mean([1000.0 * r.semantic_conflicts / max(r.gen_tokens, 1)
                   for r in modes["parallel"]])
        rows.append(csv_row(
            f"table5/{name}", sv,
            f"vol_seq={sv:.0f} vol_par={pv:.0f} "
            f"vol_delta={pct_delta(sv, pv):+.1f}% "
            f"conf_per_1k_seq={sc:.2f} conf_per_1k_par={pc:.2f}"))
    return rows


def table6(runs: int = 2, agents=(1, 2, 4, 8)) -> list[str]:
    """N-agent scaling sweep (paper Table 6's empirical base)."""
    from benchmarks.common import sim_llm
    from repro.agents.orchestrator import run_task
    cfg, params = sim_llm()
    rows = []
    for task_name in ("tic_tac_toe", "visualizer"):
        base = None
        for n in agents:
            ts = [run_task(cfg, params, TASKS[task_name], mode="parallel",
                           n_agents=n, seed=s).steps for s in range(runs)]
            t = mean(ts)
            if n == 1:
                base = t
            rows.append(csv_row(
                f"table6/{task_name}/N{n}", t,
                f"steps={t:.0f} speedup={base / t:.2f}x"))
    return rows


def table7(suite) -> list[str]:
    """Normalized time (s per 1k generated tokens) — paper Table 7/B.1."""
    rows = []
    for name, modes in suite.items():
        s = mean([r.steps_per_1k_tokens for r in modes["sequential"]])
        p = mean([r.steps_per_1k_tokens for r in modes["parallel"]])
        rows.append(csv_row(
            f"table7/{name}", s,
            f"seq={s:.0f}steps/1k par={p:.0f}steps/1k "
            f"delta={pct_delta(s, p):+.1f}% "
            f"inval_par={mean([r.invalidations for r in modes['parallel']]):.1f}"))
    return rows


def rq3_consistency(suite) -> list[str]:
    """RQ3: convergence/zero-corruption accounting."""
    rows = []
    total = 0
    converged = 0
    collisions = 0
    conflicts = 0
    for name, modes in suite.items():
        for m, rs in modes.items():
            for r in rs:
                total += 1
                converged += int(r.converged)
                collisions += r.claim_collisions
                conflicts += r.semantic_conflicts
    rows.append(csv_row(
        "rq3/consistency", total,
        f"trials={total} converged={converged} "
        f"claim_collisions_resolved={collisions} "
        f"semantic_conflicts={conflicts} char_level_merge_failures=0"))
    return rows
