"""Merge-strategy sweep: bytes-on-wire and wall-clock per replica sync.

Sweeps replicas × slot-capacity × edit-rate over a SlotDoc bank and measures
what each sync strategy actually ships:

  allgather — every replica ships full state to every peer (paper-faithful
              observation):                wire = R·(R-1)·state_bytes
  pmax      — ring all-reduce join (reduce-scatter + all-gather phases):
                                           wire = 2·(R-1)·state_bytes
  delta     — delta-state sync (core/delta.py): fixed-capacity delta buffers
              circulate the ring:          wire = (R-1)·Σ delta_bytes (exact)

Each cell builds R replicas that each appended ``rate × S`` tokens to their
own slots since the last sync (slots partitioned round-robin), then times one
sync (jitted, warm) and reports

    merge/<strategy>/R<r>_S<s>_rate<rate>,<us_per_sync>,bytes=<wire_bytes>

rows per the harness CSV contract.  The O(S) → O(Δ) claim is the acceptance
criterion: at edit rates below ~10% of slot capacity the delta rows must ship
fewer bytes than pmax (asserted in tests/test_delta_properties.py via
``sweep_cell``).  A final section times the Pallas scatter-apply kernel
(kernels/delta_apply.py) against its jnp oracle.

  PYTHONPATH=src python -m benchmarks.bench_merge [--quick]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row
from repro.core import delta as delta_mod
from repro.core import doc as doc_mod
from repro.core import merge as merge_mod

K_SLOTS = 16


def _edited_replicas(n_rep: int, n_slots: int, slot_cap: int, rate: float,
                     seed: int = 0) -> tuple[doc_mod.SlotDoc, list]:
    """Base doc plus R replicas that each appended rate·S tokens per owned
    slot since the base state (the per-sync-interval edit pattern)."""
    rng = np.random.default_rng(seed)
    base = doc_mod.empty(n_slots, slot_cap)
    # Pre-existing content: half-full slots (so deltas sit mid-buffer).
    for s in range(n_slots):
        n = slot_cap // 2
        buf = rng.integers(1, 100, size=slot_cap).astype(np.int32)
        base = doc_mod.append(base, s, jnp.asarray(buf), n)
    edits = max(1, int(round(rate * slot_cap)))
    replicas = []
    for r in range(n_rep):
        rep = base
        for s in range(r, n_slots, n_rep):       # round-robin slot ownership
            buf = np.zeros((edits,), np.int32)
            buf[:] = rng.integers(1, 100, size=edits)
            rep = doc_mod.append(rep, s, jnp.asarray(buf), edits)
        replicas.append(rep)
    return base, replicas


def _time(fn, runs: int) -> float:
    fn()                                          # warm/compile
    t0 = time.perf_counter()
    for _ in range(runs):
        jax.block_until_ready(jax.tree.leaves(fn())[0])
    return (time.perf_counter() - t0) / runs * 1e6


def sweep_cell(n_rep: int, slot_cap: int, rate: float, *, runs: int = 5,
               seed: int = 0) -> dict:
    """One (replicas, slot-capacity, edit-rate) cell: µs + wire bytes per
    strategy, plus a bit-equality check of delta-sync vs the fold join."""
    base, replicas = _edited_replicas(n_rep, K_SLOTS, slot_cap, rate, seed)
    state_bytes = delta_mod.nbytes(base)
    edits = max(1, int(round(rate * slot_cap)))
    capacity = max(8, -(-edits // 8) * 8)         # edits rounded up to 8

    fold = jax.jit(merge_mod.fold_join)
    want = fold(replicas)

    # pmax strategy timed as the real pmax join over a replica axis (vmap is
    # the single-process stand-in for the mesh axis; collectives lower to
    # local reductions with identical semantics).
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *replicas)
    pmax_fn = jax.jit(jax.vmap(
        lambda s: merge_mod.pmax_merge(s, "r"), axis_name="r"))

    # One DeltaSync reused across timed iterations (extract/apply jits are
    # module-level and warm); the frontier resets each call so every
    # iteration re-ships the same deltas.
    ds = delta_mod.DeltaSync(base, capacity=capacity)
    fr0 = ds.frontier

    def delta_round():
        ds.frontier = fr0
        return ds.sync(replicas)

    outs = delta_round()
    delta_bytes_per_sync = ds.bytes_shipped // ds.syncs
    exact = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for out in outs
        for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(want)))

    return {
        "replicas": n_rep, "slot_cap": slot_cap, "rate": rate,
        "capacity": capacity, "state_bytes": state_bytes,
        "bytes": {
            "allgather": delta_mod.full_state_wire_bytes(
                "allgather", n_rep, state_bytes),
            "pmax": delta_mod.full_state_wire_bytes(
                "pmax", n_rep, state_bytes),
            "delta": delta_bytes_per_sync,
        },
        "us": {
            "allgather": _time(lambda: fold(replicas), runs),
            "pmax": _time(lambda: pmax_fn(stacked), runs),
            "delta": _time(lambda: delta_round()[0], runs),
        },
        "delta_exact": exact,
    }


def sweep(replicas=(2, 4, 8), slot_caps=(256, 1024),
          rates=(0.01, 0.05, 0.10, 0.50), runs: int = 5):
    for r in replicas:
        for s in slot_caps:
            for rate in rates:
                cell = sweep_cell(r, s, rate, runs=runs)
                for strat in ("allgather", "pmax", "delta"):
                    name = f"merge/{strat}/R{r}_S{s}_rate{rate:g}"
                    derived = (f"bytes={cell['bytes'][strat]}"
                               f";exact={int(cell['delta_exact'])}")
                    yield csv_row(name, cell["us"][strat], derived)


def kernel_rows(runs: int = 20):
    """Pallas delta_apply vs jnp oracle on a flat register bank."""
    from repro.kernels import ops
    rng = np.random.default_rng(0)
    k, d, dc = 4096, 128, 256
    key = jnp.asarray(rng.integers(0, 10_000, k), jnp.int32)
    pay = jnp.asarray(rng.integers(-99, 99, (k, d)), jnp.int32)
    idx = jnp.asarray(rng.permutation(k)[:dc], jnp.int32)
    dkey = jnp.asarray(rng.integers(0, 20_000, dc), jnp.int32)
    dpay = jnp.asarray(rng.integers(-99, 99, (dc, d)), jnp.int32)
    for use_pallas, tag in ((True, "pallas"), (False, "ref")):
        fn = jax.jit(lambda: ops.delta_apply(key, pay, idx, dkey, dpay,
                                             use_pallas=use_pallas))
        us = _time(fn, runs)
        yield csv_row(f"kernel/delta_apply/{tag}/K{k}_D{d}_Dc{dc}", us,
                      f"bytes={delta_mod.nbytes((idx, dkey, dpay))}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller sweep, fewer timing runs")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    if args.quick:
        rows = sweep(replicas=(2, 4), slot_caps=(256,),
                     rates=(0.05, 0.5), runs=2)
    else:
        rows = sweep()
    for row in rows:
        print(row, flush=True)
    for row in kernel_rows(runs=5 if args.quick else 20):
        print(row, flush=True)


if __name__ == "__main__":
    main()
