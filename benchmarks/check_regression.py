"""CI gate: fail when the serving benchmark's paged path regresses more
than ``--max-regression`` (default 15%) against the checked-in baseline.

Only paged rows are gated, keyed by (batch, skew), on two signal classes:

* **Deterministic counters** — analytic write/read bytes per step, resident
  cache MB, peak pages.  These are pure functions of the code (bit-identical
  across reruns of the same commit), so they get the strict
  ``--max-regression`` threshold: any increase past it is a real paged-path
  regression (more bytes touched per step, more resident memory), never
  runner noise.
The replicated sweep (N engines on one CRDT page table) is gated the same
way: anti-entropy sync bytes and step counts are deterministic counters,
plus boolean acceptance flags (bitwise replica convergence, cross-replica
shared-prefix hits > 0, all requests completed).  The speculative-decoding
sweep gates waste counters (steps, draft/rollback tokens) against a strict
ceiling, acceptance counters (accept_rate, accepted_tokens, tokens/step)
against a strict floor, µs/accepted-token normalized by the same run's
non-speculative row, and the stream-identity / digest-match flags.  The
quantized page-pool sweep ceiling-gates analytic traffic, floor-gates the
resident-capacity gain (>=1.8x is an acceptance flag), and checks the int8
greedy-identity + logit-error-budget flags; the tiered-memory sweep gates
the swap counters both ways (an increase is thrashing, a decrease means
the tier quietly disengaged) plus the swap-beats-recompute flags.  The
disaggregation sweep ceiling-gates transfer traffic / aborts / TTFT,
floor-gates adopted pages and avoided prefill steps, and checks the
adoption acceptance flags (TTFT-with <= TTFT-without, bitwise stream
identity against local prefill, coordination-only baseline moved zero
bytes).  A
gated counter missing from either report is a loud failure, and the run
ends with a one-line-per-counter pass/fail table.

* **Wall clock** — µs/token normalized by the *same run's* dense row at the
  same key (which cancels the runner-speed term; absolute interpret-mode
  timings are machine-dependent).  Tiny CPU benches still jitter ±20% on
  the ratio, so timing gets the looser ``--timing-slack`` (default 50%) —
  wide enough to ignore dispatch jitter, tight enough to catch an
  accidentally-quadratic paged step.  A report missing its dense row falls
  back to absolute µs/token for that key.

Both files are BENCH_serving.json outputs of ``benchmarks.bench_serving``
at matching --quick settings, CPU interpret mode.

  PYTHONPATH=src python -m benchmarks.check_regression \
      --baseline benchmarks/baselines/BENCH_serving_quick.json \
      --current BENCH_serving.json --max-regression 0.15
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

COUNTERS = ("write_bytes_per_step", "read_bytes_per_step",
            "resident_cache_mb", "peak_pages")

# Chunked-admission sweep counters: greedy decoding at fixed seeds makes
# step counts, stall counts and TTFT-in-steps bit-identical across reruns
# of the same commit, so they get the strict threshold too.
CHUNK_COUNTERS = ("steps", "decode_stall_steps", "stalled_lane_steps",
                  "ttft_steps_mean", "peak_pages")

# Replicated sweep counters: the gossip schedule is reliable and in-order
# and decoding is greedy, so anti-entropy wire bytes and step counts are
# bit-identical across reruns of the same commit (the suite asserts this).
# An increase past the strict threshold means the sync protocol started
# shipping more metadata per step — a real coordination-cost regression.
REPL_COUNTERS = ("sync_bytes_per_step", "sync_bytes", "steps")

# Fault sweep counters: the chaos harness decodes greedily over a seeded
# channel, so recovery cost and shedding volume are bit-identical across
# reruns of the same commit.  An increase past the strict threshold means
# failover got slower (more overhead steps to re-complete orphans) or the
# runtime started dropping more work (shed/failed/lost) — both real
# robustness regressions.
FAULT_COUNTERS = ("steps", "recovery_step_overhead", "recovered", "retried",
                  "shed", "lost", "failed")

# Speculative-decoding sweep counters: drafting is a pure function of the
# (seeded) token streams and verification is greedy, so every counter is
# bit-identical across reruns of the same commit.  Counters where an
# INCREASE is a regression (more steps, more wasted drafts) get the strict
# ceiling gate; counters where a DECREASE is a regression (acceptance
# collapsed, throughput-per-step dropped) get the strict floor gate.
SPEC_COUNTERS = ("steps", "draft_tokens", "rollback_tokens")
SPEC_FLOOR_COUNTERS = ("accept_rate", "accepted_tokens", "tokens_per_step")
SPEC_AGENT_COUNTERS = ("steps", "rollback_tokens")
SPEC_AGENT_FLOOR_COUNTERS = ("accept_rate", "accepted_tokens")

# Quantized page-pool sweep counters: analytic bytes / slot sizes are pure
# functions of the CacheSpec leaves and decoding is greedy, so every
# counter is bit-identical across reruns.  Ceiling-gate the traffic and
# step counters; floor-gate ``resident_capacity_gain`` (a drop means the
# quant layout got fatter — wider scales or payload) and the completion
# counters.
QUANT_COUNTERS = ("write_bytes_per_step", "read_bytes_per_step",
                  "slot_bytes", "steps")
QUANT_FLOOR_COUNTERS = ("resident_capacity_gain", "gen_tokens", "completed")

# Tiered-memory sweep counters: the preemption schedule is deterministic
# (greedy decode, fixed seeds), so swap traffic is bit-identical across
# reruns.  ``steps`` / ``preempt_recompute`` are waste (ceiling);
# ``completed`` / ``gen_tokens`` are floors; the swap-tier counters are
# gated BOTH ways — an increase is thrashing, a decrease means the tier
# quietly disengaged.
SWAP_COUNTERS = ("steps", "preempt_recompute")
SWAP_FLOOR_COUNTERS = ("completed", "gen_tokens")
SWAP_BIDIR_COUNTERS = ("swap_outs", "swap_ins", "preempt_swap")

# Disaggregation sweep counters: role-aware routing, greedy decode and a
# seeded arrival schedule make every adoption counter bit-identical across
# reruns of the same commit.  Ceiling-gate the transfer traffic and waste
# (more bytes shipped per step means the page-transfer path got fatter;
# more aborts means the epoch check started losing races), the step count
# and TTFT-in-steps; floor-gate the adoption wins (fewer adopted pages or
# avoided prefill steps means the cross-replica path quietly disengaged)
# and the completion counters.
DISAGG_COUNTERS = ("steps", "transfer_bytes", "transfer_bytes_per_step",
                   "adopt_aborts", "ttft_steps_mean")
DISAGG_FLOOR_COUNTERS = ("adopted_pages", "prefill_steps_avoided",
                         "completed", "gen_tokens")


def rows_by_key(report: dict, mode: str) -> dict[tuple, dict]:
    return {(r["batch"], r["skew"]): r
            for r in report["rows"] if r["mode"] == mode}


def chunk_rows_by_key(report: dict) -> dict[tuple, dict]:
    return {(r["admission"], r.get("chunk_size", 0)): r
            for r in report.get("chunked_admission", [])}


def repl_rows_by_key(report: dict) -> dict[tuple, dict]:
    return {(r["replicas"],): r for r in report.get("replicated", [])}


def fault_rows_by_key(report: dict) -> dict[tuple, dict]:
    return {(r["schedule"], r["crash_at"]): r
            for r in report.get("fault", [])}


def spec_rows_by_key(report: dict) -> dict[tuple, dict]:
    return {(r["spec"],): r
            for r in report.get("spec_decode", {}).get("engine", [])}


def spec_agent_rows_by_key(report: dict) -> dict[tuple, dict]:
    return {(r["spec"],): r
            for r in report.get("spec_decode", {}).get("agents", [])}


def quant_rows_by_key(report: dict) -> dict[tuple, dict]:
    return {(r["kv_quant"],): r for r in report.get("quant", [])}


def swap_rows_by_key(report: dict) -> dict[tuple, dict]:
    return {(r["tier"],): r for r in report.get("swap", [])}


def disagg_rows_by_key(report: dict) -> dict[tuple, dict]:
    return {(r["adoption"],): r for r in report.get("disagg", [])}


def timing_value(report: dict, key: tuple) -> tuple[float, str]:
    """Dense-normalized paged µs/token (absolute when dense row missing)."""
    paged = rows_by_key(report, "paged")[key]
    dense = rows_by_key(report, "dense").get(key)
    if dense is not None and dense["us_per_token"] > 0:
        return paged["us_per_token"] / dense["us_per_token"], "paged/dense"
    return paged["us_per_token"], "us/tok"


def check(baseline: dict, current: dict, max_regression: float,
          timing_slack: float) -> tuple[bool, list[str]]:
    base = rows_by_key(baseline, "paged")
    cur = rows_by_key(current, "paged")
    ok = True
    lines = []
    # Per-counter tally for the summary table: name -> [ok, fail, missing].
    tally: dict[str, list[int]] = {}

    def _tally(name, kind):
        tally.setdefault(name, [0, 0, 0])[kind] += 1

    def judge(label, name, bval, cval, limit, floor=False):
        nonlocal ok
        ratio = cval / max(bval, 1e-9) - 1.0
        if floor:     # a DECREASE past the limit is the regression
            bad = -ratio > limit and bval - cval > 1e-9
        else:
            bad = ratio > limit and cval - bval > 1e-9
        if bad:
            ok = False
        _tally(name, 1 if bad else 0)
        lines.append(
            f"{label:>16} {name:>18}: baseline "
            f"{bval:12.3f}, current {cval:12.3f} ({ratio:+.1%}) "
            f"{'FAIL' if bad else 'ok'}")

    def counter(label, name, brow, crow, limit, floor=False):
        """Judge one gated counter, failing LOUDLY when either report is
        missing it (a silently absent counter would otherwise let a broken
        bench ship)."""
        nonlocal ok
        missing = [w for w, row in (("baseline", brow), ("current", crow))
                   if name not in row]
        if missing:
            ok = False
            _tally(name, 2)
            lines.append(f"{label:>16} {name:>18}: MISSING in "
                         f"{' and '.join(missing)} report FAIL")
            return
        judge(label, name, float(brow[name]), float(crow[name]), limit,
              floor=floor)

    for key in sorted(base):
        if key not in cur:
            ok = False
            lines.append(f"MISSING paged row {key} in current run")
            continue
        label = f"paged b{key[0]} {key[1]}"
        for name in COUNTERS:
            counter(label, name, base[key], cur[key], max_regression)
        bval, bkind = timing_value(baseline, key)
        cval, ckind = timing_value(current, key)
        if bkind != ckind:          # one report lacks its dense row
            bval = base[key]["us_per_token"]
            cval = cur[key]["us_per_token"]
            bkind = "us/tok"
        judge(label, bkind, bval, cval, timing_slack)

    cbase = chunk_rows_by_key(baseline)
    ccur = chunk_rows_by_key(current)
    for key in sorted(cbase):
        if key not in ccur:
            ok = False
            lines.append(f"MISSING chunked-admission row {key} in current "
                         "run")
            continue
        for name in CHUNK_COUNTERS:
            counter(f"{key[0]} c{key[1]}", name, cbase[key], ccur[key],
                    max_regression)
    if cbase and "chunked_admission" in current:
        stalls_ok = current.get("admission", {}).get(
            "chunked_stalls_below_baseline", False)
        lines.append(f"chunked stalls < stalled baseline: "
                     f"{'ok' if stalls_ok else 'FAIL'}")
        ok = ok and stalls_ok

    rbase = repl_rows_by_key(baseline)
    rcur = repl_rows_by_key(current)
    for key in sorted(rbase):
        if key not in rcur:
            ok = False
            lines.append(f"MISSING replicated row {key} in current run")
            continue
        for name in REPL_COUNTERS:
            counter(f"repl r{key[0]}", name, rbase[key], rcur[key],
                    max_regression)
    if rbase and "replicated" in current:
        for flag, desc in (("all_converged",
                            "replicas bitwise converged"),
                           ("cross_replica_hits_positive",
                            "cross-replica shared-prefix hits > 0"),
                           ("all_completed",
                            "replicated sweep completed all requests")):
            flag_ok = current.get("replication", {}).get(flag, False)
            lines.append(f"{desc}: {'ok' if flag_ok else 'FAIL'}")
            ok = ok and flag_ok

    fbase = fault_rows_by_key(baseline)
    fcur = fault_rows_by_key(current)
    for key in sorted(fbase):
        if key not in fcur:
            ok = False
            lines.append(f"MISSING fault row {key} in current run")
            continue
        label = (f"fault {key[0]}"
                 + (" clean" if key[1] < 0 else f" c{key[1]}"))
        for name in FAULT_COUNTERS:
            counter(label, name, fbase[key], fcur[key], max_regression)
    if fbase and "fault" in current:
        for flag, desc in (("all_invariants_ok",
                            "chaos invariants (exactly-once, convergence, "
                            "lane conservation) hold"),
                           ("no_lost_requests",
                            "no accepted request lost across failover"),
                           ("crash_runs_recovered",
                            "every crash trial recovered orphans")):
            flag_ok = current.get("fault_tolerance", {}).get(flag, False)
            lines.append(f"{desc}: {'ok' if flag_ok else 'FAIL'}")
            ok = ok and flag_ok

    # Speculative-decoding sweep: ceiling-gate waste counters, floor-gate
    # acceptance, and gate µs/accepted-token normalized by the SAME run's
    # non-speculative row (cancels the runner-speed term, like paged/dense).
    sbase = spec_rows_by_key(baseline)
    scur = spec_rows_by_key(current)
    for key in sorted(sbase):
        if key not in scur:
            ok = False
            lines.append(f"MISSING spec-decode row {key} in current run")
            continue
        label = f"spec {key[0]}"
        for name in SPEC_COUNTERS:
            counter(label, name, sbase[key], scur[key], max_regression)
        for name in SPEC_FLOOR_COUNTERS:
            counter(label, name, sbase[key], scur[key], max_regression,
                    floor=True)
        boff, coff = sbase.get(("off",)), scur.get(("off",))
        if key != ("off",) and boff and coff:
            bval = (sbase[key]["us_per_accepted_token"]
                    / max(boff["us_per_accepted_token"], 1e-9))
            cval = (scur[key]["us_per_accepted_token"]
                    / max(coff["us_per_accepted_token"], 1e-9))
            judge(label, "usAccTok/off", bval, cval, timing_slack)
    abase = spec_agent_rows_by_key(baseline)
    acur = spec_agent_rows_by_key(current)
    for key in sorted(abase):
        if key not in acur:
            ok = False
            lines.append(f"MISSING spec-agent row {key} in current run")
            continue
        label = f"spec-agents {key[0]}"
        for name in SPEC_AGENT_COUNTERS:
            counter(label, name, abase[key], acur[key], max_regression)
        if key != ("off",):
            for name in SPEC_AGENT_FLOOR_COUNTERS:
                counter(label, name, abase[key], acur[key], max_regression,
                        floor=True)
    if sbase and "spec_decode" in current:
        for flag, desc in (("streams_match",
                            "speculative streams token-identical to greedy"),
                           ("accept_rate_positive",
                            "every drafter accepted > 0 tokens"),
                           ("agents_digest_match",
                            "agent-trial document digest matches baseline"),
                           ("agents_steps_reduced",
                            "speculative agent trial used fewer steps")):
            flag_ok = current.get("speculation", {}).get(flag, False)
            lines.append(f"{desc}: {'ok' if flag_ok else 'FAIL'}")
            ok = ok and flag_ok

    # Quantized page-pool sweep: ceiling-gate traffic, floor-gate the
    # resident-capacity gain, and gate µs/token normalized by the SAME
    # run's kv_quant=off row (cancels the runner-speed term).
    qbase = quant_rows_by_key(baseline)
    qcur = quant_rows_by_key(current)
    for key in sorted(qbase):
        if key not in qcur:
            ok = False
            lines.append(f"MISSING quant row {key} in current run")
            continue
        label = f"quant {key[0]}"
        for name in QUANT_COUNTERS:
            counter(label, name, qbase[key], qcur[key], max_regression)
        for name in QUANT_FLOOR_COUNTERS:
            counter(label, name, qbase[key], qcur[key], max_regression,
                    floor=True)
        boff, coff = qbase.get(("off",)), qcur.get(("off",))
        if key != ("off",) and boff and coff:
            bval = (qbase[key]["us_per_token"]
                    / max(boff["us_per_token"], 1e-9))
            cval = (qcur[key]["us_per_token"]
                    / max(coff["us_per_token"], 1e-9))
            judge(label, "usTok/off", bval, cval, timing_slack)
    if qbase and "quant" in current:
        for flag, desc in (("streams_match_int8",
                            "int8 greedy streams identical to bf16 pools"),
                           ("resident_capacity_gain_ok",
                            "quant slot pins >= 1.8x fewer bytes"),
                           ("read_bytes_below_fp32",
                            "quant step reads fewer bytes than bf16 paged"),
                           ("resident_mb_below_fp32",
                            "quant run pins fewer resident MB"),
                           ("greedy_match_int8",
                            "int8 teacher-forced argmax matches reference"),
                           ("error_within_tol",
                            "quant logit error inside documented budget")):
            flag_ok = current.get("quantization", {}).get(flag, False)
            lines.append(f"{desc}: {'ok' if flag_ok else 'FAIL'}")
            ok = ok and flag_ok

    # Tiered-memory sweep: swap-tier counters are gated both ways (see the
    # SWAP_* comment) plus the swap-beats-recompute acceptance flags.
    wbase = swap_rows_by_key(baseline)
    wcur = swap_rows_by_key(current)
    for key in sorted(wbase):
        if key not in wcur:
            ok = False
            lines.append(f"MISSING swap row {key} in current run")
            continue
        label = f"swap {key[0]}"
        for name in SWAP_COUNTERS:
            counter(label, name, wbase[key], wcur[key], max_regression)
        for name in SWAP_FLOOR_COUNTERS:
            counter(label, name, wbase[key], wcur[key], max_regression,
                    floor=True)
        for name in SWAP_BIDIR_COUNTERS:
            counter(label, name, wbase[key], wcur[key], max_regression)
            counter(label, name, wbase[key], wcur[key], max_regression,
                    floor=True)
    if wbase and "swap" in current:
        for flag, desc in (("swap_beats_recompute",
                            "swap re-admission uses fewer steps than "
                            "recompute"),
                           ("streams_match",
                            "swap/recompute token streams identical"),
                           ("swap_counters_positive",
                            "swap tier actually engaged (outs/ins/preempts "
                            "> 0)"),
                           ("recompute_reference_unswapped",
                            "recompute reference never swapped"),
                           ("all_completed",
                            "memory-tier sweep completed all requests")):
            flag_ok = current.get("memory_tiers", {}).get(flag, False)
            lines.append(f"{desc}: {'ok' if flag_ok else 'FAIL'}")
            ok = ok and flag_ok

    # Disaggregation sweep: ceiling-gate the transfer traffic and TTFT,
    # floor-gate the adoption wins, and check the acceptance flags — the
    # adoption-on row must beat (or tie) the coordination-only row on TTFT
    # while producing bitwise-identical token streams.
    dbase = disagg_rows_by_key(baseline)
    dcur = disagg_rows_by_key(current)
    for key in sorted(dbase):
        if key not in dcur:
            ok = False
            lines.append(f"MISSING disagg row {key} in current run")
            continue
        label = f"disagg {key[0]}"
        for name in DISAGG_COUNTERS:
            counter(label, name, dbase[key], dcur[key], max_regression)
        for name in DISAGG_FLOOR_COUNTERS:
            counter(label, name, dbase[key], dcur[key], max_regression,
                    floor=True)
    if dbase and "disagg" in current:
        for flag, desc in (("adopted_pages_positive",
                            "decode tier adopted > 0 published pages"),
                           ("prefill_steps_avoided_positive",
                            "adoption avoided > 0 prefill steps"),
                           ("ttft_adopt_not_worse",
                            "TTFT with adoption <= TTFT without"),
                           ("streams_match",
                            "adoption streams token-identical to local "
                            "prefill"),
                           ("baseline_never_adopts",
                            "adoption-off row moved zero pages/bytes"),
                           ("all_completed",
                            "disagg sweep completed all requests"),
                           ("all_converged",
                            "disagg replicas bitwise converged")):
            flag_ok = current.get("disaggregation", {}).get(flag, False)
            lines.append(f"{desc}: {'ok' if flag_ok else 'FAIL'}")
            ok = ok and flag_ok

    # One line per gated counter: how many keys passed / failed / were
    # missing, so a red run names the offending counter at a glance.
    lines.append("per-counter gate table:")
    for name, (n_ok, n_fail, n_miss) in tally.items():
        status = "FAIL" if (n_fail or n_miss) else "ok"
        lines.append(f"{name:>24}: {n_ok} ok, {n_fail} fail, "
                     f"{n_miss} missing  {status}")
    return ok, lines


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--current", default="BENCH_serving.json")
    ap.add_argument("--max-regression", type=float, default=0.15,
                    help="threshold for deterministic per-step counters")
    ap.add_argument("--timing-slack", type=float, default=0.50,
                    help="threshold for the dense-normalized timing ratio")
    args = ap.parse_args()

    baseline = json.loads(Path(args.baseline).read_text())
    current = json.loads(Path(args.current).read_text())
    ok, lines = check(baseline, current, args.max_regression,
                      args.timing_slack)
    for line in lines:
        print(line)
    if not ok:
        print("REGRESSION: paged path exceeded baseline "
              f"(counters >{args.max_regression:.0%} or timing "
              f">{args.timing_slack:.0%})")
        sys.exit(1)
    print("serving regression gate passed")


if __name__ == "__main__":
    main()
