"""Roofline analysis from dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch × shape × mesh) cell:
    compute term    = flops_per_device / PEAK_FLOPS_BF16
    memory term     = bytes_per_device / HBM_BW
    collective term = collective_bytes_per_device / ICI_BW
(all per-device quantities from the dry-run's extrapolated cost analysis —
per-device-time formulation; equivalent to the global/chips form).

Reports the dominant term (the bottleneck), the MODEL_FLOPS/HLO ratio
(useful-compute fraction — catches remat/dispatch waste), the roofline
fraction (model-flops-time / bound-time), and a one-line "what would move
the dominant term down".
"""
from __future__ import annotations

import json
import math
from pathlib import Path

import jax.numpy as jnp

PEAK_FLOPS_BF16 = 197e12
HBM_BW = 819e9
ICI_BW = 50e9 * 4          # ~4 usable links per v5e chip (2D torus)

DRYRUN_DIR = Path(__file__).resolve().parent.parent / "experiments" / "dryrun"


# ---------------------------------------------------------------------------
# KV-cache byte accounting, shared with bench_serving's analytic counters.
#
# Everything is derived from a cache tree's own leaf shapes/dtypes (works on
# concrete arrays and on jax.eval_shape abstract trees alike), so the roofline
# model and the serving bench agree on bytes/token by construction: there is
# exactly one place that knows how many bytes a page or a token slot costs,
# including quantized pools where int8/fp8 payload and f32 scale leaves have
# different dtypes.
# ---------------------------------------------------------------------------

def leaf_nbytes(leaf) -> int:
    """Bytes of one cache leaf (concrete array or ShapeDtypeStruct)."""
    return int(math.prod(leaf.shape)) * jnp.dtype(leaf.dtype).itemsize


def kv_page_bytes(cache) -> int:
    """Bytes one page occupies summed over every paged layer of the model.

    Covers every leaf that travels with a page — quantized pools AND their
    scale leaves — via cache._POOL_LEAF_NDIM, so a q8 layout reports the
    int8 payload plus the f32 per-row scales, not a hand-derived formula.
    Stacked [G, P, ...] group pools count all G groups.
    """
    from repro.models import cache as cache_mod
    total = 0
    for _path, layout, layer in cache_mod.iter_layers(cache):
        if layout not in cache_mod.PAGED_LAYOUTS:
            continue
        for name, core in cache_mod._POOL_LEAF_NDIM[layout].items():
            leaf = layer[name]
            stacked = leaf.ndim == core + 1
            num_pages = leaf.shape[1 if stacked else 0]
            total += leaf_nbytes(leaf) // num_pages
    return total


def kv_slot_bytes(cache) -> int:
    """Bytes one token slot occupies summed over every paged layer."""
    from repro.models import cache as cache_mod
    total = 0
    for _path, layout, layer in cache_mod.iter_layers(cache):
        if layout not in cache_mod.PAGED_LAYOUTS:
            continue
        ax = cache_mod._SPAN_SLOT_AXIS[layout]
        for name, core in cache_mod._POOL_LEAF_NDIM[layout].items():
            leaf = layer[name]
            stacked = leaf.ndim == core + 1
            num_pages = leaf.shape[1 if stacked else 0]
            page_size = leaf.shape[ax + (1 if stacked else 0)]
            total += leaf_nbytes(leaf) // (num_pages * page_size)
    return total


def dense_kv_bytes(cache) -> int:
    """Total KV bytes of every non-paged layer (dense / dense_mla / xattn):
    what a step streams when the whole preallocated cache is read+written."""
    from repro.models import cache as cache_mod
    total = 0
    for _path, layout, layer in cache_mod.iter_layers(cache):
        if layout in cache_mod.PAGED_LAYOUTS or layout == "state":
            continue
        total += sum(leaf_nbytes(v) for k, v in layer.items()
                     if k != "block_tables")
    return total


def load_cells(mesh: str = "single", variant: str = "baseline") -> list[dict]:
    d = DRYRUN_DIR / mesh
    if not d.exists():
        raise FileNotFoundError(f"run launch/dryrun.py first ({d})")
    return [json.loads(f.read_text())
            for f in sorted(d.glob(f"*__{variant}.json"))]


def roofline(cell: dict) -> dict | None:
    if cell.get("status") != "ok":
        return None
    t_comp = cell["flops_per_device"] / PEAK_FLOPS_BF16
    t_mem = cell["bytes_per_device"] / HBM_BW
    t_coll = (cell["collective_bytes_per_device"].get("total", 0.0)) / ICI_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    model_flops = cell.get("model_flops_est", 0.0)
    n_dev = cell["n_devices"]
    hlo_global = cell["flops_per_device"] * n_dev
    useful = model_flops / hlo_global if hlo_global else 0.0
    # Roofline fraction: time the model's useful flops WOULD take at peak,
    # over the bound (dominant) time — "how close to roofline the step is".
    t_useful = (model_flops / n_dev) / PEAK_FLOPS_BF16
    bound = max(terms.values())
    frac = t_useful / bound if bound > 0 else 0.0
    # Memory-roofline fraction (decode/serving): a decode step MUST stream
    # the persistent state (params + cache) once; useful_bytes/HLO_bytes is
    # the fair closeness metric for memory-bound cells (the compute-peak
    # fraction is structurally tiny for decode).
    mem_frac = None
    ma = cell.get("memory_analytic")
    if ma and cell.get("kind") == "decode":
        useful_bytes = ma.get("params_per_device", 0) + ma.get(
            "cache_per_device", 0)
        if cell["bytes_per_device"] > 0:
            mem_frac = useful_bytes / cell["bytes_per_device"]
    return dict(cell, t_compute=t_comp, t_memory=t_mem, t_collective=t_coll,
                dominant=dominant, useful_ratio=useful,
                roofline_fraction=frac, memory_roofline_fraction=mem_frac)


_ADVICE = {
    "compute": "cut non-useful FLOPs: remat policy, MoE dispatch tightness, "
               "fused attention (no score materialization)",
    "memory": "cut HBM traffic: bf16/quantized KV, windowed cache, fusion, "
              "larger per-step batch to amortize weight streaming",
    "collective": "cut bytes on ICI: pmax-packed coordination merge, "
                  "reduce-scatter instead of all-gather, overlap, "
                  "lower sync cadence",
}


def table(mesh: str = "single", variant: str = "baseline") -> list[str]:
    rows = []
    hdr = (f"{'arch':24s} {'shape':12s} {'t_comp(s)':>10s} {'t_mem(s)':>10s} "
           f"{'t_coll(s)':>10s} {'domin':>6s} {'MODEL/HLO':>9s} {'frac':>6s}")
    rows.append(hdr)
    for cell in load_cells(mesh, variant):
        if cell.get("status") == "skipped":
            rows.append(f"{cell['arch']:24s} {cell['shape']:12s} "
                        f"{'N/A — ' + cell['reason']}")
            continue
        r = roofline(cell)
        if r is None:
            rows.append(f"{cell['arch']:24s} {cell['shape']:12s} ERROR "
                        f"{cell.get('error', '')[:60]}")
            continue
        mf = (f" memfrac={r['memory_roofline_fraction']:.3f}"
              if r.get("memory_roofline_fraction") is not None else "")
        rows.append(
            f"{r['arch']:24s} {r['shape']:12s} {r['t_compute']:10.3e} "
            f"{r['t_memory']:10.3e} {r['t_collective']:10.3e} "
            f"{r['dominant']:>6s} {r['useful_ratio']:9.3f} "
            f"{r['roofline_fraction']:6.3f}{mf}")
    return rows


def summary_rows(mesh: str = "single", variant: str = "baseline") -> list[str]:
    """CSV rows for benchmarks/run.py."""
    out = []
    for cell in load_cells(mesh, variant):
        r = roofline(cell)
        if r is None:
            continue
        out.append(
            f"roofline/{r['arch']}/{r['shape']},{r['t_compute'] * 1e6:.2f},"
            f"dom={r['dominant']} t_mem={r['t_memory']:.2e}s "
            f"t_coll={r['t_collective']:.2e}s frac={r['roofline_fraction']:.3f} "
            f"advice={_ADVICE[r['dominant']][:40]}")
    return out


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--variant", default="baseline")
    args = ap.parse_args()
    for row in table(args.mesh, args.variant):
        print(row)


if __name__ == "__main__":
    main()
