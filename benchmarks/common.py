"""Shared benchmark harness utilities."""
from __future__ import annotations

import json
import statistics
from dataclasses import asdict
from pathlib import Path

from repro.agents.orchestrator import RunResult, make_sim_llm, run_task
from repro.agents.tasks import TASKS

RESULTS_DIR = Path(__file__).resolve().parent.parent / "experiments" / "bench"
RESULTS_DIR.mkdir(parents=True, exist_ok=True)

_CACHE: dict = {}


def sim_llm():
    if "llm" not in _CACHE:
        _CACHE["llm"] = make_sim_llm()
    return _CACHE["llm"]


def run_suite(runs_per_mode: int = 5, n_agents: int = 4,
              tasks: list[str] | None = None, force: bool = False
              ) -> dict[str, dict[str, list[RunResult]]]:
    """Run (or load cached) seq/par trials for every task.

    Results are cached to JSON so the per-table benchmarks share one suite
    (the paper's 600-trial design, scaled to CPU budget).
    """
    tasks = tasks or list(TASKS)
    cache_f = RESULTS_DIR / f"suite_r{runs_per_mode}_a{n_agents}.json"
    if cache_f.exists() and not force:
        raw = json.loads(cache_f.read_text())
        return {t: {m: [RunResult(**r) for r in raw[t][m]]
                    for m in raw[t]} for t in raw if t in tasks}

    cfg, params = sim_llm()
    out: dict = {}
    for name in tasks:
        out[name] = {"sequential": [], "parallel": []}
        for mode in ("sequential", "parallel"):
            for run in range(runs_per_mode):
                r = run_task(cfg, params, TASKS[name], mode=mode,
                             n_agents=n_agents, seed=run)
                out[name][mode].append(r)
    cache_f.write_text(json.dumps(
        {t: {m: [asdict(r) for r in rs] for m, rs in ms.items()}
         for t, ms in out.items()}))
    return out


def mean(xs):
    return statistics.fmean(xs) if xs else float("nan")


def stdev(xs):
    return statistics.stdev(xs) if len(xs) > 1 else 0.0


def pct_delta(seq: float, par: float) -> float:
    return 100.0 * (par - seq) / seq if seq else float("nan")


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
