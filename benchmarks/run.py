"""Benchmark entry: one function per paper table + roofline summary.

Prints ``name,us_per_call,derived`` CSV rows (the harness contract).

  PYTHONPATH=src python -m benchmarks.run [--runs N] [--agents N] [--quick]
"""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--runs", type=int, default=5)
    ap.add_argument("--agents", type=int, default=4)
    ap.add_argument("--quick", action="store_true",
                    help="2 runs/mode, smaller table6 sweep")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    runs = 2 if args.quick else args.runs

    from benchmarks import tables
    from benchmarks.common import run_suite

    print("name,us_per_call,derived")
    suite = run_suite(runs_per_mode=runs, n_agents=args.agents,
                      force=args.force)
    for row in tables.table3(suite):
        print(row)
    for row in tables.table4(suite):
        print(row)
    for row in tables.table5(suite):
        print(row)
    for row in tables.table6(runs=1 if args.quick else 2,
                             agents=(1, 2, 4) if args.quick
                             else (1, 2, 4, 8)):
        print(row)
    for row in tables.table7(suite):
        print(row)
    for row in tables.rq3_consistency(suite):
        print(row)

    # Serving sweep: dense-vs-paged KV cache (also writes BENCH_serving.json).
    from benchmarks.bench_serving import run_bench
    run_bench(quick=args.quick)

    # Roofline summary (reads dry-run artifacts if present).
    try:
        from benchmarks.roofline import summary_rows
        for row in summary_rows():
            print(row)
    except FileNotFoundError:
        print("roofline/skipped,0,run launch/dryrun.py first")


if __name__ == "__main__":
    main()
