"""Deterministic fault-injecting replica simulator for the replicated page
table (serving/replicated.py).

N simulated engine replicas drive the REAL protocol objects —
``ReplicatedPageStore`` + ``ReplicatedPageAllocator`` +
``ReplicatedPrefixCache`` + ``AntiEntropyNode`` — through a seeded schedule
of admit / grow / preempt / complete / crash events, while every gossip
packet (deltas AND acks) crosses a ``FaultyChannel`` that can drop,
duplicate, delay, reorder, and partition.  Pages here are abstract (no
model, no KV bytes), which is exactly what lets the simulator exercise the
one thing the engine path defers: real cross-replica page adoption through
the provisional-share protocol.

After the event horizon the simulator *quiesces*: faults stop, the channel
drains, and replicas keep gossiping until their page tables agree.  Then it
checks the three contracts the distributed tier sells:

  convergence   every live replica's CRDT state is BITWISE identical, and
                identical to the full fold-join of all live states
                (``merge.fold_join`` — the oracle the delta path must match).
  conservation  per lane and per page: replica r's lane value equals the
                references r's live requests (plus frozen crash holdings)
                actually hold — no leak, no double-free (``dec <= inc``
                cellwise), no cross-replica aliasing without a share.
  lease safety  at no point did two live replicas hold an open write
                session on the same page (checked online by ``Monitor``,
                not post-hoc).

Every run emits a JSON-able convergence trace (per-round digests, events,
violations) — CI uploads it on failure.  Run standalone:

    PYTHONPATH=src python -m repro.serving.simulator \
        --replicas 4 --seed 0 --schedule partition_heal --trace /tmp/t.json
"""
from __future__ import annotations

import argparse
import heapq
import json
from dataclasses import dataclass, field
from typing import Any, Optional

import numpy as np

from repro.core import merge as merge_mod
from repro.serving.replicated import (AckPacket, AntiEntropyNode,
                                      ReplicatedPageAllocator,
                                      ReplicatedPageStore,
                                      ReplicatedPrefixCache)

# ---------------------------------------------------------------------------
# Fault model
# ---------------------------------------------------------------------------


@dataclass
class FaultSpec:
    """Adversarial channel behaviour, all driven by the run's seeded RNG.

    ``partitions`` entries are ``(t0, t1, side)``: during [t0, t1) packets
    between ``side`` and its complement are dropped (both directions).
    ``crash`` maps replica -> crash step (crash-stop: no further ops,
    heartbeats, or packets)."""

    drop: float = 0.0
    dup: float = 0.0
    delay_max: int = 0          # extra delivery delay, uniform in [0, max]
    reorder: float = 0.0        # probability of +[1, 3] extra delay
    partitions: list = field(default_factory=list)
    crash: dict = field(default_factory=dict)


SCHEDULES: dict[str, FaultSpec] = {
    "lossy": FaultSpec(drop=0.3, dup=0.3),
    "reorder_delay": FaultSpec(dup=0.15, delay_max=3, reorder=0.5),
    "partition_heal": FaultSpec(drop=0.1,
                                partitions=[(12, 34, frozenset({0}))]),
    "crash_reclaim": FaultSpec(drop=0.15, crash={1: 18}),
}


class FaultyChannel:
    """Deterministic unreliable transport for gossip packets."""

    def __init__(self, rng: np.random.Generator, spec: FaultSpec):
        self.rng = rng
        self.spec = spec
        self.healed = False
        self._q: list = []          # heap of (deliver_at, seqno, packet)
        self._seq = 0
        self.sent = 0
        self.dropped = 0
        self.duplicated = 0

    def _partitioned(self, a: int, b: int, now: int) -> bool:
        if self.healed:
            return False
        for t0, t1, side in self.spec.partitions:
            if t0 <= now < t1 and ((a in side) != (b in side)):
                return True
        return False

    def send(self, pkt: Any, now: int) -> None:
        self.sent += 1
        if self._partitioned(pkt.src, pkt.dst, now):
            self.dropped += 1
            return
        if not self.healed and self.rng.random() < self.spec.drop:
            self.dropped += 1
            return
        copies = 1
        if not self.healed and self.rng.random() < self.spec.dup:
            copies = 2
            self.duplicated += 1
        for _ in range(copies):
            delay = 1
            if not self.healed:
                if self.spec.delay_max:
                    delay += int(self.rng.integers(0,
                                                   self.spec.delay_max + 1))
                if self.spec.reorder and self.rng.random() < self.spec.reorder:
                    delay += int(self.rng.integers(1, 4))
            heapq.heappush(self._q, (now + delay, self._seq, pkt))
            self._seq += 1

    def deliver(self, now: int) -> list:
        out = []
        while self._q and self._q[0][0] <= now:
            _, _, pkt = heapq.heappop(self._q)
            out.append(pkt)
        return out

    @property
    def in_flight(self) -> int:
        return len(self._q)


# ---------------------------------------------------------------------------
# Lease-safety monitor (online, global observer)
# ---------------------------------------------------------------------------


class Monitor:
    """Tracks open write sessions per page and flags dual live writers.

    A session opens at a replica's first write to a page it allocated and
    closes when that replica releases the page (or crashes — a crashed
    writer cannot race anyone).  A write by X while Y != X holds an open
    session AND is still live is a lease violation: two live owners wrote
    the same physical page."""

    def __init__(self):
        self.open: dict[int, tuple[int, int]] = {}    # page -> (rid, seq)
        self.violations: list[dict] = []
        self.writes = 0

    def on_write(self, rid: int, page: int, seq: int, now: int,
                 live) -> None:
        self.writes += 1
        cur = self.open.get(page)
        if cur is not None and cur[0] != rid and live(cur[0]):
            self.violations.append(
                {"page": page, "now": now, "writer": rid,
                 "writer_seq": seq, "holder": cur[0], "holder_seq": cur[1]})
        self.open[page] = (rid, seq)

    def on_release(self, rid: int, page: int) -> None:
        if self.open.get(page, (None,))[0] == rid:
            del self.open[page]


# ---------------------------------------------------------------------------
# Simulated replica
# ---------------------------------------------------------------------------


@dataclass
class SimRequest:
    rid: int                    # request id (globally unique)
    prompt_id: int
    n_prompt: int               # prompt pages (shareable, written once)
    grow_left: int              # private growth pages still to allocate
    shared: list = field(default_factory=list)
    owned: list = field(default_factory=list)

    @property
    def held(self) -> list:
        return self.shared + self.owned


class SimReplica:
    """One engine replica at page-table granularity.

    Prompt pages are shareable: the first replica to admit a prompt
    allocates + writes + publishes them; later admissions share — locally
    with an immediate commit, cross-replica through the provisional
    protocol (share lane → wait to hear from the owner → commit iff the
    lease epoch is unchanged, else abort).  Growth pages are private and
    written by their owner every allocation — the write stream the lease
    monitor audits."""

    ADOPT_TTL = 12              # abort provisional adoptions unheard this long

    def __init__(self, rid: int, store: ReplicatedPageStore,
                 node: AntiEntropyNode, allocator: ReplicatedPageAllocator,
                 monitor: Monitor, live):
        self.rid = rid
        self.store = store
        self.node = node
        self.allocator = allocator
        self.cache = ReplicatedPrefixCache(allocator, page_size=1)
        self.monitor = monitor
        self.live = live
        self.requests: dict[int, SimRequest] = {}
        self.requeue: list[tuple[int, int, int]] = []
        self.pending_adopt: dict[int, tuple[int, SimRequest, int, int]] = {}
        self.crashed = False
        self.frozen_holdings: Optional[dict[int, int]] = None
        self.counters = {"admitted": 0, "admit_failed": 0, "completed": 0,
                         "preempted": 0, "grown": 0, "grow_starved": 0,
                         "adopt_committed": 0, "adopt_aborted": 0,
                         "local_shares": 0, "fenced_skips": 0}

    # -- bookkeeping ---------------------------------------------------------

    def holdings(self) -> dict[int, int]:
        """page -> references this replica's lane should hold right now."""
        held: dict[int, int] = {}
        for req in self.requests.values():
            for p in req.held:
                held[p] = held.get(p, 0) + 1
        for p in self.pending_adopt:
            held[p] = held.get(p, 0) + 1
        return held

    def _write(self, page: int, now: int) -> None:
        _, seq = self.store.lease(page)
        self.monitor.on_write(self.rid, page, seq, now, self.live)

    def _release_pages(self, req: SimRequest) -> None:
        for p in req.owned:
            self.monitor.on_release(self.rid, p)
        self.allocator.free(req.held)

    # -- events --------------------------------------------------------------

    def admit(self, job: tuple[int, int, int], now: int) -> bool:
        if self.allocator.halted or self.allocator.fenced(now):
            self.counters["fenced_skips"] += 1
            self.requeue.append(job)
            return False
        rid_req, prompt_id, n_prompt = job[0], job[1], job[2]
        grow = job[3] if len(job) > 3 else 0
        req = SimRequest(rid=rid_req, prompt_id=prompt_id,
                         n_prompt=n_prompt, grow_left=grow)
        for k in range(1, n_prompt + 1):
            key = (prompt_id, k)
            hit = self.cache.resolve_remote(key)
            if hit is not None:
                owner, page, seq = hit
                if owner == self.rid:
                    self.allocator.share([page])
                    req.shared.append(page)
                    self.counters["local_shares"] += 1
                    continue
                if page not in self.pending_adopt:
                    self.allocator.share([page])
                    self.pending_adopt[page] = (seq, req, now, owner)
                    continue
            pages = self.allocator.alloc(1)
            if pages is None:
                # Roll back and retry later (admission is all-or-nothing
                # for the pages we DID take; pending adoptions stay in
                # flight and resolve to an already-dead request → abort).
                self._rollback(req)
                self.counters["admit_failed"] += 1
                self.requeue.append(job)
                return False
            p = pages[0]
            req.owned.append(p)
            self._write(p, now)
            # The abstract write above already landed the page's bytes, so
            # publication is immediate (publish-on-fill, as the engine path).
            self.cache.mark_filled([p])
            self.cache._publish_page(key, p)
        self.requests[req.rid] = req
        self.counters["admitted"] += 1
        return True

    def _rollback(self, req: SimRequest) -> None:
        for p in req.owned:
            self.monitor.on_release(self.rid, p)
        self.allocator.free(req.held)
        drop = [p for p, (_, r, _, _) in self.pending_adopt.items()
                if r is req]
        for p in drop:
            del self.pending_adopt[p]
            self.store.ref_sub(p)
            self.counters["adopt_aborted"] += 1

    def grow(self, now: int) -> None:
        if self.allocator.halted or self.allocator.fenced(now):
            self.counters["fenced_skips"] += 1
            return
        for req in sorted(self.requests.values(), key=lambda r: r.rid):
            if req.grow_left <= 0:
                continue
            pages = self.allocator.alloc(1)
            if pages is None:
                self.counters["grow_starved"] += 1
                return
            req.owned.append(pages[0])
            req.grow_left -= 1
            self._write(pages[0], now)
            self.counters["grown"] += 1
            return                        # one growth per event

    def complete(self) -> None:
        if not self.requests:
            return
        rid = min(self.requests)          # FIFO-ish, deterministic
        req = self.requests.pop(rid)
        self._release_pages(req)
        self.counters["completed"] += 1

    def preempt(self) -> None:
        if not self.requests:
            return
        rid = max(self.requests)          # youngest, deterministic
        req = self.requests.pop(rid)
        self._release_pages(req)
        # Re-queued with its remaining growth folded back in.
        self.requeue.append((req.rid, req.prompt_id, req.n_prompt,
                             req.grow_left))
        self.counters["preempted"] += 1

    def crash(self) -> None:
        self.crashed = True
        # Frozen holdings: the references this lane will hold forever unless
        # the replica is retired (then the lane is masked out entirely).
        self.frozen_holdings = self.holdings()

    # -- per-step protocol work ----------------------------------------------

    def resolve_adoptions(self, now: int) -> None:
        for page in sorted(self.pending_adopt):
            seq, req, t0, owner = self.pending_adopt[page]
            cur_owner, cur_seq = self.store.lease(page)
            epoch_ok = (cur_owner, cur_seq) == (owner, seq)
            # The request may have completed / been preempted while the
            # adoption was in flight — commit-to-dead would leak the ref.
            req_live = self.requests.get(req.rid) is req
            if not epoch_ok or not req_live \
                    or now - t0 > self.ADOPT_TTL:
                del self.pending_adopt[page]
                self.store.ref_sub(page)
                self.counters["adopt_aborted"] += 1
            elif self.store.last_heard.get(owner, 0) > t0:
                del self.pending_adopt[page]
                req.shared.append(page)
                self.counters["adopt_committed"] += 1


# ---------------------------------------------------------------------------
# The simulator proper
# ---------------------------------------------------------------------------


class Simulator:
    """Drives N replicas through a seeded event schedule over a faulty
    channel, then quiesces and checks the distributed contracts."""

    def __init__(self, *, replicas: int = 2, num_pages: int = 48,
                 seed: int = 0, schedule: str = "lossy",
                 steps: int = 40, ttl: int = 6, capacity: int = 24,
                 prompt_pool: int = 4, linger: int = 4):
        if schedule not in SCHEDULES:
            raise ValueError(f"unknown schedule {schedule!r}; "
                             f"choose from {sorted(SCHEDULES)}")
        self.n = replicas
        self.num_pages = num_pages
        self.seed = seed
        self.schedule = schedule
        self.steps = steps
        self.ttl = ttl
        self.spec = SCHEDULES[schedule]
        self.rng = np.random.default_rng(seed)
        self.channel = FaultyChannel(np.random.default_rng(seed + 1),
                                     self.spec)
        self.monitor = Monitor()
        self.now = 0
        self._next_req = 0
        self.trace: dict = {"config": {
            "replicas": replicas, "num_pages": num_pages, "seed": seed,
            "schedule": schedule, "steps": steps, "ttl": ttl,
            "capacity": capacity}, "events": [], "rounds": [],
            "violations": []}

        self.stores = [ReplicatedPageStore(r, replicas, num_pages)
                       for r in range(replicas)]
        gossip = None
        self.nodes = []
        for st in self.stores:
            node = AntiEntropyNode(st, capacity=capacity, gossip=gossip)
            gossip = node.gossip
            self.nodes.append(node)
        self.allocs = [ReplicatedPageAllocator(st, ttl=ttl, linger=linger)
                       for st in self.stores]
        self.reps = [SimReplica(r, self.stores[r], self.nodes[r],
                                self.allocs[r], self.monitor, self._is_live)
                     for r in range(replicas)]

    # -- helpers -------------------------------------------------------------

    def _is_live(self, rid: int) -> bool:
        """Crashed OR halted replicas are out of the membership: a halted
        replica was retired by a majority (e.g. after a long partition) and
        fenced itself strictly before retirement was reachable (ttl <
        2*ttl), so like a crashed node it will never write again and is
        excluded from convergence, settlement, and lease-liveness checks."""
        rep = self.reps[rid]
        return not rep.crashed and not rep.allocator.halted

    def live_rids(self) -> list[int]:
        return [r for r in range(self.n) if self._is_live(r)]

    def _log_event(self, rid: int, kind: str, **kw) -> None:
        self.trace["events"].append({"t": self.now, "rid": rid,
                                     "op": kind, **kw})

    # -- one step ------------------------------------------------------------

    def _deliver(self) -> None:
        for pkt in self.channel.deliver(self.now):
            if self.reps[pkt.dst].crashed:
                continue
            node = self.nodes[pkt.dst]
            if isinstance(pkt, AckPacket):
                node.receive_ack(pkt, self.now)
            else:
                ack = node.receive(pkt, self.now)
                self.channel.send(ack, self.now)

    def _gossip(self) -> None:
        for r in self.live_rids():
            for peer in range(self.n):
                if peer == r:
                    continue
                self.channel.send(self.nodes[r].make_packet(peer, self.now),
                                  self.now)

    def _replica_step(self, rep: SimReplica) -> None:
        rep.resolve_adoptions(self.now)
        rep.allocator.maintain(self.now)
        rep.allocator.scavenge()

    def step(self, events: Optional[list] = None) -> None:
        """One simulated tick: deliver → apply events → protocol upkeep →
        gossip.  ``events`` is a list of (rid, op, args) tuples."""
        self._deliver()
        for rid, op, args in (events or []):
            rep = self.reps[rid]
            if rep.crashed:
                continue
            if op == "crash":
                rep.crash()
                self._log_event(rid, "crash")
                continue
            if op == "admit":
                job = rep.requeue.pop(0) if rep.requeue else args
                ok = rep.admit(job, self.now)
                self._log_event(rid, "admit", job=list(job), ok=ok)
            elif op == "grow":
                rep.grow(self.now)
            elif op == "complete":
                rep.complete()
            elif op == "preempt":
                rep.preempt()
        for r in self.live_rids():
            self._replica_step(self.reps[r])
        self._gossip()
        self.now += 1

    # -- schedule generation -------------------------------------------------

    def _draw_events(self) -> list:
        evs = []
        for rid in range(self.n):
            if self.spec.crash.get(rid) == self.now:
                evs.append((rid, "crash", None))
                continue
            u = self.rng.random()
            if u < 0.30:
                job = (self._next_req, int(self.rng.integers(
                    0, 4)), int(self.rng.integers(1, 4)),
                    int(self.rng.integers(0, 3)))
                self._next_req += 1
                evs.append((rid, "admit", job))
            elif u < 0.60:
                evs.append((rid, "grow", None))
            elif u < 0.78:
                evs.append((rid, "complete", None))
            elif u < 0.86:
                evs.append((rid, "preempt", None))
        return evs

    # -- run + quiesce -------------------------------------------------------

    def run(self) -> dict:
        for _ in range(self.steps):
            self.step(self._draw_events())
        self.drain()
        self.quiesce()
        result = self.check_invariants()
        self.trace["result"] = result
        self.trace["violations"] = self.monitor.violations
        return result

    def drain(self) -> None:
        """Retire all live requests so page tables can reach refcount 0."""
        for r in self.live_rids():
            rep = self.reps[r]
            rep.requeue.clear()
            while rep.requests and not rep.allocator.halted:
                self.step([(r, "complete", None)])

    def quiesce(self, max_rounds: Optional[int] = None) -> None:
        """Heal all faults, finish pending protocol work, then freeze
        liveness traffic and flush gossip until live replicas are BITWISE
        identical.

        Two phases because heartbeats are *designed* to never converge: every
        ``maintain`` bumps the local counter, so each replica is always one
        gossip hop behind its peers' latest beat.  Phase A runs the full
        protocol (heartbeats, retirement votes, reclamation) until no replica
        has pending work; phase B stops calling ``maintain`` — freezing the
        heartbeat lattice — and alternates gossip rounds with channel drains
        until every array, heartbeats included, matches exactly."""
        self.channel.healed = True
        if max_rounds is None:
            # Long enough for crash retirement (hb stale > 2*ttl) plus the
            # reclamation grace window, with slack for gossip catch-up.
            max_rounds = 4 * self.ttl + 40
        # Phase A — active protocol until no pending work anywhere.
        settled_at = None
        for _ in range(max_rounds):
            self.step()
            if self._work_settled():
                settled_at = self.now
                break
        if settled_at is None:
            raise AssertionError(
                f"protocol work never settled after {max_rounds} rounds")
        # Phase B — liveness frozen; flush deltas to bitwise convergence.
        flush_cap = 4 * self.num_pages + 40
        for _ in range(flush_cap):
            self._drain_channel()
            digests = sorted({self.stores[r].digest()
                              for r in self.live_rids()})
            self.trace["rounds"].append(
                {"t": self.now, "digests": [d[:16] for d in digests]})
            if len(digests) == 1:
                return
            self._gossip()
            self.now += 1
        raise AssertionError(
            f"no bitwise convergence after {flush_cap} flush rounds: "
            f"digests={[self.stores[r].digest()[:8] for r in self.live_rids()]}")

    def _work_settled(self) -> bool:
        for r in self.live_rids():
            rep = self.reps[r]
            if rep.pending_adopt or rep.allocator._claims \
                    or rep.allocator._cooling or rep.requests:
                return False
        return True

    def _drain_channel(self) -> None:
        """Deliver every in-flight packet (including acks spawned by those
        deliveries) without generating new gossip."""
        while self.channel.in_flight:
            self.now += 1
            self._deliver()

    # -- invariants ----------------------------------------------------------

    def check_invariants(self) -> dict:
        live = self.live_rids()
        failures = []

        # 1. Bitwise convergence across live replicas.
        digests = [self.stores[r].digest() for r in live]
        if len(set(digests)) != 1:
            failures.append(f"divergent digests: {digests}")

        # 2. Delta path matches the full fold-join oracle.
        states = [self.stores[r].state() for r in live]
        oracle = merge_mod.fold_join(states)
        import jax
        for r, st in zip(live, states):
            same = jax.tree.all(jax.tree.map(
                lambda a, b: bool((np.asarray(a) == np.asarray(b)).all()),
                st, oracle))
            if not same:
                failures.append(f"replica {r} != fold_join oracle")

        # 3. No double-free anywhere: dec <= inc cellwise (merged view).
        ref = self.stores[live[0]]
        if not (ref.dec <= ref.inc).all():
            failures.append("dec > inc: double-free in merged counter state")

        # 4. Per-lane conservation: each lane's refcount total equals the
        #    references that replica's live requests actually hold (frozen
        #    snapshot for crashed-but-unretired lanes; retired lanes are
        #    excluded from refcounts entirely).  Each lane is audited against
        #    its OWN replica's store — lanes are single-writer, so that copy
        #    is authoritative even when a crash lost the final deltas.
        retired = ref.retired_mask()
        for r in range(self.n):
            own = self.stores[r]
            lane = (own.inc[r] - own.dec[r])
            rep = self.reps[r]
            if retired[r]:
                continue                     # masked out of every refcount
            held = (rep.frozen_holdings if rep.crashed else rep.holdings())
            expect = np.zeros(self.num_pages, dtype=np.int64)
            for p, c in (held or {}).items():
                expect[p] += c
            if not (lane == expect).all():
                bad = np.nonzero(lane != expect)[0][:8]
                failures.append(
                    f"lane {r} refcount leak at pages {bad.tolist()}: "
                    f"lane={lane[bad].tolist()} held={expect[bad].tolist()}")

        # 5. Free-list / refcount partition per live replica: every home
        #    page is either free (refcount 0) or referenced; a page on the
        #    free list with refcount > 0 would alias live data.
        for r in live:
            rep = self.reps[r]
            refs = self.stores[r].refcounts()
            for p in rep.allocator._free:
                if refs[p] != 0:
                    failures.append(
                        f"replica {r}: free page {p} has refcount {refs[p]}")
            for p in rep.allocator._cooling:
                if refs[p] != 0:
                    failures.append(
                        f"replica {r}: cooling page {p} refcount {refs[p]}")

        # 6. Lease safety (collected online by the monitor).
        if self.monitor.violations:
            failures.append(
                f"{len(self.monitor.violations)} lease violations: "
                f"{self.monitor.violations[:3]}")

        counters: dict[str, int] = {}
        for rep in self.reps:
            for k, v in rep.counters.items():
                counters[k] = counters.get(k, 0) + v
        return {
            "ok": not failures,
            "failures": failures,
            "live_replicas": live,
            "retired": [int(r) for r in np.nonzero(retired)[0]],
            "digest": digests[0][:16] if digests else None,
            "rounds": self.now,
            "channel": {"sent": self.channel.sent,
                        "dropped": self.channel.dropped,
                        "duplicated": self.channel.duplicated},
            "sync_bytes": sum(n.bytes_sent for n in self.nodes),
            "monitor_writes": self.monitor.writes,
            "reclaimed_pages": sum(a.reclaimed_pages for a in self.allocs),
            "fence_steps": sum(a.fence_steps for a in self.allocs),
            "counters": counters,
        }


def run_sim(**kw) -> tuple[dict, dict]:
    """Convenience wrapper: build, run, return (result, trace)."""
    sim = Simulator(**kw)
    result = sim.run()
    return result, sim.trace


def main(argv: Optional[list] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--pages", type=int, default=48)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--schedule", default="lossy",
                    choices=sorted(SCHEDULES))
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--trace", default=None,
                    help="write JSON convergence trace here")
    args = ap.parse_args(argv)
    result, trace = run_sim(replicas=args.replicas, num_pages=args.pages,
                            seed=args.seed, schedule=args.schedule,
                            steps=args.steps)
    if args.trace:
        with open(args.trace, "w") as f:
            json.dump(trace, f, indent=1, default=str)
    print(json.dumps(result, indent=1, default=str))
    return 0 if result["ok"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
