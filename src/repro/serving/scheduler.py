"""Continuous batching over the paged KV cache: admission, page accounting,
copy-on-write prefix sharing, and completion at token granularity.

The scheduler owns a fixed decode batch of B rows backed by a shared page
pool.  Requests queue up; whenever a row is free and the allocator can
reserve the pages the *prompt* needs (generation pages are allocated
incrementally as decode crosses page boundaries — not up front), the request
is admitted by a *ragged prefill* — one jitted call whose ``lengths`` vector
is zero for every other row, so in-flight rows keep decoding from
bit-identical cache while the new row's prompt lands in its pages.  On
completion the row's pages are released immediately (memory scales with live
tokens, not B × max_len).

Prefix sharing (``prefix_sharing=True``): rows admitted with an identical
prompt share the prompt's pages (refcounted, copy-on-write).  Full prefix
pages are shared through a longest-prefix chain; the partial boundary page
is shared on an exact-prompt match and duplicated (copy-then-remap) the
moment a sharer is about to write into it — agents forked from the same
CodeCRDT prompt pay for one copy of the prompt KV, not fan-out copies.

When incremental growth finds the pool empty, the least-recently-allocating
row is preempted: its pages are released and the request re-queued at the
front with its generated tokens folded into the prompt (preemption by
recomputation — the re-admission prefill replays prompt + generated and
decoding continues where it stopped).

Freed rows still ride the batched decode step (there is no dynamic batch
shape under jit).  Their writes are steered to a dedicated trash page —
never allocated to real rows — because the fused kernel writes one slot per
row per step unconditionally; block tables therefore never contain -1 for a
slot that will be written.

Dense mode (``paged=False``) runs the same admission logic against the
classic [B, Hkv, S, D] cache — the benchmark's apples-to-apples baseline.
"""
from __future__ import annotations

import time
from collections import OrderedDict, deque
from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import cache as cache_mod
from repro.models import lm
from repro.models.config import ModelConfig
from repro.serving import engine as engine_mod
from repro.serving.engine import PROMPT_BUCKETS, bucket_len  # noqa: F401

Params = Any


class Reservation:
    """Pages earmarked for one admission candidate (already out of the free
    list, so a later candidate's ``available`` check cannot double-count
    them).  ``take`` hands them out; ``release`` returns the rest."""

    def __init__(self, allocator: "PageAllocator", pages: list[int]):
        self._allocator = allocator
        self._pages = pages

    @property
    def count(self) -> int:
        return len(self._pages)

    def take(self, n: int | None = None) -> list[int]:
        n = len(self._pages) if n is None else n
        out, self._pages = self._pages[:n], self._pages[n:]
        return out

    def release(self) -> None:
        if self._pages:
            self._allocator.free(self._pages)
            self._pages = []


class PageAllocator:
    """Host-side refcounted page pool (unit = one page).

    Pages are handed out at refcount 1; ``share`` adds a reference (prefix
    sharing), ``free`` drops one and returns the page to the free list at
    zero.  ``generation`` bumps on every fresh hand-out so stale prefix
    entries can detect reuse.  ``reserve`` is the admission-safe path: it
    removes pages from the free list immediately, so a two-phase admit
    cannot admit two requests against the same availability snapshot (the
    double-admission race).
    """

    def __init__(self, num_pages: int):
        self.num_pages = num_pages
        self._free = list(range(num_pages - 1, -1, -1))
        self._ref = np.zeros(num_pages, np.int32)
        self._gen = np.zeros(num_pages, np.int64)

    @property
    def available(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> Optional[list[int]]:
        if n <= 0:
            return []                 # [:-0] would hand out the whole list
        if n > len(self._free):
            return None
        pages, self._free = self._free[-n:][::-1], self._free[:-n]
        for p in pages:
            self._ref[p] = 1
            self._gen[p] += 1
        return pages

    def reserve(self, n: int) -> Optional[Reservation]:
        pages = self.alloc(n)
        if pages is None:
            return None
        return Reservation(self, pages)

    def share(self, pages: list[int]) -> None:
        for p in pages:
            if self._ref[p] <= 0:
                raise ValueError(f"cannot share unallocated page {p}")
            self._ref[p] += 1

    def refcount(self, page: int) -> int:
        return int(self._ref[page])

    def generation(self, page: int) -> int:
        return int(self._gen[page])

    def free(self, pages: list[int]) -> None:
        for p in reversed(pages):
            if self._ref[p] <= 0:
                raise ValueError(f"double free of page {p}")
            self._ref[p] -= 1
            if self._ref[p] == 0:
                self._free.append(p)


class PrefixCache:
    """Longest-prefix index from prompt tokens to resident pages.

    Full pages chain through keys ``tuple(tokens[:k*ps])`` (page k-1 holds
    positions [(k-1)·ps, k·ps) and its KV depends on the whole prefix, so
    the key must be the whole prefix); the partial boundary page is indexed
    by the exact full prompt.  Entries carry (page, generation) and are
    pruned lazily when the page was freed or re-allocated.

    Stale entries for *distinct* prompts never collide with a later key, so
    lazy pruning alone would grow the index without bound (each registered
    prompt holds O(len²) ints of key material).  Both maps are therefore
    LRU-bounded at ``max_entries``: hits refresh recency, inserts past the
    cap evict the coldest key.  Eviction only forgets a sharing opportunity
    — resident pages stay owned by their rows/refcounts.
    """

    def __init__(self, allocator: PageAllocator, page_size: int,
                 max_entries: int = 4096):
        self._allocator = allocator
        self.page_size = page_size
        self.max_entries = max_entries
        self._chain: OrderedDict[tuple, tuple[int, int]] = OrderedDict()
        self._boundary: OrderedDict[tuple, tuple[int, int]] = OrderedDict()

    def _valid(self, entry: tuple[int, int] | None) -> Optional[int]:
        if entry is None:
            return None
        page, gen = entry
        if (self._allocator.refcount(page) > 0
                and self._allocator.generation(page) == gen):
            return page
        return None

    def _get(self, table: "OrderedDict[tuple, tuple[int, int]]", key: tuple
             ) -> Optional[int]:
        """Validated lookup: refreshes recency on hit, prunes on miss."""
        page = self._valid(table.get(key))
        if page is None:
            table.pop(key, None)
            return None
        table.move_to_end(key)
        return page

    def _put(self, table: "OrderedDict[tuple, tuple[int, int]]", key: tuple,
             page: int) -> None:
        table[key] = (page, self._allocator.generation(page))
        table.move_to_end(key)
        while len(table) > self.max_entries:
            table.popitem(last=False)

    def lookup(self, tokens: list[int], *, boundary: bool = True
               ) -> list[int]:
        """Longest shareable run of pages for ``tokens`` (prefix order)."""
        ps = self.page_size
        n_full = len(tokens) // ps
        pages: list[int] = []
        for k in range(1, n_full + 1):
            page = self._get(self._chain, tuple(tokens[:k * ps]))
            if page is None:
                break
            pages.append(page)
        if (boundary and len(pages) == n_full and len(tokens) % ps):
            page = self._get(self._boundary, tuple(tokens))
            if page is not None:
                pages.append(page)
        return pages

    def register(self, tokens: list[int], pages: list[int]) -> None:
        """Index a row's freshly prefilled prompt pages."""
        ps = self.page_size
        n_full = len(tokens) // ps
        for k in range(1, min(n_full, len(pages)) + 1):
            key = tuple(tokens[:k * ps])
            if self._get(self._chain, key) is None:
                self._put(self._chain, key, pages[k - 1])
        npages = -(-len(tokens) // ps)
        if len(tokens) % ps and len(pages) >= npages:
            key = tuple(tokens)
            if self._get(self._boundary, key) is None:
                self._put(self._boundary, key, pages[npages - 1])


@dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int
    eos_id: Optional[int] = None
    tokens: list[int] = field(default_factory=list)   # generated output
    admitted_step: int = -1
    finished_step: int = -1
    pages: list[int] = field(default_factory=list)

    @property
    def context(self) -> list[int]:
        """Tokens the next prefill must cover (prompt + generated so far —
        nonempty generated means the request was preempted and resumed)."""
        return self.prompt + self.tokens


class ContinuousBatchingEngine:
    """Token-granularity continuous batching over a (paged) decode engine."""

    def __init__(self, cfg: ModelConfig, params: Params, *, batch: int,
                 max_len: int, paged: bool = True, page_size: int = 64,
                 num_pages: Optional[int] = None, impl: str = "ref",
                 temperature: float = 0.0, seed: int = 0,
                 prefix_sharing: bool = False):
        self.cfg = cfg
        self.params = params
        self.batch = batch
        self.max_len = max_len
        self.paged = paged
        self.page_size = page_size
        self.temperature = temperature
        self.prefix_sharing = prefix_sharing and paged
        self.maxp = -(-max_len // page_size)
        if paged:
            if num_pages is None:
                num_pages = batch * self.maxp
            self.allocator = PageAllocator(num_pages)
            self.prefix_cache = PrefixCache(self.allocator, page_size)
            self.trash_page = num_pages          # extra physical page
            self.cache = lm.init_cache(cfg, batch, max_len, paged=True,
                                       page_size=page_size,
                                       num_pages=num_pages + 1)
            self.host_bt = np.full((batch, self.maxp), self.trash_page,
                                   np.int32)
            self.cache = lm.set_block_tables(self.cache,
                                             jnp.asarray(self.host_bt))
            self._copy_pages = jax.jit(lm.copy_pages, donate_argnums=(0,))
        else:
            self.allocator = None
            self.prefix_cache = None
            self.cache = lm.init_cache(cfg, batch, max_len)
        self._prefill = jax.jit(
            engine_mod.make_ragged_prefill_fn(cfg, impl=impl),
            donate_argnums=(1,))
        self._step = jax.jit(
            engine_mod.make_serve_step(cfg, impl=impl,
                                       temperature=temperature),
            donate_argnums=(1,))
        self.rng = jax.random.PRNGKey(seed)
        self.pos = jnp.zeros((batch,), jnp.int32)
        # Host mirror of pos, refreshed at the one mandatory post-step sync;
        # the pre-step growth/COW walk must not force its own device sync.
        self._host_pos = np.zeros((batch,), np.int32)
        self.token = jnp.zeros((batch,), jnp.int32)
        self.rows: list[Optional[Request]] = [None] * batch
        self.queue: deque[Request] = deque()
        self._bt_dirty = False
        self._last_alloc = [0] * batch        # LRU clock for preemption
        self._cow_src: list[int] = []         # COW pairs pending this step
        self._cow_dst: list[int] = []
        self.stats = {"steps": 0, "prefills": 0, "admitted": 0,
                      "completed": 0, "peak_pages": 0, "gen_tokens": 0,
                      "shared_pages": 0, "cow_copies": 0, "preemptions": 0,
                      "grown_pages": 0, "admit_s": 0.0}

    # -- request lifecycle --------------------------------------------------

    def submit(self, req: Request) -> None:
        if req.max_new_tokens < 1:
            raise ValueError(f"request {req.rid}: max_new_tokens must be "
                             ">= 1 (prefill always yields one token)")
        if not req.prompt:
            raise ValueError(f"request {req.rid}: empty prompt")
        if len(req.prompt) + req.max_new_tokens > self.max_len:
            raise ValueError(f"request {req.rid} needs "
                             f"{len(req.prompt) + req.max_new_tokens} slots "
                             f"> max_len {self.max_len}")
        # Fail here, not mid-run inside admit(): the prompt must fit a
        # prefill bucket (buckets are clamped to max_len at admission).
        bucket_len(len(req.prompt))
        if self.paged:
            worst = -(-(len(req.prompt) + req.max_new_tokens)
                      // self.page_size)
            if worst > self.allocator.num_pages:
                raise ValueError(f"request {req.rid} needs {worst} pages "
                                 f"> pool {self.allocator.num_pages}")
        self.queue.append(req)

    def _note_peak(self) -> None:
        used = self.allocator.num_pages - self.allocator.available
        self.stats["peak_pages"] = max(self.stats["peak_pages"], used)

    def _free_row(self, row: int) -> None:
        req = self.rows[row]
        req.finished_step = self.stats["steps"]
        self.stats["completed"] += 1
        self._release_row(row)
        self.rows[row] = None

    def _release_row(self, row: int) -> None:
        req = self.rows[row]
        if self.paged:
            # req.pages is kept (now historical) — the allocator owns reuse,
            # and a preempted request's re-admission overwrites the list.
            self.allocator.free(req.pages)
            self.host_bt[row, :] = self.trash_page
            self._bt_dirty = True

    def _push_tables(self) -> None:
        if self._bt_dirty:
            self.cache = lm.set_block_tables(self.cache,
                                             jnp.asarray(self.host_bt))
            self._bt_dirty = False

    def admit(self) -> int:
        """Admit queued requests into free rows (one ragged prefill call).

        Two-phase: pages are *reserved* per candidate first (reservation
        removes them from the free list, so candidates later in the loop
        see the true availability — no double admission), then the batch
        prefill lands every accepted prompt at once.  Head-of-line blocking
        on page budget is deliberate: FIFO completion-time fairness.
        """
        t0 = time.perf_counter()
        pending: list[tuple[int, Request]] = []
        for row in range(self.batch):
            if self.rows[row] is not None or not self.queue:
                continue
            req = self.queue[0]
            if self.paged:
                ctx = req.context
                npages = -(-len(ctx) // self.page_size)
                shared: list[int] = []
                if self.prefix_sharing:
                    shared = self.prefix_cache.lookup(ctx)[:npages]
                res = self.allocator.reserve(npages - len(shared))
                if res is None:
                    break                      # wait for completions
                if shared:
                    self.allocator.share(shared)
                    self.stats["shared_pages"] += len(shared)
                req.pages = shared + res.take()
                self.host_bt[row, :] = self.trash_page
                self.host_bt[row, :len(req.pages)] = req.pages
                self._bt_dirty = True
                self._last_alloc[row] = self.stats["steps"]
                if self.prefix_sharing and not req.tokens:
                    # Register at reservation time, not after the prefill:
                    # fan-out clones admitted in the SAME batch then share
                    # these pages, and the one ragged prefill writes the
                    # identical prompt KV into them once per slot.
                    self.prefix_cache.register(req.prompt, req.pages)
            self.queue.popleft()
            self.rows[row] = req
            req.admitted_step = self.stats["steps"]
            pending.append((row, req))
        if not pending:
            self.stats["admit_s"] += time.perf_counter() - t0
            return 0

        if self.paged:
            self._push_tables()
            self._note_peak()
        # Context lengths BEFORE the first sampled token is appended: pos is
        # the number of tokens already cached, and the sampled token is only
        # written by the next decode step.
        ctx_len = {row: len(req.context) for row, req in pending}
        logits, _, self.cache = engine_mod.ragged_prefill_batch(
            self._prefill, self.params, self.cache, self.batch,
            {row: req.context for row, req in pending},
            max_len=self.max_len)
        self.rng, sub = jax.random.split(self.rng)
        first = np.asarray(engine_mod.sample_token(logits, sub,
                                                   self.temperature))
        token = np.array(self.token)           # writable host copies
        pos = self._host_pos
        for row, req in pending:
            req.tokens.append(int(first[row]))
            self.stats["gen_tokens"] += 1
            token[row] = int(first[row])
            pos[row] = ctx_len[row]
        self.token = jnp.asarray(token)
        self.pos = jnp.asarray(pos)
        self.stats["prefills"] += 1
        self.stats["admitted"] += len(pending)
        # A request can complete at its very first token (max_new == 1).
        for row, req in pending:
            if self._done(req):
                self._free_row(row)
        self.stats["admit_s"] += time.perf_counter() - t0
        return len(pending)

    def _done(self, req: Request) -> bool:
        return (len(req.tokens) >= req.max_new_tokens
                or (req.eos_id is not None
                    and req.tokens
                    and req.tokens[-1] == req.eos_id))

    # -- incremental growth / COW / preemption ------------------------------

    def _preempt_for_pages(self, needy_row: int) -> bool:
        """Evict the least-recently-allocating other row (recomputation)."""
        victims = [r for r in range(self.batch)
                   if r != needy_row and self.rows[r] is not None]
        if not victims:
            return False
        victim = min(victims, key=lambda r: (self._last_alloc[r], r))
        req = self.rows[victim]
        # A COW copy queued this step whose destination dies with the victim
        # must be dropped: the freed page can be re-handed out in this same
        # pass, and a duplicate destination in one batched scatter would
        # write undefined contents into a live row's page.
        dead = set(req.pages)
        keep = [(s, d) for s, d in zip(self._cow_src, self._cow_dst)
                if d not in dead]
        self._cow_src = [s for s, _ in keep]
        self._cow_dst = [d for _, d in keep]
        self._release_row(victim)
        self.rows[victim] = None
        self.queue.appendleft(req)             # resumes with context intact
        self._host_pos[victim] = 0
        self.pos = jnp.asarray(self._host_pos)
        self.stats["preemptions"] += 1
        return True

    def _alloc_one(self, row: int) -> int:
        while True:
            pages = self.allocator.alloc(1)
            if pages is not None:
                self._last_alloc[row] = self.stats["steps"]
                return pages[0]
            if not self._preempt_for_pages(row):
                raise RuntimeError(
                    f"page pool exhausted ({self.allocator.num_pages} pages)"
                    " with no preemptable row — pool too small for one "
                    "request")

    def _grow_and_cow(self) -> None:
        """Before a decode step: every active row must own, privately, the
        page its next token lands in.  Crossing into an unallocated page
        allocates one (incremental growth); a page shared with other rows
        or the prefix cache is duplicated and remapped (copy-on-write)."""
        pos = self._host_pos
        self._cow_src = []
        self._cow_dst = []
        for row in range(self.batch):
            req = self.rows[row]
            if req is None:
                continue
            widx = int(pos[row]) // self.page_size
            if widx >= self.maxp:
                continue                       # clamped write; cannot grow
            page = int(self.host_bt[row, widx])
            if page == self.trash_page:
                new = self._alloc_one(row)
                self.host_bt[row, widx] = new
                req.pages.append(new)
                self._bt_dirty = True
                self.stats["grown_pages"] += 1
            elif self.allocator.refcount(page) > 1:
                new = self._alloc_one(row)
                self._cow_src.append(page)
                self._cow_dst.append(new)
                self.host_bt[row, widx] = new
                req.pages[req.pages.index(page)] = new
                self.allocator.free([page])    # drop our shared reference
                self._bt_dirty = True
                self.stats["cow_copies"] += 1
        if self._cow_src:
            # Pad to the fixed batch width (-1 lanes drop in copy_pages):
            # at most one COW per row per step, and a constant shape keeps
            # the whole-cache scatter compiled once instead of per count.
            pad = self.batch - len(self._cow_src)
            src = np.asarray(self._cow_src + [-1] * pad, np.int32)
            dst = np.asarray(self._cow_dst + [-1] * pad, np.int32)
            self.cache = self._copy_pages(self.cache, jnp.asarray(src),
                                          jnp.asarray(dst))
        self._cow_src = []
        self._cow_dst = []
        if self.paged:
            self._note_peak()
            self._push_tables()

    # -- decode loop --------------------------------------------------------

    def step(self) -> bool:
        """One batched decode step.  Returns False when fully drained."""
        self.admit()
        if all(r is None for r in self.rows):
            return bool(self.queue)
        if self.paged:
            self._grow_and_cow()
        self.rng, sub = jax.random.split(self.rng)
        self.token, self.cache, self.pos = self._step(
            self.params, self.cache, self.token, self.pos, sub)
        self.stats["steps"] += 1
        sampled = np.asarray(self.token)
        pos = np.array(self.pos)               # the one post-step sync
        self._host_pos = pos
        freed = False
        for row, req in enumerate(self.rows):
            if req is None:
                # Idle lanes park at pos 0: their (trash-page) writes stay
                # in slot range and their walk reads a single garbage page.
                pos[row] = 0
                continue
            req.tokens.append(int(sampled[row]))
            self.stats["gen_tokens"] += 1
            if self._done(req):
                self._free_row(row)
                freed = True
        self.pos = jnp.asarray(pos)
        if freed:
            self.admit()
        return any(r is not None for r in self.rows) or bool(self.queue)

    def run(self, requests: list[Request], max_steps: int = 100_000
            ) -> list[Request]:
        for r in requests:
            self.submit(r)
        for _ in range(max_steps):
            if not self.step():
                break
        else:
            raise RuntimeError("scheduler hit max_steps with work remaining")
        return requests

    @property
    def live_tokens(self) -> int:
        return sum(len(r.prompt) + len(r.tokens)
                   for r in self.rows if r is not None)

    def resident_cache_bytes(self) -> int:
        """Bytes of KV actually pinned right now.

        Dense: the whole [B, Hkv, S, D] allocation, always.  Paged: pages in
        use × per-page bytes — what a pool sized to the live-token watermark
        would hold.  Shared (prefix) pages count once: that is the point.
        """
        if not self.paged:
            return sum(int(x.nbytes) for x in jax.tree.leaves(self.cache))
        used = self.allocator.num_pages - self.allocator.available
        total = 0
        for _, layout, layer in cache_mod.iter_layers(self.cache):
            for name in cache_mod.pool_leaves(layer, layout):
                pool = layer[name]
                core = 4 if layout == "paged_mha" else 3
                p = pool.shape[1] if pool.ndim == core + 1 else pool.shape[0]
                total += int(pool.nbytes) * used // p
        return total


class PrefixPageMapper:
    """Shared-prefix page mapping for a fixed-row agent engine (no COW).

    The orchestrator's agents re-contextualize in place: each (re-)prefill
    remaps the row's pages, sharing the full pages of any previously
    registered identical prefix — the CodeCRDT task/TODO prompt header —
    and allocating private pages for the rest of the row's horizon.  Only
    pages strictly below the row's first decode write are shared, so no
    copy-on-write machinery is needed here.
    """

    def __init__(self, num_rows: int, maxp: int, page_size: int,
                 trash_page: int, num_pages: Optional[int] = None):
        # A row transiently holds old + new mappings during remap.
        self.allocator = PageAllocator(num_pages if num_pages is not None
                                       else (num_rows + 1) * maxp)
        if trash_page < self.allocator.num_pages:
            raise ValueError(
                f"trash_page {trash_page} lies inside the allocatable pool "
                f"[0, {self.allocator.num_pages}): decode writes of unmapped "
                "rows would corrupt live pages")
        self.prefix_cache = PrefixCache(self.allocator, page_size)
        self.page_size = page_size
        self.maxp = maxp
        self.trash_page = trash_page
        self.host_bt = np.full((num_rows, maxp), trash_page, np.int32)
        self._row_pages: list[list[int]] = [[] for _ in range(num_rows)]
        self.shared_pages = 0
        self._dirty = True                # initial table needs installing

    def map_row(self, row: int, tokens: list[int], horizon: int) -> int:
        """Remap ``row`` for a prompt of ``tokens`` and a total horizon of
        ``horizon`` positions (prompt + generation budget).  Returns the
        number of pages shared with previously mapped prompts."""
        ps = self.page_size
        npages = min(-(-horizon // ps), self.maxp)
        n_write = len(tokens) // ps       # decode writes from page n_write
        shared = self.prefix_cache.lookup(tokens, boundary=False)[:n_write]
        fresh = self.allocator.alloc(npages - len(shared))
        if fresh is None:
            raise RuntimeError("agent page pool exhausted")
        self.allocator.share(shared)
        pages = shared + fresh
        old = self._row_pages[row]
        self._row_pages[row] = pages
        self.host_bt[row, :] = self.trash_page
        self.host_bt[row, :len(pages)] = pages
        if old:
            self.allocator.free(old)      # after remap: self-prefix shares
        self.prefix_cache.register(tokens[:n_write * ps], pages[:n_write])
        self.shared_pages += len(shared)
        self._dirty = True
        return len(shared)

    def free_row(self, row: int) -> None:
        if self._row_pages[row]:
            self.allocator.free(self._row_pages[row])
            self._row_pages[row] = []
        self.host_bt[row, :] = self.trash_page
        self._dirty = True

    def install(self, cache: Params) -> Params:
        """Install the host block table into ``cache`` iff it changed since
        the last install (one jnp transfer per batch of remaps)."""
        if self._dirty:
            cache = lm.set_block_tables(cache, jnp.asarray(self.host_bt))
            self._dirty = False
        return cache
