"""Continuous batching over the paged KV cache: token-budget mixed steps,
chunk-granular page accounting, copy-on-write prefix sharing, and completion
at token granularity.

The scheduler owns a fixed decode batch of B rows backed by a shared page
pool.  Every iteration is ONE **token-budget mixed step**: it composes a
batch of per-row query spans — span 1 for rows that are decoding, span ≤
``chunk_size`` for rows whose prompt is being admitted through a per-request
*prompt cursor*, span 0 for idle rows — and lands them all in a single
jitted call (``engine.make_mixed_step_fn``).  Admission therefore never
stalls decode: while one row's prompt streams in chunk by chunk, every other
row keeps emitting a token per step.  ``token_budget`` caps the total new
tokens a step may spend (decode rows are funded first; prefill chunks take
what remains), trading time-to-first-token against inter-token latency.

Page reservation is **chunk-granular**: admission reserves only the pages
the first chunk needs (plus any prefix-shared pages, refcounted); later
chunks allocate their pages as the cursor crosses page boundaries — the same
incremental-growth walk decode rows use.  On completion the row's pages are
released immediately (memory scales with live tokens, not B × max_len).

Prefix sharing (``prefix_sharing=True``): rows admitted with an identical
prompt share the prompt's pages (refcounted, copy-on-write).  A row's writes
below its shared-prefix match (``safe_upto``) land identical bytes and need
no copy; the first divergent write into a still-shared page (the first
generated token in a shared boundary page) duplicates it copy-then-remap.

When growth finds the pool empty, the least-recently-allocating row is
preempted: pages released, request re-queued at the front with generated
tokens folded into its context (preemption by recomputation), its span this
step zeroed.

Idle rows still ride the batched mixed step (no dynamic batch shape under
jit) with span 0 — a span-0 row writes nothing, so its block table can stay
parked on the trash page indefinitely.

Dense mode (``paged=False``) runs the same composer against the classic
[B, Hkv, S, D] cache — the benchmark's apples-to-apples baseline.

``prefill_interleave=False`` is the *stalled-admission* baseline the bench
sweeps against: admission chunks run whole-prompt and decode rows get span 0
while any prompt is in flight — the old bucketed-admission behaviour,
measured by ``decode_stall_steps`` / ``stalled_lane_steps``.
"""
from __future__ import annotations

import time
from collections import OrderedDict, deque
from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import cache as cache_mod
from repro.models import lm
from repro.models.config import ModelConfig
from repro.serving import draft as draft_mod
from repro.serving import engine as engine_mod
from repro.serving.engine import PROMPT_BUCKETS, bucket_len  # noqa: F401

Params = Any

# Request lifecycle states.  QUEUED -> RUNNING -> COMPLETED is the happy
# path; PREEMPTED requests re-queue at the front and run again; SHED /
# EXPIRED / FAILED are terminal (the request never completes here — a
# failed-over request is *reconstructed* as a fresh QUEUED request by the
# survivor, see serving/replicated.py).
QUEUED = "queued"
RUNNING = "running"
PREEMPTED = "preempted"
FAILED = "failed"
SHED = "shed"
EXPIRED = "expired"
COMPLETED = "completed"


class Reservation:
    """Pages earmarked for one admission candidate (already out of the free
    list, so a later candidate's ``available`` check cannot double-count
    them).  ``take`` hands them out; ``release`` returns the rest."""

    def __init__(self, allocator: "PageAllocator", pages: list[int]):
        self._allocator = allocator
        self._pages = pages

    @property
    def count(self) -> int:
        return len(self._pages)

    def take(self, n: int | None = None) -> list[int]:
        n = len(self._pages) if n is None else n
        out, self._pages = self._pages[:n], self._pages[n:]
        return out

    def release(self) -> None:
        if self._pages:
            self._allocator.free(self._pages)
            self._pages = []


def _row_ctx(row: Optional[int]) -> str:
    """Error-message suffix naming the engine row an allocator misuse came
    from (allocators are row-agnostic; callers pass the context)."""
    return "" if row is None else f" (row {row})"


class PageAllocator:
    """Host-side refcounted page pool (unit = one page).

    Pages are handed out at refcount 1; ``share`` adds a reference (prefix
    sharing), ``free`` drops one and returns the page to the free list at
    zero.  ``generation`` bumps on every fresh hand-out so stale prefix
    entries can detect reuse.  ``reserve`` is the admission-safe path: it
    removes pages from the free list immediately, so a two-phase admit
    cannot admit two requests against the same availability snapshot (the
    double-admission race).
    """

    def __init__(self, num_pages: int):
        self.num_pages = num_pages
        self._free = list(range(num_pages - 1, -1, -1))
        self._ref = np.zeros(num_pages, np.int32)
        self._gen = np.zeros(num_pages, np.int64)

    @property
    def available(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> Optional[list[int]]:
        if n <= 0:
            return []                 # [:-0] would hand out the whole list
        if n > len(self._free):
            return None
        pages, self._free = self._free[-n:][::-1], self._free[:-n]
        for p in pages:
            self._ref[p] = 1
            self._gen[p] += 1
        return pages

    def reserve(self, n: int) -> Optional[Reservation]:
        pages = self.alloc(n)
        if pages is None:
            return None
        return Reservation(self, pages)

    def share(self, pages: list[int], row: Optional[int] = None) -> None:
        for p in pages:
            if self._ref[p] <= 0:
                raise ValueError(
                    f"cannot share unallocated page {p}{_row_ctx(row)} "
                    f"(refcount {int(self._ref[p])})")
            self._ref[p] += 1

    def refcount(self, page: int) -> int:
        return int(self._ref[page])

    def generation(self, page: int) -> int:
        return int(self._gen[page])

    def free(self, pages: list[int], row: Optional[int] = None) -> None:
        for p in reversed(pages):
            if self._ref[p] <= 0:
                raise ValueError(
                    f"double free of page {p}{_row_ctx(row)} "
                    f"(refcount {int(self._ref[p])})")
            self._ref[p] -= 1
            if self._ref[p] == 0:
                self._free.append(p)


class PrefixCache:
    """Longest-prefix index from prompt tokens to resident pages.

    Full pages chain through keys ``tuple(tokens[:k*ps])`` (page k-1 holds
    positions [(k-1)·ps, k·ps) and its KV depends on the whole prefix, so
    the key must be the whole prefix); the partial boundary page is indexed
    by the exact full prompt.  Entries carry (page, generation) and are
    pruned lazily when the page was freed or re-allocated.

    Stale entries for *distinct* prompts never collide with a later key, so
    lazy pruning alone would grow the index without bound (each registered
    prompt holds O(len²) ints of key material).  Both maps are therefore
    LRU-bounded at ``max_entries``: hits refresh recency, inserts past the
    cap evict the coldest key.  Eviction only forgets a sharing opportunity
    — resident pages stay owned by their rows/refcounts.
    """

    def __init__(self, allocator: PageAllocator, page_size: int,
                 max_entries: int = 4096):
        self._allocator = allocator
        self.page_size = page_size
        self.max_entries = max_entries
        self._chain: OrderedDict[tuple, tuple[int, int]] = OrderedDict()
        self._boundary: OrderedDict[tuple, tuple[int, int]] = OrderedDict()

    def _valid(self, entry: tuple[int, int] | None) -> Optional[int]:
        if entry is None:
            return None
        page, gen = entry
        if (self._allocator.refcount(page) > 0
                and self._allocator.generation(page) == gen):
            return page
        return None

    def _get(self, table: "OrderedDict[tuple, tuple[int, int]]", key: tuple
             ) -> Optional[int]:
        """Validated lookup: refreshes recency on hit, prunes on miss."""
        page = self._valid(table.get(key))
        if page is None:
            table.pop(key, None)
            return None
        table.move_to_end(key)
        return page

    def _put(self, table: "OrderedDict[tuple, tuple[int, int]]", key: tuple,
             page: int) -> None:
        table[key] = (page, self._allocator.generation(page))
        table.move_to_end(key)
        while len(table) > self.max_entries:
            table.popitem(last=False)

    def lookup(self, tokens: list[int], *, boundary: bool = True
               ) -> list[int]:
        """Longest shareable run of pages for ``tokens`` (prefix order)."""
        ps = self.page_size
        n_full = len(tokens) // ps
        pages: list[int] = []
        for k in range(1, n_full + 1):
            page = self._get(self._chain, tuple(tokens[:k * ps]))
            if page is None:
                break
            pages.append(page)
        if (boundary and len(pages) == n_full and len(tokens) % ps):
            page = self._get(self._boundary, tuple(tokens))
            if page is not None:
                pages.append(page)
        return pages

    def lookup_page(self, tokens: list[int], widx: int) -> Optional[int]:
        """Resident page for context page ``widx`` of ``tokens`` (exact
        prefix key), or None — O(prefix), for the growth-time re-share."""
        ps = self.page_size
        if (widx + 1) * ps <= len(tokens):
            return self._get(self._chain, tuple(tokens[:(widx + 1) * ps]))
        if len(tokens) % ps and widx == len(tokens) // ps:
            return self._get(self._boundary, tuple(tokens))
        return None

    def register(self, tokens: list[int], pages: list[int]) -> None:
        """Index a row's (so far) prefilled prompt pages — safe to call
        again as chunked admission maps more of the prompt."""
        ps = self.page_size
        n_full = len(tokens) // ps
        for k in range(1, min(n_full, len(pages)) + 1):
            key = tuple(tokens[:k * ps])
            if self._get(self._chain, key) is None:
                self._put(self._chain, key, pages[k - 1])
        npages = -(-len(tokens) // ps)
        if len(tokens) % ps and len(pages) >= npages:
            key = tuple(tokens)
            if self._get(self._boundary, key) is None:
                self._put(self._boundary, key, pages[npages - 1])

    def register_tail(self, tokens: list[int], pages: list[int]) -> None:
        """Index only the LAST page in ``pages`` (the page growth just
        mapped) — O(prefix) key material instead of re-keying every
        earlier page on every growth step."""
        ps = self.page_size
        k = len(pages)                    # pages cover prefix pages [0, k)
        if k == 0:
            return
        if k * ps <= len(tokens):         # page k-1 is full
            key = tuple(tokens[:k * ps])
            if self._get(self._chain, key) is None:
                self._put(self._chain, key, pages[k - 1])
        elif len(tokens) % ps and k == -(-len(tokens) // ps):
            key = tuple(tokens)           # the partial boundary page
            if self._get(self._boundary, key) is None:
                self._put(self._boundary, key, pages[k - 1])


@dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int
    eos_id: Optional[int] = None
    tokens: list[int] = field(default_factory=list)   # generated output
    admitted_step: int = -1
    first_token_step: int = -1        # step that emitted the first token
    finished_step: int = -1
    pages: list[int] = field(default_factory=list)
    filled: int = 0                   # prompt cursor: context tokens cached
    admit_len: int = 0                # admission target: len(context) at bind
    safe_upto: int = 0                # writes below this match shared bytes
    # -- lifecycle / SLO ----------------------------------------------------
    status: str = QUEUED
    priority: int = 0                 # higher = shed later, admitted earlier
    ttft_deadline: Optional[int] = None   # steps from submit to first token
    deadline: Optional[int] = None        # steps from submit to completion
    submitted_step: int = -1
    retries: int = 0                  # failover re-admissions so far
    max_retries: int = 2
    retry_at: int = 0                 # earliest step admission may bind this
    # -- tiered page memory -------------------------------------------------
    swap_slots: list[int] = field(default_factory=list)  # held host slots
    swap_tokens: int = 0              # context tokens the swapped pages cover

    @property
    def context(self) -> list[int]:
        """Tokens the next admission must cover (prompt + generated so far —
        nonempty generated means the request was preempted and resumed)."""
        return self.prompt + self.tokens

    @property
    def admitting(self) -> bool:
        """Still streaming its admission context in (vs decoding)."""
        return self.filled < self.admit_len

    @property
    def terminal(self) -> bool:
        return self.status in (COMPLETED, SHED, EXPIRED, FAILED)


class ContinuousBatchingEngine:
    """Token-granularity continuous batching over a (paged) decode engine."""

    def __init__(self, cfg: ModelConfig, params: Params, *, batch: int,
                 max_len: int, paged: bool = True, page_size: int = 64,
                 num_pages: Optional[int] = None, impl: str = "ref",
                 temperature: float = 0.0, seed: int = 0,
                 prefix_sharing: bool = False, chunk_size: int = 32,
                 token_budget: Optional[int] = None,
                 prefill_interleave: bool = True,
                 allocator: Optional[Any] = None,
                 prefix_cache: Optional[Any] = None,
                 max_queue: Optional[int] = None,
                 journal: Optional[Any] = None,
                 spec_decode: str = "off", spec_k: int = 4,
                 drafter: Optional[Any] = None,
                 kv_quant: str = "off", swap_tier_pages: int = 0,
                 swap_min_tokens: Optional[int] = None,
                 role: str = "mixed"):
        self.cfg = cfg
        self.params = params
        self.batch = batch
        self.max_len = max_len
        self.paged = paged
        self.page_size = page_size
        if role not in ("prefill", "decode", "mixed"):
            raise ValueError(f"role must be prefill/decode/mixed, "
                             f"got {role!r}")
        # Disaggregation hint: "prefill" replicas take cold prompts and
        # publish filled pages; "decode"/"mixed" replicas may install an
        # ``adopt_hook`` (server-side) that pulls published physical pages
        # into this engine's pool at admission, skipping the covered
        # prefill.  The hook is ``(rid, ctx, shared) -> (lead_pages,
        # adopted_pages, covered_tokens)``: the row's full leading page
        # chain (every page already ref-held by the hook), the subset that
        # was physically transferred, and the cached leading positions.
        self.role = role
        self.adopt_hook = None
        self.temperature = temperature
        self.prefix_sharing = prefix_sharing and paged
        self.chunk_size = max(1, min(chunk_size, max_len))
        self.token_budget = (max(1, token_budget)
                             if token_budget is not None else None)
        self.prefill_interleave = prefill_interleave
        self.maxp = -(-max_len // page_size)
        if kv_quant not in cache_mod.KV_QUANT_MODES:
            raise ValueError(f"kv_quant must be one of "
                             f"{cache_mod.KV_QUANT_MODES}, got {kv_quant!r}")
        if kv_quant != "off" and not paged:
            raise ValueError("kv_quant requires paged=True (quantized "
                             "layouts are page-pool layouts)")
        self.kv_quant = kv_quant
        if paged:
            # Injectable backends: a replicated allocator / prefix cache
            # (serving/replicated.py) swaps in for the host-local ones as
            # long as it speaks the same API; the engine sizes its physical
            # pool to the allocator's full page space either way.
            if allocator is not None:
                num_pages = allocator.num_pages
            elif num_pages is None:
                num_pages = batch * self.maxp
            self.allocator = (PageAllocator(num_pages) if allocator is None
                              else allocator)
            self.prefix_cache = (PrefixCache(self.allocator, page_size)
                                 if prefix_cache is None else prefix_cache)
            self.trash_page = num_pages          # extra physical page
            self.cache = lm.init_cache(cfg, batch, max_len, paged=True,
                                       page_size=page_size,
                                       num_pages=num_pages + 1,
                                       kv_quant=kv_quant)
            self.host_bt = np.full((batch, self.maxp), self.trash_page,
                                   np.int32)
            self.cache = lm.set_block_tables(self.cache,
                                             jnp.asarray(self.host_bt))
            self._copy_pages = jax.jit(lm.copy_pages, donate_argnums=(0,))
        else:
            self.allocator = None
            self.prefix_cache = None
            self.cache = lm.init_cache(cfg, batch, max_len)
        self._mixed = jax.jit(
            engine_mod.make_mixed_step_fn(cfg, impl=impl,
                                          temperature=temperature),
            donate_argnums=(1,))
        self._has_state = any(
            cache_mod.layout_for(k, cfg, paged=False) == "state"
            for k in tuple(cfg.block_pattern) + tuple(cfg.tail_blocks))
        if self._has_state:
            self._reset_state = jax.jit(
                lambda c, m: lm.reset_state_rows(cfg, c, m),
                donate_argnums=(0,))
        # Tiered page memory: a host-buffer swap pool of ``swap_tier_pages``
        # slots.  Preemption victims with enough cached context swap their
        # pages out instead of recomputing; re-admission swaps them back in
        # bit-exactly and resumes from the saved cursor.  Recurrent (state)
        # architectures always recompute — swap restores pages, not carries.
        self.swap_tier_pages = int(swap_tier_pages)
        if paged and self.swap_tier_pages > 0 and not self._has_state:
            self.swap_pool = cache_mod.make_swap_pool(self.cache,
                                                      self.swap_tier_pages)
            self._swap_free = list(range(self.swap_tier_pages))
        else:
            self.swap_pool = None
            self._swap_free = []
        # Swap-vs-recompute break-even: a victim below this many cached
        # tokens is cheaper to re-prefill (recompute cost scales with
        # context; swap cost is fixed per page).
        self.swap_min_tokens = (2 * page_size if swap_min_tokens is None
                                else int(swap_min_tokens))
        if spec_decode not in ("off", "ngram", "doc"):
            raise ValueError(f"spec_decode must be off/ngram/doc, got "
                             f"{spec_decode!r}")
        if spec_decode != "off" and temperature > 0.0:
            raise ValueError(
                "speculative decoding requires greedy decoding "
                "(temperature 0): acceptance compares argmax streams")
        self.spec_decode = spec_decode
        self.spec_k = max(1, int(spec_k))
        self.drafter = None
        if spec_decode != "off":
            self.drafter = (drafter if drafter is not None
                            else draft_mod.make_drafter(spec_decode))
            # Verify serves EVERY lane when speculation is on (non-drafting
            # rows read preds at their last span position), so only one
            # compiled step runs per width either way.
            self._verify = jax.jit(
                engine_mod.make_verify_step_fn(cfg, impl=impl),
                donate_argnums=(1,))
            has_state = self._has_state

            def snap_fn(cache, start, width):
                out = {"spans": cache_mod.snapshot_span(cache, start, width)}
                if has_state:
                    out["state"] = lm.snapshot_state_rows(cfg, cache)
                return out

            def restore_fn(cache, snap, start, lo, hi, smask):
                cache = cache_mod.restore_span(cache, snap["spans"], start,
                                               lo, hi)
                if has_state:
                    cache = lm.restore_state_rows(cfg, cache, snap["state"],
                                                  smask)
                return cache

            # Snapshot is jitted WITHOUT donation: its outputs are fresh
            # buffers that survive the verify call donating the live cache.
            self._snap = jax.jit(snap_fn, static_argnums=(2,))
            self._restore = jax.jit(restore_fn, donate_argnums=(0,))
        self.rng = jax.random.PRNGKey(seed)
        # Positions are host-owned: the mixed step takes (start, span) as
        # inputs and never returns pos, so there is no per-step host→device
        # pos upload to skip NOR a post-step pos sync — the old scheduler
        # paid both.  The one remaining sync is reading the sampled tokens.
        self.row_pos = np.zeros((batch,), np.int64)   # tokens cached per row
        self.token = np.zeros((batch,), np.int64)     # last sampled token
        self.rows: list[Optional[Request]] = [None] * batch
        self.queue: deque[Request] = deque()
        self._bt_dirty = False
        self._last_alloc = [0] * batch        # LRU clock for preemption
        self._cow_src: list[int] = []         # COW pairs pending this step
        self._cow_dst: list[int] = []
        self._dev_memo: dict[str, tuple[np.ndarray, jax.Array]] = {}
        self.max_queue = max_queue
        self._journal = journal           # callable(kind, req) or None
        self.stats = {"steps": 0, "prefills": 0, "prefill_chunks": 0,
                      "admitted": 0, "completed": 0, "peak_pages": 0,
                      "gen_tokens": 0, "prefill_tokens": 0,
                      "shared_pages": 0, "cow_copies": 0, "preemptions": 0,
                      "grown_pages": 0, "admit_s": 0.0,
                      "decode_stall_steps": 0, "stalled_lane_steps": 0,
                      # Fault-tolerance accounting: totals plus per-cause
                      # counters (the satellite: causes are distinct).
                      "shed": 0, "shed_queue_full": 0, "shed_capacity": 0,
                      "expired": 0, "expired_ttft": 0, "expired_deadline": 0,
                      "expired_queued": 0, "retried": 0,
                      "preempt_for_pages": 0, "preempt_fenced": 0,
                      # Speculative decoding: drafts proposed, drafts
                      # accepted, cache writes rolled back, steps that
                      # carried >= 1 draft, steps that rolled anything back.
                      "draft_tokens": 0, "accepted_tokens": 0,
                      "rollback_tokens": 0, "spec_steps": 0,
                      "spec_rollbacks": 0,
                      # Tiered page memory: pages moved across tiers plus
                      # how each preemption resolved (swap vs recompute).
                      "swap_outs": 0, "swap_ins": 0,
                      "preempt_swap": 0, "preempt_recompute": 0,
                      # Disaggregation: physical pages adopted from peer
                      # pools, prompt tokens those pages covered, and
                      # prefill chunk steps that adoption skipped.
                      "adopted_pages": 0, "adopted_tokens": 0,
                      "prefill_steps_avoided": 0}

    # -- request lifecycle --------------------------------------------------

    def submit(self, req: Request) -> None:
        if req.max_new_tokens < 1:
            raise ValueError(f"request {req.rid}: max_new_tokens must be "
                             ">= 1 (admission always yields one token)")
        if not req.prompt:
            raise ValueError(f"request {req.rid}: empty prompt")
        if len(req.prompt) + req.max_new_tokens > self.max_len:
            raise ValueError(f"request {req.rid} needs "
                             f"{len(req.prompt) + req.max_new_tokens} slots "
                             f"> max_len {self.max_len}")
        if self.paged:
            worst = -(-(len(req.prompt) + req.max_new_tokens)
                      // self.page_size)
            if worst > self.allocator.num_pages:
                raise ValueError(f"request {req.rid} needs {worst} pages "
                                 f"> pool {self.allocator.num_pages}")
        req.status = QUEUED
        req.submitted_step = self.stats["steps"]
        if self.max_queue is not None and len(self.queue) >= self.max_queue:
            # Bounded admission queue: shed the lowest-priority request
            # (ties broken toward the youngest) among the queue plus the
            # newcomer — backpressure never evicts higher-priority work.
            idx = min(range(len(self.queue)),
                      key=lambda i: (self.queue[i].priority, -i))
            victim = self.queue[idx]
            if req.priority <= victim.priority:
                self._shed(req, "shed_queue_full")
                return
            del self.queue[idx]
            self._shed(victim, "shed_queue_full")
        self.queue.append(req)

    def _drop_swap(self, req: Request) -> None:
        """Return a terminal request's held swap slots to the free list —
        its saved pages will never be swapped back in."""
        if req.swap_slots:
            self._swap_free.extend(req.swap_slots)
            req.swap_slots = []
            req.swap_tokens = 0

    def _shed(self, req: Request, cause: str) -> None:
        req.status = SHED
        self._drop_swap(req)
        req.finished_step = self.stats["steps"]
        self.stats["shed"] += 1
        self.stats[cause] += 1
        if self._journal is not None:
            self._journal("shed", req)

    def _expire(self, req: Request, cause: str) -> None:
        req.status = EXPIRED
        self._drop_swap(req)
        req.finished_step = self.stats["steps"]
        self.stats["expired"] += 1
        self.stats[cause] += 1
        if self._journal is not None:
            self._journal("expired", req)

    def _check_deadlines(self) -> None:
        """Drop queued and running requests whose TTFT / end-to-end deadline
        (in engine steps since submission) can no longer be met."""
        now = self.stats["steps"]

        def _late(req: Request) -> Optional[str]:
            if req.submitted_step < 0:
                return None
            age = now - req.submitted_step
            if (req.ttft_deadline is not None and req.first_token_step < 0
                    and age >= req.ttft_deadline):
                return "expired_ttft"
            if req.deadline is not None and age >= req.deadline:
                return "expired_deadline"
            return None

        if self.queue and any(_late(q) for q in self.queue):
            keep: deque[Request] = deque()
            for q in self.queue:
                if _late(q) is None:
                    keep.append(q)
                else:
                    self._expire(q, "expired_queued")
            self.queue = keep
        for row in range(self.batch):
            req = self.rows[row]
            if req is None:
                continue
            cause = _late(req)
            if cause is not None:
                self._release_row(row)
                self.rows[row] = None
                self.row_pos[row] = 0
                self._expire(req, cause)

    def _shed_on_capacity_loss(self) -> None:
        """Graceful degradation: a halted replica (retired by the majority)
        can never admit again — shed its queue, lowest priority first, so
        callers see SHED now instead of requests pinned forever."""
        if not self.queue or not getattr(self.allocator, "halted", False):
            return
        for q in sorted(self.queue, key=lambda q: (q.priority,
                                                   q.submitted_step)):
            self._shed(q, "shed_capacity")
        self.queue.clear()

    def _note_peak(self) -> None:
        used = self.allocator.num_pages - self.allocator.available
        self.stats["peak_pages"] = max(self.stats["peak_pages"], used)

    def _free_row(self, row: int) -> None:
        req = self.rows[row]
        req.finished_step = self.stats["steps"]
        req.status = COMPLETED
        self.stats["completed"] += 1
        if self._journal is not None:
            self._journal("done", req)
        self._release_row(row)
        self.rows[row] = None
        self.row_pos[row] = 0

    def _release_row(self, row: int) -> None:
        req = self.rows[row]
        if self.paged:
            # req.pages is kept (now historical) — the allocator owns reuse,
            # and a preempted request's re-admission overwrites the list.
            self.allocator.free(req.pages, row=row)
            self.host_bt[row, :] = self.trash_page
            self._bt_dirty = True

    def _push_tables(self) -> None:
        if self._bt_dirty:
            self.cache = lm.set_block_tables(self.cache,
                                             jnp.asarray(self.host_bt))
            self._bt_dirty = False

    def _chunk_pages(self, n_tokens: int) -> int:
        """Pages covering context positions [0, n_tokens)."""
        return -(-n_tokens // self.page_size)

    def _mark_filled(self, req: Request, upto: Optional[int] = None) -> None:
        """Tell a filled-page-tracking prefix cache (replicated serving)
        which of this row's pages physically hold their bytes.  Pages are
        *published* at reservation time, before the chunk writes land, so
        physical adoption gates on this — the host-local ``PrefixCache``
        has no ``mark_filled`` and metadata-only sharing skips the call."""
        if self.prefix_cache is None:
            return
        mf = getattr(self.prefix_cache, "mark_filled", None)
        if mf is None or not req.pages:
            return
        n = int(req.filled if upto is None else upto)
        pages = req.pages[:n // self.page_size]
        if pages:
            mf(pages)

    def admit(self) -> int:
        """Bind queued requests to free rows (chunk-granular reservation).

        Two-phase: pages for each candidate's FIRST chunk are *reserved*
        (reservation removes them from the free list, so candidates later in
        the loop see the true availability — no double admission); later
        chunks and generation pages allocate incrementally as the prompt
        cursor advances.  No prefill happens here — the next mixed steps
        stream the prompt in.  Head-of-line blocking on page budget is
        deliberate: FIFO completion-time fairness.

        Candidate order is priority-first (FIFO within a priority class);
        a request in retry backoff (``retry_at`` in the future) is skipped
        without blocking the requests behind it.
        """
        t0 = time.perf_counter()
        admitted = 0
        reset_rows: list[int] = []
        now = self.stats["steps"]
        for row in range(self.batch):
            if self.rows[row] is not None or not self.queue:
                continue
            cand = None
            for i, q in enumerate(self.queue):
                if q.retry_at > now:
                    continue                   # backing off: not eligible yet
                if cand is None or q.priority > self.queue[cand].priority:
                    cand = i
            if cand is None:
                break                          # every queued request backs off
            req = self.queue[cand]
            ctx = req.context
            swapped = bool(req.swap_slots)
            covered = 0
            if self.paged and swapped:
                # Swapped-out victim: pull its saved pages back from the
                # host tier into fresh device pages and resume from the
                # saved cursor — no recompute chunks for the covered prefix.
                res = self.allocator.reserve(len(req.swap_slots))
                if res is None:
                    break                      # wait for completions
                pages = res.take()
                self.cache = cache_mod.swap_in_pages(
                    self.cache, self.swap_pool, req.swap_slots, pages)
                self._swap_free.extend(req.swap_slots)
                self.stats["swap_ins"] += len(pages)
                req.pages = pages
                req.safe_upto = 0
                self.host_bt[row, :] = self.trash_page
                self.host_bt[row, :len(pages)] = pages
                self._bt_dirty = True
                self._last_alloc[row] = self.stats["steps"]
            elif self.paged:
                first = min(self.chunk_size, len(ctx)) \
                    if self.prefill_interleave else len(ctx)
                npages_ctx = self._chunk_pages(len(ctx))
                shared: list[int] = []
                if self.prefix_sharing:
                    shared = self.prefix_cache.lookup(ctx)[:npages_ctx]
                need = max(0, self._chunk_pages(first) - len(shared))
                res = self.allocator.reserve(need)
                if res is None:
                    break                      # wait for completions
                lead = shared
                if self.prefix_sharing and self.adopt_hook is not None:
                    # Disaggregated adoption: the hook walks the prompt's
                    # page chain, keeping filled locally shared pages and
                    # pulling published peer pages (physical transfer +
                    # rule-3 commit) where the local copy is missing or
                    # unwritten.  It returns the row's full leading chain
                    # with every page already ref-held, so the plain
                    # ``share(shared)`` below is skipped.  ``covered``
                    # prompt positions are then already cached, so
                    # admission streams only the tail, exactly like
                    # swap-in.  Runs after ``reserve`` so a page-budget
                    # miss never strands a committed transfer.
                    lead, adopted, covered = self.adopt_hook(
                        req.rid, ctx, shared)
                    covered = max(0, min(covered, len(ctx) - 1))
                    self.stats["shared_pages"] += len(lead) - len(adopted)
                    if adopted:
                        self.stats["adopted_pages"] += len(adopted)
                    if covered:
                        self.stats["adopted_tokens"] += covered
                        full = -(-len(ctx) // self.chunk_size)
                        rest = -(-(len(ctx) - covered) // self.chunk_size)
                        self.stats["prefill_steps_avoided"] += full - rest
                elif shared:
                    self.allocator.share(shared, row=row)
                    self.stats["shared_pages"] += len(shared)
                req.pages = lead + res.take()
                req.safe_upto = min(len(lead) * self.page_size, len(ctx))
                self.host_bt[row, :] = self.trash_page
                self.host_bt[row, :len(req.pages)] = req.pages
                self._bt_dirty = True
                self._last_alloc[row] = self.stats["steps"]
                if self.prefix_sharing and not req.tokens:
                    # Register at reservation time: fan-out clones admitted
                    # while this prompt is still streaming in share these
                    # pages, and the chunked writes land the identical
                    # prompt KV once per slot.  The registrant's own prompt
                    # writes are identical-by-construction as well (sharers
                    # match on exact tokens), so its safe region is the
                    # whole prompt — only generated-token writes diverge.
                    self.prefix_cache.register(req.prompt, req.pages)
                    req.safe_upto = max(req.safe_upto, len(req.prompt))
            del self.queue[cand]
            self.rows[row] = req
            if req.retries and req.status == QUEUED:
                self.stats["retried"] += 1    # a backoff re-admission bound
            req.status = RUNNING
            req.filled = 0
            req.admit_len = len(ctx)
            req.admitted_step = self.stats["steps"]
            self.row_pos[row] = 0
            if swapped:
                # The swapped-in pages already hold positions
                # [0, swap_tokens): admission streams only the tail.
                req.filled = req.swap_tokens
                self.row_pos[row] = req.swap_tokens
                req.swap_slots = []
                req.swap_tokens = 0
                self._mark_filled(req)
            elif covered:
                # Adopted/filled shared pages already hold positions
                # [0, covered): same tail-only admission as swap-in.
                req.filled = covered
                self.row_pos[row] = covered
            reset_rows.append(row)
            admitted += 1
        if admitted:
            if self._has_state:
                # A freed row's recurrent state must not leak into the next
                # request: blend fresh init into the admitted rows.
                mask = np.zeros((self.batch,), bool)
                mask[reset_rows] = True
                self.cache = self._reset_state(self.cache, jnp.asarray(mask))
            self.stats["admitted"] += admitted
            if self.paged:
                self._note_peak()
        self.stats["admit_s"] += time.perf_counter() - t0
        return admitted

    def _done(self, req: Request) -> bool:
        return (len(req.tokens) >= req.max_new_tokens
                or (req.eos_id is not None
                    and req.tokens
                    and req.tokens[-1] == req.eos_id))

    # -- incremental growth / COW / preemption ------------------------------

    def _try_swap_out(self, victim: int) -> bool:
        """Swap ``victim``'s cached pages to the host tier if the context is
        long enough to beat recomputation.  Eligible when: swap tier exists,
        the cached context clears the break-even (``swap_min_tokens`` —
        recompute cost grows with context, swap cost is fixed per page),
        every covering page is privately owned (a shared prefix page stays
        resident for re-share — recompute is nearly free there anyway), and
        host slots are available.  Returns True with the request's
        ``swap_slots``/``swap_tokens`` recording the saved state."""
        if self.swap_pool is None:
            return False
        req = self.rows[victim]
        n_tokens = int(self.row_pos[victim])
        if n_tokens < self.swap_min_tokens:
            return False
        npages = self._chunk_pages(n_tokens)
        if npages > len(self._swap_free):
            return False
        pages = [int(self.host_bt[victim, w]) for w in range(npages)]
        if any(p == self.trash_page or self.allocator.refcount(p) != 1
               for p in pages):
            return False
        slots = [self._swap_free.pop() for _ in range(npages)]
        cache_mod.swap_out_pages(self.cache, self.swap_pool, pages, slots)
        req.swap_slots = slots
        req.swap_tokens = n_tokens
        self.stats["swap_outs"] += npages
        return True

    def _evict_row(self, victim: int, spans: np.ndarray, cause: str) -> None:
        """Release ``victim``'s pages and re-queue it at the front; a
        long-context victim swaps its pages to the host tier first
        (preemption by swap), the rest recompute on re-admission.
        Per-cause counters stay distinct."""
        req = self.rows[victim]
        if self._try_swap_out(victim):
            self.stats["preempt_swap"] += 1
        else:
            self.stats["preempt_recompute"] += 1
        # A COW copy queued this step whose destination dies with the victim
        # must be dropped: the freed page can be re-handed out in this same
        # pass, and a duplicate destination in one batched scatter would
        # write undefined contents into a live row's page.
        dead = set(req.pages)
        keep = [(s, d) for s, d in zip(self._cow_src, self._cow_dst)
                if d not in dead]
        self._cow_src = [s for s, _ in keep]
        self._cow_dst = [d for _, d in keep]
        self._release_row(victim)
        self.rows[victim] = None
        req.status = PREEMPTED
        self.queue.appendleft(req)             # resumes with context intact
        self.row_pos[victim] = 0
        spans[victim] = 0                      # no span for the evicted row
        self.stats["preemptions"] += 1
        self.stats[cause] += 1

    def _preempt_for_pages(self, needy_row: int, spans: np.ndarray) -> bool:
        """Evict the least-recently-allocating other row (recomputation)."""
        victims = [r for r in range(self.batch)
                   if r != needy_row and self.rows[r] is not None]
        if not victims:
            return False
        victim = min(victims, key=lambda r: (self._last_alloc[r], r))
        self._evict_row(victim, spans, "preempt_for_pages")
        return True

    def _alloc_blocked(self) -> bool:
        """True while the allocator refuses ALL allocation for reasons no
        preemption can fix: a replicated allocator that is fenced (a peer is
        unheard) or halted (retired by the majority).  Preempting victims
        then would shed work without freeing anything usable."""
        a = self.allocator
        if getattr(a, "halted", False):
            return True
        fenced = getattr(a, "fenced", None)
        return bool(fenced is not None and fenced(getattr(a, "now", 0)))

    def _alloc_one(self, row: int, spans: np.ndarray) -> int:
        """One page for ``row``, preempting other rows if needed.  Returns
        -1 when allocation is fenced/halted shut: the needy row itself is
        preempted (it resumes once the allocator unblocks)."""
        while True:
            pages = self.allocator.alloc(1)
            if pages is not None:
                self._last_alloc[row] = self.stats["steps"]
                return pages[0]
            if self._alloc_blocked():
                self._evict_row(row, spans, "preempt_fenced")
                return -1
            if not self._preempt_for_pages(row, spans):
                raise RuntimeError(
                    f"page pool exhausted ({self.allocator.num_pages} pages)"
                    " with no preemptable row — pool too small for one "
                    "request")

    def _ensure_pages(self, spans: np.ndarray) -> None:
        """Before the mixed step: every row must own, privately, each page
        its span will write.  Crossing into an unallocated page allocates
        one (chunk-granular growth); a page shared with other rows or the
        prefix cache is duplicated and remapped (copy-on-write) — unless
        every position written into it lies below the row's shared-prefix
        match (``safe_upto``), where the bytes are identical by
        construction and a copy would only waste a page."""
        self._cow_src = []
        self._cow_dst = []
        for row in range(self.batch):
            req = self.rows[row]
            if req is None or spans[row] == 0:
                continue
            w0 = int(self.row_pos[row])
            w1 = w0 + int(spans[row])          # writes cover [w0, w1)
            for widx in range(w0 // self.page_size,
                              (w1 - 1) // self.page_size + 1):
                if widx >= self.maxp:
                    continue                   # clamped write; cannot grow
                if self.rows[row] is not req:
                    break                      # row was preempted mid-walk
                page = int(self.host_bt[row, widx])
                lo = max(w0, widx * self.page_size)
                hi = min(w1, (widx + 1) * self.page_size)
                if page == self.trash_page:
                    if self.prefix_sharing and req.admitting:
                        # Growth-time re-share: a later chunk whose page is
                        # already resident for the identical context prefix
                        # (a concurrent clone, or a survivor of the same
                        # fan-out) aliases it instead of allocating — the
                        # writes it would land there are identical bytes.
                        pg = self.prefix_cache.lookup_page(req.context,
                                                           widx)
                        if pg is not None:
                            self.allocator.share([pg], row=row)
                            self.host_bt[row, widx] = pg
                            req.pages.append(pg)
                            self._bt_dirty = True
                            self.stats["shared_pages"] += 1
                            req.safe_upto = max(
                                req.safe_upto,
                                min((widx + 1) * self.page_size,
                                    len(req.context)))
                            continue
                    new = self._alloc_one(row, spans)
                    if new < 0:
                        break              # fenced: the row self-preempted
                    if self.rows[row] is not req:
                        self.allocator.free([new])
                        break
                    self.host_bt[row, widx] = new
                    req.pages.append(new)
                    self._bt_dirty = True
                    self.stats["grown_pages"] += 1
                    if (self.prefix_sharing and req.admitting
                            and not req.tokens):
                        # Index the freshly grown prompt page immediately so
                        # clones growing later in this same pass share it.
                        self.prefix_cache.register_tail(req.prompt,
                                                        req.pages)
                elif (self.allocator.refcount(page) > 1
                        and max(lo, req.safe_upto) < hi):
                    new = self._alloc_one(row, spans)
                    if new < 0:
                        break              # fenced: the row self-preempted
                    if self.rows[row] is not req:
                        self.allocator.free([new])
                        break
                    self._cow_src.append(page)
                    self._cow_dst.append(new)
                    self.host_bt[row, widx] = new
                    req.pages[req.pages.index(page)] = new
                    self.allocator.free([page], row=row)  # drop shared ref
                    self._bt_dirty = True
                    self.stats["cow_copies"] += 1
        if self._cow_src:
            # Pad to the fixed batch width (-1 lanes drop in copy_pages):
            # at most one COW per row per step, and a constant shape keeps
            # the whole-cache scatter compiled once instead of per count.
            pad = max(0, self.batch - len(self._cow_src))
            src = np.asarray(self._cow_src + [-1] * pad, np.int32)
            dst = np.asarray(self._cow_dst + [-1] * pad, np.int32)
            self.cache = self._copy_pages(self.cache, jnp.asarray(src),
                                          jnp.asarray(dst))
        self._cow_src = []
        self._cow_dst = []
        self._note_peak()
        self._push_tables()

    # -- token-budget composer + mixed step ---------------------------------

    def _compose(self) -> np.ndarray:
        """Per-row spans for this step: decode rows are funded first (one
        token each), then prompt chunks split the remaining budget.  Under
        a constraining budget, funding order rotates with the step counter
        so no fixed row index is starved indefinitely."""
        spans = np.zeros((self.batch,), np.int64)
        rot = self.stats["steps"] % self.batch
        order = sorted(range(self.batch),
                       key=lambda r: (r - rot) % self.batch)
        decoding = [r for r in order
                    if self.rows[r] is not None
                    and not self.rows[r].admitting]
        admitting = [r for r in order
                     if self.rows[r] is not None and self.rows[r].admitting]
        budget = self.token_budget if self.token_budget is not None \
            else self.batch * self.chunk_size
        if admitting and not self.prefill_interleave:
            # Stalled-admission baseline: prompts land whole, decode lanes
            # idle while any admission is in flight (the pre-mixed-step
            # behaviour the bench quantifies).
            if decoding:
                self.stats["decode_stall_steps"] += 1
                self.stats["stalled_lane_steps"] += len(decoding)
            for r in admitting:
                req = self.rows[r]
                spans[r] = req.admit_len - req.filled
            return spans
        starved = 0
        for r in decoding:
            if budget <= 0:
                starved += 1
                continue
            spans[r] = 1
            budget -= 1
        if starved:
            # Same unit as the stalled baseline: a step counts once however
            # many lanes it starves; the lane total rides the second counter.
            self.stats["decode_stall_steps"] += 1
            self.stats["stalled_lane_steps"] += starved
        for r in admitting:
            if budget <= 0:
                break
            req = self.rows[r]
            take = min(self.chunk_size, req.admit_len - req.filled, budget)
            spans[r] = take
            budget -= take
        return spans

    def _fund_drafts(self, spans: np.ndarray) -> dict[int, list[int]]:
        """Widen decode rows with drafter proposals from whatever token
        budget decode + admission left over — drafts are funded LAST, so
        speculation never displaces guaranteed work.  Mutates ``spans``
        (row span 1 -> 1 + len(draft)) and returns {row: draft tokens}.

        The per-row cap keeps every invariant the non-speculative path
        holds: committed tokens never exceed the request's remaining
        generation budget (the +1 bonus makes the cap ``remaining - 1``),
        and writes never pass ``max_len - 1`` (the final sampled token is
        never written, exactly as in plain decode).
        """
        drafts: dict[int, list[int]] = {}
        if self.drafter is None:
            return drafts
        budget = (self.token_budget if self.token_budget is not None
                  else self.batch * self.chunk_size) - int(spans.sum())
        if budget <= 0:
            return drafts
        rot = self.stats["steps"] % self.batch
        for r in sorted(range(self.batch),
                        key=lambda r: (r - rot) % self.batch):
            if budget <= 0:
                break
            req = self.rows[r]
            if req is None or spans[r] != 1 or req.admitting:
                continue
            cap = min(self.spec_k, budget,
                      req.max_new_tokens - len(req.tokens) - 1,
                      self.max_len - int(self.row_pos[r]) - 1)
            if cap <= 0:
                continue
            d = self.drafter.propose(req.context, cap)[:cap]
            if not d:
                continue
            drafts[r] = [int(t) for t in d]
            spans[r] = 1 + len(d)
            budget -= len(d)
        return drafts

    def _rollback_tail_pages(self, row: int, keep_pos: int,
                             end_pos: int) -> None:
        """Free the pages a rejected draft tail grew: every page wholly
        beyond the committed cursor inside the step's write window.  Safe
        by construction — drafting rows are past admission, so window
        pages beyond the pre-step fill were grown (or COW'd) this step
        with refcount 1, and the committed t0 write keeps its own page
        (n_app >= 1) so a COW'd boundary page is never freed."""
        ps = self.page_size
        req = self.rows[row]
        for widx in range(-(-keep_pos // ps), min(self.maxp,
                                                  -(-end_pos // ps))):
            page = int(self.host_bt[row, widx])
            if page == self.trash_page:
                continue
            self.allocator.free([page], row=row)
            req.pages.remove(page)
            self.host_bt[row, widx] = self.trash_page
            self._bt_dirty = True

    def _to_dev(self, name: str, arr: np.ndarray) -> jax.Array:
        """Upload ``arr`` unless it is unchanged since the last step — the
        drained/idle steady state then reuses the resident device buffer
        instead of re-transferring identical bytes."""
        memo = self._dev_memo.get(name)
        if memo is not None and np.array_equal(memo[0], arr):
            return memo[1]
        dev = jnp.asarray(arr)
        self._dev_memo[name] = (arr.copy(), dev)
        return dev

    # -- decode loop --------------------------------------------------------

    def step(self) -> bool:
        """One token-budget mixed step.  Returns False when fully drained."""
        self._check_deadlines()
        self._shed_on_capacity_loss()
        self.admit()
        if all(r is None for r in self.rows):
            if self.queue:
                # Nothing bound (every queued request backing off or blocked
                # on pages): the step clock must still tick, or retry_at
                # would never be reached.
                self.stats["steps"] += 1
                return True
            return False
        spans = self._compose()
        drafts = self._fund_drafts(spans) if self.drafter is not None else {}
        if self.paged:
            self._ensure_pages(spans)
        # A mid-walk eviction zeroes the victim's span; drop its draft.
        drafts = {r: d for r, d in drafts.items()
                  if self.rows[r] is not None and spans[r] == 1 + len(d)}
        if not spans.any():
            # Budget 0 with live rows cannot make progress — treat as a
            # stall-only bookkeeping step.
            self.stats["steps"] += 1
            return True
        clamp = (max(self.chunk_size, 1) if self.prefill_interleave
                 else self.max_len)
        if self.drafter is not None:
            clamp = max(clamp, 1 + self.spec_k)
        width = engine_mod.width_bucket(int(spans.max()), clamp)
        toks = np.zeros((self.batch, width), np.int64)
        for row in range(self.batch):
            req = self.rows[row]
            if req is None or spans[row] == 0:
                continue
            if req.admitting:
                seg = req.context[req.filled: req.filled + int(spans[row])]
                toks[row, :len(seg)] = seg
            else:
                toks[row, 0] = self.token[row]
                d = drafts.get(row)
                if d:
                    toks[row, 1:1 + len(d)] = d
        toks_dev = self._to_dev(f"tok{width}", toks.astype(np.int32))
        start_dev = self._to_dev("start", self.row_pos.astype(np.int32))
        span_dev = self._to_dev(f"span{width}", spans.astype(np.int32))
        if self.drafter is not None:
            snap = (self._snap(self.cache, start_dev, width)
                    if drafts else None)
            preds_d, acc_d, self.cache = self._verify(
                self.params, self.cache, toks_dev, start_dev, span_dev)
            preds = np.asarray(preds_d)        # [B, width]
            acc = np.asarray(acc_d)
            sampled = preds[np.arange(self.batch),
                            np.clip(spans - 1, 0, width - 1)]
        else:
            self.rng, sub = jax.random.split(self.rng)
            nxt, self.cache = self._mixed(self.params, self.cache, toks_dev,
                                          start_dev, span_dev, sub)
            sampled = np.asarray(nxt)          # the one per-step sync
        self.stats["steps"] += 1
        chunks = 0
        freed = False
        roll_lo = np.zeros((self.batch,), np.int64)
        roll_hi = np.zeros((self.batch,), np.int64)   # lo == hi: no-op row
        replay_spans = np.zeros((self.batch,), np.int64)
        rolled = False
        for row in range(self.batch):
            req = self.rows[row]
            if req is None or spans[row] == 0:
                continue
            d = drafts.get(row)
            if d is not None:
                # Speculative lane: commit the longest accepted prefix plus
                # the verifier's bonus token, roll the rejected tail back.
                pos0 = int(self.row_pos[row])
                appended, a_dev = draft_mod.accept_tokens(
                    d, acc[row], preds[row],
                    req.max_new_tokens - len(req.tokens), req.eos_id)
                n_app = len(appended)
                self.stats["draft_tokens"] += len(d)
                self.stats["accepted_tokens"] += min(n_app, a_dev)
                n_roll = int(spans[row]) - n_app
                self.row_pos[row] += n_app
                for t in appended:
                    req.tokens.append(int(t))
                    if self._journal is not None:
                        self._journal("gen", req)
                self.stats["gen_tokens"] += n_app
                self.token[row] = int(appended[-1])
                if req.first_token_step < 0:
                    req.first_token_step = self.stats["steps"]
                if n_roll > 0:
                    self.stats["rollback_tokens"] += n_roll
                    roll_lo[row] = pos0 + n_app
                    roll_hi[row] = pos0 + int(spans[row])
                    replay_spans[row] = n_app
                    rolled = True
                    if self.paged:
                        self._rollback_tail_pages(row, pos0 + n_app,
                                                  pos0 + int(spans[row]))
                self._mark_filled(req, upto=int(self.row_pos[row]))
                if self._done(req):
                    self._free_row(row)
                    freed = True
                continue
            self.row_pos[row] += int(spans[row])
            if req.admitting:
                req.filled += int(spans[row])
                chunks += 1
                self.stats["prefill_tokens"] += int(spans[row])
                if req.admitting:
                    self._mark_filled(req)
                    continue                  # mid-prompt logits: discarded
                # Admission complete: this chunk's last logits sampled the
                # request's first token.  TTFT is recorded below, guarded,
                # so a preempted request's re-admission keeps its TRUE
                # time-to-first-token.
                if self.prefix_sharing and not req.tokens:
                    self.prefix_cache.register(req.prompt, req.pages)
            self._mark_filled(req, upto=int(self.row_pos[row]))
            self.token[row] = int(sampled[row])
            req.tokens.append(int(sampled[row]))
            self.stats["gen_tokens"] += 1
            if self._journal is not None:
                self._journal("gen", req)
            if req.first_token_step < 0:
                req.first_token_step = self.stats["steps"]
            if self._done(req):
                self._free_row(row)
                freed = True
        if drafts:
            self.stats["spec_steps"] += 1
        if rolled:
            # Restore rejected-tail slots bitwise from the pre-verify
            # snapshot.  The scatter walks the block tables INSIDE the
            # device cache, which still hold the pre-rollback mapping (the
            # host-side page frees above only touch host_bt; _push_tables
            # runs before the next verify) — so tail bytes land in exactly
            # the pages they were snapshotted from.
            self.stats["spec_rollbacks"] += 1
            self.cache = self._restore(
                self.cache, snap, start_dev,
                jnp.asarray(roll_lo.astype(np.int32)),
                jnp.asarray(roll_hi.astype(np.int32)),
                jnp.asarray(replay_spans > 0))
            if self._has_state and replay_spans.any():
                # Recurrent carries fold every span token irreversibly, so
                # a partial rejection restored the PRE-verify state above;
                # replay just the committed tokens to advance it.  The
                # replay's attention writes are writes of the same tokens
                # at the same positions — harmless overwrites.
                w2 = engine_mod.width_bucket(int(replay_spans.max()), clamp)
                _, _, self.cache = self._verify(
                    self.params, self.cache,
                    self._to_dev(f"rtok{w2}",
                                 toks[:, :w2].astype(np.int32)),
                    start_dev,
                    self._to_dev(f"rspan{w2}",
                                 replay_spans.astype(np.int32)))
        if chunks:
            self.stats["prefill_chunks"] += chunks
            self.stats["prefills"] += 1        # steps that carried a chunk
        if freed:
            self.admit()
        return any(r is not None for r in self.rows) or bool(self.queue)

    def run(self, requests: list[Request], max_steps: int = 100_000
            ) -> list[Request]:
        for r in requests:
            self.submit(r)
        for _ in range(max_steps):
            if not self.step():
                break
        else:
            raise RuntimeError("scheduler hit max_steps with work remaining")
        return requests

    @property
    def spec_accept_rate(self) -> float:
        """Accepted drafts / proposed drafts (0.0 before any speculation)."""
        return (self.stats["accepted_tokens"]
                / max(1, self.stats["draft_tokens"]))

    @property
    def live_tokens(self) -> int:
        return sum(len(r.prompt) + len(r.tokens)
                   for r in self.rows if r is not None)

    def resident_cache_bytes(self) -> int:
        """Bytes of KV actually pinned right now.

        Dense: the whole [B, Hkv, S, D] allocation, always.  Paged: pages in
        use × per-page bytes — what a pool sized to the live-token watermark
        would hold.  Shared (prefix) pages count once: that is the point.
        """
        if not self.paged:
            return sum(int(x.nbytes) for x in jax.tree.leaves(self.cache))
        used = self.allocator.num_pages - self.allocator.available
        total = 0
        for _, layout, layer in cache_mod.iter_layers(self.cache):
            for name in cache_mod.pool_leaves(layer, layout):
                pool = layer[name]
                core = cache_mod._POOL_LEAF_NDIM[layout][name]
                p = pool.shape[1] if pool.ndim == core + 1 else pool.shape[0]
                total += int(pool.nbytes) * used // p
        return total


class PrefixPageMapper:
    """Shared-prefix page mapping for a fixed-row agent engine (no COW).

    The orchestrator's agents re-contextualize in place: each (re-)prefill
    remaps the row's pages, sharing the full pages of any previously
    registered identical prefix — the CodeCRDT task/TODO prompt header —
    and allocating private pages for the rest of the row's horizon.  Only
    pages strictly below the row's first decode write are shared, so no
    copy-on-write machinery is needed here.
    """

    def __init__(self, num_rows: int, maxp: int, page_size: int,
                 trash_page: int, num_pages: Optional[int] = None):
        # A row transiently holds old + new mappings during remap.
        self.allocator = PageAllocator(num_pages if num_pages is not None
                                       else (num_rows + 1) * maxp)
        if trash_page < self.allocator.num_pages:
            raise ValueError(
                f"trash_page {trash_page} lies inside the allocatable pool "
                f"[0, {self.allocator.num_pages}): decode writes of unmapped "
                "rows would corrupt live pages")
        self.prefix_cache = PrefixCache(self.allocator, page_size)
        self.page_size = page_size
        self.maxp = maxp
        self.trash_page = trash_page
        self.host_bt = np.full((num_rows, maxp), trash_page, np.int32)
        self._row_pages: list[list[int]] = [[] for _ in range(num_rows)]
        self.shared_pages = 0
        self._dirty = True                # initial table needs installing

    def map_row(self, row: int, tokens: list[int], horizon: int) -> int:
        """Remap ``row`` for a prompt of ``tokens`` and a total horizon of
        ``horizon`` positions (prompt + generation budget).  Returns the
        number of pages shared with previously mapped prompts."""
        ps = self.page_size
        npages = min(-(-horizon // ps), self.maxp)
        n_write = len(tokens) // ps       # decode writes from page n_write
        shared = self.prefix_cache.lookup(tokens, boundary=False)[:n_write]
        fresh = self.allocator.alloc(npages - len(shared))
        if fresh is None:
            raise RuntimeError("agent page pool exhausted")
        self.allocator.share(shared)
        pages = shared + fresh
        old = self._row_pages[row]
        self._row_pages[row] = pages
        self.host_bt[row, :] = self.trash_page
        self.host_bt[row, :len(pages)] = pages
        if old:
            self.allocator.free(old)      # after remap: self-prefix shares
        self.prefix_cache.register(tokens[:n_write * ps], pages[:n_write])
        self.shared_pages += len(shared)
        self._dirty = True
        return len(shared)

    def free_row(self, row: int) -> None:
        if self._row_pages[row]:
            self.allocator.free(self._row_pages[row])
            self._row_pages[row] = []
        self.host_bt[row, :] = self.trash_page
        self._dirty = True

    def install(self, cache: Params) -> Params:
        """Install the host block table into ``cache`` iff it changed since
        the last install (one jnp transfer per batch of remaps)."""
        if self._dirty:
            cache = lm.set_block_tables(cache, jnp.asarray(self.host_bt))
            self._dirty = False
        return cache
