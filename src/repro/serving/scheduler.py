"""Continuous batching over the paged KV cache: admission, page accounting,
and completion at token granularity.

The scheduler owns a fixed decode batch of B rows backed by a shared page
pool.  Requests queue up; whenever a row is free and the allocator can cover
``ceil((prompt + max_new) / page_size)`` pages, the request is admitted by a
*ragged prefill* — one jitted call whose ``lengths`` vector is zero for every
other row, so in-flight rows keep decoding from bit-identical cache while the
new row's prompt lands in its freshly allocated pages.  On completion the
row's pages return to the free list immediately (memory scales with live
tokens, not B × max_len).

Freed rows still ride the batched decode step (there is no dynamic batch
shape under jit).  Their writes are steered to a dedicated trash page —
never allocated to real rows — because the fused kernel writes one slot per
row per step unconditionally; block tables therefore never contain -1 for a
slot that will be written.

Dense mode (``paged=False``) runs the same admission logic against the
classic [B, Hkv, S, D] cache — the benchmark's apples-to-apples baseline.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm
from repro.models.config import ModelConfig
from repro.serving import engine as engine_mod
from repro.serving.engine import PROMPT_BUCKETS, bucket_len  # noqa: F401

Params = Any


class PageAllocator:
    """Host-side free list of pool page ids (unit = one page)."""

    def __init__(self, num_pages: int):
        self.num_pages = num_pages
        self._free = list(range(num_pages - 1, -1, -1))

    @property
    def available(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> Optional[list[int]]:
        if n <= 0:
            return []                 # [:-0] would hand out the whole list
        if n > len(self._free):
            return None
        pages, self._free = self._free[-n:][::-1], self._free[:-n]
        return pages

    def free(self, pages: list[int]) -> None:
        self._free.extend(reversed(pages))


@dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int
    eos_id: Optional[int] = None
    tokens: list[int] = field(default_factory=list)   # generated output
    admitted_step: int = -1
    finished_step: int = -1
    pages: list[int] = field(default_factory=list)


class ContinuousBatchingEngine:
    """Token-granularity continuous batching over a (paged) decode engine."""

    def __init__(self, cfg: ModelConfig, params: Params, *, batch: int,
                 max_len: int, paged: bool = True, page_size: int = 64,
                 num_pages: Optional[int] = None, impl: str = "ref",
                 temperature: float = 0.0, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.batch = batch
        self.max_len = max_len
        self.paged = paged
        self.page_size = page_size
        self.temperature = temperature
        self.maxp = -(-max_len // page_size)
        if paged:
            if num_pages is None:
                num_pages = batch * self.maxp
            self.allocator = PageAllocator(num_pages)
            self.trash_page = num_pages          # extra physical page
            self.cache = lm.init_cache(cfg, batch, max_len, paged=True,
                                       page_size=page_size,
                                       num_pages=num_pages + 1)
            self.host_bt = np.full((batch, self.maxp), self.trash_page,
                                   np.int32)
            self.cache = lm.set_block_tables(self.cache,
                                             jnp.asarray(self.host_bt))
        else:
            self.allocator = None
            self.cache = lm.init_cache(cfg, batch, max_len)
        self._prefill = jax.jit(
            engine_mod.make_ragged_prefill_fn(cfg, impl=impl),
            donate_argnums=(1,))
        self._step = jax.jit(
            engine_mod.make_serve_step(cfg, impl=impl,
                                       temperature=temperature),
            donate_argnums=(1,))
        self.rng = jax.random.PRNGKey(seed)
        self.pos = jnp.zeros((batch,), jnp.int32)
        self.token = jnp.zeros((batch,), jnp.int32)
        self.rows: list[Optional[Request]] = [None] * batch
        self.queue: deque[Request] = deque()
        self.stats = {"steps": 0, "prefills": 0, "admitted": 0,
                      "completed": 0, "peak_pages": 0, "gen_tokens": 0}

    # -- request lifecycle --------------------------------------------------

    def submit(self, req: Request) -> None:
        if req.max_new_tokens < 1:
            raise ValueError(f"request {req.rid}: max_new_tokens must be "
                             ">= 1 (prefill always yields one token)")
        if not req.prompt:
            raise ValueError(f"request {req.rid}: empty prompt")
        if len(req.prompt) + req.max_new_tokens > self.max_len:
            raise ValueError(f"request {req.rid} needs "
                             f"{len(req.prompt) + req.max_new_tokens} slots "
                             f"> max_len {self.max_len}")
        # Fail here, not mid-run inside admit(): the prompt must fit a
        # prefill bucket (buckets are clamped to max_len at admission).
        bucket_len(len(req.prompt))
        if self.paged:
            need = self._pages_needed(req)
            if need > self.allocator.num_pages:
                raise ValueError(f"request {req.rid} needs {need} pages "
                                 f"> pool {self.allocator.num_pages}")
        self.queue.append(req)

    def _pages_needed(self, req: Request) -> int:
        return -(-(len(req.prompt) + req.max_new_tokens) // self.page_size)

    def _free_row(self, row: int) -> None:
        req = self.rows[row]
        req.finished_step = self.stats["steps"]
        self.stats["completed"] += 1
        if self.paged:
            # req.pages is kept (now historical) — the allocator owns reuse.
            self.allocator.free(req.pages)
            self.host_bt[row, :] = self.trash_page
        self.rows[row] = None

    def admit(self) -> int:
        """Admit queued requests into free rows (one ragged prefill call).

        Returns the number admitted.  Head-of-line blocking on page budget
        is deliberate: FIFO completion-time fairness.
        """
        pending: list[tuple[int, Request]] = []
        for row in range(self.batch):
            if self.rows[row] is not None or not self.queue:
                continue
            req = self.queue[0]
            if self.paged:
                pages = self.allocator.alloc(self._pages_needed(req))
                if pages is None:
                    break                      # wait for completions
                req.pages = pages
                self.host_bt[row, :] = self.trash_page
                self.host_bt[row, :len(pages)] = pages
            self.queue.popleft()
            self.rows[row] = req
            req.admitted_step = self.stats["steps"]
            pending.append((row, req))
        if not pending:
            return 0

        if self.paged:
            self.cache = lm.set_block_tables(self.cache,
                                             jnp.asarray(self.host_bt))
            used = self.allocator.num_pages - self.allocator.available
            self.stats["peak_pages"] = max(self.stats["peak_pages"], used)
        logits, _, self.cache = engine_mod.ragged_prefill_batch(
            self._prefill, self.params, self.cache, self.batch,
            {row: req.prompt for row, req in pending}, max_len=self.max_len)
        self.rng, sub = jax.random.split(self.rng)
        first = np.asarray(engine_mod.sample_token(logits, sub,
                                                   self.temperature))
        token = np.array(self.token)           # writable host copies
        pos = np.array(self.pos)
        for row, req in pending:
            req.tokens.append(int(first[row]))
            self.stats["gen_tokens"] += 1
            token[row] = int(first[row])
            pos[row] = len(req.prompt)
        self.token = jnp.asarray(token)
        self.pos = jnp.asarray(pos)
        self.stats["prefills"] += 1
        self.stats["admitted"] += len(pending)
        # A request can complete at its very first token (max_new == 1).
        for row, req in pending:
            if self._done(req):
                self._free_row(row)
        return len(pending)

    def _done(self, req: Request) -> bool:
        return (len(req.tokens) >= req.max_new_tokens
                or (req.eos_id is not None
                    and req.tokens
                    and req.tokens[-1] == req.eos_id))

    # -- decode loop --------------------------------------------------------

    def step(self) -> bool:
        """One batched decode step.  Returns False when fully drained."""
        self.admit()
        if all(r is None for r in self.rows):
            return bool(self.queue)
        self.rng, sub = jax.random.split(self.rng)
        self.token, self.cache, self.pos = self._step(
            self.params, self.cache, self.token, self.pos, sub)
        self.stats["steps"] += 1
        sampled = np.asarray(self.token)
        pos = np.array(self.pos)
        freed = False
        for row, req in enumerate(self.rows):
            if req is None:
                # Idle lanes park at pos 0: their (trash-page) writes stay
                # in slot range and their walk reads a single garbage page.
                pos[row] = 0
                continue
            req.tokens.append(int(sampled[row]))
            self.stats["gen_tokens"] += 1
            if self._done(req):
                self._free_row(row)
                freed = True
        self.pos = jnp.asarray(pos)
        if freed:
            self.admit()
        return any(r is not None for r in self.rows) or bool(self.queue)

    def run(self, requests: list[Request], max_steps: int = 100_000
            ) -> list[Request]:
        for r in requests:
            self.submit(r)
        for _ in range(max_steps):
            if not self.step():
                break
        else:
            raise RuntimeError("scheduler hit max_steps with work remaining")
        return requests

    @property
    def live_tokens(self) -> int:
        return sum(len(r.prompt) + len(r.tokens)
                   for r in self.rows if r is not None)

    def resident_cache_bytes(self) -> int:
        """Bytes of KV actually pinned right now.

        Dense: the whole [B, Hkv, S, D] allocation, always.  Paged: pages in
        use × per-page bytes — what a pool sized to the live-token watermark
        would hold (the preallocated pool is the *capacity*, this is the
        footprint the allocator actually needs).
        """
        if not self.paged:
            return sum(int(x.nbytes) for x in jax.tree.leaves(self.cache))
        used = self.allocator.num_pages - self.allocator.available
        pools: list = []

        def grab(d):
            pools.extend((d["k_pages"], d["v_pages"]))
            return d

        lm._map_paged_dicts(self.cache, grab)
        return sum(int(p.nbytes) * used // p.shape[-4] for p in pools)
