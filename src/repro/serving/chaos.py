"""Chaos harness: seeded faults against the REAL multi-engine server.

The PR-6 simulator proved the replicated page table converges under
adversarial gossip — but over abstract replicas.  This harness drives the
actual ``MultiEngineServer`` (real ``ContinuousBatchingEngine``s decoding a
real model) through the simulator's ``FaultyChannel`` schedules, crashes an
engine mid-flight, and asserts the end-to-end invariants the serving tier
promises:

  1. **Exactly-once completion** — every accepted request that was never
     shed/expired/failed has exactly one ``J_DONE`` in the merged journal
     (and no request ever has more than one).
  2. **Bitwise convergence** — after quiescence (channel healed, frozen
     heartbeats, gossip drained) every live replica's page-table digest is
     identical.
  3. **Per-lane refcount conservation** — at every step, each live
     replica's own counter lane holds exactly one reference per page bound
     to one of its rows; and the merged view never shows ``dec > inc``
     anywhere (no double-free), including across failover.

Run it as a module for the CI chaos smoke job::

    python -m repro.serving.chaos --schedule lossy --seed 0 \
        --crash-at 6 --out /tmp/chaos_trace.json

The JSON trace (events, per-invariant verdicts, channel + server stats) is
written win or lose — CI uploads it on failure.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Optional

import numpy as np

from repro.serving import replicated as repl
from repro.serving.scheduler import Request
from repro.serving.simulator import SCHEDULES, FaultyChannel


def tiny_model():
    """The tests' tiny LLM (olmo-1b reduced): small enough for CI, real
    enough that recovered requests re-decode through actual kernels."""
    import jax
    import jax.numpy as jnp

    import repro.configs as configs
    from repro.models import lm
    cfg = configs.reduced(configs.get("olmo-1b"), d_model=32, vocab=128)
    cfg = cfg.replace(num_layers=2)
    params = jax.tree.map(lambda x: x.astype(jnp.float32),
                          lm.init(jax.random.PRNGKey(0), cfg))
    return cfg, params


def fanout_requests(rng: np.random.Generator, count: int = 10,
                    prompt_len: int = 12, new_tokens: int = 4
                    ) -> list[Request]:
    """Two prompt families interleaved AABB… (round-robin dispatch lands
    copies on several replicas; shared prefixes exercise the replicated
    prefix map during recovery re-admission)."""
    prompts = {c: [int(t) for t in rng.integers(2, 100, prompt_len)]
               for c in "AB"}
    pattern = ("AABB" * (count // 4 + 1))[:count]
    return [Request(rid=i, prompt=list(prompts[c]),
                    max_new_tokens=new_tokens)
            for i, c in enumerate(pattern)]


def _lane_conservation(server: repl.MultiEngineServer, r: int) -> bool:
    """Replica r's own counter lane == references held by its bound rows."""
    store = server.stores[r]
    held = np.zeros(store.num_pages, np.int64)
    for req in server.engines[r].rows:
        if req is not None:
            for p in req.pages:
                held[p] += 1
    lane = store.inc[r].astype(np.int64) - store.dec[r].astype(np.int64)
    return bool(np.array_equal(lane, held))


def _exactly_once(server: repl.MultiEngineServer) -> tuple[bool, dict]:
    """Fold a live replica's merged journal and check delivery semantics."""
    live = [r for r in range(server.replicas) if not server.crashed[r]]
    store = server.stores[live[0]]
    accepted: set[int] = set()
    dropped: set[int] = set()          # shed / expired / failed
    dones: dict[int, int] = {}
    for _lane, rid, tag, _a, _b in store.journal_entries():
        if tag == repl.J_ACCEPT:
            accepted.add(rid)
        elif tag in (repl.J_SHED, repl.J_EXPIRED, repl.J_FAIL):
            dropped.add(rid)
        elif tag == repl.J_DONE:
            dones[rid] = dones.get(rid, 0) + 1
    must_complete = accepted - dropped
    ok = (all(dones.get(rid, 0) == 1 for rid in must_complete)
          and all(n <= 1 for n in dones.values()))
    detail = {"accepted": sorted(accepted), "dropped": sorted(dropped),
              "done_counts": {str(k): v for k, v in sorted(dones.items())},
              "missing": sorted(must_complete - set(dones)),
              "duplicated": sorted(k for k, v in dones.items() if v > 1)}
    return ok, detail


def _no_double_free(server: repl.MultiEngineServer) -> bool:
    """Merged view: no lane anywhere released more than it acquired."""
    return all(bool(np.all(server.stores[r].dec <= server.stores[r].inc))
               for r in range(server.replicas) if not server.crashed[r])


def _xfer_balanced(server: repl.MultiEngineServer) -> tuple[bool, dict]:
    """Transfer journal balance: after quiescence every ``J_XFER_BEGIN``
    is closed by exactly one ``J_XFER_COMMIT`` or ``J_XFER_ABORT`` in the
    same lane for the same (rid, page, seq).  Transfers are journaled in
    the ADOPTER's lane, so an exporter crash cannot lose the closer — an
    unbalanced journal means a transfer left the adopter in limbo."""
    live = [r for r in range(server.replicas) if not server.crashed[r]]
    store = server.stores[live[0]]
    opens: dict[tuple, int] = {}
    begins = commits = aborts = 0
    for lane, rid, tag, a, b in store.journal_entries():
        key = (lane, rid, a, b)
        if tag == repl.J_XFER_BEGIN:
            opens[key] = opens.get(key, 0) + 1
            begins += 1
        elif tag == repl.J_XFER_COMMIT:
            opens[key] = opens.get(key, 0) - 1
            commits += 1
        elif tag == repl.J_XFER_ABORT:
            opens[key] = opens.get(key, 0) - 1
            aborts += 1
    ok = all(v == 0 for v in opens.values())
    detail = {"begins": begins, "commits": commits, "aborts": aborts,
              "unbalanced": [list(k) for k, v in opens.items() if v != 0]}
    return ok, detail


def drain(server: repl.MultiEngineServer, max_rounds: int = 300) -> bool:
    """Quiesce (mirrors the simulator's two-phase scheme): heartbeats
    frozen — no engine steps, no ``maintain`` — gossip rounds until every
    live digest matches, then pump-only ticks to flush the last in-flight
    packets (late deltas join as no-ops on converged state; acks only
    advance frontiers)."""
    server.channel.healed = True
    for _ in range(max_rounds):
        server.clock += 1
        server.sync()
        if server.converged():
            break
    else:
        return False
    for _ in range(max_rounds):
        if server.channel.in_flight == 0:
            break
        server.clock += 1
        server._pump(server.clock)
    return bool(server.channel.in_flight == 0 and server.converged())


def run_chaos(cfg=None, params=None, *, schedule: str = "lossy",
              seed: int = 0, replicas: int = 3, batch: int = 3,
              max_len: int = 32, page_size: int = 8, chunk_size: int = 8,
              sync_every: int = 1, ttl: Optional[int] = None,
              crash_replica: Optional[int] = 1, crash_at: int = 4,
              count: int = 10, prompt_len: int = 12, new_tokens: int = 6,
              max_queue: Optional[int] = None, max_steps: int = 3000,
              disagg: bool = False, xfer_crash: bool = False
              ) -> dict[str, Any]:
    """One seeded chaos trial.  Returns the JSON-able fault trace; the
    headline verdict is ``trace["ok"]``.

    ``disagg=True`` runs a disaggregated topology (replica 0 prefill, the
    rest decode) and staggers submissions so later requests arrive after
    the prefill replica has published filled pages — routing sends them to
    decode replicas, whose adoption hooks physically transfer the bytes.
    ``xfer_crash=True`` additionally crash-stops the prefill replica in
    the middle of its first exported transfer (``arm_transfer_crash``), so
    the trial asserts the adopter rolled back cleanly (the rule-3 epoch
    re-check aborted) on top of the usual invariants.
    """
    if cfg is None:
        cfg, params = tiny_model()
    spec = SCHEDULES[schedule]
    channel = FaultyChannel(np.random.default_rng(seed + 1), spec)
    roles = (["prefill"] + ["decode"] * (replicas - 1)) if disagg else None
    server = repl.MultiEngineServer(
        cfg, params, replicas=replicas, batch=batch, max_len=max_len,
        page_size=page_size, sync_every=sync_every, ttl=ttl,
        chunk_size=chunk_size, channel=channel, max_queue=max_queue,
        roles=roles)
    if xfer_crash:
        server.arm_transfer_crash(0)       # exporter dies mid-transfer
        crash_replica = None               # the transfer IS the crash event
    rng = np.random.default_rng(seed)
    requests = fanout_requests(rng, count, prompt_len, new_tokens)
    events: list[dict] = []
    pending = list(requests)
    # Disaggregated mode staggers arrivals (one per step after the first
    # batch) so the decode tier sees published pages; otherwise everything
    # arrives at t=0 as before.
    first_wave = batch if disagg else len(pending)
    for req in pending[:first_wave]:
        events.append({"t": 0, "event": "submit", "rid": req.rid,
                       "replica": server.submit(req)})
    pending = pending[first_wave:]
    conservation_ok = True
    steps = 0
    while steps < max_steps:
        if (crash_replica is not None and not server.crashed[crash_replica]
                and server.clock >= crash_at):
            server.crash(crash_replica)
            events.append({"t": server.clock, "event": "crash",
                           "replica": crash_replica})
        if pending and steps >= 1:
            req = pending.pop(0)
            events.append({"t": server.clock, "event": "submit",
                           "rid": req.rid, "replica": server.submit(req)})
        more = server.step()
        steps += 1
        if pending:
            more = True                    # arrivals still queued here
        for r in range(server.replicas):
            if not server.crashed[r] and not _lane_conservation(server, r):
                conservation_ok = False
                events.append({"t": server.clock, "event":
                               "conservation_violation", "replica": r})
        if not more:
            break
    drained = drain(server)
    once_ok, once_detail = _exactly_once(server)
    no_dfree = _no_double_free(server)
    converged = bool(server.converged() and channel.in_flight == 0)
    xfer_ok, xfer_detail = _xfer_balanced(server)
    invariants = {"exactly_once": once_ok, "converged": converged,
                  "drained": drained,
                  "lane_conservation": conservation_ok,
                  "no_double_free": no_dfree,
                  "xfer_journal_balanced": xfer_ok}
    if xfer_crash:
        # The armed crash must actually have fired mid-transfer, and the
        # adopter must have aborted (rolled its provisional share back).
        invariants["xfer_crash_fired"] = bool(server._xfer_crash is None)
        invariants["adopter_rolled_back"] = bool(server.adopt_aborts >= 1)
    elif disagg:
        # No-crash disagg run: the decode tier must actually have adopted.
        invariants["pages_adopted"] = bool(server.transferred_pages > 0)
    trace = {
        "schedule": schedule, "seed": seed, "replicas": replicas,
        "crash_replica": crash_replica, "crash_at": crash_at,
        "disagg": disagg, "xfer_crash": xfer_crash,
        "steps": steps, "hit_max_steps": steps >= max_steps,
        "events": events,
        "channel": {"sent": channel.sent, "dropped": channel.dropped,
                    "duplicated": channel.duplicated,
                    "in_flight": channel.in_flight},
        "server": server.stats(),
        "invariants": invariants,
        "exactly_once_detail": once_detail,
        "xfer_detail": xfer_detail,
    }
    trace["ok"] = bool(all(invariants.values())
                       and not trace["hit_max_steps"])
    return trace


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--schedule", default="lossy",
                    choices=sorted(SCHEDULES))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--replicas", type=int, default=3)
    ap.add_argument("--crash-at", type=int, default=4)
    ap.add_argument("--crash-replica", type=int, default=1)
    ap.add_argument("--no-crash", action="store_true")
    ap.add_argument("--count", type=int, default=10)
    ap.add_argument("--ttl", type=int, default=None)
    ap.add_argument("--disagg", action="store_true",
                    help="prefill/decode roles + staggered arrivals")
    ap.add_argument("--xfer-crash", action="store_true",
                    help="crash the prefill exporter mid-transfer "
                         "(implies --disagg)")
    ap.add_argument("--out", default=None, help="fault-trace JSON path")
    args = ap.parse_args(argv)
    trace = run_chaos(schedule=args.schedule, seed=args.seed,
                      replicas=args.replicas, ttl=args.ttl,
                      crash_replica=None if args.no_crash
                      else args.crash_replica,
                      crash_at=args.crash_at, count=args.count,
                      disagg=args.disagg or args.xfer_crash,
                      xfer_crash=args.xfer_crash)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(trace, f, indent=2, default=int)
    verdicts = " ".join(f"{k}={'PASS' if v else 'FAIL'}"
                        for k, v in trace["invariants"].items())
    print(f"chaos[{args.schedule} seed={args.seed}] {verdicts} "
          f"recovered={trace['server']['recovered_requests']} "
          f"shed={trace['server']['shed']} "
          f"retried={trace['server']['retried']} "
          f"xfer={trace['xfer_detail']['begins']}b/"
          f"{trace['xfer_detail']['commits']}c/"
          f"{trace['xfer_detail']['aborts']}a")
    return 0 if trace["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
