"""repro.serving subsystem."""
