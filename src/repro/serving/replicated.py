"""Replicated CRDT page table — the distributed serving tier.

The scheduler's host-local ``PageAllocator`` refcounts and ``PrefixCache``
chain become replicated state shared by N serving engines:

  * per-page refcounts   — a PN-counter with one writer lane per replica
                           (``core/counter.py``): replica r's references to
                           page p live in lane r; the observed refcount is
                           the live-lane sum, so a crashed replica's zombie
                           references stop pinning pages once its retirement
                           is observed.
  * prefix → page map    — an LWW register bank (``core/lww.py``) keyed by a
                           62-bit hash of the token prefix: full chain pages
                           (immutable once filled) are published so peers
                           can discover shareable prompt KV.
  * page ownership       — an LWW lease ``(owner, seq)`` per page.  ``seq``
                           is the page's *epoch*: it bumps on every alloc
                           AND every free-to-zero, so any stale reference a
                           peer resolved under an old epoch fails validation
                           instead of aliasing reused KV.
  * liveness             — heartbeat G-counter + retirement-vote G-set.

All of it syncs through the PR-1 delta engine: ``delta.frontier`` /
``delta.extract`` / ``delta.apply`` on the registered CRDT leaves, shipped
as fixed-capacity packets by ``AntiEntropyNode`` (host gossip with per-peer
ack frontiers — the fault-tolerant sibling of ``delta.DeltaSync``).

Protocol rules (verified by serving/simulator.py)
-------------------------------------------------

1. **Home-partition allocation.**  Page p is allocated only by its home
   replica ``home(p) = p * N // P``, so allocation never needs consensus.
   Any replica may *reference* any page (prefix sharing); only the lease
   owner writes it.

2. **Epoch fencing.**  The lease seq bumps on alloc and on free-to-zero.
   Published prefix entries carry the seq they were minted under; every
   cross-replica resolution re-validates ``seq`` against the current lease.

3. **Provisional cross-replica shares.**  A replica adopting a peer's page
   increments its own refcount lane first (so the home can never observe
   refcount 0 while the adoption is in flight... once the inc has synced),
   then commits only after it has since *heard from the owner* with the
   epoch unchanged; otherwise it aborts and decrements.  The home absorbs
   the in-flight window by lingering: an exported page that reaches
   refcount 0 cools for ``linger`` steps (and is re-validated at promotion)
   before re-entering the free list.

4. **Fencing / retirement / reclamation.**  Replicas heartbeat every step.
   A replica FENCES ITSELF (stops allocating and writing) while any
   non-retired peer has been unheard for > ``ttl`` steps — during a
   partition *both* sides stall rather than risk divergent ownership
   (safety over liveness).  A peer whose merged heartbeat is stale by
   > ``2*ttl`` gets a retirement vote; retirement takes effect at a
   majority (floor(N/2)+1), so an N=2 crash pins pages forever rather than
   reclaiming unsafely.  The lowest-id live replica then re-homes a retired
   replica's pages: claim (lease write, seq+1) → wait ``grace`` → commit if
   still the lease winner and itself unfenced.  Safety margin: an isolated
   owner fences at ``ttl`` unheard, strictly before any claim can commit at
   ``2*ttl (vote) + grace``.

5. **Self-halt.**  A replica that observes its own retirement stops
   operating (its lanes are already excluded from effective refcounts).

The engine-facing adapters ``ReplicatedPageAllocator`` /
``ReplicatedPrefixCache`` are drop-in for the scheduler's ``PageAllocator``
/ ``PrefixCache`` API, so ``ContinuousBatchingEngine(allocator=...,
prefix_cache=...)`` runs unmodified on replicated state.
``MultiEngineServer`` drives N such engines with reliable in-process gossip
(cross-replica prefix hits are accounted at the metadata layer there;
physical cross-engine KV adoption is the ROADMAP follow-on — the simulator,
whose pages are abstract, exercises real adoption end to end).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional

import numpy as np

from repro.core import counter as counter_mod
from repro.core import delta as delta_mod
from repro.core import gset, lww
from repro.core.clock import MAX_CLIENTS, MAX_CLOCK
from repro.models import cache as cache_mod
from repro.serving import scheduler as sched_mod

HASH_BITS = 62

# Request-journal entry tags (the ``tag`` field of the ``journal`` GLog).
# The journal is the crash-failover substrate: every accepted request's
# descriptor (prompt tokens + generated-so-far) is journaled in its owner
# replica's append-only lane and gossips with the rest of the CRDT state,
# so any survivor can reconstruct a crashed replica's in-flight requests.
#   ACCEPT : a = (prompt_len << 16) | max_new_tokens, b = eos_id+1 (0=None)
#   PROMPT : a = position, b = token            (one entry per prompt token)
#   GEN    : a = output index, b = token        (one entry per decode step)
#   DONE / SHED / EXPIRED / FAIL : terminal markers (DONE: a = output len)
#   ADOPT  : a = retry count — a survivor took ownership after retirement
#   XFER_BEGIN / XFER_COMMIT / XFER_ABORT : physical page adoption
#     (disaggregation): a = page, b = publishing lease seq, rid = the
#     adopting request.  Every BEGIN is closed by exactly one COMMIT or
#     ABORT in the same lane — the chaos harness asserts the balance, and
#     an ABORT means the adopter rolled back (the page was never bound to
#     a row, so discarding the staged bytes is the whole rollback).
(J_ACCEPT, J_PROMPT, J_GEN, J_DONE,
 J_SHED, J_EXPIRED, J_ADOPT, J_FAIL,
 J_XFER_BEGIN, J_XFER_COMMIT, J_XFER_ABORT) = range(11)

JOURNAL_FIELDS = {"rid": ((), np.int32), "tag": ((), np.int32),
                  "a": ((), np.int32), "b": ((), np.int32)}


def prefix_hash(key: tuple) -> int:
    """Deterministic 62-bit FNV-1a of an int tuple (a token prefix).  Both
    31-bit halves fit an int32 lane of the LWW payload."""
    h = 0xcbf29ce484222325
    for t in key:
        h ^= int(t) & 0xFFFFFFFFFFFFFFFF
        h = (h * 0x100000001B3) & 0xFFFFFFFFFFFFFFFF
    return h & ((1 << HASH_BITS) - 1)


def zero_state(num_replicas: int, num_pages: int, prefix_slots: int,
               journal_capacity: int = 256) -> dict:
    """The pristine CRDT pytree every replica starts from (and the template
    gossip frontiers are seeded with)."""
    return {
        "ref": counter_mod.PNCounter.zeros(num_replicas, num_pages),
        "lease": lww.empty(num_pages, {"owner": ((), np.int32),
                                       "seq": ((), np.int32)}),
        "prefix": lww.empty(prefix_slots, {"hash_lo": ((), np.int32),
                                           "hash_hi": ((), np.int32),
                                           "page": ((), np.int32),
                                           "seq": ((), np.int32),
                                           "owner": ((), np.int32)}),
        "hb": gset.GCounter.zeros(num_replicas),
        "retire": gset.GSet.empty(num_replicas * num_replicas),
        "journal": gset.GLog.empty(num_replicas, journal_capacity,
                                   JOURNAL_FIELDS),
    }


class ReplicatedPageStore:
    """One replica's view of the replicated page table.

    Working state is host numpy (mutations are O(1) scalar ops on the hot
    admission/growth path); ``state()`` materializes the registered CRDT
    pytree for the delta engine and ``load()`` writes a joined state back.
    Local mutators implement exactly the CRDT op semantics — single-writer
    monotone lane bumps, Lamport-guarded LWW writes — so a replica's state
    is always the join of the ops it generated and the deltas it applied.
    """

    def __init__(self, rid: int, num_replicas: int, num_pages: int,
                 prefix_slots: Optional[int] = None,
                 journal_capacity: int = 256):
        if not 0 <= rid < num_replicas:
            raise ValueError(f"rid {rid} outside [0, {num_replicas})")
        if num_replicas >= MAX_CLIENTS:
            raise ValueError("num_replicas exceeds LWW client space")
        self.rid = rid
        self.num_replicas = num_replicas
        self.num_pages = num_pages
        self.prefix_slots = (2 * num_pages if prefix_slots is None
                             else prefix_slots)
        self.journal_capacity = journal_capacity
        self.majority = num_replicas // 2 + 1
        n, p, s = num_replicas, num_pages, self.prefix_slots
        self.inc = np.zeros((n, p), np.int32)
        self.dec = np.zeros((n, p), np.int32)
        self.lease_clock = np.zeros(p, np.int32)
        self.lease_client = np.zeros(p, np.int32)
        self.lease_owner = np.zeros(p, np.int32)      # rid+1; 0 = unleased
        self.lease_seq = np.zeros(p, np.int32)
        self.pfx_clock = np.zeros(s, np.int32)
        self.pfx_client = np.zeros(s, np.int32)
        self.pfx = {name: np.zeros(s, np.int32)
                    for name in ("hash_lo", "hash_hi", "page", "seq",
                                 "owner")}
        self.hb = np.zeros(n, np.int32)
        self.retire = np.zeros(n * n, bool)
        self.jr_count = np.zeros(n, np.int32)
        self.jr = {name: np.zeros((n, journal_capacity), np.int32)
                   for name in ("rid", "tag", "a", "b")}
        self.journal_dropped = 0          # appends lost to a full lane
        self.lam = 0                                  # local Lamport time
        # Host metadata (not CRDT state): gossip recency per peer, fed by
        # AntiEntropyNode and read by the fencing rule.
        self.last_heard = {j: 0 for j in range(n) if j != rid}

    # -- Lamport ------------------------------------------------------------

    def _tick(self) -> int:
        self.lam += 1
        if self.lam > MAX_CLOCK:
            raise OverflowError("Lamport clock exhausted")
        return self.lam

    # -- refcount lanes (single-writer: own lane only) ----------------------

    def ref_add(self, page: int, n: int = 1) -> None:
        self.inc[self.rid, page] += n

    def ref_sub(self, page: int, n: int = 1) -> None:
        if self.lane_held(page) < n:
            raise ValueError(
                f"double free of page {page} (lane {self.rid} holds "
                f"{self.lane_held(page)}, releasing {n})")
        self.dec[self.rid, page] += n

    def lane_held(self, page: int) -> int:
        return int(self.inc[self.rid, page] - self.dec[self.rid, page])

    def retired_mask(self) -> np.ndarray:
        """bool[N] — replicas whose retirement has majority support in this
        replica's merged view.  Votes are monotone facts, so every replica
        converges to the same mask."""
        n = self.num_replicas
        votes = self.retire.reshape(n, n).sum(axis=0)
        return votes >= self.majority

    def live_lanes(self) -> np.ndarray:
        return ~self.retired_mask()

    def refcount(self, page: int) -> int:
        live = self.live_lanes()
        return int((self.inc[live, page] - self.dec[live, page]).sum())

    def refcounts(self) -> np.ndarray:
        """Effective (live-lane) refcount of every page: i32[P]."""
        live = self.live_lanes()
        return (self.inc[live] - self.dec[live]).sum(axis=0)

    # -- lease --------------------------------------------------------------

    def _lww_write(self, clock_arr, client_arr, idx: int,
                   fields: dict[str, dict]) -> bool:
        clock = self._tick()
        client = self.rid + 1
        new_key = clock * MAX_CLIENTS + client
        cur_key = int(clock_arr[idx]) * MAX_CLIENTS + int(client_arr[idx])
        if new_key <= cur_key:
            return False
        clock_arr[idx] = clock
        client_arr[idx] = client
        for payload, values in fields.items():
            for name, value in values.items():
                getattr(self, payload)[name][idx] = value
        return True

    def lease_write(self, page: int, owner_rid: int, seq: int) -> None:
        ok = self._lww_write(
            self.lease_clock, self.lease_client, page,
            {"_lease_payload": {"owner": owner_rid + 1, "seq": seq}})
        if not ok:
            raise RuntimeError(f"lease write lost on page {page} — a local "
                               "Lamport tick can never lose locally")

    @property
    def _lease_payload(self) -> dict[str, np.ndarray]:
        return {"owner": self.lease_owner, "seq": self.lease_seq}

    def lease(self, page: int) -> tuple[int, int]:
        """(owner_rid or -1, seq) of the page's current epoch."""
        return int(self.lease_owner[page]) - 1, int(self.lease_seq[page])

    # -- prefix map ---------------------------------------------------------

    def publish_prefix(self, h: int, page: int, seq: int) -> None:
        slot = h % self.prefix_slots
        self._lww_write(
            self.pfx_clock, self.pfx_client, slot,
            {"pfx": {"hash_lo": h & 0x7FFFFFFF, "hash_hi": h >> 31,
                     "page": page, "seq": seq, "owner": self.rid + 1}})

    def lookup_prefix(self, h: int) -> Optional[tuple[int, int, int]]:
        """(owner_rid, page, seq) of a published prefix page, or None.  The
        caller still must validate seq against the page's current lease."""
        slot = h % self.prefix_slots
        if self.pfx_clock[slot] == 0:
            return None
        if (int(self.pfx["hash_lo"][slot]) != (h & 0x7FFFFFFF)
                or int(self.pfx["hash_hi"][slot]) != (h >> 31)):
            return None                     # slot collision — treat as miss
        return (int(self.pfx["owner"][slot]) - 1,
                int(self.pfx["page"][slot]), int(self.pfx["seq"][slot]))

    # -- request journal (single-writer: own lane only) ---------------------

    def journal_append(self, rid: int, tag: int, a: int = 0, b: int = 0
                       ) -> None:
        """One entry in this replica's journal lane (GLog semantics: drops
        silently when the lane is full — ``journal_dropped`` counts it, and
        a request whose descriptor is incomplete fails over as FAIL instead
        of resurrecting with corrupt state)."""
        i = int(self.jr_count[self.rid])
        if i >= self.journal_capacity:
            self.journal_dropped += 1
            return
        for name, v in (("rid", rid), ("tag", tag), ("a", a), ("b", b)):
            self.jr[name][self.rid, i] = v
        self.jr_count[self.rid] = i + 1

    def journal_entries(self):
        """Every journal entry visible in this replica's merged view, as
        ``(lane, rid, tag, a, b)`` — per-lane append order within a lane."""
        for lane in range(self.num_replicas):
            for i in range(int(self.jr_count[lane])):
                yield (lane, int(self.jr["rid"][lane, i]),
                       int(self.jr["tag"][lane, i]),
                       int(self.jr["a"][lane, i]),
                       int(self.jr["b"][lane, i]))

    # -- liveness -----------------------------------------------------------

    def heartbeat(self, now: int) -> None:
        self.hb[self.rid] = max(int(self.hb[self.rid]), now)

    def vote_retire(self, target: int) -> None:
        self.retire[self.rid * self.num_replicas + target] = True

    def is_retired(self, r: int) -> bool:
        return bool(self.retired_mask()[r])

    # -- CRDT pytree bridge -------------------------------------------------

    def state(self) -> dict:
        """The registered-CRDT pytree this replica's state IS (the thing the
        delta engine extracts from / applies into / joins)."""
        import jax.numpy as jnp
        return {
            "ref": counter_mod.PNCounter(inc=jnp.asarray(self.inc),
                                         dec=jnp.asarray(self.dec)),
            "lease": lww.LWWBank(
                clock=jnp.asarray(self.lease_clock),
                client=jnp.asarray(self.lease_client),
                payload={"owner": jnp.asarray(self.lease_owner),
                         "seq": jnp.asarray(self.lease_seq)}),
            "prefix": lww.LWWBank(
                clock=jnp.asarray(self.pfx_clock),
                client=jnp.asarray(self.pfx_client),
                payload={k: jnp.asarray(v) for k, v in self.pfx.items()}),
            "hb": gset.GCounter(jnp.asarray(self.hb)),
            "retire": gset.GSet(jnp.asarray(self.retire)),
            "journal": gset.GLog(
                count=jnp.asarray(self.jr_count),
                fields={k: jnp.asarray(v) for k, v in self.jr.items()}),
        }

    def load(self, tree: dict) -> None:
        """Adopt a joined state (post delta-apply) and observe its clocks so
        later local LWW writes stay ahead of everything merged in."""
        host = lambda x: np.array(x)       # mutable host copy
        self.inc = host(tree["ref"].inc)
        self.dec = host(tree["ref"].dec)
        self.lease_clock = host(tree["lease"].clock)
        self.lease_client = host(tree["lease"].client)
        self.lease_owner = host(tree["lease"].payload["owner"])
        self.lease_seq = host(tree["lease"].payload["seq"])
        self.pfx_clock = host(tree["prefix"].clock)
        self.pfx_client = host(tree["prefix"].client)
        self.pfx = {k: host(v) for k, v in tree["prefix"].payload.items()}
        self.hb = host(tree["hb"].counts)
        self.retire = host(tree["retire"].member)
        self.jr_count = host(tree["journal"].count)
        self.jr = {k: host(v) for k, v in tree["journal"].fields.items()}
        self.lam = max(self.lam, int(self.lease_clock.max()),
                       int(self.pfx_clock.max()))

    def apply_delta(self, d: Any) -> None:
        self.load(delta_mod.apply_jit(self.state(), d))

    def digest(self) -> bytes:
        """Order-stable byte digest of the CRDT state (for convergence
        traces; bitwise equality of digests == bitwise equality of state)."""
        import hashlib
        m = hashlib.sha256()
        for arr in (self.inc, self.dec, self.lease_clock, self.lease_client,
                    self.lease_owner, self.lease_seq, self.pfx_clock,
                    self.pfx_client, *(self.pfx[k] for k in sorted(self.pfx)),
                    self.hb, self.retire, self.jr_count,
                    *(self.jr[k] for k in sorted(self.jr))):
            m.update(np.ascontiguousarray(arr).tobytes())
        return m.digest()


# ---------------------------------------------------------------------------
# Anti-entropy gossip (delta engine on an unreliable channel)
# ---------------------------------------------------------------------------


@dataclass
class DeltaPacket:
    """One gossip hop: a fixed-capacity delta of src's state beyond what dst
    last acknowledged.  ``nbytes`` is constant per (store shape, capacity) —
    that is what makes sync-bytes a deterministic, regression-gatable
    counter."""

    src: int
    dst: int
    send_time: int
    delta: Any
    nbytes: int


@dataclass
class AckPacket:
    src: int
    dst: int
    send_time: int


class AntiEntropyNode:
    """Per-replica gossip endpoint with per-peer acknowledged frontiers.

    Unlike ``delta.DeltaSync`` (reliable shared-frontier all-to-all), this
    node tolerates an adversarial channel: the frontier for a peer advances
    only when that peer ACKNOWLEDGES a packet, so dropped packets simply
    re-extract on the next round; duplicated or reordered packets are
    no-ops by join idempotence/commutativity; delayed acks join in late
    (frontiers are monotone).  Convergence is delayed, never lost.
    """

    PENDING_LIMIT = 64        # unacked shipped-frontiers kept per peer

    def __init__(self, store: ReplicatedPageStore, capacity: int = 32,
                 gossip=None, journal_capacity: Optional[int] = None):
        from repro.serving import engine as engine_mod
        self.store = store
        self.capacity = capacity
        # The journal lane is chattier than the page-table leaves (one entry
        # per prompt/decode token), so it ships with its own, larger delta
        # capacity — a per-leaf override resolved by delta._cap_for.
        jcap = min(store.journal_capacity,
                   4 * capacity if journal_capacity is None
                   else journal_capacity)
        cap_spec = (("journal", jcap), ("*", capacity))
        self.gossip = gossip if gossip is not None else \
            engine_mod.make_gossip_fns(
                zero_state(store.num_replicas, store.num_pages,
                           store.prefix_slots, store.journal_capacity),
                cap_spec)
        peers = [j for j in range(store.num_replicas) if j != store.rid]
        self.acked = {j: self.gossip.genesis for j in peers}
        self.pending: dict[int, dict[int, Any]] = {j: {} for j in peers}
        self.bytes_sent = 0
        self.packets_sent = 0

    def make_packet(self, dst: int, now: int) -> DeltaPacket:
        d, shipped = self.gossip.extract(self.store.state(), self.acked[dst])
        pend = self.pending[dst]
        pend[now] = shipped
        while len(pend) > self.PENDING_LIMIT:
            pend.pop(min(pend))           # oldest unacked: superseded anyway
        nb = delta_mod.nbytes(d)
        self.bytes_sent += nb
        self.packets_sent += 1
        return DeltaPacket(self.store.rid, dst, now, d, nb)

    def receive(self, pkt: DeltaPacket, now: int) -> AckPacket:
        self.store.last_heard[pkt.src] = max(self.store.last_heard[pkt.src],
                                             now)
        self.store.load(self.gossip.apply(self.store.state(), pkt.delta))
        return AckPacket(self.store.rid, pkt.src, pkt.send_time)

    def receive_ack(self, ack: AckPacket, now: int) -> None:
        self.store.last_heard[ack.src] = max(self.store.last_heard[ack.src],
                                             now)
        fr = self.pending[ack.src].pop(ack.send_time, None)
        if fr is not None:
            self.acked[ack.src] = delta_mod.join_frontiers(
                self.acked[ack.src], fr)


# ---------------------------------------------------------------------------
# Scheduler-facing backends
# ---------------------------------------------------------------------------


class ReplicatedPageAllocator:
    """Drop-in for ``scheduler.PageAllocator`` backed by the replicated
    store.  Allocation draws from this replica's home partition only;
    refcounts, leases and the retirement protocol ride the CRDT state.

    ``ttl``/``grace``/``linger`` are in the caller's step units (the
    simulator's logical clock, or engine steps for ``MultiEngineServer``).
    The safety inequality — fence at ``ttl`` < retire-vote at ``2*ttl`` +
    ``grace`` — is baked in; ``linger`` must exceed the channel's maximum
    in-flight time for rule 3 (see module docstring) to hold.
    """

    def __init__(self, store: ReplicatedPageStore, *, ttl: int = 8,
                 grace: Optional[int] = None, linger: int = 0):
        self.store = store
        self.ttl = ttl
        self.retire_after = 2 * ttl
        self.grace = ttl if grace is None else grace
        self.linger = linger
        p, n, rid = store.num_pages, store.num_replicas, store.rid
        self._home0 = (np.arange(p, dtype=np.int64) * n) // p
        self._mine = {int(pg) for pg in np.nonzero(self._home0 == rid)[0]}
        self._free = sorted(self._mine, reverse=True)
        self._outstanding: set[int] = set()
        self._cooling: dict[int, int] = {}      # page -> cooled-since step
        self._exported: set[int] = set()
        self._claims: dict[int, tuple[int, int]] = {}   # page -> (t0, seq)
        self.now = 0                            # advanced by maintain()
        self.reclaimed_pages = 0
        self.fence_steps = 0

    # -- PageAllocator API --------------------------------------------------

    @property
    def num_pages(self) -> int:
        return self.store.num_pages        # engines size their pool to this

    @property
    def available(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> Optional[list[int]]:
        if n <= 0:
            return []
        if self.halted or self.fenced(self.now) or n > len(self._free):
            return None
        pages, self._free = self._free[-n:][::-1], self._free[:-n]
        for p in pages:
            _, seq = self.store.lease(p)
            self.store.lease_write(p, self.store.rid, seq + 1)
            self.store.ref_add(p)
            self._outstanding.add(p)
        return pages

    def reserve(self, n: int) -> Optional[sched_mod.Reservation]:
        pages = self.alloc(n)
        if pages is None:
            return None
        return sched_mod.Reservation(self, pages)

    def share(self, pages: list[int], row: Optional[int] = None) -> None:
        for p in pages:
            if self.store.refcount(p) <= 0:
                raise ValueError(
                    f"cannot share unallocated page {p}"
                    f"{sched_mod._row_ctx(row)} (refcount "
                    f"{self.store.refcount(p)})")
            self.store.ref_add(p)

    def refcount(self, page: int) -> int:
        return self.store.refcount(page)

    def generation(self, page: int) -> int:
        """The page's lease epoch: bumps on every alloc and every
        free-to-zero, which is exactly the staleness the local PrefixCache
        guards against."""
        return self.store.lease(page)[1]

    def free(self, pages: list[int], row: Optional[int] = None) -> None:
        for p in reversed(pages):
            if self.store.lane_held(p) < 1:
                raise ValueError(
                    f"double free of page {p}{sched_mod._row_ctx(row)} "
                    f"(lane {self.store.rid} holds "
                    f"{self.store.lane_held(p)})")
            self.store.ref_sub(p)          # raises on lane double-free
            self._retire_if_idle(p)

    # -- replication-side machinery ------------------------------------------

    def _retire_if_idle(self, p: int) -> None:
        """Home-side: a page of ours at effective refcount 0 ends its epoch
        (seq bump fences stale references) and cools or frees."""
        if p not in self._mine or p not in self._outstanding:
            return
        if self.store.refcount(p) != 0:
            return                         # remote lanes still hold refs
        _, seq = self.store.lease(p)
        self.store.lease_write(p, self.store.rid, seq + 1)
        self._outstanding.discard(p)
        if p in self._exported and self.linger > 0:
            self._cooling[p] = self.now
        else:
            self._free.append(p)

    def mark_exported(self, page: int) -> None:
        self._exported.add(page)

    def scavenge(self) -> None:
        """After a sync round: reap home pages whose last remote references
        were released elsewhere, and promote cooled pages whose linger has
        elapsed (re-validating refcount — an in-flight provisional share
        may have resurrected one; it will abort on the epoch bump, so the
        page just keeps cooling until the release arrives)."""
        for p in sorted(self._outstanding):
            self._retire_if_idle(p)
        for p in sorted(self._cooling):
            if self.now - self._cooling[p] >= self.linger:
                if self.store.refcount(p) == 0:
                    del self._cooling[p]
                    self._free.append(p)
                else:
                    self._cooling[p] = self.now

    @property
    def halted(self) -> bool:
        return self.store.is_retired(self.store.rid)

    def fenced(self, now: int) -> bool:
        """Safety rule 4: stall while any non-retired peer is unheard."""
        retired = self.store.retired_mask()
        return any(now - t > self.ttl
                   for j, t in self.store.last_heard.items()
                   if not retired[j])

    def maintain(self, now: int) -> None:
        """One protocol step: heartbeat, stale-peer votes, reclamation."""
        self.now = now
        if self.halted:
            return
        self.store.heartbeat(now)
        retired = self.store.retired_mask()
        for j in self.store.last_heard:
            if not retired[j] and now - int(self.store.hb[j]) \
                    > self.retire_after:
                self.store.vote_retire(j)
        retired = self.store.retired_mask()
        if self.fenced(now):
            self.fence_steps += 1
            self._claims.clear()           # a fenced claimant starts over
            return
        live = [r for r in range(self.store.num_replicas) if not retired[r]]
        if not live or live[0] != self.store.rid:
            return
        # Lowest live replica re-homes every retired replica's pages.
        for p in np.nonzero(retired[self._home0])[0]:
            p = int(p)
            if p in self._mine:
                continue
            owner, seq = self.store.lease(p)
            claim = self._claims.get(p)
            if claim is None:
                self.store.lease_write(p, self.store.rid, seq + 1)
                self._claims[p] = (now, seq + 1)
            else:
                t0, cseq = claim
                if owner != self.store.rid or seq != cseq:
                    del self._claims[p]    # lost the epoch — retry next step
                elif now - t0 >= self.grace:
                    del self._claims[p]
                    self._mine.add(p)
                    self.reclaimed_pages += 1
                    if self.store.refcount(p) == 0:
                        self._free.append(p)
                    else:                  # live sharers elsewhere
                        self._outstanding.add(p)


class ReplicatedPrefixCache(sched_mod.PrefixCache):
    """The scheduler's ``PrefixCache`` plus cross-replica publication.

    Local lookups/registration behave exactly like the host-local cache
    (same OrderedDict LRU, same generation validation — the generation now
    being the page's replicated lease epoch).  On top of that, full chain
    pages this replica OWNS are published to the replicated prefix map, and
    ``resolve_remote`` probes the map for prompt pages resident on peers.
    ``cross_replica_hits`` counts *committed* uses of a remote page — the
    share/adoption survived the rule-3 epoch re-check — not raw resolves,
    so the bench counter only ever counts usable hits.

    Physical adoption needs one more fact registration cannot carry: the
    local cache registers at reservation time, before the owner's chunk
    writes land the bytes.  ``mark_filled`` records (page → lease seq) once
    this engine has physically written a page, and cross-replica
    publication is DEFERRED until then — the replicated map only ever
    advertises pages whose bytes landed, so it implicitly carries the
    data-plane readiness flag an RDMA transport would signal out of band.
    (Adopters still re-check the exporter's ``filled_seq`` before
    transferring: a later re-registration of the same prefix may have
    re-homed the map entry onto a page mid-write.)
    """

    def __init__(self, allocator: ReplicatedPageAllocator, page_size: int,
                 max_entries: int = 4096):
        super().__init__(allocator, page_size, max_entries)
        self.store = allocator.store
        self.cross_replica_hits = 0
        self.published = 0
        self.filled: dict[int, int] = {}   # page -> lease seq at fill time
        self._pending: dict[int, tuple[int, int]] = {}  # page -> (hash, seq)

    def mark_filled(self, pages: list[int]) -> None:
        """Record that this engine's pool physically holds ``pages``' bytes
        (called by the scheduler once the covering writes have landed, and
        by the server after a committed transfer), and flush any deferred
        publication for them."""
        for pg in pages:
            pg = int(pg)
            _owner, seq = self.store.lease(pg)
            self.filled[pg] = seq
            pend = self._pending.pop(pg, None)
            if pend is not None and pend[1] == seq:
                self._do_publish(pend[0], pg, seq)

    def filled_seq(self, page: int) -> Optional[int]:
        """The lease seq this engine's bytes for ``page`` were written
        under, or None if unwritten / stale (the epoch moved since: the
        page was freed-to-zero or re-homed, so the bytes are garbage)."""
        seq = self.filled.get(int(page))
        if seq is None:
            return None
        _owner, cur = self.store.lease(int(page))
        return seq if cur == seq else None

    def _do_publish(self, h: int, page: int, seq: int) -> None:
        self.store.publish_prefix(h, page, seq)
        self._allocator.mark_exported(page)
        self.published += 1

    def _publish_page(self, key: tuple, page: int) -> None:
        owner, seq = self.store.lease(page)
        if owner != self.store.rid:
            return                         # only the lease owner publishes
        h = prefix_hash(key)
        if self.filled_seq(page) == seq:
            self._do_publish(h, page, seq)
        else:                              # bytes not landed yet: defer to
            self._pending[page] = (h, seq)  # mark_filled (publish-on-fill)

    def _publish_chain(self, tokens: list[int], pages: list[int]) -> None:
        ps = self.page_size
        for k in range(1, min(len(tokens) // ps, len(pages)) + 1):
            self._publish_page(tuple(tokens[:k * ps]), pages[k - 1])

    def register(self, tokens: list[int], pages: list[int]) -> None:
        super().register(tokens, pages)
        self._publish_chain(tokens, pages)

    def register_tail(self, tokens: list[int], pages: list[int]) -> None:
        super().register_tail(tokens, pages)
        ps = self.page_size
        k = len(pages)
        if k and k * ps <= len(tokens):    # the page just grown is full
            self._publish_page(tuple(tokens[:k * ps]), pages[k - 1])

    def resolve_remote(self, key: tuple) -> Optional[tuple[int, int, int]]:
        """Validated replicated-map probe for the full chain page covering
        ``key``: (owner_rid, page, seq), or None.  Validation: hash match,
        publishing epoch still current, page still referenced, owner lane
        still live.  The *caller* performs the provisional share + commit
        dance (protocol rule 3)."""
        hit = self.store.lookup_prefix(prefix_hash(key))
        if hit is None:
            return None
        owner, page, seq = hit
        if owner < 0 or owner >= self.store.num_replicas:
            return None
        cur_owner, cur_seq = self.store.lease(page)
        if (cur_seq != seq or cur_owner != owner
                or self.store.retired_mask()[owner]
                or self.store.refcount(page) <= 0):
            return None
        return owner, page, seq

    # NOTE: ``lookup`` is the inherited local-only longest-prefix match.
    # Remote continuation is the server's adoption hook: it resolves,
    # transfers the physical bytes, and bumps ``cross_replica_hits`` only
    # on commit — a resolve the epoch re-check aborts is not a usable hit.


# ---------------------------------------------------------------------------
# Multi-engine serving
# ---------------------------------------------------------------------------


class ReliableChannel:
    """Lossless in-process transport: every packet sent this tick delivers
    this tick, in send order.  API-compatible with the simulator's
    ``FaultyChannel`` (``send``/``deliver``/``in_flight``/``healed``), so
    ``MultiEngineServer`` syncs through either interchangeably."""

    def __init__(self):
        self._q: list = []
        self.sent = 0
        self.healed = True                 # nothing to heal

    def send(self, pkt, now: int) -> None:
        self._q.append(pkt)
        self.sent += 1

    def deliver(self, now: int) -> list:
        out, self._q = self._q, []
        return out

    @property
    def in_flight(self) -> int:
        return len(self._q)


class MultiEngineServer:
    """N continuous-batching engines on one replicated page table.

    Each engine gets its own ``ReplicatedPageStore`` replica plus the
    allocator/prefix-cache adapters; requests are dispatched round-robin;
    every ``sync_every`` steps the replicas gossip all-to-all through their
    ``AntiEntropyNode``s over ``channel`` — the default ``ReliableChannel``
    (under which ``ttl`` is sized so the fencing rule never fires) or the
    simulator's ``FaultyChannel``, which subjects the *real* engines to
    drop/dup/delay/reorder/partition schedules.

    Fault tolerance (the PR-6 fault model, promoted to the real path):

      * Every accepted request's descriptor is journaled in its owner's
        CRDT journal lane (``J_ACCEPT`` + per-token ``J_PROMPT``, then one
        ``J_GEN`` per decode step and a terminal marker) and gossips with
        the page table.
      * ``crash(r)`` crash-stops replica r mid-flight.  Its heartbeat
        freezes; survivors fence, vote, and retire it through the existing
        lease/TTL/majority machinery, after which its pages re-home and
        the lowest live replica ADOPTS its unfinished requests: each is
        reconstructed from the merged journal (prompt + generated-so-far)
        and re-admitted — through the prefix cache, so recovered prefill
        is mostly page hits — with capped retries and deterministic
        backoff (``engine.backoff_steps``).
      * Exactly-once delivery = journaled ``J_DONE``: completion is
        recorded once (re-runs that find a DONE already visible suppress
        the duplicate), and the adopter is deterministic (lowest live), so
        an accepted-and-not-shed request completes exactly once.
      * Crash failover needs enough survivors to form a retirement
        majority (``floor(N/2)+1``): with N=2 a crashed peer's requests
        stay pinned rather than being reclaimed unsafely — the same
        trade the page table itself makes.
    """

    def __init__(self, cfg, params, *, replicas: int = 2, batch: int,
                 max_len: int, page_size: int = 64,
                 pages_per_replica: Optional[int] = None,
                 sync_every: int = 1, delta_capacity: int = 32,
                 channel=None, ttl: Optional[int] = None,
                 journal_capacity: int = 256,
                 max_queue: Optional[int] = None, max_retries: int = 2,
                 adopt_grace: Optional[int] = None,
                 roles: Optional[list] = None,
                 adopt_pages: bool = True,
                 **engine_kwargs):
        if replicas < 1:
            raise ValueError("replicas must be >= 1")
        if roles is None:
            roles = ["mixed"] * replicas
        roles = list(roles)
        if len(roles) != replicas:
            raise ValueError(f"roles must name every replica: got "
                             f"{len(roles)} roles for {replicas} replicas")
        for role in roles:
            if role not in ("prefill", "decode", "mixed"):
                raise ValueError(f"role must be prefill/decode/mixed, "
                                 f"got {role!r}")
        self.roles = roles
        self.replicas = replicas
        self.sync_every = sync_every
        maxp = -(-max_len // page_size)
        per = pages_per_replica if pages_per_replica is not None \
            else batch * maxp
        num_pages = replicas * per
        ttl = 4 * sync_every if ttl is None else ttl
        self.channel = channel if channel is not None else ReliableChannel()
        self.max_retries = max_retries
        self.adopt_grace = ttl if adopt_grace is None else adopt_grace
        self.stores = [ReplicatedPageStore(r, replicas, num_pages,
                                           journal_capacity=journal_capacity)
                       for r in range(replicas)]
        gossip = None
        self.allocators, self.caches, self.nodes = [], [], []
        for store in self.stores:
            node = AntiEntropyNode(store, capacity=delta_capacity,
                                   gossip=gossip)
            gossip = node.gossip           # share the jitted triple
            alloc = ReplicatedPageAllocator(store, ttl=ttl, linger=0)
            self.nodes.append(node)
            self.allocators.append(alloc)
            self.caches.append(ReplicatedPrefixCache(alloc, page_size))
        self.engines = [
            sched_mod.ContinuousBatchingEngine(
                cfg, params, batch=batch, max_len=max_len, paged=True,
                page_size=page_size, num_pages=num_pages,
                prefix_sharing=True, allocator=self.allocators[r],
                prefix_cache=self.caches[r], max_queue=max_queue,
                journal=(lambda rr: lambda kind, req:
                         self._journal(rr, kind, req))(r),
                role=roles[r],
                **engine_kwargs)
            for r in range(replicas)]
        # Disaggregation data plane: decode/mixed replicas adopt published
        # physical pages from peers at admission (prefill replicas only
        # export).  The hook runs the rule-3 share + transfer + commit
        # dance; see ``_adopt``.  ``adopt_pages=False`` keeps the
        # coordination layer (publication, routing) but never moves bytes —
        # the local-prefill baseline the disagg bench compares against.
        if adopt_pages:
            for r, eng in enumerate(self.engines):
                if eng.role != "prefill":
                    eng.adopt_hook = (lambda rr: lambda rid, ctx, shared:
                                      self._adopt(rr, rid, ctx, shared))(r)
        self.transfer_bytes = 0            # physical bytes moved by adoption
        self.transferred_pages = 0         # committed page transfers
        self.adopt_aborts = 0              # rule-3 aborts (epoch moved/crash)
        self._xfer_crash: Optional[tuple[int, int]] = None
        self.clock = 0
        self.syncs = 0
        self._rr = 0
        self.crashed = [False] * replicas
        self.crash_events: list[dict] = []
        self._retired_seen: dict[int, int] = {}
        self._recovery_pending = False
        self._adopted_this_step = 0
        self.recovered_requests = 0        # reconstructed + re-admitted
        self.recovered_complete = 0        # finished; only the DONE was lost
        self.failed_requests = 0           # exceeded max_retries
        self.lost_requests = 0             # descriptor incomplete (journal)
        self.dup_done_suppressed = 0       # exactly-once dedup hits

    # -- request journal ----------------------------------------------------

    def _journal(self, r: int, kind: str, req: sched_mod.Request) -> None:
        """Engine → journal hook: record decode progress and terminal
        status in replica r's journal lane."""
        store = self.stores[r]
        if kind == "gen":
            store.journal_append(req.rid, J_GEN, len(req.tokens) - 1,
                                 req.tokens[-1])
        elif kind == "done":
            if self._done_logged(store, req.rid):
                self.dup_done_suppressed += 1
            else:
                store.journal_append(req.rid, J_DONE, len(req.tokens))
        elif kind == "shed":
            store.journal_append(req.rid, J_SHED)
        elif kind == "expired":
            store.journal_append(req.rid, J_EXPIRED)

    @staticmethod
    def _done_logged(store: ReplicatedPageStore, rid: int) -> bool:
        return any(t == J_DONE and r == rid
                   for _, r, t, _a, _b in store.journal_entries())

    # -- physical page adoption (prefill/decode disaggregation) -------------

    def arm_transfer_crash(self, exporter: int, after: int = 0) -> None:
        """Chaos hook: crash-stop ``exporter`` in the middle of its
        (``after``+1)-th exported page transfer — after the adopter's
        provisional share and BEGIN journal entry, before the commit check
        — so the epoch re-check must abort and roll the adopter back."""
        self._xfer_crash = (exporter, after)

    def _adopt(self, r: int, rid: int, ctx: list,
               shared: list) -> tuple[list, list, int]:
        """Admission-time adoption hook for decode/mixed replica ``r``.

        Walks the prompt's full-page chain.  Position k's page is, in
        order of preference: the locally shared page if this engine's pool
        already holds its bytes (``filled_seq``); otherwise a peer-published
        page (validated resolve — hash/epoch/owner-live, as
        ``resolve_remote``) whose exporter reports the bytes landed, pulled
        by the rule-3 dance — provisional ``share``, physical
        ``copy_pages_across`` into this engine's pool, then commit iff the
        publishing epoch is unchanged and the exporter survived the
        transfer.  An unfilled local page with no adoptable peer copy
        breaks the covered chain; the remaining locally shared pages are
        kept as plain mapping targets (the admission stream rewrites them
        with identical bytes), exactly as before.  An aborted transfer
        drops the provisional reference and discards the staged bytes: the
        page was never bound to a row, so the adopter state is untouched.
        Every transfer is journaled (BEGIN then COMMIT/ABORT) in this
        replica's lane under the adopting request's rid.

        Returns ``(lead_pages, adopted_pages, covered_tokens)``: the row's
        full leading page chain (every page already ref-held here — kept
        locals are shared by this hook, adopted pages by the rule-3
        commit), the subset that was physically transferred, and how many
        leading prompt positions are physically cached in this pool.
        """
        eng = self.engines[r]
        cache = self.caches[r]
        alloc = self.allocators[r]
        store = self.stores[r]
        ps = cache.page_size
        lead: list = []
        adopted: list = []
        covered_pages = 0
        chain_live = True
        can_adopt = not alloc.halted and not alloc.fenced(alloc.now)
        n_full = len(ctx) // ps
        for k in range(1, max(n_full, len(shared)) + 1):
            local = shared[k - 1] if k <= len(shared) else None
            if chain_live and local is not None \
                    and cache.filled_seq(local) is not None:
                lead.append(local)
                covered_pages = k
                continue
            if chain_live and can_adopt and k <= n_full:
                page = self._pull_page(r, rid, tuple(ctx[:k * ps]), lead)
                if page is not None:
                    lead.append(page)
                    adopted.append(page)
                    covered_pages = k
                    continue
            chain_live = False
            if local is None:
                break
            lead.append(local)             # mapping-only use past the break
        kept = [p for p in lead if p not in set(adopted)]
        if kept:
            alloc.share(kept)
        return lead, adopted, covered_pages * ps

    def _pull_page(self, r: int, rid: int, key: tuple,
                   lead: list) -> Optional[int]:
        """One rule-3 physical pull for the chain page covering ``key``;
        returns the committed page or None (no adoptable copy / abort)."""
        eng = self.engines[r]
        cache = self.caches[r]
        alloc = self.allocators[r]
        store = self.stores[r]
        hit = cache.resolve_remote(key)
        if hit is None:
            return None
        owner, page, seq = hit
        if owner == r or self.crashed[owner] or page in lead:
            return None
        if self.caches[owner].filled_seq(page) != seq:
            return None                    # map entry re-homed mid-write
        alloc.share([page])
        store.journal_append(rid, J_XFER_BEGIN, page, seq)
        newc, nb = cache_mod.copy_pages_across(
            self.engines[owner].cache, eng.cache, [page])
        if self._xfer_crash is not None and self._xfer_crash[0] == owner:
            exp, after = self._xfer_crash
            if after <= 0:
                self._xfer_crash = None
                self.crash(owner)          # exporter dies mid-transfer
            else:
                self._xfer_crash = (exp, after - 1)
        if self.crashed[owner] or store.lease(page) != (owner, seq):
            store.ref_sub(page)            # roll the provisional share back
            store.journal_append(rid, J_XFER_ABORT, page, seq)
            self.adopt_aborts += 1
            return None
        eng.cache = newc
        store.journal_append(rid, J_XFER_COMMIT, page, seq)
        cache.mark_filled([page])
        cache.cross_replica_hits += 1
        self.transferred_pages += 1
        self.transfer_bytes += nb
        return page

    # -- request routing ----------------------------------------------------

    def _prefix_published(self, prompt: list) -> bool:
        """Routing probe: does any live replica's view publish this
        prompt's first full page?  (Unvalidated — a routing heuristic, not
        an adoption decision.)"""
        ps = self.caches[0].page_size
        if len(prompt) < ps:
            return False
        h = prefix_hash(tuple(prompt[:ps]))
        return any(self.stores[r].lookup_prefix(h) is not None
                   for r in range(self.replicas) if not self.crashed[r])

    def _accept(self, r: int, req: sched_mod.Request) -> int:
        store = self.stores[r]
        store.journal_append(
            req.rid, J_ACCEPT,
            (len(req.prompt) << 16) | req.max_new_tokens,
            0 if req.eos_id is None else req.eos_id + 1)
        for i, t in enumerate(req.prompt):
            store.journal_append(req.rid, J_PROMPT, i, t)
        self.engines[r].submit(req)
        return r

    def submit(self, req: sched_mod.Request) -> int:
        """Dispatch a request to a live replica; journals the descriptor in
        the accepting replica's lane.  Returns the replica.

        All-mixed topology: plain round-robin.  Disaggregated topology:
        cold prompts (no replica publishes their first page yet) go to
        prefill-role replicas, warm prompts to decode-role replicas — whose
        adoption hook pulls the published pages — with mixed replicas as
        second choice and any live replica as the last resort, so a
        one-sided crash degrades to the old behavior instead of rejecting.
        """
        if all(role == "mixed" for role in self.roles):
            for _ in range(self.replicas):
                r = self._rr
                self._rr = (self._rr + 1) % self.replicas
                if self.crashed[r] or self.allocators[r].halted:
                    continue
                return self._accept(r, req)
            raise RuntimeError("no live replica to accept the request")
        want = ("decode" if self._prefix_published(req.prompt)
                else "prefill")
        tiers = ([r for r in range(self.replicas) if self.roles[r] == want],
                 [r for r in range(self.replicas)
                  if self.roles[r] == "mixed"],
                 [r for r in range(self.replicas)
                  if self.roles[r] not in (want, "mixed")])
        start = self._rr
        self._rr += 1
        for tier in tiers:
            for i in range(len(tier)):
                r = tier[(start + i) % len(tier)]
                if self.crashed[r] or self.allocators[r].halted:
                    continue
                return self._accept(r, req)
        raise RuntimeError("no live replica to accept the request")

    # -- gossip through the channel -----------------------------------------

    def _pump(self, now: int) -> None:
        """Deliver everything the channel has due: delta packets go to the
        destination node (its ack rides the channel back), acks advance the
        sender's frontier.  Packets addressed to a crashed replica drop on
        the floor — exactly what a dead process does."""
        progressed = True
        while progressed:
            progressed = False
            for pkt in self.channel.deliver(now):
                progressed = True
                if self.crashed[pkt.dst]:
                    continue
                node = self.nodes[pkt.dst]
                if isinstance(pkt, AckPacket):
                    node.receive_ack(pkt, now)
                else:
                    self.channel.send(node.receive(pkt, now), now)

    def sync(self) -> None:
        """One all-to-all gossip round through the channel.  Reliable
        channel: packets and acks deliver in order, same tick — bit-
        identical to the pre-channel reliable sync.  Faulty channel:
        this round's packets land on later ticks (min delay 1), earlier
        rounds' survivors land now."""
        now = self.clock
        self._pump(now)
        for src in range(self.replicas):
            if self.crashed[src] or self.allocators[src].halted:
                continue
            node = self.nodes[src]
            retired = self.stores[src].retired_mask()
            for dst in node.acked:
                if retired[dst]:
                    continue               # no point gossiping to the dead
                self.channel.send(node.make_packet(dst, now), now)
        self._pump(now)
        for r in range(self.replicas):
            if not self.crashed[r]:
                self.allocators[r].scavenge()
        self.syncs += 1

    # -- crash failover -----------------------------------------------------

    def crash(self, r: int) -> None:
        """Crash-stop replica r: it stops stepping, heartbeating and
        gossiping, and every packet addressed to it is dropped.  Recovery
        rides the retirement protocol; see the class docstring."""
        if self.crashed[r]:
            return
        self.crashed[r] = True
        self.crash_events.append({"replica": r, "step": self.clock})
        live = self.replicas - sum(self.crashed)
        if live >= self.stores[0].majority:
            self._recovery_pending = True

    @staticmethod
    def _contiguous(entries: dict[int, int]) -> list[int]:
        """Longest gap-free run of journaled (index → value) from 0."""
        out: list[int] = []
        while len(out) in entries:
            out.append(entries[len(out)])
        return out

    def _fold_journal(self, store: ReplicatedPageStore) -> dict[int, dict]:
        """Merge the journal into per-request descriptors.  The owner is
        the ACCEPT lane until an ADOPT supersedes it (highest retry count
        wins — lanes are scanned in id order, not arrival order)."""
        info: dict[int, dict] = {}
        for lane, rid, tag, a, b in store.journal_entries():
            d = info.setdefault(rid, {
                "accept_lane": None, "adopt_lane": None, "retries": 0,
                "plen": 0, "max_new": 0, "eos": None,
                "prompt": {}, "gen": {}, "terminal": False})
            if tag == J_ACCEPT:
                d["accept_lane"] = lane
                d["plen"] = a >> 16
                d["max_new"] = a & 0xFFFF
                d["eos"] = b - 1 if b > 0 else None
            elif tag == J_PROMPT:
                d["prompt"][a] = b
            elif tag == J_GEN:
                d["gen"][a] = b
            elif tag == J_ADOPT:
                if a >= d["retries"]:
                    d["adopt_lane"], d["retries"] = lane, a
            elif tag in (J_DONE, J_SHED, J_EXPIRED, J_FAIL):
                d["terminal"] = True
        for d in info.values():
            d["owner"] = (d["adopt_lane"] if d["adopt_lane"] is not None
                          else d["accept_lane"])
        return info

    def _recover(self) -> None:
        """Adopt a retired replica's unfinished requests.  Runs on the
        lowest live replica's view only (a single deterministic adopter,
        like page re-homing), after retirement has been observed for
        ``adopt_grace`` ticks so the crashed lane's journal entries have
        converged across survivors."""
        from repro.serving import engine as engine_mod
        live = [r for r in range(self.replicas)
                if not self.crashed[r] and not self.allocators[r].halted]
        if not live:
            self._recovery_pending = False
            return
        adopter = live[0]
        store = self.stores[adopter]
        retired = store.retired_mask()
        crashed = [r for r in range(self.replicas) if self.crashed[r]]
        if not all(retired[r] for r in crashed):
            return                         # retirement votes still in flight
        for r in crashed:
            self._retired_seen.setdefault(r, self.clock)
        if any(self.clock - self._retired_seen[r] < self.adopt_grace
               for r in crashed):
            return                         # journal still converging
        engine = self.engines[adopter]
        info = self._fold_journal(store)
        adopted = 0
        for rid in sorted(info):
            d = info[rid]
            if (d["owner"] is None or not retired[d["owner"]]
                    or d["terminal"]):
                continue
            prompt = self._contiguous(d["prompt"])
            gen = self._contiguous(d["gen"])
            if len(prompt) != d["plen"] or d["max_new"] < 1:
                store.journal_append(rid, J_FAIL)   # descriptor incomplete
                self.lost_requests += 1
                continue
            retries = d["retries"] + 1
            if retries > self.max_retries:
                store.journal_append(rid, J_FAIL)
                self.failed_requests += 1
                continue
            store.journal_append(rid, J_ADOPT, retries)
            req = sched_mod.Request(rid=rid, prompt=prompt,
                                    max_new_tokens=d["max_new"],
                                    eos_id=d["eos"])
            req.tokens = list(gen)
            req.retries = retries
            if (len(gen) >= d["max_new"]
                    or (d["eos"] is not None and gen
                        and gen[-1] == d["eos"])):
                # Finished on the crashed replica; only the DONE was lost.
                req.status = sched_mod.COMPLETED
                store.journal_append(rid, J_DONE, len(gen))
                self.recovered_complete += 1
                continue
            engine.submit(req)
            req.retry_at = engine.stats["steps"] + \
                engine_mod.backoff_steps(rid, retries)
            self.recovered_requests += 1
            adopted += 1
        self._recovery_pending = False
        self._adopted_this_step = adopted

    # -- serve loop ---------------------------------------------------------

    def step(self) -> bool:
        more = False
        for r, e in enumerate(self.engines):
            if not self.crashed[r]:
                more = e.step() or more
        self.clock += 1
        for r, alloc in enumerate(self.allocators):
            if not self.crashed[r]:
                alloc.maintain(self.clock)
        if self.clock % self.sync_every == 0:
            self.sync()
        self._adopted_this_step = 0
        if self._recovery_pending:
            self._recover()
        # Adoption re-enqueues work AFTER the engines stepped — the step
        # that adopts must report progress or the serve loop would exit
        # with the recovered requests still queued.
        return more or self._recovery_pending or self._adopted_this_step > 0

    def run(self, requests: list[sched_mod.Request],
            max_steps: int = 100_000) -> list[sched_mod.Request]:
        for req in requests:
            self.submit(req)
        for _ in range(max_steps):
            if not self.step():
                break
        else:
            raise RuntimeError("multi-engine serve hit max_steps")
        self.sync()                        # final round: frontiers settle
        return requests

    @property
    def sync_bytes(self) -> int:
        return sum(node.bytes_sent for node in self.nodes)

    def stats(self) -> dict:
        out = {"replicas": self.replicas, "steps": self.clock,
               "syncs": self.syncs, "sync_bytes": self.sync_bytes,
               "sync_bytes_per_step": (self.sync_bytes // self.clock
                                       if self.clock else 0),
               "cross_replica_hits": sum(c.cross_replica_hits
                                         for c in self.caches),
               "published_prefix_pages": sum(c.published
                                             for c in self.caches),
               "crashes": len(self.crash_events),
               "recovered_requests": self.recovered_requests,
               "recovered_complete": self.recovered_complete,
               "failed_requests": self.failed_requests,
               "lost_requests": self.lost_requests,
               "dup_done_suppressed": self.dup_done_suppressed,
               "transferred_pages": self.transferred_pages,
               "transfer_bytes": self.transfer_bytes,
               "adopt_aborts": self.adopt_aborts}
        for key in ("admitted", "completed", "gen_tokens", "prefill_tokens",
                    "shared_pages", "cow_copies", "preemptions",
                    "prefill_chunks", "decode_stall_steps",
                    "shed", "expired", "retried", "preempt_fenced",
                    "adopted_pages", "adopted_tokens",
                    "prefill_steps_avoided"):
            out[key] = sum(e.stats[key] for e in self.engines)
        return out

    def converged(self) -> bool:
        """Bitwise page-table agreement across live (non-crashed,
        non-halted) replicas."""
        stores = [s for r, s in enumerate(self.stores)
                  if not self.crashed[r] and not self.allocators[r].halted]
        if not stores:
            return True
        d0 = stores[0].digest()
        return all(s.digest() == d0 for s in stores[1:])


class ReplicatedPrefixPageMapper:
    """``PrefixPageMapper`` over a replicated page table (orchestrator
    ``--replicas N``).

    Agent rows are partitioned round-robin across N metadata replicas, each
    owning a home slice of ONE physical page pool (the agents still share a
    single batched engine, so page ids are globally meaningful).  Because
    the pool is physically shared, a validated remote prefix hit is adopted
    for REAL here: the row's block table points straight at the peer-owned
    page while this replica's counter lane holds the share — the in-process
    degenerate case of protocol rule 3, where the provisional share commits
    immediately because the lease epoch is re-read in the same tick.
    Replicas gossip at every coordination sync (``gossip()``), so
    cross-replica hits only appear once a peer's publication has shipped —
    exactly the observation-driven coordination the paper measures, applied
    to the serving plane.
    """

    def __init__(self, num_rows: int, maxp: int, page_size: int,
                 trash_page: int, *, replicas: int = 2,
                 num_pages: Optional[int] = None,
                 delta_capacity: int = 32, disaggregate: bool = False):
        if replicas < 1:
            raise ValueError("replicas must be >= 1")
        if disaggregate and replicas < 2:
            raise ValueError("disaggregate requires >= 2 metadata replicas "
                             "(one prefill home + decode homes)")
        num_pages = (num_rows + replicas) * maxp if num_pages is None \
            else num_pages
        if trash_page < num_pages:
            raise ValueError(
                f"trash_page {trash_page} lies inside the allocatable pool "
                f"[0, {num_pages})")
        self.replicas = replicas
        self.page_size = page_size
        self.maxp = maxp
        self.trash_page = trash_page
        self.stores = [ReplicatedPageStore(r, replicas, num_pages)
                       for r in range(replicas)]
        gossip = None
        self.nodes, self.allocators, self.caches = [], [], []
        for store in self.stores:
            node = AntiEntropyNode(store, capacity=delta_capacity,
                                   gossip=gossip)
            gossip = node.gossip
            alloc = ReplicatedPageAllocator(store, ttl=4, linger=0)
            self.nodes.append(node)
            self.allocators.append(alloc)
            self.caches.append(ReplicatedPrefixCache(alloc, page_size))
        self.host_bt = np.full((num_rows, maxp), trash_page, np.int32)
        self._row_pages: list[list[int]] = [[] for _ in range(num_rows)]
        self.shared_pages = 0
        self.cross_replica_hits = 0
        self.disaggregate = disaggregate
        self.now = 0
        self._dirty = True

    def _domain(self, row: int) -> int:
        # Disaggregated homing (orchestrator ``--disaggregate``): agent 0 —
        # the first to map the shared task header — homes on the prefill
        # domain 0 and publishes the header chain; every other agent homes
        # on a decode domain, so its header hits are cross-replica
        # adoptions of domain 0's filled pages rather than same-domain
        # local shares.  Default: round-robin.
        if self.disaggregate:
            return 0 if row == 0 else 1 + (row - 1) % (self.replicas - 1)
        return row % self.replicas

    def map_row(self, row: int, tokens: list[int], horizon: int) -> int:
        """Remap ``row``: longest local prefix run, then validated remote
        adoption for chain pages published by peers, fresh home pages for
        the rest.  Returns the number of shared (local + adopted) pages."""
        d = self._domain(row)
        alloc, cache = self.allocators[d], self.caches[d]
        ps = self.page_size
        npages = min(-(-horizon // ps), self.maxp)
        n_write = len(tokens) // ps       # decode writes from page n_write
        local = sched_mod.PrefixCache.lookup(cache, tokens,
                                             boundary=False)[:n_write]
        alloc.share(local)
        adopted: list[int] = []
        for k in range(len(local) + 1, n_write + 1):
            hit = cache.resolve_remote(tuple(tokens[:k * ps]))
            if hit is None or hit[0] == d:
                break
            owner, page, seq = hit
            alloc.share([page])            # provisional...
            if cache.store.lease(page) != (owner, seq):
                cache.store.ref_sub(page)  # ...epoch moved: abort
                break
            adopted.append(page)           # ...same tick: commit
            self.cross_replica_hits += 1
        shared = local + adopted
        fresh = alloc.alloc(npages - len(shared))
        if fresh is None:
            alloc.free(shared)
            raise RuntimeError("agent page pool exhausted")
        pages = shared + fresh
        old = self._row_pages[row]
        self._row_pages[row] = pages
        self.host_bt[row, :] = self.trash_page
        self.host_bt[row, :len(pages)] = pages
        if old:
            alloc.free(old)               # after remap: self-prefix shares
        cache.register(tokens[:n_write * ps], pages[:n_write])
        # The pool is physically shared and the row replays its own prompt
        # through the serve step, so the chain's bytes land in place —
        # mark filled here to flush the deferred (publish-on-fill)
        # publication for the pages this domain owns.
        cache.mark_filled(pages[:n_write])
        if self.disaggregate and d == 0 and n_write:
            # Prefill tier notifies on fill (as a disaggregated deployment
            # would): push the publication to the decode homes eagerly so
            # their very next map can adopt instead of re-allocating.
            self.gossip()
        self.shared_pages += len(shared)
        self._dirty = True
        return len(shared)

    def free_row(self, row: int) -> None:
        if self._row_pages[row]:
            self.allocators[self._domain(row)].free(self._row_pages[row])
            self._row_pages[row] = []
        self.host_bt[row, :] = self.trash_page
        self._dirty = True

    def install(self, cache):
        if self._dirty:
            import jax.numpy as jnp
            from repro.models import lm
            cache = lm.set_block_tables(cache, jnp.asarray(self.host_bt))
            self._dirty = False
        return cache

    def gossip(self) -> None:
        """One reliable all-to-all anti-entropy round (same tick)."""
        self.now += 1
        for alloc in self.allocators:
            alloc.now = self.now
            alloc.maintain(self.now)
        for src in range(self.replicas):
            for dst in range(self.replicas):
                if src == dst:
                    continue
                pkt = self.nodes[src].make_packet(dst, self.now)
                ack = self.nodes[dst].receive(pkt, self.now)
                self.nodes[src].receive_ack(ack, self.now)
        for alloc in self.allocators:
            alloc.scavenge()

    @property
    def sync_bytes(self) -> int:
        return sum(node.bytes_sent for node in self.nodes)

    def converged(self) -> bool:
        d0 = self.stores[0].digest()
        return all(s.digest() == d0 for s in self.stores[1:])
