"""Replicated CRDT page table — the distributed serving tier.

The scheduler's host-local ``PageAllocator`` refcounts and ``PrefixCache``
chain become replicated state shared by N serving engines:

  * per-page refcounts   — a PN-counter with one writer lane per replica
                           (``core/counter.py``): replica r's references to
                           page p live in lane r; the observed refcount is
                           the live-lane sum, so a crashed replica's zombie
                           references stop pinning pages once its retirement
                           is observed.
  * prefix → page map    — an LWW register bank (``core/lww.py``) keyed by a
                           62-bit hash of the token prefix: full chain pages
                           (immutable once filled) are published so peers
                           can discover shareable prompt KV.
  * page ownership       — an LWW lease ``(owner, seq)`` per page.  ``seq``
                           is the page's *epoch*: it bumps on every alloc
                           AND every free-to-zero, so any stale reference a
                           peer resolved under an old epoch fails validation
                           instead of aliasing reused KV.
  * liveness             — heartbeat G-counter + retirement-vote G-set.

All of it syncs through the PR-1 delta engine: ``delta.frontier`` /
``delta.extract`` / ``delta.apply`` on the registered CRDT leaves, shipped
as fixed-capacity packets by ``AntiEntropyNode`` (host gossip with per-peer
ack frontiers — the fault-tolerant sibling of ``delta.DeltaSync``).

Protocol rules (verified by serving/simulator.py)
-------------------------------------------------

1. **Home-partition allocation.**  Page p is allocated only by its home
   replica ``home(p) = p * N // P``, so allocation never needs consensus.
   Any replica may *reference* any page (prefix sharing); only the lease
   owner writes it.

2. **Epoch fencing.**  The lease seq bumps on alloc and on free-to-zero.
   Published prefix entries carry the seq they were minted under; every
   cross-replica resolution re-validates ``seq`` against the current lease.

3. **Provisional cross-replica shares.**  A replica adopting a peer's page
   increments its own refcount lane first (so the home can never observe
   refcount 0 while the adoption is in flight... once the inc has synced),
   then commits only after it has since *heard from the owner* with the
   epoch unchanged; otherwise it aborts and decrements.  The home absorbs
   the in-flight window by lingering: an exported page that reaches
   refcount 0 cools for ``linger`` steps (and is re-validated at promotion)
   before re-entering the free list.

4. **Fencing / retirement / reclamation.**  Replicas heartbeat every step.
   A replica FENCES ITSELF (stops allocating and writing) while any
   non-retired peer has been unheard for > ``ttl`` steps — during a
   partition *both* sides stall rather than risk divergent ownership
   (safety over liveness).  A peer whose merged heartbeat is stale by
   > ``2*ttl`` gets a retirement vote; retirement takes effect at a
   majority (floor(N/2)+1), so an N=2 crash pins pages forever rather than
   reclaiming unsafely.  The lowest-id live replica then re-homes a retired
   replica's pages: claim (lease write, seq+1) → wait ``grace`` → commit if
   still the lease winner and itself unfenced.  Safety margin: an isolated
   owner fences at ``ttl`` unheard, strictly before any claim can commit at
   ``2*ttl (vote) + grace``.

5. **Self-halt.**  A replica that observes its own retirement stops
   operating (its lanes are already excluded from effective refcounts).

The engine-facing adapters ``ReplicatedPageAllocator`` /
``ReplicatedPrefixCache`` are drop-in for the scheduler's ``PageAllocator``
/ ``PrefixCache`` API, so ``ContinuousBatchingEngine(allocator=...,
prefix_cache=...)`` runs unmodified on replicated state.
``MultiEngineServer`` drives N such engines with reliable in-process gossip
(cross-replica prefix hits are accounted at the metadata layer there;
physical cross-engine KV adoption is the ROADMAP follow-on — the simulator,
whose pages are abstract, exercises real adoption end to end).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional

import numpy as np

from repro.core import counter as counter_mod
from repro.core import delta as delta_mod
from repro.core import gset, lww
from repro.core.clock import MAX_CLIENTS, MAX_CLOCK
from repro.serving import scheduler as sched_mod

HASH_BITS = 62


def prefix_hash(key: tuple) -> int:
    """Deterministic 62-bit FNV-1a of an int tuple (a token prefix).  Both
    31-bit halves fit an int32 lane of the LWW payload."""
    h = 0xcbf29ce484222325
    for t in key:
        h ^= int(t) & 0xFFFFFFFFFFFFFFFF
        h = (h * 0x100000001B3) & 0xFFFFFFFFFFFFFFFF
    return h & ((1 << HASH_BITS) - 1)


def zero_state(num_replicas: int, num_pages: int, prefix_slots: int) -> dict:
    """The pristine CRDT pytree every replica starts from (and the template
    gossip frontiers are seeded with)."""
    return {
        "ref": counter_mod.PNCounter.zeros(num_replicas, num_pages),
        "lease": lww.empty(num_pages, {"owner": ((), np.int32),
                                       "seq": ((), np.int32)}),
        "prefix": lww.empty(prefix_slots, {"hash_lo": ((), np.int32),
                                           "hash_hi": ((), np.int32),
                                           "page": ((), np.int32),
                                           "seq": ((), np.int32),
                                           "owner": ((), np.int32)}),
        "hb": gset.GCounter.zeros(num_replicas),
        "retire": gset.GSet.empty(num_replicas * num_replicas),
    }


class ReplicatedPageStore:
    """One replica's view of the replicated page table.

    Working state is host numpy (mutations are O(1) scalar ops on the hot
    admission/growth path); ``state()`` materializes the registered CRDT
    pytree for the delta engine and ``load()`` writes a joined state back.
    Local mutators implement exactly the CRDT op semantics — single-writer
    monotone lane bumps, Lamport-guarded LWW writes — so a replica's state
    is always the join of the ops it generated and the deltas it applied.
    """

    def __init__(self, rid: int, num_replicas: int, num_pages: int,
                 prefix_slots: Optional[int] = None):
        if not 0 <= rid < num_replicas:
            raise ValueError(f"rid {rid} outside [0, {num_replicas})")
        if num_replicas >= MAX_CLIENTS:
            raise ValueError("num_replicas exceeds LWW client space")
        self.rid = rid
        self.num_replicas = num_replicas
        self.num_pages = num_pages
        self.prefix_slots = (2 * num_pages if prefix_slots is None
                             else prefix_slots)
        self.majority = num_replicas // 2 + 1
        n, p, s = num_replicas, num_pages, self.prefix_slots
        self.inc = np.zeros((n, p), np.int32)
        self.dec = np.zeros((n, p), np.int32)
        self.lease_clock = np.zeros(p, np.int32)
        self.lease_client = np.zeros(p, np.int32)
        self.lease_owner = np.zeros(p, np.int32)      # rid+1; 0 = unleased
        self.lease_seq = np.zeros(p, np.int32)
        self.pfx_clock = np.zeros(s, np.int32)
        self.pfx_client = np.zeros(s, np.int32)
        self.pfx = {name: np.zeros(s, np.int32)
                    for name in ("hash_lo", "hash_hi", "page", "seq",
                                 "owner")}
        self.hb = np.zeros(n, np.int32)
        self.retire = np.zeros(n * n, bool)
        self.lam = 0                                  # local Lamport time
        # Host metadata (not CRDT state): gossip recency per peer, fed by
        # AntiEntropyNode and read by the fencing rule.
        self.last_heard = {j: 0 for j in range(n) if j != rid}

    # -- Lamport ------------------------------------------------------------

    def _tick(self) -> int:
        self.lam += 1
        if self.lam > MAX_CLOCK:
            raise OverflowError("Lamport clock exhausted")
        return self.lam

    # -- refcount lanes (single-writer: own lane only) ----------------------

    def ref_add(self, page: int, n: int = 1) -> None:
        self.inc[self.rid, page] += n

    def ref_sub(self, page: int, n: int = 1) -> None:
        if self.lane_held(page) < n:
            raise ValueError(
                f"double free of page {page} (lane {self.rid} holds "
                f"{self.lane_held(page)}, releasing {n})")
        self.dec[self.rid, page] += n

    def lane_held(self, page: int) -> int:
        return int(self.inc[self.rid, page] - self.dec[self.rid, page])

    def retired_mask(self) -> np.ndarray:
        """bool[N] — replicas whose retirement has majority support in this
        replica's merged view.  Votes are monotone facts, so every replica
        converges to the same mask."""
        n = self.num_replicas
        votes = self.retire.reshape(n, n).sum(axis=0)
        return votes >= self.majority

    def live_lanes(self) -> np.ndarray:
        return ~self.retired_mask()

    def refcount(self, page: int) -> int:
        live = self.live_lanes()
        return int((self.inc[live, page] - self.dec[live, page]).sum())

    def refcounts(self) -> np.ndarray:
        """Effective (live-lane) refcount of every page: i32[P]."""
        live = self.live_lanes()
        return (self.inc[live] - self.dec[live]).sum(axis=0)

    # -- lease --------------------------------------------------------------

    def _lww_write(self, clock_arr, client_arr, idx: int,
                   fields: dict[str, dict]) -> bool:
        clock = self._tick()
        client = self.rid + 1
        new_key = clock * MAX_CLIENTS + client
        cur_key = int(clock_arr[idx]) * MAX_CLIENTS + int(client_arr[idx])
        if new_key <= cur_key:
            return False
        clock_arr[idx] = clock
        client_arr[idx] = client
        for payload, values in fields.items():
            for name, value in values.items():
                getattr(self, payload)[name][idx] = value
        return True

    def lease_write(self, page: int, owner_rid: int, seq: int) -> None:
        ok = self._lww_write(
            self.lease_clock, self.lease_client, page,
            {"_lease_payload": {"owner": owner_rid + 1, "seq": seq}})
        if not ok:
            raise RuntimeError(f"lease write lost on page {page} — a local "
                               "Lamport tick can never lose locally")

    @property
    def _lease_payload(self) -> dict[str, np.ndarray]:
        return {"owner": self.lease_owner, "seq": self.lease_seq}

    def lease(self, page: int) -> tuple[int, int]:
        """(owner_rid or -1, seq) of the page's current epoch."""
        return int(self.lease_owner[page]) - 1, int(self.lease_seq[page])

    # -- prefix map ---------------------------------------------------------

    def publish_prefix(self, h: int, page: int, seq: int) -> None:
        slot = h % self.prefix_slots
        self._lww_write(
            self.pfx_clock, self.pfx_client, slot,
            {"pfx": {"hash_lo": h & 0x7FFFFFFF, "hash_hi": h >> 31,
                     "page": page, "seq": seq, "owner": self.rid + 1}})

    def lookup_prefix(self, h: int) -> Optional[tuple[int, int, int]]:
        """(owner_rid, page, seq) of a published prefix page, or None.  The
        caller still must validate seq against the page's current lease."""
        slot = h % self.prefix_slots
        if self.pfx_clock[slot] == 0:
            return None
        if (int(self.pfx["hash_lo"][slot]) != (h & 0x7FFFFFFF)
                or int(self.pfx["hash_hi"][slot]) != (h >> 31)):
            return None                     # slot collision — treat as miss
        return (int(self.pfx["owner"][slot]) - 1,
                int(self.pfx["page"][slot]), int(self.pfx["seq"][slot]))

    # -- liveness -----------------------------------------------------------

    def heartbeat(self, now: int) -> None:
        self.hb[self.rid] = max(int(self.hb[self.rid]), now)

    def vote_retire(self, target: int) -> None:
        self.retire[self.rid * self.num_replicas + target] = True

    def is_retired(self, r: int) -> bool:
        return bool(self.retired_mask()[r])

    # -- CRDT pytree bridge -------------------------------------------------

    def state(self) -> dict:
        """The registered-CRDT pytree this replica's state IS (the thing the
        delta engine extracts from / applies into / joins)."""
        import jax.numpy as jnp
        return {
            "ref": counter_mod.PNCounter(inc=jnp.asarray(self.inc),
                                         dec=jnp.asarray(self.dec)),
            "lease": lww.LWWBank(
                clock=jnp.asarray(self.lease_clock),
                client=jnp.asarray(self.lease_client),
                payload={"owner": jnp.asarray(self.lease_owner),
                         "seq": jnp.asarray(self.lease_seq)}),
            "prefix": lww.LWWBank(
                clock=jnp.asarray(self.pfx_clock),
                client=jnp.asarray(self.pfx_client),
                payload={k: jnp.asarray(v) for k, v in self.pfx.items()}),
            "hb": gset.GCounter(jnp.asarray(self.hb)),
            "retire": gset.GSet(jnp.asarray(self.retire)),
        }

    def load(self, tree: dict) -> None:
        """Adopt a joined state (post delta-apply) and observe its clocks so
        later local LWW writes stay ahead of everything merged in."""
        host = lambda x: np.array(x)       # mutable host copy
        self.inc = host(tree["ref"].inc)
        self.dec = host(tree["ref"].dec)
        self.lease_clock = host(tree["lease"].clock)
        self.lease_client = host(tree["lease"].client)
        self.lease_owner = host(tree["lease"].payload["owner"])
        self.lease_seq = host(tree["lease"].payload["seq"])
        self.pfx_clock = host(tree["prefix"].clock)
        self.pfx_client = host(tree["prefix"].client)
        self.pfx = {k: host(v) for k, v in tree["prefix"].payload.items()}
        self.hb = host(tree["hb"].counts)
        self.retire = host(tree["retire"].member)
        self.lam = max(self.lam, int(self.lease_clock.max()),
                       int(self.pfx_clock.max()))

    def apply_delta(self, d: Any) -> None:
        self.load(delta_mod.apply_jit(self.state(), d))

    def digest(self) -> bytes:
        """Order-stable byte digest of the CRDT state (for convergence
        traces; bitwise equality of digests == bitwise equality of state)."""
        import hashlib
        m = hashlib.sha256()
        for arr in (self.inc, self.dec, self.lease_clock, self.lease_client,
                    self.lease_owner, self.lease_seq, self.pfx_clock,
                    self.pfx_client, *(self.pfx[k] for k in sorted(self.pfx)),
                    self.hb, self.retire):
            m.update(np.ascontiguousarray(arr).tobytes())
        return m.digest()


# ---------------------------------------------------------------------------
# Anti-entropy gossip (delta engine on an unreliable channel)
# ---------------------------------------------------------------------------


@dataclass
class DeltaPacket:
    """One gossip hop: a fixed-capacity delta of src's state beyond what dst
    last acknowledged.  ``nbytes`` is constant per (store shape, capacity) —
    that is what makes sync-bytes a deterministic, regression-gatable
    counter."""

    src: int
    dst: int
    send_time: int
    delta: Any
    nbytes: int


@dataclass
class AckPacket:
    src: int
    dst: int
    send_time: int


class AntiEntropyNode:
    """Per-replica gossip endpoint with per-peer acknowledged frontiers.

    Unlike ``delta.DeltaSync`` (reliable shared-frontier all-to-all), this
    node tolerates an adversarial channel: the frontier for a peer advances
    only when that peer ACKNOWLEDGES a packet, so dropped packets simply
    re-extract on the next round; duplicated or reordered packets are
    no-ops by join idempotence/commutativity; delayed acks join in late
    (frontiers are monotone).  Convergence is delayed, never lost.
    """

    PENDING_LIMIT = 64        # unacked shipped-frontiers kept per peer

    def __init__(self, store: ReplicatedPageStore, capacity: int = 32,
                 gossip=None):
        from repro.serving import engine as engine_mod
        self.store = store
        self.capacity = capacity
        self.gossip = gossip if gossip is not None else \
            engine_mod.make_gossip_fns(
                zero_state(store.num_replicas, store.num_pages,
                           store.prefix_slots), capacity)
        peers = [j for j in range(store.num_replicas) if j != store.rid]
        self.acked = {j: self.gossip.genesis for j in peers}
        self.pending: dict[int, dict[int, Any]] = {j: {} for j in peers}
        self.bytes_sent = 0
        self.packets_sent = 0

    def make_packet(self, dst: int, now: int) -> DeltaPacket:
        d, shipped = self.gossip.extract(self.store.state(), self.acked[dst])
        pend = self.pending[dst]
        pend[now] = shipped
        while len(pend) > self.PENDING_LIMIT:
            pend.pop(min(pend))           # oldest unacked: superseded anyway
        nb = delta_mod.nbytes(d)
        self.bytes_sent += nb
        self.packets_sent += 1
        return DeltaPacket(self.store.rid, dst, now, d, nb)

    def receive(self, pkt: DeltaPacket, now: int) -> AckPacket:
        self.store.last_heard[pkt.src] = max(self.store.last_heard[pkt.src],
                                             now)
        self.store.load(self.gossip.apply(self.store.state(), pkt.delta))
        return AckPacket(self.store.rid, pkt.src, pkt.send_time)

    def receive_ack(self, ack: AckPacket, now: int) -> None:
        self.store.last_heard[ack.src] = max(self.store.last_heard[ack.src],
                                             now)
        fr = self.pending[ack.src].pop(ack.send_time, None)
        if fr is not None:
            self.acked[ack.src] = delta_mod.join_frontiers(
                self.acked[ack.src], fr)


# ---------------------------------------------------------------------------
# Scheduler-facing backends
# ---------------------------------------------------------------------------


class ReplicatedPageAllocator:
    """Drop-in for ``scheduler.PageAllocator`` backed by the replicated
    store.  Allocation draws from this replica's home partition only;
    refcounts, leases and the retirement protocol ride the CRDT state.

    ``ttl``/``grace``/``linger`` are in the caller's step units (the
    simulator's logical clock, or engine steps for ``MultiEngineServer``).
    The safety inequality — fence at ``ttl`` < retire-vote at ``2*ttl`` +
    ``grace`` — is baked in; ``linger`` must exceed the channel's maximum
    in-flight time for rule 3 (see module docstring) to hold.
    """

    def __init__(self, store: ReplicatedPageStore, *, ttl: int = 8,
                 grace: Optional[int] = None, linger: int = 0):
        self.store = store
        self.ttl = ttl
        self.retire_after = 2 * ttl
        self.grace = ttl if grace is None else grace
        self.linger = linger
        p, n, rid = store.num_pages, store.num_replicas, store.rid
        self._home0 = (np.arange(p, dtype=np.int64) * n) // p
        self._mine = {int(pg) for pg in np.nonzero(self._home0 == rid)[0]}
        self._free = sorted(self._mine, reverse=True)
        self._outstanding: set[int] = set()
        self._cooling: dict[int, int] = {}      # page -> cooled-since step
        self._exported: set[int] = set()
        self._claims: dict[int, tuple[int, int]] = {}   # page -> (t0, seq)
        self.now = 0                            # advanced by maintain()
        self.reclaimed_pages = 0
        self.fence_steps = 0

    # -- PageAllocator API --------------------------------------------------

    @property
    def num_pages(self) -> int:
        return self.store.num_pages        # engines size their pool to this

    @property
    def available(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> Optional[list[int]]:
        if n <= 0:
            return []
        if self.halted or self.fenced(self.now) or n > len(self._free):
            return None
        pages, self._free = self._free[-n:][::-1], self._free[:-n]
        for p in pages:
            _, seq = self.store.lease(p)
            self.store.lease_write(p, self.store.rid, seq + 1)
            self.store.ref_add(p)
            self._outstanding.add(p)
        return pages

    def reserve(self, n: int) -> Optional[sched_mod.Reservation]:
        pages = self.alloc(n)
        if pages is None:
            return None
        return sched_mod.Reservation(self, pages)

    def share(self, pages: list[int]) -> None:
        for p in pages:
            if self.store.refcount(p) <= 0:
                raise ValueError(f"cannot share unallocated page {p}")
            self.store.ref_add(p)

    def refcount(self, page: int) -> int:
        return self.store.refcount(page)

    def generation(self, page: int) -> int:
        """The page's lease epoch: bumps on every alloc and every
        free-to-zero, which is exactly the staleness the local PrefixCache
        guards against."""
        return self.store.lease(page)[1]

    def free(self, pages: list[int]) -> None:
        for p in reversed(pages):
            self.store.ref_sub(p)          # raises on lane double-free
            self._retire_if_idle(p)

    # -- replication-side machinery ------------------------------------------

    def _retire_if_idle(self, p: int) -> None:
        """Home-side: a page of ours at effective refcount 0 ends its epoch
        (seq bump fences stale references) and cools or frees."""
        if p not in self._mine or p not in self._outstanding:
            return
        if self.store.refcount(p) != 0:
            return                         # remote lanes still hold refs
        _, seq = self.store.lease(p)
        self.store.lease_write(p, self.store.rid, seq + 1)
        self._outstanding.discard(p)
        if p in self._exported and self.linger > 0:
            self._cooling[p] = self.now
        else:
            self._free.append(p)

    def mark_exported(self, page: int) -> None:
        self._exported.add(page)

    def scavenge(self) -> None:
        """After a sync round: reap home pages whose last remote references
        were released elsewhere, and promote cooled pages whose linger has
        elapsed (re-validating refcount — an in-flight provisional share
        may have resurrected one; it will abort on the epoch bump, so the
        page just keeps cooling until the release arrives)."""
        for p in sorted(self._outstanding):
            self._retire_if_idle(p)
        for p in sorted(self._cooling):
            if self.now - self._cooling[p] >= self.linger:
                if self.store.refcount(p) == 0:
                    del self._cooling[p]
                    self._free.append(p)
                else:
                    self._cooling[p] = self.now

    @property
    def halted(self) -> bool:
        return self.store.is_retired(self.store.rid)

    def fenced(self, now: int) -> bool:
        """Safety rule 4: stall while any non-retired peer is unheard."""
        retired = self.store.retired_mask()
        return any(now - t > self.ttl
                   for j, t in self.store.last_heard.items()
                   if not retired[j])

    def maintain(self, now: int) -> None:
        """One protocol step: heartbeat, stale-peer votes, reclamation."""
        self.now = now
        if self.halted:
            return
        self.store.heartbeat(now)
        retired = self.store.retired_mask()
        for j in self.store.last_heard:
            if not retired[j] and now - int(self.store.hb[j]) \
                    > self.retire_after:
                self.store.vote_retire(j)
        retired = self.store.retired_mask()
        if self.fenced(now):
            self.fence_steps += 1
            self._claims.clear()           # a fenced claimant starts over
            return
        live = [r for r in range(self.store.num_replicas) if not retired[r]]
        if not live or live[0] != self.store.rid:
            return
        # Lowest live replica re-homes every retired replica's pages.
        for p in np.nonzero(retired[self._home0])[0]:
            p = int(p)
            if p in self._mine:
                continue
            owner, seq = self.store.lease(p)
            claim = self._claims.get(p)
            if claim is None:
                self.store.lease_write(p, self.store.rid, seq + 1)
                self._claims[p] = (now, seq + 1)
            else:
                t0, cseq = claim
                if owner != self.store.rid or seq != cseq:
                    del self._claims[p]    # lost the epoch — retry next step
                elif now - t0 >= self.grace:
                    del self._claims[p]
                    self._mine.add(p)
                    self.reclaimed_pages += 1
                    if self.store.refcount(p) == 0:
                        self._free.append(p)
                    else:                  # live sharers elsewhere
                        self._outstanding.add(p)


class ReplicatedPrefixCache(sched_mod.PrefixCache):
    """The scheduler's ``PrefixCache`` plus cross-replica publication.

    Local lookups/registration behave exactly like the host-local cache
    (same OrderedDict LRU, same generation validation — the generation now
    being the page's replicated lease epoch).  On top of that, full chain
    pages this replica OWNS are published to the replicated prefix map, and
    ``lookup`` probes the map for prompt pages resident on peers.  Remote
    hits are accounted in ``cross_replica_hits`` — the coordination-layer
    signal the bench gates on; engines do not adopt a peer's physical KV
    yet (each engine owns a separate device pool — ROADMAP follow-on),
    while the simulator's abstract replicas adopt for real via
    ``resolve_remote``.
    """

    def __init__(self, allocator: ReplicatedPageAllocator, page_size: int,
                 max_entries: int = 4096):
        super().__init__(allocator, page_size, max_entries)
        self.store = allocator.store
        self.cross_replica_hits = 0
        self.published = 0

    def _publish_page(self, key: tuple, page: int) -> None:
        owner, seq = self.store.lease(page)
        if owner != self.store.rid:
            return                         # only the lease owner publishes
        self.store.publish_prefix(prefix_hash(key), page, seq)
        self._allocator.mark_exported(page)
        self.published += 1

    def _publish_chain(self, tokens: list[int], pages: list[int]) -> None:
        ps = self.page_size
        for k in range(1, min(len(tokens) // ps, len(pages)) + 1):
            self._publish_page(tuple(tokens[:k * ps]), pages[k - 1])

    def register(self, tokens: list[int], pages: list[int]) -> None:
        super().register(tokens, pages)
        self._publish_chain(tokens, pages)

    def register_tail(self, tokens: list[int], pages: list[int]) -> None:
        super().register_tail(tokens, pages)
        ps = self.page_size
        k = len(pages)
        if k and k * ps <= len(tokens):    # the page just grown is full
            self._publish_page(tuple(tokens[:k * ps]), pages[k - 1])

    def resolve_remote(self, key: tuple) -> Optional[tuple[int, int, int]]:
        """Validated replicated-map probe for the full chain page covering
        ``key``: (owner_rid, page, seq), or None.  Validation: hash match,
        publishing epoch still current, page still referenced, owner lane
        still live.  The *caller* performs the provisional share + commit
        dance (protocol rule 3)."""
        hit = self.store.lookup_prefix(prefix_hash(key))
        if hit is None:
            return None
        owner, page, seq = hit
        if owner < 0 or owner >= self.store.num_replicas:
            return None
        cur_owner, cur_seq = self.store.lease(page)
        if (cur_seq != seq or cur_owner != owner
                or self.store.retired_mask()[owner]
                or self.store.refcount(page) <= 0):
            return None
        return owner, page, seq

    def lookup(self, tokens: list[int], *, boundary: bool = True
               ) -> list[int]:
        local = super().lookup(tokens, boundary=boundary)
        ps = self.page_size
        n_full = len(tokens) // ps
        for k in range(min(len(local), n_full) + 1, n_full + 1):
            hit = self.resolve_remote(tuple(tokens[:k * ps]))
            if hit is None or hit[0] == self.store.rid:
                break
            self.cross_replica_hits += 1
        return local


# ---------------------------------------------------------------------------
# Multi-engine serving
# ---------------------------------------------------------------------------


class MultiEngineServer:
    """N continuous-batching engines on one replicated page table.

    Each engine gets its own ``ReplicatedPageStore`` replica plus the
    allocator/prefix-cache adapters; requests are dispatched round-robin;
    every ``sync_every`` steps the replicas gossip all-to-all through their
    ``AntiEntropyNode``s over a reliable in-process channel (the adversarial
    channel lives in serving/simulator.py).  ``ttl`` is sized so the
    fencing rule never fires under this reliable schedule.
    """

    def __init__(self, cfg, params, *, replicas: int = 2, batch: int,
                 max_len: int, page_size: int = 64,
                 pages_per_replica: Optional[int] = None,
                 sync_every: int = 1, delta_capacity: int = 32,
                 **engine_kwargs):
        if replicas < 1:
            raise ValueError("replicas must be >= 1")
        self.replicas = replicas
        self.sync_every = sync_every
        maxp = -(-max_len // page_size)
        per = pages_per_replica if pages_per_replica is not None \
            else batch * maxp
        num_pages = replicas * per
        ttl = 4 * sync_every
        self.stores = [ReplicatedPageStore(r, replicas, num_pages)
                       for r in range(replicas)]
        gossip = None
        self.allocators, self.caches, self.nodes = [], [], []
        for store in self.stores:
            node = AntiEntropyNode(store, capacity=delta_capacity,
                                   gossip=gossip)
            gossip = node.gossip           # share the jitted triple
            alloc = ReplicatedPageAllocator(store, ttl=ttl, linger=0)
            self.nodes.append(node)
            self.allocators.append(alloc)
            self.caches.append(ReplicatedPrefixCache(alloc, page_size))
        self.engines = [
            sched_mod.ContinuousBatchingEngine(
                cfg, params, batch=batch, max_len=max_len, paged=True,
                page_size=page_size, num_pages=num_pages,
                prefix_sharing=True, allocator=self.allocators[r],
                prefix_cache=self.caches[r], **engine_kwargs)
            for r in range(replicas)]
        self.clock = 0
        self.syncs = 0
        self._rr = 0

    def submit(self, req: sched_mod.Request) -> int:
        """Round-robin dispatch; returns the replica the request landed on."""
        r = self._rr
        self._rr = (self._rr + 1) % self.replicas
        self.engines[r].submit(req)
        return r

    def sync(self) -> None:
        """One reliable all-to-all gossip round (packets and acks delivered
        in order, same tick)."""
        now = self.clock
        packets = [node.make_packet(dst, now)
                   for node in self.nodes
                   for dst in node.acked]
        for pkt in packets:
            ack = self.nodes[pkt.dst].receive(pkt, now)
            self.nodes[pkt.src].receive_ack(ack, now)
        for alloc in self.allocators:
            alloc.scavenge()
        self.syncs += 1

    def step(self) -> bool:
        more = [e.step() for e in self.engines]
        self.clock += 1
        for alloc in self.allocators:
            alloc.maintain(self.clock)
        if self.clock % self.sync_every == 0:
            self.sync()
        return any(more)

    def run(self, requests: list[sched_mod.Request],
            max_steps: int = 100_000) -> list[sched_mod.Request]:
        for req in requests:
            self.submit(req)
        for _ in range(max_steps):
            if not self.step():
                break
        else:
            raise RuntimeError("multi-engine serve hit max_steps")
        self.sync()                        # final round: frontiers settle
        return requests

    @property
    def sync_bytes(self) -> int:
        return sum(node.bytes_sent for node in self.nodes)

    def stats(self) -> dict:
        out = {"replicas": self.replicas, "steps": self.clock,
               "syncs": self.syncs, "sync_bytes": self.sync_bytes,
               "sync_bytes_per_step": (self.sync_bytes // self.clock
                                       if self.clock else 0),
               "cross_replica_hits": sum(c.cross_replica_hits
                                         for c in self.caches),
               "published_prefix_pages": sum(c.published
                                             for c in self.caches)}
        for key in ("admitted", "completed", "gen_tokens", "prefill_tokens",
                    "shared_pages", "cow_copies", "preemptions",
                    "prefill_chunks", "decode_stall_steps"):
            out[key] = sum(e.stats[key] for e in self.engines)
        return out

    def converged(self) -> bool:
        """Bitwise page-table agreement across all replicas."""
        d0 = self.stores[0].digest()
        return all(s.digest() == d0 for s in self.stores[1:])


class ReplicatedPrefixPageMapper:
    """``PrefixPageMapper`` over a replicated page table (orchestrator
    ``--replicas N``).

    Agent rows are partitioned round-robin across N metadata replicas, each
    owning a home slice of ONE physical page pool (the agents still share a
    single batched engine, so page ids are globally meaningful).  Because
    the pool is physically shared, a validated remote prefix hit is adopted
    for REAL here: the row's block table points straight at the peer-owned
    page while this replica's counter lane holds the share — the in-process
    degenerate case of protocol rule 3, where the provisional share commits
    immediately because the lease epoch is re-read in the same tick.
    Replicas gossip at every coordination sync (``gossip()``), so
    cross-replica hits only appear once a peer's publication has shipped —
    exactly the observation-driven coordination the paper measures, applied
    to the serving plane.
    """

    def __init__(self, num_rows: int, maxp: int, page_size: int,
                 trash_page: int, *, replicas: int = 2,
                 num_pages: Optional[int] = None,
                 delta_capacity: int = 32):
        if replicas < 1:
            raise ValueError("replicas must be >= 1")
        num_pages = (num_rows + replicas) * maxp if num_pages is None \
            else num_pages
        if trash_page < num_pages:
            raise ValueError(
                f"trash_page {trash_page} lies inside the allocatable pool "
                f"[0, {num_pages})")
        self.replicas = replicas
        self.page_size = page_size
        self.maxp = maxp
        self.trash_page = trash_page
        self.stores = [ReplicatedPageStore(r, replicas, num_pages)
                       for r in range(replicas)]
        gossip = None
        self.nodes, self.allocators, self.caches = [], [], []
        for store in self.stores:
            node = AntiEntropyNode(store, capacity=delta_capacity,
                                   gossip=gossip)
            gossip = node.gossip
            alloc = ReplicatedPageAllocator(store, ttl=4, linger=0)
            self.nodes.append(node)
            self.allocators.append(alloc)
            self.caches.append(ReplicatedPrefixCache(alloc, page_size))
        self.host_bt = np.full((num_rows, maxp), trash_page, np.int32)
        self._row_pages: list[list[int]] = [[] for _ in range(num_rows)]
        self.shared_pages = 0
        self.cross_replica_hits = 0
        self.now = 0
        self._dirty = True

    def _domain(self, row: int) -> int:
        return row % self.replicas

    def map_row(self, row: int, tokens: list[int], horizon: int) -> int:
        """Remap ``row``: longest local prefix run, then validated remote
        adoption for chain pages published by peers, fresh home pages for
        the rest.  Returns the number of shared (local + adopted) pages."""
        d = self._domain(row)
        alloc, cache = self.allocators[d], self.caches[d]
        ps = self.page_size
        npages = min(-(-horizon // ps), self.maxp)
        n_write = len(tokens) // ps       # decode writes from page n_write
        local = sched_mod.PrefixCache.lookup(cache, tokens,
                                             boundary=False)[:n_write]
        alloc.share(local)
        adopted: list[int] = []
        for k in range(len(local) + 1, n_write + 1):
            hit = cache.resolve_remote(tuple(tokens[:k * ps]))
            if hit is None or hit[0] == d:
                break
            owner, page, seq = hit
            alloc.share([page])            # provisional...
            if cache.store.lease(page) != (owner, seq):
                cache.store.ref_sub(page)  # ...epoch moved: abort
                break
            adopted.append(page)           # ...same tick: commit
            self.cross_replica_hits += 1
        shared = local + adopted
        fresh = alloc.alloc(npages - len(shared))
        if fresh is None:
            alloc.free(shared)
            raise RuntimeError("agent page pool exhausted")
        pages = shared + fresh
        old = self._row_pages[row]
        self._row_pages[row] = pages
        self.host_bt[row, :] = self.trash_page
        self.host_bt[row, :len(pages)] = pages
        if old:
            alloc.free(old)               # after remap: self-prefix shares
        cache.register(tokens[:n_write * ps], pages[:n_write])
        self.shared_pages += len(shared)
        self._dirty = True
        return len(shared)

    def free_row(self, row: int) -> None:
        if self._row_pages[row]:
            self.allocators[self._domain(row)].free(self._row_pages[row])
            self._row_pages[row] = []
        self.host_bt[row, :] = self.trash_page
        self._dirty = True

    def install(self, cache):
        if self._dirty:
            import jax.numpy as jnp
            from repro.models import lm
            cache = lm.set_block_tables(cache, jnp.asarray(self.host_bt))
            self._dirty = False
        return cache

    def gossip(self) -> None:
        """One reliable all-to-all anti-entropy round (same tick)."""
        self.now += 1
        for alloc in self.allocators:
            alloc.now = self.now
            alloc.maintain(self.now)
        for src in range(self.replicas):
            for dst in range(self.replicas):
                if src == dst:
                    continue
                pkt = self.nodes[src].make_packet(dst, self.now)
                ack = self.nodes[dst].receive(pkt, self.now)
                self.nodes[src].receive_ack(ack, self.now)
        for alloc in self.allocators:
            alloc.scavenge()

    @property
    def sync_bytes(self) -> int:
        return sum(node.bytes_sent for node in self.nodes)

    def converged(self) -> bool:
        d0 = self.stores[0].digest()
        return all(s.digest() == d0 for s in self.stores[1:])
