"""Speculative-decoding drafters: prompt-lookup (n-gram) and CRDT-doc.

Both drafters are *model-free*: they propose k-token continuations by
matching the row's trailing n-gram against a token source and copying
whatever followed the most recent earlier occurrence.  The serving engine
then verifies the whole draft span in ONE ``lm.mixed_step`` call (decode
rows widen from span 1 to span 1+k) and commits the longest accepted
prefix plus the verifier's bonus token; rejected tails roll back bitwise
(`cache.snapshot_span` / `restore_span`), so speculative greedy output is
token-identical to non-speculative greedy output by construction.

Two token sources:

* :class:`NgramDrafter` — the row's own prompt + generated history
  ("prompt lookup").  Catches self-repetition: code generation re-emits
  identifiers, signatures, and boilerplate that already appeared
  upstream in the same context.
* :class:`DocDrafter` — the shared CRDT RGA document.  CodeCRDT agents
  regenerate text the document already converged on (re-contextualization
  literally replays committed code), so the *document* predicts a row's
  continuation even when the row's own history does not — e.g. an agent
  writing a call site for a function another agent already committed.
  Falls back to own-history lookup when the document has no match.

The drafters run on the host between steps; cost is O(len(source)) per
proposal at bench scales, far below one model step.
"""
from __future__ import annotations

from typing import Iterable, Optional, Sequence


def _lookup(source: Sequence[int], context: Sequence[int], k: int,
            max_ngram: int, min_ngram: int,
            exclude_final: bool = False) -> list[int]:
    """Continuation after the most recent match of context's trailing
    n-gram inside ``source`` (longest n first, rightmost occurrence).

    With ``exclude_final`` the match may not end at source's last token
    (used for self-lookup, where the trailing n-gram trivially matches
    itself and would propose nothing).
    """
    if k <= 0 or not source or not context:
        return []
    src = list(source)
    for n in range(min(max_ngram, len(context)), min_ngram - 1, -1):
        pat = list(context[-n:])
        hi = len(src) - n - (1 if exclude_final else 0)
        for i in range(hi, -1, -1):
            if src[i:i + n] == pat:
                cont = src[i + n:i + n + k]
                if cont:
                    return [int(t) for t in cont]
    return []


class NgramDrafter:
    """Prompt-lookup drafting from the row's own prompt+generated history."""

    name = "ngram"

    def __init__(self, max_ngram: int = 3, min_ngram: int = 1):
        if not 1 <= min_ngram <= max_ngram:
            raise ValueError("need 1 <= min_ngram <= max_ngram")
        self.max_ngram = max_ngram
        self.min_ngram = min_ngram

    def propose(self, context: Sequence[int], k: int) -> list[int]:
        return _lookup(context, context, k, self.max_ngram, self.min_ngram,
                       exclude_final=True)


class DocDrafter:
    """Drafting from shared CRDT document content, own-history fallback.

    ``docs`` holds token sequences of converged document regions (e.g.
    the orchestrator's per-slot host mirrors); sequences may be live
    lists that grow as the document does.  Matches in later (more
    recently updated) docs win ties at equal n-gram length.
    """

    name = "doc"

    def __init__(self, max_ngram: int = 3, min_ngram: int = 2,
                 fallback: bool = True):
        self._docs: list[Sequence[int]] = []
        self.max_ngram = max_ngram
        self.min_ngram = min_ngram
        self._fallback = (NgramDrafter(max_ngram=max_ngram)
                          if fallback else None)

    def set_docs(self, docs: Iterable[Sequence[int]]) -> None:
        self._docs = list(docs)

    def add_doc(self, doc: Sequence[int]) -> None:
        self._docs.append(doc)

    def propose(self, context: Sequence[int], k: int) -> list[int]:
        for n in range(self.max_ngram, self.min_ngram - 1, -1):
            for doc in reversed(self._docs):
                got = _lookup(doc, context, k, n, n)
                if got:
                    return got
        if self._fallback is not None:
            return self._fallback.propose(context, k)
        return []


def make_drafter(kind: str, **kw):
    """Factory for ``--spec-decode {ngram,doc}``."""
    if kind == "ngram":
        return NgramDrafter(**kw)
    if kind == "doc":
        return DocDrafter(**kw)
    raise ValueError(f"unknown drafter kind {kind!r} (want 'ngram' or 'doc')")


def accept_tokens(draft: Sequence[int], accepted: int, preds_row,
                  remaining: int, eos_id: Optional[int]) -> tuple[list[int], int]:
    """Host half of greedy longest-accepted-prefix acceptance.

    ``accepted`` is the device count from ``kernels.ref.speculative_accept``
    (how many draft tokens matched the verifier's argmax at their
    predecessor position); ``preds_row[j]`` is the argmax after span
    position j, so ``preds_row[accepted]`` is the *bonus* token — exactly
    the token non-speculative greedy decode would emit next, making every
    verify step commit >= 1 token.  The committed run is then truncated at
    the first eos (inclusive — matching the non-speculative stop rule) and
    capped at the row's remaining generation budget.

    Returns ``(appended, accepted)`` — the tokens to commit, and the
    device accept count clamped to the draft length (callers count
    ``min(len(appended), accepted)`` draft tokens as accepted).
    """
    a = min(int(accepted), len(draft))
    appended = [int(t) for t in draft[:a]] + [int(preds_row[a])]
    if eos_id is not None and eos_id in appended:
        appended = appended[:appended.index(eos_id) + 1]
    return appended[:max(1, int(remaining))], a
