"""Serving engine: prefill/decode steps, host-side generation loop, and the
fused decode+coordination step (the paper's architecture on a mesh).

``make_serve_step`` builds the pure function the multi-pod dry-run lowers for
decode shapes.  ``make_fused_serve_step`` additionally threads the CRDT
coordination state through the step: each data-parallel replica hosts a set
of agents (its decode-batch rows), appends their tokens to its own SlotDoc
replica, and the replicas converge through a pmax (or all-gather) collective
merge — observation-driven coordination fused into the serving step, with
the collective playing the role of the paper's WebSocket relay.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import delta as delta_mod
from repro.core import doc as doc_mod
from repro.core import gset, merge as merge_mod
from repro.models import lm
from repro.models.config import ModelConfig

Params = Any


def sample_token(logits: jax.Array, rng: jax.Array,
                 temperature: float = 0.0) -> jax.Array:
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(rng, logits / temperature).astype(jnp.int32)


def make_serve_step(cfg: ModelConfig, *, impl: str = "ref",
                    temperature: float = 0.0):
    """(params, cache, token[B], pos[B], rng) -> (next_token, cache, pos+1)."""

    def serve_step(params, cache, token, pos, rng):
        logits, cache = lm.decode_step(params, cfg, token, cache, pos,
                                       impl=impl)
        nxt = sample_token(logits, rng, temperature)
        return nxt, cache, pos + 1

    return serve_step


def make_prefill_fn(cfg: ModelConfig, *, impl: str = "ref"):
    def prefill_fn(params, cache, tokens, prefix_embeds=None, enc_frames=None):
        return lm.prefill(params, cfg, tokens, cache,
                          prefix_embeds=prefix_embeds, enc_frames=enc_frames,
                          impl=impl)

    return prefill_fn


def make_ragged_prefill_fn(cfg: ModelConfig, *, impl: str = "ref"):
    """(params, cache, tokens [B, P], lengths i32[B]) -> (logits, cache).

    Rows with ``lengths[b] == 0`` keep their cache.  This is the one-shot
    oracle the mixed step is verified against — serving itself admits
    prompts chunk by chunk through ``make_mixed_step_fn``.
    """
    def prefill_fn(params, cache, tokens, lengths):
        return lm.prefill(params, cfg, tokens, cache, impl=impl,
                          lengths=lengths)

    return prefill_fn


PROMPT_BUCKETS = (8, 16, 32, 64, 128, 256, 512, 1024)


def bucket_len(n: int, buckets=PROMPT_BUCKETS, max_len: Optional[int] = None
               ) -> int:
    """Smallest bucket >= n — bounds prefill recompiles.

    ``max_len`` clamps: a prompt longer than the largest bucket still lands
    (in one [B, max_len] call) as long as it fits the cache — the clamp is
    applied BEFORE raising, so only prompts that genuinely cannot fit fail.
    """
    if max_len is not None and n > max_len:
        raise ValueError(f"prompt length {n} exceeds max_len {max_len}")
    for b in buckets:
        if n <= b:
            return b if max_len is None else min(b, max_len)
    if max_len is not None:
        return max_len                # longer than every bucket, still fits
    raise ValueError(f"prompt length {n} exceeds largest bucket {buckets[-1]}")


# ---------------------------------------------------------------------------
# Token-budget mixed step (chunked prefill fused with decode)
# ---------------------------------------------------------------------------

def make_mixed_step_fn(cfg: ModelConfig, *, impl: str = "ref",
                       temperature: float = 0.0):
    """(params, cache, tokens [B, C], start [B], span [B], rng)
    -> (next_token [B], cache).

    One call spends every row's span — 1 token for decoding rows, a prompt
    chunk for rows being admitted, 0 for idle rows — so admission never
    stalls decode.  ``next_token`` is sampled from each row's last valid
    span position (garbage for span-0 rows; callers ignore it).
    """
    def mixed_step(params, cache, tokens, start, span, rng):
        logits, cache = lm.mixed_step(params, cfg, tokens, cache, start,
                                      span, impl=impl)
        nxt = sample_token(logits, rng, temperature)
        return nxt, cache

    return mixed_step


def make_verify_step_fn(cfg: ModelConfig, *, impl: str = "ref"):
    """(params, cache, tokens [B, C], start [B], span [B])
    -> (preds [B, C], accepted [B], cache).

    The speculative-decoding verify step (greedy only — acceptance
    compares argmax streams, so there is no rng): one all-logits mixed
    step over each row's [last_committed, draft...] span plus the per-row
    longest-accepted-prefix count.  ``preds[b, j]`` is the greedy token
    after span position j — non-drafting rows decode normally by reading
    ``preds[b, span[b]-1]``, so one compiled fn serves every lane.
    """
    def verify_step(params, cache, tokens, start, span):
        return lm.verify_step(params, cfg, tokens, cache, start, span,
                              impl=impl)

    return verify_step


def width_bucket(n: int, chunk: int) -> int:
    """Smallest power-of-two >= n, clamped to ``chunk`` — the mixed step
    compiles once per bucketed span width instead of once per width."""
    n = max(1, min(n, chunk))
    return min(1 << (n - 1).bit_length(), chunk)


def mixed_width_buckets(chunk: int) -> tuple[int, ...]:
    """Every width ``width_bucket`` can produce for spans in [1, chunk]."""
    out = []
    w = 1
    while w < chunk:
        out.append(w)
        w <<= 1
    out.append(chunk)
    return tuple(out)


def backoff_steps(rid: int, attempt: int, *, base: int = 4,
                  cap: int = 64) -> int:
    """Retry delay (in steps) for attempt ``attempt`` of request/agent
    ``rid``: capped exponential backoff plus deterministic jitter.

    The jitter is a pure hash of (rid, attempt), so re-admission order is
    reproducible across runs (the fault benches and chaos harness gate on
    deterministic counters) while still de-synchronizing retries that failed
    together — the reason jitter exists at all.
    """
    delay = min(cap, base << max(0, attempt - 1))
    h = (rid * 0x9E3779B1 + attempt * 0x85EBCA77) & 0xFFFFFFFF
    h ^= h >> 16
    return delay + h % max(1, delay // 2)


# ---------------------------------------------------------------------------
# Fused decode + CRDT coordination
# ---------------------------------------------------------------------------

def replicate_coord(coord: Any, n_replicas: int) -> Any:
    """Stack a coordination state into per-replica rows [R, ...]."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x, (n_replicas,) + x.shape), coord)


def with_delta_frontier(coord: dict) -> dict:
    """Attach the delta-sync frontier to a coordination dict.

    The delta merge strategy threads a shared frontier (the previous sync
    point) alongside the CRDT state; it rides in the coord dict under
    ``"frontier"`` so the fused step's signature and shardings are unchanged.
    """
    state = {k: v for k, v in coord.items() if k != "frontier"}
    return dict(coord, frontier=delta_mod.frontier(state))


def make_coord_merge(mesh: Mesh, dp_axes: tuple[str, ...],
                     strategy: str = "pmax", *, delta_capacity: int = 64):
    """Collective merge of stacked per-replica CRDT state (leaves [R, ...]).

    For ``strategy="delta"`` the coord dict must carry a ``"frontier"`` entry
    (see ``with_delta_frontier``); deltas beyond it ring-circulate instead of
    the full state.
    """
    axis_sizes = tuple(mesh.shape[a] for a in dp_axes)

    def local(stacked):
        state = jax.tree.map(lambda x: jnp.squeeze(x, 0), stacked)
        if strategy == "delta":
            fr = state.pop("frontier")
            merged, fr = merge_mod.delta_merge(
                state, fr, dp_axes, axis_sizes, capacity=delta_capacity)
            merged = dict(merged, frontier=fr)
        else:
            merged = merge_mod.collective_merge(state, dp_axes, strategy)
        return jax.tree.map(lambda x: x[None], merged)

    def merge_fn(coord_stacked):
        specs = jax.tree.map(
            lambda x: P(dp_axes, *([None] * (x.ndim - 1))), coord_stacked)
        return merge_mod.shard_map(local, mesh=mesh, in_specs=(specs,),
                                   out_specs=specs,
                                   check_vma=False)(coord_stacked)

    return merge_fn


class GossipFns:
    """Jitted delta-sync triple for host-side replica gossip.

    The host analogue of ``make_coord_merge(strategy="delta")``: where the
    fused step syncs coordination state through an in-mesh ``ppermute``
    ring, host-level replicas (multi-engine serving, the replica simulator)
    gossip the same frontiers/deltas over an explicit — possibly faulty —
    channel.  One instance per state *template*: the jitted callables cache
    on the pytree structure, so every replica of the same store shares the
    compilations.
    """

    def __init__(self, template: Any, capacity: int):
        self.capacity = capacity
        self.genesis = delta_mod.frontier_jit(template)
        self._apply = delta_mod.apply_jit

    def extract(self, state: Any, frontier: Any) -> tuple[Any, Any]:
        """(delta beyond ``frontier``, frontier actually shipped)."""
        return delta_mod.extract_jit(state, frontier, self.capacity)

    def apply(self, state: Any, delta: Any) -> Any:
        return self._apply(state, delta)


def make_gossip_fns(template: Any, capacity: int = 32) -> GossipFns:
    """Build the jitted (genesis frontier, extract, apply) gossip triple for
    a CRDT state template (any registered type or dict container)."""
    return GossipFns(template, capacity)


def make_fused_serve_step(cfg: ModelConfig, mesh: Mesh,
                          dp_axes: tuple[str, ...], *, impl: str = "ref",
                          merge_strategy: str = "pmax",
                          merge_every: int = 1, delta_capacity: int = 64,
                          temperature: float = 0.0):
    """Decode one token per agent stream AND converge coordination state.

    Inputs (leading dims):
      params                     model-sharded
      cache                      batch-sharded over dp_axes
      token, pos: [B]            agent streams (B rows = N agents × replicas)
      slots: [B] i32             each agent's claimed doc slot
      active: [B] bool           streams still generating
      coord: {doc: SlotDoc, heartbeats: GCounter} leaves stacked [R, ...]
      step: i32                  global step (for merge cadence)

    The local replica appends its rows' tokens into its own doc replica;
    the collective join then makes every replica observe everyone's edits —
    deterministic convergence with one-collective staleness.  ``merge_every``
    trades staleness for collective bytes (a §Perf axis; the paper's 50 ms
    sync delay is the analogous knob).

    With ``merge_strategy="delta"`` the coord dict additionally carries a
    ``"frontier"`` entry (build it with ``with_delta_frontier``) and each
    sync ships O(Δ) delta buffers around the replica ring instead of O(S)
    state — see core/delta.py.

    ``temperature > 0`` samples instead of argmax-decoding; pass an rng key
    as the trailing ``rng`` argument (split per step by the caller).
    """
    merge_fn = make_coord_merge(mesh, dp_axes, merge_strategy,
                                delta_capacity=delta_capacity)
    n_rep = 1
    for a in dp_axes:
        n_rep *= mesh.shape[a]

    def append_local(coord_stacked, token, slots, active):
        def local(stacked, tok, sl, act):
            state = jax.tree.map(lambda x: jnp.squeeze(x, 0), stacked)
            d = doc_mod.append_token_batch(state["doc"], sl, tok, act)
            hb = state["heartbeats"]
            hb = gset.GCounter(hb.counts + 1)          # every worker beats
            out = dict(state, doc=d, heartbeats=hb)
            return jax.tree.map(lambda x: x[None], out)

        specs = jax.tree.map(
            lambda x: P(dp_axes, *([None] * (x.ndim - 1))), coord_stacked)
        bspec = P(dp_axes)
        return merge_mod.shard_map(local, mesh=mesh,
                                   in_specs=(specs, bspec, bspec, bspec),
                                   out_specs=specs, check_vma=False)(
            coord_stacked, token, slots, active)

    def serve_step(params, cache, token, pos, slots, active, coord, step,
                   rng=None):
        logits, cache = lm.decode_step(params, cfg, token, cache, pos,
                                       impl=impl)
        if temperature > 0.0 and rng is None:
            raise ValueError("temperature > 0 requires an rng key")
        nxt = sample_token(logits, rng, temperature)
        nxt = jnp.where(active, nxt, token)
        coord = append_local(coord, nxt, slots, active)
        if merge_every == 1:
            coord = merge_fn(coord)
        else:
            coord = jax.lax.cond(step % merge_every == 0,
                                 merge_fn, lambda c: c, coord)
        pos = pos + jnp.where(active, 1, 0)
        return nxt, cache, pos, coord

    return serve_step


# ---------------------------------------------------------------------------
# Host-side engine (CPU benchmarks / agents layer)
# ---------------------------------------------------------------------------

class Engine:
    """Single-process serving engine wrapping jitted prefill/decode.

    Supports continuous batching at token granularity: rows carry per-row
    position and active flags; new requests can be swapped into inactive
    rows between steps.
    """

    def __init__(self, cfg: ModelConfig, params: Params, *, batch: int,
                 max_len: int, impl: str = "ref", temperature: float = 0.0,
                 paged: bool = False, page_size: int = 64):
        self.cfg = cfg
        self.params = params
        self.batch = batch
        self.max_len = max_len
        self.paged = paged
        self.page_size = page_size
        # Donate the cache: without donation XLA keeps the input and output
        # KV cache alive simultaneously — 2x resident HBM on the largest
        # buffer in the system — and loses the in-place cache update.
        self._prefill = jax.jit(make_prefill_fn(cfg, impl=impl),
                                donate_argnums=(1,))
        self._step = jax.jit(make_serve_step(cfg, impl=impl,
                                             temperature=temperature),
                             donate_argnums=(1,))
        self.reset()

    def reset(self):
        self.cache = lm.init_cache(self.cfg, self.batch, self.max_len,
                                   paged=self.paged,
                                   page_size=self.page_size)
        if self.paged:
            from repro.models import attention
            self.cache = lm.set_block_tables(
                self.cache, attention.default_block_tables(
                    self.batch, self.max_len, self.page_size))
        self.pos = jnp.zeros((self.batch,), jnp.int32)
        self.token = jnp.zeros((self.batch,), jnp.int32)
        self.rng = jax.random.PRNGKey(0)
        # Host mirror of max(pos): the paged-full guard must not force a
        # device sync per step.  Callers doing per-row pos surgery reset
        # rows to 0, which can only lower the true max — the mirror stays
        # a safe upper bound.
        self._pos_ceiling = 0

    def prefill(self, tokens: jax.Array, **stubs):
        """Uniform prompt for all rows. tokens: [B, P]."""
        logits, self.cache = self._prefill(self.params, self.cache, tokens,
                                           **stubs)
        self.pos = jnp.full((self.batch,),
                            tokens.shape[1] + self.cfg.num_prefix_tokens,
                            jnp.int32)
        self._pos_ceiling = tokens.shape[1] + self.cfg.num_prefix_tokens
        self.token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return self.token

    def step(self) -> jax.Array:
        if self.paged and self._pos_ceiling >= self.max_len:
            raise ValueError(
                f"paged cache is full (pos {self._pos_ceiling} >= max_len "
                f"{self.max_len}); a dense cache ring-wraps, pages do not — "
                "bound generation or raise max_len")
        self.rng, sub = jax.random.split(self.rng)
        self.token, self.cache, self.pos = self._step(
            self.params, self.cache, self.token, self.pos, sub)
        self._pos_ceiling += 1
        return self.token

    def generate(self, tokens: jax.Array, steps: int, **stubs) -> jax.Array:
        outs = [self.prefill(tokens, **stubs)]
        for _ in range(steps - 1):
            outs.append(self.step())
        return jnp.stack(outs, axis=1)
