"""deepseek-v2-lite-16b [moe] — MLA kv_lora=512 + 2 shared/64 routed top-6
(arXiv:2405.04434).  The assignment's bracketed config says 64 experts while
its prose says 160; we follow the bracket (DESIGN.md §4)."""
from repro.models.config import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", family="moe",
    num_layers=27, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1408, vocab_size=102400,
    block_pattern=("mla_moe",),
    moe=MoEConfig(num_experts=64, top_k=6, d_expert=1408, num_shared=2),
    mla=MLAConfig(kv_lora_rank=512, rope_head_dim=64, nope_head_dim=128,
                  v_head_dim=128),
)
