"""whisper-tiny [audio] — enc-dec; conv frontend STUB (arXiv:2212.04356).

input_specs() provides 1500 precomputed frame embeddings (the conv stem is
out of assignment scope); 4-layer bidirectional encoder + 4-layer decoder
with cross-attention.
"""
from repro.models.config import EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="audio",
    num_layers=4, d_model=384, num_heads=6, num_kv_heads=6,
    d_ff=1536, vocab_size=51865,
    block_pattern=("xattn",),
    norm_type="layernorm", use_bias=True, ffn_activation="gelu_mlp",
    encoder=EncoderConfig(num_layers=4, num_heads=6, seq_len=1500),
)
