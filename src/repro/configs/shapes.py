"""Assigned input-shape sets and (arch × shape) applicability.

Four LM shapes (seq_len × global_batch):
  train_4k     4_096 × 256   -> lowers train_step
  prefill_32k  32_768 × 32   -> lowers prefill (inference prompt pass)
  decode_32k   32_768 × 128  -> lowers serve_step (1 new token, 32k cache)
  long_500k    524_288 × 1   -> serve_step; ONLY sub-quadratic archs

Skips (DESIGN.md §4): long_500k is skipped for pure full-attention archs
(granite, olmo, command-r+, starcoder2, both deepseeks, paligemma) and for
the enc-dec audio arch (whisper) — recorded as N/A in the roofline table.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def applicable(arch_cfg, shape: ShapeSpec) -> tuple[bool, str]:
    """(runs?, reason-if-skipped) for an (arch, shape) cell."""
    if shape.name == "long_500k":
        if arch_cfg.is_encdec:
            return False, "enc-dec audio arch: 500k-token decode undefined"
        if not arch_cfg.sub_quadratic:
            return False, "pure full-attention arch: needs sub-quadratic attention"
    return True, ""
