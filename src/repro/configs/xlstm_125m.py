"""xlstm-125m [ssm] — sLSTM + mLSTM alternating blocks (arXiv:2405.04517)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m", family="ssm",
    num_layers=12, d_model=768, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab_size=50304,
    block_pattern=("slstm", "mlstm"),
    proj_factor=2.0,
    tie_embeddings=True,
)
