"""paligemma-3b [vlm] — SigLIP (stub) + gemma decoder (arXiv:2407.07726).

The SigLIP vision tower is a STUB per the assignment: input_specs() provides
256 precomputed patch embeddings at d_model, attended bidirectionally as a
prefix (prefix-LM mask); text is causal.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b", family="vlm",
    num_layers=18, d_model=2048, num_heads=8, num_kv_heads=1,
    head_dim=256, d_ff=16384, vocab_size=257216,
    block_pattern=("attn",),
    ffn_activation="gelu",          # GeGLU (gemma)
    tie_embeddings=True, embed_scale=True,
    num_prefix_tokens=256,
)
