"""Architecture registry: ``--arch <id>`` resolves here.

Each module exports ``CONFIG`` (the exact assigned full-size config).
``reduced(cfg)`` derives the family-preserving small config used by CPU
smoke tests (full configs are exercised only via the dry-run).
"""
from __future__ import annotations

import dataclasses

from repro.configs import shapes
from repro.configs.command_r_plus_104b import CONFIG as command_r_plus_104b
from repro.configs.deepseek_moe_16b import CONFIG as deepseek_moe_16b
from repro.configs.deepseek_v2_lite_16b import CONFIG as deepseek_v2_lite_16b
from repro.configs.granite_34b import CONFIG as granite_34b
from repro.configs.olmo_1b import CONFIG as olmo_1b
from repro.configs.paligemma_3b import CONFIG as paligemma_3b
from repro.configs.recurrentgemma_2b import CONFIG as recurrentgemma_2b
from repro.configs.starcoder2_15b import CONFIG as starcoder2_15b
from repro.configs.whisper_tiny import CONFIG as whisper_tiny
from repro.configs.xlstm_125m import CONFIG as xlstm_125m
from repro.models.config import EncoderConfig, MLAConfig, ModelConfig, MoEConfig

ARCHS: dict[str, ModelConfig] = {
    c.name: c for c in [
        xlstm_125m, paligemma_3b, granite_34b, olmo_1b,
        command_r_plus_104b, starcoder2_15b, whisper_tiny,
        recurrentgemma_2b, deepseek_moe_16b, deepseek_v2_lite_16b,
    ]
}


def get(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


def reduced(cfg: ModelConfig, *, layers: int | None = None,
            d_model: int = 64, vocab: int = 256) -> ModelConfig:
    """Family-preserving tiny config for CPU smoke tests."""
    # One full pattern group, plus the same tail remainder as the full config
    # (so tail code paths are exercised too).
    n_pat = len(cfg.block_pattern)
    n_layers = layers if layers is not None else n_pat + len(cfg.tail_blocks)
    heads = min(cfg.num_heads, 4)
    kv = min(cfg.num_kv_heads, heads)
    while heads % kv:
        kv -= 1
    kw = dict(
        num_layers=n_layers, d_model=d_model,
        num_heads=heads, num_kv_heads=kv, head_dim=d_model // heads,
        d_ff=0 if cfg.d_ff == 0 else 4 * d_model,
        vocab_size=vocab,
        rglru_width=d_model if cfg.rglru_width else 0,
        window=min(cfg.window, 16) if cfg.window else None,
        num_prefix_tokens=8 if cfg.num_prefix_tokens else 0,
    )
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe, num_experts=8, top_k=2, d_expert=32, num_shared=1,
            capacity_factor=4.0)
    if cfg.mla is not None:
        kw["mla"] = dataclasses.replace(
            cfg.mla, kv_lora_rank=32, rope_head_dim=8, nope_head_dim=16,
            v_head_dim=16)
    if cfg.encoder is not None:
        kw["encoder"] = EncoderConfig(num_layers=2, num_heads=heads,
                                      seq_len=16)
    return cfg.replace(**kw)


__all__ = ["ARCHS", "get", "reduced", "shapes", "ModelConfig", "MoEConfig",
           "MLAConfig", "EncoderConfig"]
