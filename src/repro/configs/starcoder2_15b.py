"""starcoder2-15b [dense] — GQA kv=4, RoPE, biases (arXiv:2402.19173)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b", family="dense",
    num_layers=40, d_model=6144, num_heads=48, num_kv_heads=4,
    d_ff=24576, vocab_size=49152,
    block_pattern=("attn",),
    use_bias=True, norm_type="layernorm", ffn_activation="gelu_mlp",
)
