"""command-r-plus-104b [dense] — GQA kv=8, no biases, parallel blocks
(hf:CohereForAI/c4ai-command-r-plus)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b", family="dense",
    num_layers=64, d_model=12288, num_heads=96, num_kv_heads=8,
    d_ff=33792, vocab_size=256000,
    block_pattern=("attn",),
    parallel_block=True, norm_type="layernorm", use_bias=False,
)
