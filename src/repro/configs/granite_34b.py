"""granite-34b [dense] — llama-arch code model, MQA (arXiv:2405.04324)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b", family="dense",
    num_layers=88, d_model=6144, num_heads=48, num_kv_heads=1,
    d_ff=24576, vocab_size=49152,
    block_pattern=("attn",),
    use_bias=True, norm_type="layernorm", ffn_activation="gelu_mlp",
)
