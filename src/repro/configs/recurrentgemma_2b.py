"""recurrentgemma-2b [hybrid] — RG-LRU + local attn 1:2 (arXiv:2402.19427).

26 layers = 8 × (rglru, rglru, local-attn) + tail (rglru, rglru); local
window 2048.  Sub-quadratic => long_500k RUNS for this arch.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    num_layers=26, d_model=2560, num_heads=10, num_kv_heads=1,
    head_dim=256, d_ff=7680, vocab_size=256000,
    block_pattern=("rglru", "rglru", "local"),
    window=2048, rglru_width=2560, conv_width=4,
    ffn_activation="gelu", tie_embeddings=True, embed_scale=True,
)
