"""repro.runtime subsystem."""
