"""Sharded, atomic, async checkpointing with restore validation.

Layout:  <dir>/step_<N>/
           meta.json            tree structure + shapes/dtypes + step
           arr_<i>.npy          one file per leaf (local shard on real pods)
         <dir>/LATEST           text pointer, written last (atomic commit)

Writes go to a tmp directory first and are renamed into place, so a crash
mid-write can never corrupt the latest checkpoint; the LATEST pointer is
flipped only after the step directory is complete.  ``AsyncCheckpointer``
moves serialization off the training thread (the step only blocks if the
previous save is still in flight — standard checkpoint/compute overlap).
GC keeps the newest ``keep`` steps.

On a real multi-host pod each process saves only its addressable shards;
here (single host) the full array is the local shard.
"""
from __future__ import annotations

import json
import shutil
import threading
from pathlib import Path
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

Params = Any


def _flatten(tree: Params):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(directory: str | Path, step: int, tree: Params, *, keep: int = 3
         ) -> Path:
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:08d}"
    tmp = directory / f".tmp_step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()

    leaves, treedef = _flatten(tree)
    meta = {
        "step": int(step),
        "treedef": str(treedef),
        "leaves": [],
    }
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        dtype_name = str(arr.dtype)
        store = arr
        if dtype_name == "bfloat16":      # np.save would pickle ml_dtypes
            store = arr.view(np.uint16)
        np.save(tmp / f"arr_{i}.npy", store)
        meta["leaves"].append({"shape": list(arr.shape),
                               "dtype": dtype_name})
    (tmp / "meta.json").write_text(json.dumps(meta))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)                                  # atomic commit
    (directory / "LATEST.tmp").write_text(final.name)
    (directory / "LATEST.tmp").rename(directory / "LATEST")
    _gc(directory, keep)
    return final


def latest_step(directory: str | Path) -> Optional[int]:
    directory = Path(directory)
    ptr = directory / "LATEST"
    if not ptr.exists():
        return None
    name = ptr.read_text().strip()
    if not (directory / name / "meta.json").exists():
        return None
    return int(name.split("_")[1])


def restore(directory: str | Path, tree_like: Params,
            step: Optional[int] = None) -> tuple[Params, int]:
    """Restore into the structure of ``tree_like`` (validates shapes/dtypes)."""
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    d = directory / f"step_{step:08d}"
    meta = json.loads((d / "meta.json").read_text())
    leaves_like, treedef = _flatten(tree_like)
    if len(leaves_like) != len(meta["leaves"]):
        raise ValueError(
            f"checkpoint has {len(meta['leaves'])} leaves, expected "
            f"{len(leaves_like)} — tree structure changed")
    leaves = []
    for i, (like, info) in enumerate(zip(leaves_like, meta["leaves"])):
        arr = np.load(d / f"arr_{i}.npy")
        if info["dtype"] == "bfloat16":
            import ml_dtypes
            arr = arr.view(ml_dtypes.bfloat16)
        want_shape = tuple(getattr(like, "shape", arr.shape))
        if tuple(arr.shape) != want_shape:
            raise ValueError(f"leaf {i}: shape {arr.shape} != {want_shape}")
        leaves.append(jnp.asarray(arr))
    return treedef.unflatten(leaves), int(meta["step"])


def _gc(directory: Path, keep: int) -> None:
    steps = sorted(p for p in directory.glob("step_*") if p.is_dir())
    for p in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(p, ignore_errors=True)


class AsyncCheckpointer:
    """Overlap checkpoint serialization with training compute."""

    def __init__(self, directory: str | Path, *, keep: int = 3):
        self.directory = Path(directory)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree: Params) -> None:
        self.wait()
        # Device->host transfer happens here (synchronously, consistent
        # snapshot); file IO happens on the worker thread.
        host_tree = jax.tree.map(np.asarray, tree)

        def work():
            try:
                save(self.directory, step, host_tree, keep=self.keep)
            except BaseException as e:   # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
