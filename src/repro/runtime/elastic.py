"""Elastic, fault-tolerant work distribution via the CRDT TodoBoard.

The paper's TODO-claim protocol, reused as the training control plane:
*data shards* are the TODOs.  Workers claim shards through the optimistic
write-verify protocol (at-most-one-winner ⇒ no duplicated work in the steady
state), heartbeat through a G-counter, and any live worker can reclaim
shards whose owner went silent (the paper's 120 s liveness rule).  Because
shard → batches is a pure function (data/pipeline.py), a reclaimed shard
reproduces identical data, so worker loss never skews the data distribution
— duplicated work on the loss boundary is idempotent by construction.

Workers may join or leave between claims (elastic scaling); no central
scheduler exists — the merged CRDT state IS the schedule.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import gset, merge as merge_mod, protocol, todo
from repro.core.clock import Lamport


@dataclass
class WorkQueueState:
    board: todo.TodoBoard
    heartbeats: gset.GCounter          # monotone wall-clock per worker
    completed: gset.GSet               # shard done flags (redundant w/ board,
                                       # kept as the idempotent commit record)

    def merge(self, other: "WorkQueueState") -> "WorkQueueState":
        return WorkQueueState(
            board=merge_mod.join(self.board, other.board),
            heartbeats=self.heartbeats.join(other.heartbeats),
            completed=self.completed.join(other.completed),
        )


def make_queue(num_shards: int, num_workers: int) -> WorkQueueState:
    board = todo.empty(num_shards)
    lam = Lamport.create(client=1023)
    deps = jnp.zeros((num_shards,), bool)
    for k in range(num_shards):
        lam = lam.tick()
        board = todo.post(board, k, deps, lam.time, lam.client)
    return WorkQueueState(
        board=board,
        heartbeats=gset.GCounter.zeros(max(num_workers + 1, 8)),
        completed=gset.GSet.empty(num_shards),
    )


class Worker:
    """One elastic worker's view of the queue.

    ``sync_fn`` plays the relay role: it takes this worker's state and
    returns the merged global state (in-process tests pass a shared-fold;
    a real deployment merges through collectives or a gossip mesh —
    the protocol is substrate-agnostic, paper §3.2).
    """

    def __init__(self, worker_id: int, state: WorkQueueState,
                 sync_fn: Callable[[WorkQueueState], WorkQueueState],
                 *, stale_timeout: int = 120):
        assert worker_id >= 1
        self.id = worker_id
        self.state = state
        self.sync = sync_fn
        self.lamport = Lamport.create(worker_id)
        self.stale_timeout = stale_timeout

    def heartbeat(self, now: int) -> None:
        self.state.heartbeats = self.state.heartbeats.bump_to(self.id, now)
        self.state = self.sync(self.state)

    def try_claim_shard(self, now: int) -> Optional[int]:
        """Claim protocol round; returns shard id on success."""
        def merge_board(b):
            s = self.sync(WorkQueueState(b, self.state.heartbeats,
                                         self.state.completed))
            self.state = s
            return s.board

        out = protocol.try_claim(self.state.board, self.lamport,
                                 jnp.int32(now), merge_board)
        self.lamport = out.lamport
        self.state.board = out.board
        if bool(out.won):
            return int(out.todo_id)
        return None

    def complete_shard(self, shard_id: int) -> None:
        def merge_board(b):
            s = self.sync(WorkQueueState(b, self.state.heartbeats,
                                         self.state.completed))
            self.state = s
            return s.board

        self.state.completed = self.state.completed.add(jnp.int32(shard_id))
        board, self.lamport = protocol.complete(
            self.state.board, self.lamport, jnp.int32(shard_id), merge_board)
        self.state.board = board

    def reclaim_stale(self, now: int) -> int:
        """Reset claims past the timeout (paper's 120 s liveness rule)."""
        def merge_board(b):
            s = self.sync(WorkQueueState(b, self.state.heartbeats,
                                         self.state.completed))
            self.state = s
            return s.board

        before = int(jnp.sum(self.state.board.status == todo.CLAIMED))
        board, self.lamport = protocol.reclaim_stale(
            self.state.board, self.lamport, jnp.int32(now),
            jnp.int32(self.stale_timeout), merge_board)
        self.state.board = board
        after = int(jnp.sum(board.status == todo.CLAIMED))
        return before - after

    def stragglers(self, now: int, lag: int) -> list[int]:
        """Workers whose heartbeat lags ``now`` by more than ``lag``."""
        hb = np.asarray(self.state.heartbeats.counts)
        return [i for i in range(1, len(hb))
                if hb[i] > 0 and now - int(hb[i]) > lag]

    def done(self) -> bool:
        return bool(todo.all_done(self.state.board))


def make_shared_fold_sync(shared: dict) -> Callable:
    """In-process 'relay': fold every worker's state into a shared cell."""
    def sync(state: WorkQueueState) -> WorkQueueState:
        shared["state"] = (state if "state" not in shared
                           else shared["state"].merge(state))
        return shared["state"]
    return sync
