"""Evaluator agent: semantic-conflict detection and automatic reconciliation
(paper §4.3: "Evaluator agent identifies conflicts via TypeScript
diagnostics; applies automatic fixes or flags for review").

CRDTs guarantee character-level convergence but cannot see semantics.  The
evaluator scans the converged document for duplicate symbol declarations
(the paper's dominant conflict class) and reconciles them the way its
auto-fix does: the *later* declaration is renamed to a fresh symbol.  The
fix is itself an ordinary CRDT edit (append-only patch slot entries), so it
merges and converges like any agent edit — reconciliation needs no special
machinery, which is the point of building on SEC.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

from repro.core import doc as doc_mod

DECL_MOD = 13
DECL_RESIDUE = 5
SYMBOL_SPACE = 64


@dataclass
class Conflict:
    symbol: int
    first_slot: int
    dup_slot: int
    dup_index: int          # position within the dup slot


@dataclass
class Report:
    conflicts: list[Conflict] = field(default_factory=list)
    total_declarations: int = 0
    fixed: int = 0
    flagged: list[Conflict] = field(default_factory=list)

    @property
    def conflict_rate_per_1k(self) -> float:
        total_tokens = max(self.total_tokens, 1)
        return 1000.0 * len(self.conflicts) / total_tokens

    total_tokens: int = 0


def scan(merged: doc_mod.SlotDoc) -> Report:
    """Find duplicate declarations across slots (deterministic order)."""
    lengths = np.asarray(merged.length)
    tokens = np.asarray(merged.tokens)
    declared: dict[int, int] = {}
    rep = Report(total_tokens=int(lengths.sum()))
    for s in range(merged.num_slots):
        for i in range(int(lengths[s])):
            t = int(tokens[s, i])
            if t % DECL_MOD == DECL_RESIDUE:
                rep.total_declarations += 1
                sym = t % SYMBOL_SPACE
                if sym in declared and declared[sym] != s:
                    rep.conflicts.append(
                        Conflict(symbol=sym, first_slot=declared[sym],
                                 dup_slot=s, dup_index=i))
                else:
                    declared.setdefault(sym, s)
    return rep


def _fresh_symbol_token(used: set[int]) -> int | None:
    """A declaration-class token whose symbol is unused (tok ≡ 5 mod 13)."""
    for sym in range(SYMBOL_SPACE):
        if sym in used:
            continue
        # Find tok with tok % 13 == 5 and tok % 64 == sym (CRT over 13·64).
        for tok in range(DECL_RESIDUE, 13 * 64, DECL_MOD):
            if tok % SYMBOL_SPACE == sym:
                return tok
    return None


def reconcile(merged: doc_mod.SlotDoc, patch_slot: int | None = None
              ) -> tuple[doc_mod.SlotDoc, Report]:
    """Auto-fix duplicate declarations by appending rename patches.

    Appends, per fixable conflict, a 3-token patch record
    (old declaration token, dup slot id, fresh declaration token) to the
    patch slot — the append-only analogue of a rename refactor.  Conflicts
    with no fresh symbol available are flagged for review.
    """
    rep = scan(merged)
    if patch_slot is None:
        patch_slot = merged.num_slots - 1
    used = {c.symbol for c in rep.conflicts}
    lengths = np.asarray(merged.length)
    tokens = np.asarray(merged.tokens)
    for s in range(merged.num_slots):
        for i in range(int(lengths[s])):
            t = int(tokens[s, i])
            if t % DECL_MOD == DECL_RESIDUE:
                used.add(t % SYMBOL_SPACE)

    doc = merged
    for c in rep.conflicts:
        fresh = _fresh_symbol_token(used)
        if fresh is None:
            rep.flagged.append(c)
            continue
        used.add(fresh % SYMBOL_SPACE)
        old_tok = None
        # The duplicated declaration token:
        old_tok = int(np.asarray(merged.tokens)[c.dup_slot, c.dup_index])
        patch = jnp.asarray([old_tok, c.dup_slot, fresh], jnp.int32)
        doc = doc_mod.append(doc, jnp.int32(patch_slot),
                             jnp.pad(patch, (0, 1)), 3)
        rep.fixed += 1
    return doc, rep


def score(merged: doc_mod.SlotDoc, rep: Report | None = None
          ) -> dict[str, float]:
    """Objective 0-20 scores over measurable quantities (paper §5.2.3's
    objective half; LLM-judged subjective scores are out of CPU scope)."""
    rep = rep or scan(merged)
    tokens = max(rep.total_tokens, 1)
    quality = max(0.0, 20.0 - 40.0 * len(rep.conflicts) / tokens * 10)
    functionality = 20.0 * min(1.0, rep.total_declarations / 8)
    return {
        "code_quality": round(quality, 2),
        "functionality": round(functionality, 2),
        "conflicts_per_1k": round(1000.0 * len(rep.conflicts) / tokens, 3),
    }
