"""Multi-agent code-generation orchestrator (the paper's experiment loop).

Agents are rows of one batched decode engine — the TPU-native analogue of
"N concurrent LLM API calls".  Coordination is exclusively through CRDT
state (TodoBoard + per-agent SlotDoc replicas, merged through the join):
no message passing, no scheduler.  The loop implements the paper's four
observation-driven behaviours:

  completed-work detection   claims skip DONE TODOs (board observation)
  context integration        prompts embed the *current* content of read slots
  naming alignment           (same mechanism — context replay of neighbors)
  conflict avoidance         optimistic claim → LWW arbitration → losers re-pick

Invalidations: if a read slot's version advances mid-generation, the agent
re-contextualizes (replays a fresh prompt) — the measured source of the
coupled-task slowdown (paper §4.2, Table 7).

Sequential mode is the same machinery with one agent.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.agents.tasks import TaskSpec
from repro.core import delta as delta_mod
from repro.core import doc as doc_mod
from repro.core import merge as merge_mod
from repro.core import observe, protocol, todo
from repro.core.clock import Lamport
from repro.models import cache as cache_mod
from repro.models import lm
from repro.models.config import ModelConfig
from repro.serving import draft as draft_mod
from repro.serving import engine as engine_mod

IDLE, PREFILL, GEN, HALT = "idle", "prefill", "gen", "halt"
OBSERVE_EVERY = 8          # steps between observation sweeps
MAX_REPREFILL = 2          # bounded re-contextualizations per TODO
MAX_MAP_FAILURES = 3       # consecutive page-map failures before giving up
SLOT_CAP = 1024


@dataclass
class AgentState:
    row: int                            # engine batch row
    client: int                         # CRDT client id (>=1)
    phase: str = IDLE
    todo_id: int = -1
    queue: list = field(default_factory=list)     # prompt tokens to replay
    tokens_left: int = 0
    reprefills: int = 0
    snapshot: Optional[observe.Snapshot] = None
    lamport: Lamport = None
    failures: int = 0                   # consecutive page-map failures
    needs_map: bool = False             # row unmapped; waiting to retry
    retry_at: int = 0                   # step at which to retry the map
    hist: list = field(default_factory=list)  # raw prompt+generated tokens
                                        # (speculative drafting context)


@dataclass
class RunResult:
    task: str
    mode: str
    n_agents: int
    wall_s: float
    gen_tokens: int
    replay_tokens: int
    steps: int
    invalidations: int
    claim_collisions: int
    observation_events: int
    semantic_conflicts: int
    declared_symbols: int
    converged: bool
    digest: int
    merge_strategy: str = "allgather"
    sync_rounds: int = 0
    sync_bytes: int = 0     # wire bytes (see delta.full_state_wire_bytes)
    kv_mode: str = "dense"          # dense | paged KV cache
    prefill_mode: str = "replay"    # replay (token-by-token) | ragged
    shared_prefix_pages: int = 0    # prompt pages shared across (re-)prefills
    replicas: int = 1               # page-table metadata replicas
    disaggregated: bool = False     # prefill/decode role-partitioned homes
    cross_replica_prefix_hits: int = 0  # prefix pages adopted from a peer
    page_sync_bytes: int = 0        # page-table anti-entropy wire bytes
    agent_failures: int = 0         # page-map failures hit by agent loops
    agent_retries: int = 0          # successful backoff re-maps after failure
    spec_decode: str = "off"        # off | ngram | doc drafting source
    draft_tokens: int = 0           # speculative tokens proposed
    accepted_tokens: int = 0        # draft tokens the verifier accepted
    rollback_tokens: int = 0        # rejected-tail tokens rolled back

    @property
    def accept_rate(self) -> float:
        return self.accepted_tokens / max(1, self.draft_tokens)

    @property
    def tokens_per_s(self) -> float:
        return self.gen_tokens / max(self.wall_s, 1e-9)

    @property
    def s_per_1k_tokens(self) -> float:
        return 1000.0 * self.wall_s / max(self.gen_tokens, 1)

    # Response time in decode-step units: on the serving target (TPU v5e)
    # decode latency is weight-streaming-bound and batch-invariant for B≤8,
    # so steps ≡ latency; CPU wall-clock scales with batch (no idle lanes)
    # and is reported as the secondary column.  See EXPERIMENTS.md §Agents.
    @property
    def response_steps(self) -> int:
        return self.steps

    @property
    def steps_per_1k_tokens(self) -> float:
        return 1000.0 * self.steps / max(self.gen_tokens, 1)


# ---------------------------------------------------------------------------
# Content model: prompts + semantic-conflict detection
# ---------------------------------------------------------------------------

def _prompt_tokens(task: TaskSpec, todo_id: int, docs, vocab: int,
                   rng: np.random.Generator) -> list[int]:
    """Deterministic task/TODO header + current content of read slots."""
    base = np.random.default_rng(hash((task.name, todo_id)) % (2**31))
    toks = list(2 + base.integers(0, vocab - 2, size=task.prompt_tokens))
    merged = merge_mod.fold_join(docs)
    lengths = np.asarray(merged.length)
    tokens = np.asarray(merged.tokens)
    for r in task.reads.get(todo_id, ()):
        n = int(lengths[r])
        if n > 0:     # context integration: read the neighbor's latest code
            tail = tokens[r, max(0, n - task.read_prompt_tokens): n]
            toks.extend(int(t) for t in tail)
    return toks


def count_conflicts(merged: doc_mod.SlotDoc) -> tuple[int, int]:
    """Semantic conflicts: the same symbol *declared* in two different slots.

    Declaration tokens are tokens ≡ 5 (mod 13); the symbol is tok mod 64 —
    a fixed projection of real generated content into a symbol namespace
    (duplicate declarations are exactly the paper's dominant conflict class).
    Returns (conflicts, total_declarations).
    """
    lengths = np.asarray(merged.length)
    tokens = np.asarray(merged.tokens)
    declared: dict[int, int] = {}
    conflicts = 0
    total = 0
    for s in range(merged.num_slots):
        for t in tokens[s, : lengths[s]]:
            t = int(t)
            if t % 13 == 5:
                total += 1
                sym = t % 64
                if sym in declared and declared[sym] != s:
                    conflicts += 1
                else:
                    declared.setdefault(sym, s)
    return conflicts, total


# ---------------------------------------------------------------------------
# The run loop
# ---------------------------------------------------------------------------

def run_task(cfg: ModelConfig, params, task: TaskSpec, *, mode: str,
             n_agents: int = 4, seed: int = 0, max_len: int = 1024,
             merge: str = "allgather", delta_capacity: int = 64,
             kv: str = "dense", prefill: str = "replay",
             page_size: int = 64, chunk_size: int = 32, replicas: int = 1,
             spec_decode: str = "off", spec_k: int = 4,
             kv_quant: str = "off", disaggregate: bool = False,
             time_fn=time.perf_counter) -> RunResult:
    """``kv="paged"`` backs the agents with the paged KV cache.

    ``prefill="chunked"`` (alias ``"ragged"``) rides the token-budget mixed
    serve step: each loop iteration spends one span per agent — a ≤
    ``chunk_size`` slice of any agent's pending (re-)contextualization
    prompt AND one decode token for every generating agent, in the same
    batched call — so an agent replaying a fresh prompt after an
    invalidation never stalls its neighbours.  ``"replay"`` is the paper's
    token-by-token baseline (one decode step per prompt token)."""
    assert mode in ("sequential", "parallel")
    assert merge in ("allgather", "pmax", "delta")
    assert kv in ("dense", "paged")
    assert prefill in ("replay", "ragged", "chunked")
    if replicas > 1 and kv != "paged":
        raise ValueError("--replicas > 1 requires the paged KV cache "
                         "(the replicated page table replicates page "
                         "metadata, not a dense per-row cache)")
    if kv_quant != "off" and kv != "paged":
        raise ValueError("--kv-quant requires --kv paged (quantized "
                         "layouts are page-pool layouts)")
    if disaggregate and replicas < 2:
        raise ValueError("--disaggregate requires --replicas >= 2 (one "
                         "prefill home plus at least one decode home)")
    chunked = prefill in ("ragged", "chunked")
    if spec_decode not in ("off", "ngram", "doc"):
        raise ValueError(f"spec_decode must be off/ngram/doc, got "
                         f"{spec_decode!r}")
    if spec_decode != "off" and not chunked:
        raise ValueError("--spec-decode rides the mixed serve step: "
                         "use --prefill chunked (verify widens decode "
                         "spans, which the replay baseline cannot express)")
    if mode == "sequential":
        n_agents = 1
    rng = np.random.default_rng(seed)
    k_todos = task.n_todos
    vocab = cfg.vocab_size

    # Shared coordination state (board) + per-agent document replicas.
    board = todo.empty(k_todos)
    out_lam = Lamport.create(client=100)
    deps_np = np.zeros((k_todos, k_todos), bool)
    for k, ds in task.deps.items():
        for d in ds:
            deps_np[k, d] = True

    docs = [doc_mod.empty(k_todos, SLOT_CAP) for _ in range(n_agents)]
    agents = [AgentState(row=i, client=i + 1, lamport=Lamport.create(i + 1))
              for i in range(n_agents)]
    state_bytes = delta_mod.nbytes(docs[0])
    delta_sync = (delta_mod.DeltaSync(docs[0], capacity=delta_capacity)
                  if merge == "delta" else None)

    # Jit every hot helper once: eager lax.fori_loop (claims) re-traces and
    # re-compiles per call — at one claim round per step that dominated wall
    # time (~0.5 s/step) and, worse, contaminated the seq-vs-par comparison.
    step_fn = jax.jit(engine_mod.make_serve_step(cfg))
    claims_fn = jax.jit(protocol.concurrent_claims)
    fold_fn = jax.jit(merge_mod.fold_join)
    ready_fn = jax.jit(todo.ready_mask)
    all_done_fn = jax.jit(todo.all_done)
    complete_fn = jax.jit(todo.complete)
    append_fn = jax.jit(doc_mod.append_token)
    append_run_fn = jax.jit(doc_mod.append)
    digest_fn = jax.jit(doc_mod.digest)
    mapper = None
    if kv == "paged":
        from repro.serving.scheduler import PrefixPageMapper
        # Shared-prefix admission: each (re-)contextualization maps the
        # row's pages through a refcounted pool with longest-prefix reuse —
        # the unchanged task/TODO prompt header keeps its pages across
        # invalidation replays instead of being re-pooled per agent.
        maxp = -(-max_len // page_size)
        if replicas > 1:
            from repro.serving.replicated import ReplicatedPrefixPageMapper
            # One remap-transient spare slice per metadata replica: agents
            # are partitioned round-robin, so each home partition must hold
            # its agents' pages plus one in-flight remap.
            pool_pages = (n_agents + replicas) * maxp
            mapper = ReplicatedPrefixPageMapper(
                n_agents, maxp, page_size, trash_page=pool_pages,
                replicas=replicas, num_pages=pool_pages,
                disaggregate=disaggregate)
        else:
            pool_pages = (n_agents + 1) * maxp     # +maxp: remap transient
            mapper = PrefixPageMapper(n_agents, maxp, page_size,
                                      trash_page=pool_pages,
                                      num_pages=pool_pages)
        cache = lm.init_cache(cfg, n_agents, max_len, paged=True,
                              page_size=page_size,
                              num_pages=pool_pages + 1, kv_quant=kv_quant)
        cache = mapper.install(cache)
    else:
        cache = lm.init_cache(cfg, n_agents, max_len)

    def recontextualize(a: AgentState) -> bool:
        """Map the agent's new prompt into pages (shared-prefix admission).

        Returns False when the pool cannot serve the re-map right now: the
        agent's row is released (which relieves the very pressure that made
        the map fail) and the agent backs off with deterministic jitter
        instead of the whole trial aborting.  Only after MAX_MAP_FAILURES
        consecutive failures does the pool-exhausted error propagate.
        """
        if mapper is None:
            return True
        horizon = min(len(a.queue) + gen_budget, max_len)
        try:
            mapper.map_row(a.row, a.queue, horizon)
        except RuntimeError:
            stats["agent_fail"] += 1
            a.failures += 1
            if a.failures >= MAX_MAP_FAILURES:
                raise
            mapper.free_row(a.row)
            a.needs_map = True
            a.retry_at = stats["steps"] + engine_mod.backoff_steps(
                a.client, a.failures)
            return False
        if a.needs_map:
            stats["agent_retry"] += 1
        a.needs_map = False
        a.failures = 0
        return True

    def push_tables() -> None:
        nonlocal cache
        if mapper is not None:
            cache = mapper.install(cache)
    pos = jnp.zeros((n_agents,), jnp.int32)
    token = jnp.ones((n_agents,), jnp.int32)
    key = jax.random.PRNGKey(seed)
    chunk_size = max(1, min(chunk_size, max_len))
    # Host mirrors for the chunked (mixed-step) path: positions and last
    # tokens never round-trip through the device.
    pos_h = np.zeros((n_agents,), np.int64)
    tok_h = np.ones((n_agents,), np.int64)

    mixed_fn = None
    if chunked:
        mixed_fn = jax.jit(engine_mod.make_mixed_step_fn(cfg))

    # Speculative decoding through the mixed step: a host-side drafter
    # widens GEN rows from span 1 to 1+k, one verify call scores the whole
    # batch (non-drafted lanes read preds at their last span position —
    # identical to greedy sampling), and rejected tails roll back bitwise
    # from a pre-verify snapshot.  The PrefixPageMapper pre-maps each row's
    # full generation horizon, so speculative writes always land in already
    # mapped pages and rollback never frees pages here.
    drafter = None
    verify_fn = snap_jit = restore_jit = None
    spec_k = max(1, int(spec_k))
    wclamp = chunk_size
    has_state = any(
        cache_mod.layout_for(k, cfg, paged=False) == "state"
        for k in tuple(cfg.block_pattern) + tuple(cfg.tail_blocks))
    if spec_decode != "off":
        drafter = draft_mod.make_drafter(spec_decode)
        wclamp = max(chunk_size, 1 + spec_k)
        verify_fn = jax.jit(engine_mod.make_verify_step_fn(cfg))

        def _snap_fn(c, start, width):
            out = {"spans": cache_mod.snapshot_span(c, start, width)}
            if has_state:
                out["state"] = lm.snapshot_state_rows(cfg, c)
            return out

        def _restore_fn(c, snap, start, lo, hi, smask):
            c = cache_mod.restore_span(c, snap["spans"], start, lo, hi)
            if has_state:
                c = lm.restore_state_rows(cfg, c, snap["state"], smask)
            return c

        snap_jit = jax.jit(_snap_fn, static_argnums=(2,))
        restore_jit = jax.jit(_restore_fn)

    # Warmup: compile every helper shape outside the timed region (the claim
    # helper has one shape per idle-agent count).
    _ = step_fn(params, cache, token, pos, key)
    if mixed_fn is not None:
        # One compile per span-width bucket; all-zero spans leave the cache
        # bit-for-bit as-is, so warmup is free of side effects.
        for wb in engine_mod.mixed_width_buckets(chunk_size):
            _, cache = mixed_fn(params, cache,
                                jnp.zeros((n_agents, wb), jnp.int32),
                                jnp.zeros((n_agents,), jnp.int32),
                                jnp.zeros((n_agents,), jnp.int32), key)
    if verify_fn is not None:
        # Verify + snapshot/restore per width bucket; zero spans and empty
        # rollback windows leave the cache bit-for-bit untouched.
        z = jnp.zeros((n_agents,), jnp.int32)
        for wb in engine_mod.mixed_width_buckets(wclamp):
            _, _, cache = verify_fn(params, cache,
                                    jnp.zeros((n_agents, wb), jnp.int32),
                                    z, z)
            s0 = snap_jit(cache, z, wb)
            cache = restore_jit(cache, s0, z, z, z,
                                jnp.zeros((n_agents,), bool))
    warm_board = todo.post(todo.empty(k_todos), 0,
                           jnp.zeros((k_todos,), bool), jnp.int32(1),
                           jnp.int32(100))
    for m in range(1, n_agents + 1):
        _ = claims_fn(warm_board, jnp.arange(1, m + 1, dtype=jnp.int32),
                      jnp.full((m,), 10, jnp.int32), jnp.int32(0))
    _ = complete_fn(warm_board, jnp.int32(0), jnp.int32(1), jnp.int32(5))
    _ = fold_fn(docs)
    warm = append_run_fn(docs[0], jnp.int32(0),
                         jnp.zeros((128,), jnp.int32), jnp.int32(0))
    jax.block_until_ready(warm.length)
    if delta_sync is not None:   # compile extract/apply outside timed region
        delta_mod.DeltaSync(docs[0], capacity=delta_capacity).sync(docs)

    t0 = time_fn()

    # --- Outliner: generates the skeleton, posts TODOs (both modes pay it).
    for _ in range(6 * k_todos // max(n_agents, 1) + 4):
        key, sub = jax.random.split(key)
        token, cache, pos = step_fn(params, cache, token, pos, sub)
    for k in range(k_todos):
        out_lam = out_lam.tick()
        board = todo.post(board, k, jnp.asarray(deps_np[k]), out_lam.time,
                          out_lam.client)
    pos = jnp.zeros((n_agents,), jnp.int32)

    gen_budget = int(round(task.base_tokens
                           * (task.par_inflation if mode == "parallel"
                              else 1.0)))
    stats = dict(gen=0, replay=0, steps=0, inval=0, collide=0, observe=0,
                 syncs=0, sync_bytes=0, agent_fail=0, agent_retry=0,
                 draft=0, accepted=0, rollback=0)
    merge_perm_seed = 0

    # Host-side mirrors: CRDT appends are buffered per agent and flushed at
    # observation boundaries (one jitted run-append per agent per sweep) so
    # the steady-state step costs exactly one jitted decode dispatch — the
    # LLM must dominate wall time for the seq/par comparison to be honest.
    host_len = np.zeros((k_todos,), np.int64)          # merged view lengths
    buffers: list[list[int]] = [[] for _ in range(n_agents)]
    buf_slot = [-1] * n_agents
    # Per-slot mirrors of flushed (committed) document content: the doc
    # drafter reads these LIVE lists, so anything one agent has flushed is
    # immediately draftable for every other agent — the CodeCRDT case
    # where the shared document predicts a row's continuation.
    slot_toks: list[list[int]] = [[] for _ in range(k_todos)]
    if drafter is not None and hasattr(drafter, "set_docs"):
        drafter.set_docs(slot_toks)
    done_count = 0
    board_dirty = True
    run_buf_cap = 128

    def flush_agent(i: int):
        nonlocal docs
        if buf_slot[i] < 0 or not buffers[i]:
            return
        toks = buffers[i]
        for off in range(0, len(toks), run_buf_cap):
            chunk = toks[off: off + run_buf_cap]
            arr = np.zeros((run_buf_cap,), np.int32)
            arr[: len(chunk)] = chunk
            docs[i] = append_run_fn(docs[i], jnp.int32(buf_slot[i]),
                                    jnp.asarray(arr), jnp.int32(len(chunk)))
        host_len[buf_slot[i]] += len(toks)
        slot_toks[buf_slot[i]].extend(toks)
        buffers[i] = []

    def sync_replicas():
        nonlocal docs, merge_perm_seed
        for i in range(n_agents):
            flush_agent(i)
        stats["syncs"] += 1
        if replicas > 1 and mapper is not None:
            mapper.gossip()               # page-table anti-entropy round
        if delta_sync is not None:
            docs = delta_sync.sync(docs)
            stats["sync_bytes"] = delta_sync.bytes_shipped
            return
        perm = np.random.default_rng(merge_perm_seed).permutation(n_agents)
        merge_perm_seed += 1
        m = fold_fn([docs[i] for i in perm])
        docs = [m for _ in range(n_agents)]
        stats["sync_bytes"] += delta_mod.full_state_wire_bytes(
            merge, n_agents, state_bytes)

    snap_len = {a.client: host_len.copy() for a in agents}

    def finish_agent(a: AgentState):
        nonlocal board, done_count, board_dirty
        flush_agent(a.row)
        a.lamport = a.lamport.observe(board.max_clock())
        board = complete_fn(board, jnp.int32(a.todo_id),
                            jnp.int32(a.client), a.lamport.time)
        done_count += 1
        board_dirty = True
        a.phase = IDLE
        buf_slot[a.row] = -1
        a.todo_id = -1
        sync_replicas()

    while True:
        # -- claims: all idle agents observe the SAME board snapshot --------
        idle = [a for a in agents if a.phase == IDLE]
        if idle and board_dirty:
            clients = jnp.asarray([a.client for a in idle], jnp.int32)
            clocks = jnp.asarray(
                [int(a.lamport.observe(board.max_clock()).time)
                 for a in idle], jnp.int32)
            board, ks, won = claims_fn(
                board, clients, clocks, jnp.int32(stats["steps"]))
            any_won = False
            for a, k, w, c in zip(idle, np.asarray(ks), np.asarray(won),
                                  np.asarray(clocks)):
                a.lamport = a.lamport._replace(time=jnp.int32(int(c)))
                if bool(w):
                    any_won = True
                    a.todo_id = int(k)
                    a.phase = PREFILL
                    a.reprefills = 0
                    a.queue = _prompt_tokens(task, a.todo_id, docs, vocab, rng)
                    a.hist = list(a.queue)
                    a.tokens_left = gen_budget
                    snap_len[a.client] = host_len.copy()
                    buf_slot[a.row] = a.todo_id
                    pos_h[a.row] = 0
                    if mixed_fn is None:
                        pos = pos.at[a.row].set(0)
                    recontextualize(a)
                else:
                    stats["collide"] += 1
            if not any_won:
                board_dirty = False      # wait for a completion to retry

        if all(a.phase == HALT for a in agents):
            break
        if done_count >= k_todos and all(
                a.phase in (IDLE, HALT) for a in agents):
            break
        if not any(a.phase in (PREFILL, GEN) for a in agents):
            # Deadlock guard: nothing runnable and nothing claimable yet.
            if done_count >= k_todos:
                break
            board_dirty = True
            stats["steps"] += 1
            if stats["steps"] > 20_000:
                break
            continue

        if mixed_fn is not None:
            # -- one token-budget mixed step: every pending prompt spends a
            # ≤ chunk_size slice AND every generating agent decodes one
            # token, in the same batched call — re-contextualization never
            # stalls the other agents' decode lanes.
            spans = np.zeros((n_agents,), np.int64)
            finishing: list[AgentState] = []
            for a in agents:
                if a.phase == PREFILL and a.needs_map:
                    # Unmapped row: no KV pages to write into.  Idle this
                    # lane (span 0) until the backoff expires and a re-map
                    # succeeds; positions never advanced, so nothing resets.
                    if not (stats["steps"] >= a.retry_at
                            and recontextualize(a)):
                        continue
                if a.phase == PREFILL and a.queue:
                    spans[a.row] = min(chunk_size, len(a.queue))
                elif a.phase == PREFILL:
                    a.phase = GEN
                    spans[a.row] = 1
                elif a.phase == GEN:
                    spans[a.row] = 1
            drafts: dict[int, list[int]] = {}
            if drafter is not None:
                # Widen decode lanes with drafter proposals.  The cap keeps
                # every speculative write inside the row's pre-mapped page
                # horizon AND guarantees the accepted run fits the agent's
                # remaining budget.
                for a in agents:
                    if a.phase != GEN or spans[a.row] != 1:
                        continue
                    cap = min(spec_k, a.tokens_left - 1,
                              max_len - int(pos_h[a.row]) - 1)
                    if cap <= 0:
                        continue
                    d = drafter.propose(a.hist, cap)[:cap]
                    if d:
                        drafts[a.row] = d
                        spans[a.row] = 1 + len(d)
            width = engine_mod.width_bucket(int(max(spans.max(), 1)),
                                            wclamp)
            toks = np.zeros((n_agents, width), np.int64)
            for a in agents:
                if spans[a.row] == 0:
                    continue
                if a.phase == PREFILL:
                    seg = a.queue[: int(spans[a.row])]
                    a.queue = a.queue[int(spans[a.row]):]
                    toks[a.row, :len(seg)] = seg
                    stats["replay"] += len(seg)
                else:
                    toks[a.row, 0] = tok_h[a.row]
                    d = drafts.get(a.row)
                    if d:
                        toks[a.row, 1:1 + len(d)] = d
            push_tables()
            key, sub = jax.random.split(key)
            start_h = jnp.asarray(pos_h, jnp.int32)   # pre-step positions
            if drafter is not None:
                snap = snap_jit(cache, start_h, width) if drafts else None
                preds_d, acc_d, cache = verify_fn(
                    params, cache, jnp.asarray(toks, jnp.int32), start_h,
                    jnp.asarray(spans, jnp.int32))
                preds = np.asarray(preds_d)
                acc = np.asarray(acc_d)
                sampled = preds[np.arange(n_agents),
                                np.clip(spans - 1, 0, width - 1)]
            else:
                nxt, cache = mixed_fn(params, cache,
                                      jnp.asarray(toks, jnp.int32),
                                      start_h,
                                      jnp.asarray(spans, jnp.int32), sub)
                sampled = np.asarray(nxt)
            stats["steps"] += 1
            roll_lo = np.zeros((n_agents,), np.int64)
            roll_hi = np.zeros((n_agents,), np.int64)
            replay_spans = np.zeros((n_agents,), np.int64)
            rolled = False
            for a in agents:
                if spans[a.row] == 0:
                    continue
                d = drafts.get(a.row)
                if d is not None:
                    # Speculative lane: commit the longest accepted prefix
                    # plus the verifier's bonus token; mark the rejected
                    # tail for bitwise rollback.
                    pos0 = int(pos_h[a.row])
                    appended, a_dev = draft_mod.accept_tokens(
                        d, acc[a.row], preds[a.row], a.tokens_left, None)
                    n_app = len(appended)
                    stats["draft"] += len(d)
                    stats["accepted"] += min(n_app, a_dev)
                    n_roll = int(spans[a.row]) - n_app
                    pos_h[a.row] += n_app
                    for t in appended:
                        buffers[a.row].append(int(t) % vocab)
                        a.hist.append(int(t))
                    tok_h[a.row] = int(appended[-1])
                    stats["gen"] += n_app
                    a.tokens_left -= n_app
                    if n_roll > 0:
                        stats["rollback"] += n_roll
                        roll_lo[a.row] = pos0 + n_app
                        roll_hi[a.row] = pos0 + int(spans[a.row])
                        replay_spans[a.row] = n_app
                        rolled = True
                    if a.tokens_left <= 0:
                        finishing.append(a)
                    continue
                pos_h[a.row] += int(spans[a.row])
                if a.phase == PREFILL:
                    if a.queue:
                        continue            # mid-prompt logits: discarded
                    a.phase = GEN           # chunk's last logits = 1st token
                tok_h[a.row] = int(sampled[a.row])
                buffers[a.row].append(int(sampled[a.row]) % vocab)
                if drafter is not None:
                    a.hist.append(int(sampled[a.row]))
                stats["gen"] += 1
                a.tokens_left -= 1
                if a.tokens_left <= 0:
                    finishing.append(a)
            if rolled:
                # Rejected-tail slots restored bitwise from the pre-verify
                # snapshot; recurrent state (if any) is restored to its
                # pre-verify value and re-advanced by replaying exactly the
                # committed tokens (attention re-writes are overwrites of
                # the same tokens at the same positions).
                cache = restore_jit(cache, snap, start_h,
                                    jnp.asarray(roll_lo.astype(np.int32)),
                                    jnp.asarray(roll_hi.astype(np.int32)),
                                    jnp.asarray(replay_spans > 0))
                if has_state and replay_spans.any():
                    w2 = engine_mod.width_bucket(int(replay_spans.max()),
                                                 wclamp)
                    _, _, cache = verify_fn(
                        params, cache,
                        jnp.asarray(toks[:, :w2], jnp.int32), start_h,
                        jnp.asarray(replay_spans, jnp.int32))
            for a in finishing:
                finish_agent(a)
        else:
            # -- one batched decode step (replay baseline) -------------------
            forced = np.array(token)      # writable host copy
            for a in agents:
                if a.phase == PREFILL and a.needs_map:
                    # Unmapped row: its writes land on the trash page, so
                    # the step is harmless — but its prompt must not be
                    # consumed.  On a successful re-map, restart from 0.
                    if stats["steps"] >= a.retry_at and recontextualize(a):
                        pos = pos.at[a.row].set(0)
                    else:
                        continue
                if a.phase == PREFILL and a.queue:
                    forced[a.row] = a.queue.pop(0)
                    stats["replay"] += 1
                elif a.phase == PREFILL:
                    a.phase = GEN
            token = jnp.asarray(forced)
            push_tables()
            key, sub = jax.random.split(key)
            token, cache, pos = step_fn(params, cache, token, pos, sub)
            stats["steps"] += 1
            sampled = np.array(token)

            # -- generation & completion ------------------------------------
            for a in agents:
                if a.phase != GEN:
                    continue
                buffers[a.row].append(int(sampled[a.row]) % vocab)
                stats["gen"] += 1
                a.tokens_left -= 1
                if a.tokens_left <= 0:
                    finish_agent(a)

        # -- observation sweep (paper §4.2) ----------------------------------
        if stats["steps"] % OBSERVE_EVERY == 0:
            sync_replicas()
            for a in agents:
                if a.phase not in (GEN, PREFILL):
                    continue
                delta = host_len - snap_len[a.client]
                stats["observe"] += int(delta.clip(0).sum())
                reads = task.reads.get(a.todo_id, ())
                if any(delta[r] > 0 for r in reads):
                    if a.reprefills < MAX_REPREFILL:
                        a.reprefills += 1
                        stats["inval"] += 1
                        a.queue = _prompt_tokens(task, a.todo_id, docs,
                                                 vocab, rng)
                        a.hist = list(a.queue)
                        a.phase = PREFILL
                        pos_h[a.row] = 0
                        if mixed_fn is None:
                            pos = pos.at[a.row].set(0)
                        recontextualize(a)
                    snap_len[a.client] = host_len.copy()

        if stats["steps"] > 20_000:   # safety valve
            for a in agents:
                a.phase = HALT
            break

    sync_replicas()
    if delta_sync is not None:
        # Drain capacity-overflow backlog (delta contract: convergence is
        # delayed, never lost): sync until the frontier reaches its fixed
        # point, so replicas are measurably converged before scoring.
        for _ in range(10_000):
            before = [np.asarray(x)
                      for x in jax.tree.leaves(delta_sync.frontier)]
            sync_replicas()
            after = [np.asarray(x)
                     for x in jax.tree.leaves(delta_sync.frontier)]
            if all(np.array_equal(b, a) for b, a in zip(before, after)):
                break
    wall = time_fn() - t0

    final = fold_fn(docs)
    digests = [int(digest_fn(d)) for d in docs]
    conflicts, total_decl = count_conflicts(final)
    return RunResult(
        task=task.name, mode=mode, n_agents=n_agents, wall_s=wall,
        gen_tokens=stats["gen"], replay_tokens=stats["replay"],
        steps=stats["steps"], invalidations=stats["inval"],
        claim_collisions=stats["collide"],
        observation_events=stats["observe"],
        semantic_conflicts=conflicts, declared_symbols=total_decl,
        converged=all(d == digests[0] for d in digests),
        digest=digests[0],
        merge_strategy=merge, sync_rounds=stats["syncs"],
        sync_bytes=int(stats["sync_bytes"]),
        kv_mode=kv, prefill_mode=prefill,
        shared_prefix_pages=mapper.shared_pages if mapper else 0,
        replicas=replicas,
        disaggregated=disaggregate,
        cross_replica_prefix_hits=getattr(mapper, "cross_replica_hits", 0),
        page_sync_bytes=getattr(mapper, "sync_bytes", 0),
        agent_failures=stats["agent_fail"],
        agent_retries=stats["agent_retry"],
        spec_decode=spec_decode,
        draft_tokens=stats["draft"],
        accepted_tokens=stats["accepted"],
        rollback_tokens=stats["rollback"],
    )


def make_sim_llm(seed: int = 0):
    """Tiny but real decoder used as the agents' LLM (CPU-friendly)."""
    import repro.configs as configs
    cfg = configs.reduced(configs.get("olmo-1b"), d_model=64,
                          vocab=512).replace(num_layers=2)
    params = lm.init(jax.random.PRNGKey(seed), cfg)
    return cfg, params


def main() -> None:
    """Run one task end-to-end with a chosen replica-merge strategy.

    PYTHONPATH=src python -m repro.agents.orchestrator \
        --task coupled --mode parallel --agents 4 --merge delta
    """
    import argparse
    from repro.agents.tasks import TASKS

    ap = argparse.ArgumentParser()
    ap.add_argument("--task", default=next(iter(TASKS)), choices=list(TASKS))
    ap.add_argument("--mode", default="parallel",
                    choices=["sequential", "parallel"])
    ap.add_argument("--agents", type=int, default=4)
    ap.add_argument("--merge", default="allgather",
                    choices=["allgather", "pmax", "delta"])
    ap.add_argument("--delta-capacity", type=int, default=64)
    ap.add_argument("--kv", default="dense", choices=["dense", "paged"],
                    help="KV cache layout for the agents' decode engine")
    ap.add_argument("--prefill", default="replay",
                    choices=["replay", "ragged", "chunked"],
                    help="prompt (re-)contextualization: token-by-token "
                         "replay, or chunked admission through the "
                         "token-budget mixed step ('ragged' is a "
                         "backward-compatible alias for 'chunked')")
    ap.add_argument("--page-size", type=int, default=64,
                    help="paged-KV page size; small pages (8-16) let the "
                         "task/TODO header share across re-contextualizations")
    ap.add_argument("--chunk-size", type=int, default=32,
                    help="max prompt tokens one mixed step spends per agent "
                         "while other agents keep decoding")
    ap.add_argument("--replicas", type=int, default=1,
                    help="page-table metadata replicas (> 1 requires "
                         "--kv paged): agents are partitioned round-robin "
                         "and the run reports cross-replica prefix hits "
                         "plus page-table anti-entropy bytes")
    ap.add_argument("--spec-decode", default="off",
                    choices=["off", "ngram", "doc"],
                    help="speculative decoding through the mixed step: "
                         "'ngram' drafts from each agent's own "
                         "prompt+generated history (prompt lookup), 'doc' "
                         "drafts from the shared CRDT document content "
                         "with n-gram fallback (requires --prefill chunked)")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="max draft tokens proposed per agent per step")
    ap.add_argument("--kv-quant", default="off",
                    choices=["off", "int8", "fp8"],
                    help="quantized page pools (requires --kv paged): pools "
                         "store int8/fp8 values plus per-row f32 scales and "
                         "decode dequantizes inside the fused page walk")
    ap.add_argument("--disaggregate", action="store_true",
                    help="prefill/decode role partition over the metadata "
                         "replicas (requires --replicas >= 2): agent 0 "
                         "homes on the prefill replica and publishes the "
                         "shared task-header chain; the other agents home "
                         "on decode replicas and adopt it cross-replica")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg, params = make_sim_llm(args.seed)
    r = run_task(cfg, params, TASKS[args.task], mode=args.mode,
                 n_agents=args.agents, seed=args.seed, merge=args.merge,
                 delta_capacity=args.delta_capacity, kv=args.kv,
                 prefill=args.prefill, page_size=args.page_size,
                 chunk_size=args.chunk_size, replicas=args.replicas,
                 spec_decode=args.spec_decode, spec_k=args.spec_k,
                 kv_quant=args.kv_quant, disaggregate=args.disaggregate)
    for k, v in sorted(vars(r).items()):
        print(f"{k}: {v}")


if __name__ == "__main__":
    main()
