"""repro.agents subsystem."""
