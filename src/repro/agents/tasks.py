"""The six benchmark tasks (paper §5.2.1), as coupling-structured TODO DAGs.

Coupling is operationalized exactly as the paper defines it: the fraction of
TODOs whose implementation requires *reading* shared state produced by other
TODOs.  ``deps`` are hard ordering edges (ready-gating); ``reads`` are soft
context edges — if a read slot's content changes while an agent is
generating, the agent must re-contextualize (the observation-driven
invalidation that produces the paper's coupled-task slowdown).

``par_inflation`` injects the paper's *measured* code-volume ratios
(Table 5: parallel/sequential code length) as a workload input: volume
inflation is an LLM behavior we cannot re-derive from a toy model, but its
*systems* consequences (raw-vs-normalized time inversion) are what we
reproduce and measure.
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class TaskSpec:
    name: str
    coupling: str                      # low | medium | high
    n_todos: int
    deps: dict[int, tuple[int, ...]]   # hard ordering edges
    reads: dict[int, tuple[int, ...]]  # soft context edges (invalidation)
    base_tokens: int                   # generated tokens per TODO (sequential)
    par_inflation: float               # paper Table 5 par/seq code-length ratio
    prompt_tokens: int                 # context replay length per TODO
    read_prompt_tokens: int            # extra prompt per read edge


def _all_prior_reads(n, frac):
    """Each TODO reads ~frac of the other TODOs (shared-state coupling)."""
    reads = {}
    step = max(1, int(round(1 / max(frac, 1e-6))))
    for k in range(n):
        reads[k] = tuple(j for j in range(n) if j != k and (j + k) % step == 0)
    return reads


TASKS: dict[str, TaskSpec] = {
    # Low coupling (<30%): independent cell logic / field validators.
    "tic_tac_toe": TaskSpec(
        name="tic_tac_toe", coupling="low", n_todos=8,
        deps={}, reads=_all_prior_reads(8, 0.15),
        base_tokens=56, par_inflation=0.89, prompt_tokens=24,
        read_prompt_tokens=8),
    "registration": TaskSpec(
        name="registration", coupling="low", n_todos=8,
        deps={7: (0,)}, reads=_all_prior_reads(8, 0.20),
        base_tokens=72, par_inflation=1.10, prompt_tokens=28,
        read_prompt_tokens=8),
    # Medium coupling: partially independent formatting functions.
    "markdown": TaskSpec(
        name="markdown", coupling="medium", n_todos=8,
        deps={6: (0,), 7: (1,)}, reads=_all_prior_reads(8, 0.45),
        base_tokens=80, par_inflation=0.88, prompt_tokens=32,
        read_prompt_tokens=12),
    # High coupling (>50%): most TODOs depend on shared state established by
    # other TODOs (the paper's operationalization), which serializes claims.
    "pomodoro": TaskSpec(
        name="pomodoro", coupling="high", n_todos=8,
        # 0 = timer core; logic 1-5 builds on it; UI 6-7 on the logic.
        deps={1: (0,), 2: (0,), 3: (0,), 4: (0,), 5: (0,),
              6: (4, 5), 7: (6,)},
        reads=_all_prior_reads(8, 0.60),
        base_tokens=64, par_inflation=1.82, prompt_tokens=32,
        read_prompt_tokens=16),
    "dashboard": TaskSpec(
        name="dashboard", coupling="high", n_todos=8,
        # 0 = shared data context; widgets hang off it; layout last.
        deps={1: (0,), 2: (0,), 3: (0,), 4: (0,), 5: (1, 2),
              6: (3, 4), 7: (5, 6)},
        reads=_all_prior_reads(8, 0.65),
        base_tokens=72, par_inflation=1.98, prompt_tokens=36,
        read_prompt_tokens=16),
    "visualizer": TaskSpec(
        name="visualizer", coupling="high", n_todos=8,
        # 0 = coordinated animation state; steps 1-4 animate; 5-7 render.
        deps={1: (0,), 2: (0,), 3: (0,), 4: (0,),
              5: (1, 2), 6: (2, 3), 7: (5, 6)},
        reads=_all_prior_reads(8, 0.70),
        base_tokens=80, par_inflation=2.89, prompt_tokens=36,
        read_prompt_tokens=16),
}

LOW = ("tic_tac_toe", "registration")
MEDIUM = ("markdown",)
HIGH = ("pomodoro", "dashboard", "visualizer")
