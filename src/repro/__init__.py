"""repro — CodeCRDT observation-driven coordination framework on JAX/TPU."""
__version__ = "1.0.0"
