"""Model configuration schema for all assigned architectures.

One generic decoder stack covers dense / GQA / MLA / MoE / RG-LRU-hybrid /
xLSTM / enc-dec / VLM families through the ``block_pattern`` (the repeating
layer group, scanned) plus family-specific sub-configs.  Frontends for
[audio]/[vlm] archs are stubs per the assignment: ``input_specs`` feeds
precomputed frame/patch embeddings.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int            # routed experts
    top_k: int
    d_expert: int               # per-expert FFN hidden
    num_shared: int = 0         # shared (always-on) experts
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    dispatch: str = "gather"    # "gather" (capacity einsum) | "dense" (all-expert)


@dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int           # compressed KV width (cached)
    rope_head_dim: int = 64     # decoupled shared-key RoPE dims
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclass(frozen=True)
class EncoderConfig:
    """Bidirectional encoder (whisper-style); frontend is a stub."""
    num_layers: int
    num_heads: int
    seq_len: int                # e.g. 1500 audio frames


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // num_heads

    # Layer pattern: repeated to fill num_layers; remainder applied unstacked.
    #   "attn" full causal attention + FFN          (dense archs)
    #   "local" sliding-window attention + FFN      (recurrentgemma)
    #   "rglru" RG-LRU temporal block + FFN         (recurrentgemma)
    #   "mla"  multi-head latent attention + FFN    (deepseek-v2)
    #   "moe"  full attention + MoE FFN             (deepseek-moe)
    #   "mla_moe" MLA attention + MoE FFN           (deepseek-v2-lite)
    #   "slstm"/"mlstm" xLSTM blocks (own projections, no separate FFN)
    #   "xattn" decoder block w/ cross-attention    (whisper decoder)
    block_pattern: tuple[str, ...] = ("attn",)

    # Attention details
    rope_theta: float = 10_000.0
    window: Optional[int] = None       # for "local" blocks
    qk_norm: bool = False
    use_bias: bool = False
    norm_type: str = "rmsnorm"         # rmsnorm | layernorm | nonparametric
    parallel_block: bool = False       # attn and FFN in parallel (command-r)
    ffn_activation: str = "silu"       # silu (SwiGLU) | gelu (GeGLU) | gelu_mlp
    tie_embeddings: bool = False
    logit_softcap: Optional[float] = None
    norm_eps: float = 1e-6
    embed_scale: bool = False          # multiply embeddings by sqrt(d) (gemma)

    # Family sub-configs
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    encoder: Optional[EncoderConfig] = None

    # Stub frontend: number of non-text prefix embedding tokens fed directly
    # (vlm: image patches; audio: encoder frames enter the encoder instead).
    num_prefix_tokens: int = 0

    # RG-LRU
    rglru_width: int = 0               # 0 -> d_model
    conv_width: int = 4

    # Ring cache (§Perf): bound sliding-window layers' KV cache to the
    # window via ring indexing — token at absolute position p lives at slot
    # p % window.  Exact for window attention; cuts long-context decode
    # cache memory by seq_len/window.
    ring_local_cache: bool = False

    # xLSTM
    proj_factor: float = 2.0           # mLSTM up-projection factor

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.rglru_width == 0:
            object.__setattr__(self, "rglru_width", self.d_model)
        assert self.num_heads % self.num_kv_heads == 0

    # ---- derived ----
    @property
    def pattern_groups(self) -> int:
        return self.num_layers // len(self.block_pattern)

    @property
    def tail_blocks(self) -> tuple[str, ...]:
        """Remainder layers when num_layers % len(pattern) != 0."""
        rem = self.num_layers % len(self.block_pattern)
        return self.block_pattern[:rem]

    @property
    def is_encdec(self) -> bool:
        return self.encoder is not None

    @property
    def sub_quadratic(self) -> bool:
        """True if no full-attention block exists (long_500k eligible)."""
        quad = {"attn", "mla", "moe", "mla_moe", "xattn"}
        return not any(b in quad for b in self.block_pattern)

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, v = self.d_model, self.vocab_size
        total = v * d * (1 if self.tie_embeddings else 2)
        kv_dim = self.num_kv_heads * self.head_dim
        q_dim = self.num_heads * self.head_dim
        for kind in (list(self.block_pattern) * self.pattern_groups
                     + list(self.tail_blocks)):
            if kind in ("attn", "local", "moe"):
                total += d * q_dim + 2 * d * kv_dim + q_dim * d
            elif kind in ("mla", "mla_moe"):
                m = self.mla
                total += (d * m.kv_lora_rank + d * m.rope_head_dim
                          + m.kv_lora_rank * self.num_heads
                          * (m.nope_head_dim + m.v_head_dim)
                          + d * self.num_heads * (m.nope_head_dim + m.rope_head_dim)
                          + self.num_heads * m.v_head_dim * d)
            elif kind == "rglru":
                w = self.rglru_width
                total += (2 * d * w + w * d + 2 * w * w
                          + self.conv_width * w + 3 * w)
            elif kind == "slstm":
                total += 4 * 2 * d * d + d * d
            elif kind == "mlstm":
                up = int(self.proj_factor * d)
                total += 2 * d * up + 3 * up * up // 1 + up * d
            if kind in ("attn", "local", "mla", "xattn", "rglru"):
                ffn_mats = 2 if self.ffn_activation == "gelu_mlp" else 3
                total += ffn_mats * d * self.d_ff
            if kind == "xattn":
                total += 2 * (d * q_dim + kv_dim * d)
            if kind in ("moe", "mla_moe"):
                m = self.moe
                total += 3 * d * m.d_expert * (m.num_experts + m.num_shared)
                total += d * m.num_experts
        if self.encoder is not None:
            e = self.encoder
            total += e.num_layers * (4 * d * d + 3 * d * self.d_ff)
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k + shared only)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        full = self.param_count()
        moe_layers = sum(1 for k in (list(self.block_pattern)
                                     * self.pattern_groups)
                         + list(self.tail_blocks) if k in ("moe", "mla_moe"))
        d = self.d_model
        all_experts = 3 * d * m.d_expert * (m.num_experts + m.num_shared)
        active = 3 * d * m.d_expert * (m.top_k + m.num_shared)
        return full - moe_layers * (all_experts - active)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)
