"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

KV state is compressed to a shared latent c_kv (rank ``kv_lora_rank``) plus a
small decoupled-RoPE key shared across heads — the cache stores only
[B, S, r + rope_dim] instead of [B, S, 2·H·head_dim].

Decode uses the *weight-absorption* identity: q_nopeᵀ·(c_kv·W_uk) =
(q_nope·W_ukᵀ)ᵀ·c_kv, so attention runs directly against the compressed
cache with no per-step decompression — the paper's serving trick, and the
reason MLA decode is memory-roofline-friendly.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.models import cache as cache_mod
from repro.models import common
from repro.models.config import ModelConfig

Params = Any

# Every paged-MLA layout this module serves; _q8/_fp8 carry an int8/fp8
# latent pool plus a per-row f32 scale pool and route to the *_quant kernels.
_PAGED_MLA = ("paged_mla", "paged_mla_q8", "paged_mla_fp8")


def init(key, cfg: ModelConfig) -> Params:
    m = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    ks = jax.random.split(key, 6)
    return {
        "w_dkv": common.dense_init(ks[0], d, m.kv_lora_rank),
        "w_kr": common.dense_init(ks[1], d, m.rope_head_dim),
        "w_uk": common.dense_init(ks[2], m.kv_lora_rank, h * m.nope_head_dim),
        "w_uv": common.dense_init(ks[3], m.kv_lora_rank, h * m.v_head_dim),
        "w_q": common.dense_init(ks[4], d, h * (m.nope_head_dim + m.rope_head_dim)),
        "w_o": common.dense_init(ks[5], h * m.v_head_dim, d),
        "kv_norm": common.norm_init(m.kv_lora_rank, "rmsnorm"),
    }


def _queries(p, cfg, x, positions):
    m = cfg.mla
    b, t, _ = x.shape
    h = cfg.num_heads
    q = common.dense(p["w_q"], x).reshape(b, t, h, m.nope_head_dim + m.rope_head_dim)
    q = q.transpose(0, 2, 1, 3)                                  # [B,H,T,*]
    q_nope, q_rope = jnp.split(q, [m.nope_head_dim], axis=-1)
    q_rope = common.apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _latents(p, cfg, x, positions):
    ckv = common.apply_norm(p["kv_norm"], common.dense(p["w_dkv"], x),
                            "rmsnorm", cfg.norm_eps)             # [B,T,r]
    krope = common.apply_rope(common.dense(p["w_kr"], x)[:, None],
                              positions, cfg.rope_theta)[:, 0]   # [B,T,rope]
    return ckv, krope


def forward(p: Params, cfg: ModelConfig, x: jax.Array,
            mask, positions: jax.Array, impl: str = "ref",
            chunked: bool = False, prefix_len: int = 0) -> jax.Array:
    """Train/prefill path (expanded keys/values).

    The two-term MLA logits (q_nope·k_nope + q_rope·k_rope) are expressed as
    one contraction over concat([nope; rope]) so the shared (chunked) SDPA —
    and its 32k-safe online softmax — applies unchanged.
    """
    from repro.models import attention
    m = cfg.mla
    b, t, _ = x.shape
    h = cfg.num_heads
    q_nope, q_rope = _queries(p, cfg, x, positions)
    ckv, krope = _latents(p, cfg, x, positions)
    k_nope = (ckv @ p["w_uk"]["w"].astype(ckv.dtype)).reshape(
        b, t, h, m.nope_head_dim).transpose(0, 2, 1, 3)
    v = (ckv @ p["w_uv"]["w"].astype(ckv.dtype)).reshape(
        b, t, h, m.v_head_dim).transpose(0, 2, 1, 3)
    qc = jnp.concatenate([q_nope, q_rope], axis=-1)
    kc = jnp.concatenate(
        [k_nope, jnp.broadcast_to(krope[:, None], (b, h, t, m.rope_head_dim)
                                  ).astype(k_nope.dtype)], axis=-1)
    scale = (m.nope_head_dim + m.rope_head_dim) ** -0.5
    out = attention._sdpa(qc, kc, v, mask, scale, impl, chunked=chunked,
                          prefix_len=prefix_len)
    out = out.transpose(0, 2, 1, 3).reshape(b, t, h * m.v_head_dim)
    return common.dense(p["w_o"], out.astype(x.dtype))


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16, *, paged: bool = False,
               page_size: int = 64, num_pages: int | None = None,
               kv_quant: str = "off") -> Params:
    """Dense latent cache [B, S, r] + [B, S, rd], or a paged latent pool.

    Paged mode stores concat([ckv; krope]) rows in a shared pool
    ``[P, page_size, pad128(r + rd)]`` mapped by per-row block tables —
    resident memory scales with allocated pages, and the fused decode kernel
    walks only live pages (see kernels/paged_mla_decode.py).
    """
    kind = "mla"
    return cache_mod.spec_for(kind, cfg, batch, max_len, dtype, paged=paged,
                              page_size=page_size, num_pages=num_pages,
                              kv_quant=kv_quant).init()


def _paged_latent_write(cache: Params, ckv: jax.Array, krope: jax.Array,
                        lengths: Optional[jax.Array]) -> Params:
    """Scatter a prompt's latent rows ([B, T, r]/[B, T, rd]) into pages.

    Same drop semantics as the MHA paged prefill: unallocated (-1) table
    entries, bucket padding past the table, and positions beyond a ragged
    row's length are all routed out of bounds and dropped.
    """
    bt = cache["block_tables"]
    pool = cache["latent_pages"]
    num_pages, ps, dp = pool.shape
    b, t, _ = ckv.shape
    tpos = jnp.arange(t, dtype=jnp.int32)
    pg = bt[:, tpos // ps]                              # [B, T]
    pg = jnp.where(pg < 0, num_pages, pg)
    pg = jnp.where(tpos[None, :] < bt.shape[1] * ps, pg, num_pages)
    if lengths is not None:
        pg = jnp.where(tpos[None, :] < lengths[:, None], pg, num_pages)
    slot = jnp.broadcast_to(tpos % ps, (b, t))
    lat = jnp.concatenate([ckv, krope], axis=-1)
    lat = jnp.pad(lat, ((0, 0), (0, 0), (0, dp - lat.shape[-1])))
    if "latent_scales" in cache:
        # Quantized pool: quantize the latent rows and land their scales
        # through the same drop routing.
        lq, ls = kref.quantize_rows(lat, pool.dtype)
        return dict(
            cache,
            latent_pages=pool.at[pg, slot, :].set(lq, mode="drop"),
            latent_scales=cache["latent_scales"].at[pg, slot].set(
                ls, mode="drop"))
    return dict(cache, latent_pages=pool.at[pg, slot, :].set(
        lat.astype(pool.dtype), mode="drop"))


def prefill(p, cfg, x, cache, mask, positions, impl="ref", chunked=False,
            prefix_len=0, lengths: Optional[jax.Array] = None):
    """``lengths`` (i32[B]) admits a ragged right-padded batch — attention
    over padding is masked by the caller's 3-D mask, cache writes beyond
    each row's length are dropped, and rows with ``lengths[b] == 0`` keep
    their cache bit-for-bit (the admission path relies on this)."""
    y = forward(p, cfg, x, mask, positions, impl, chunked=chunked,
                prefix_len=prefix_len)
    ckv, krope = _latents(p, cfg, x, positions)
    if cache_mod.layout_of(cache) in _PAGED_MLA:
        return y, _paged_latent_write(cache, ckv, krope, lengths)
    new_ckv = jax.lax.dynamic_update_slice(
        cache["ckv"], ckv.astype(cache["ckv"].dtype), (0, 0, 0))
    new_krope = jax.lax.dynamic_update_slice(
        cache["krope"], krope.astype(cache["krope"].dtype), (0, 0, 0))
    if lengths is not None:
        s = cache["ckv"].shape[1]
        keep = (jnp.arange(s)[None, :] < lengths[:, None])[..., None]
        new_ckv = jnp.where(keep, new_ckv, cache["ckv"])
        new_krope = jnp.where(keep, new_krope, cache["krope"])
    return y, {"ckv": new_ckv, "krope": new_krope}


def mixed_step(p: Params, cfg: ModelConfig, x: jax.Array, cache: Params,
               start: jax.Array, span: jax.Array, positions: jax.Array,
               impl: str = "ref") -> tuple[jax.Array, Params]:
    """Per-row query spans against the compressed cache (mixed serve step).

    x: [B, C, d]; start/span: i32[B]; positions: i32[B, C].  Runs the
    absorbed-weight contractions of ``decode_step`` for every query in the
    span — one math for decode (span 1) and chunked admission (span C), so
    chunk partitioning cannot change the bits.  The span's latent rows are
    written before the attend (write-then-attend, causal intra-span).
    """
    m = cfg.mla
    b, c, _ = x.shape
    h = cfg.num_heads
    q_nope, q_rope = _queries(p, cfg, x, positions)               # [B,H,C,*]
    ckv_t, krope_t = _latents(p, cfg, x, positions)               # [B,C,*]
    w_uk = p["w_uk"]["w"].reshape(m.kv_lora_rank, h, m.nope_head_dim)
    q_abs = jnp.einsum("bhcn,rhn->bhcr", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32),
                       preferred_element_type=jnp.float32)
    scale = (m.nope_head_dim + m.rope_head_dim) ** -0.5
    layout = cache_mod.layout_of(cache)
    if layout in _PAGED_MLA:
        pool = cache["latent_pages"]
        dp = pool.shape[-1]
        lat_new = jnp.concatenate([ckv_t, krope_t], axis=-1)
        lat_new = jnp.pad(lat_new, ((0, 0), (0, 0),
                                    (0, dp - lat_new.shape[-1])))
        if layout != "paged_mla":
            ctx, pool, scales = kops.paged_mla_chunk_quant(
                q_abs, q_rope, pool, cache["latent_scales"],
                cache["block_tables"], start, span, lat_new, scale=scale,
                use_pallas=(impl == "pallas"))
            new_cache = dict(cache, latent_pages=pool,
                             latent_scales=scales)
        else:
            ctx, pool = kops.paged_mla_chunk(
                q_abs, q_rope, pool, cache["block_tables"], start, span,
                lat_new, scale=scale, use_pallas=(impl == "pallas"))
            new_cache = dict(cache, latent_pages=pool)
    else:
        # Dense latent cache: write the span via a position gather, then the
        # same absorbed contractions over the full stream.
        s = cache["ckv"].shape[1]
        pidx = jnp.arange(s, dtype=jnp.int32)
        off = pidx[None, :] - start[:, None]                     # [B, S]
        wmask = ((off >= 0) & (off < span[:, None]))[..., None]
        gidx = jnp.clip(off, 0, c - 1)[:, :, None]
        ckv_in = jnp.take_along_axis(
            ckv_t.astype(cache["ckv"].dtype),
            jnp.broadcast_to(gidx, (b, s, ckv_t.shape[-1])), axis=1)
        krope_in = jnp.take_along_axis(
            krope_t.astype(cache["krope"].dtype),
            jnp.broadcast_to(gidx, (b, s, krope_t.shape[-1])), axis=1)
        ckv_c = jnp.where(wmask, ckv_in, cache["ckv"])
        krope_c = jnp.where(wmask, krope_in, cache["krope"])
        logits = (jnp.einsum("bhcr,bsr->bhcs", q_abs, ckv_c,
                             preferred_element_type=jnp.float32)
                  + jnp.einsum("bhcr,bsr->bhcs", q_rope, krope_c,
                               preferred_element_type=jnp.float32)) * scale
        valid = pidx[None, None, :] <= positions[:, :, None]
        logits = jnp.where(valid[:, None], logits, -jnp.inf)
        probs = jax.nn.softmax(logits, axis=-1)
        ctx = jnp.einsum("bhcs,bsr->bhcr", probs,
                         ckv_c.astype(jnp.float32),
                         preferred_element_type=jnp.float32)
        new_cache = {"ckv": ckv_c, "krope": krope_c}
    w_uv = p["w_uv"]["w"].reshape(m.kv_lora_rank, h, m.v_head_dim)
    out = jnp.einsum("bhcr,rhd->bhcd", ctx, w_uv.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    out = out.transpose(0, 2, 1, 3).reshape(b, c, h * m.v_head_dim)
    return common.dense(p["w_o"], out.astype(x.dtype)), new_cache


def decode_step(p: Params, cfg: ModelConfig, x: jax.Array, cache: Params,
                pos: jax.Array, impl: str = "ref") -> tuple[jax.Array, Params]:
    """Absorbed-weight decode against the compressed cache.  x: [B,1,d]."""
    m = cfg.mla
    b = x.shape[0]
    h = cfg.num_heads
    q_nope, q_rope = _queries(p, cfg, x, pos[:, None])            # [B,H,1,*]
    ckv_t, krope_t = _latents(p, cfg, x, pos[:, None])            # [B,1,*]
    layout = cache_mod.layout_of(cache)
    if layout in _PAGED_MLA:
        # Paged latent cache: O(page) fused write + block-table walk — the
        # one-hot rewrite of the full [B, S, r] latent stream disappears.
        # Absorbed q_abs/scale/contractions are IDENTICAL to the dense path
        # below, so the ref oracle is bit-compatible with dense decode.
        w_uk = p["w_uk"]["w"].reshape(m.kv_lora_rank, h, m.nope_head_dim)
        q_abs = jnp.einsum("bhn,rhn->bhr",
                           q_nope[:, :, 0].astype(jnp.float32),
                           w_uk.astype(jnp.float32),
                           preferred_element_type=jnp.float32)
        scale = (m.nope_head_dim + m.rope_head_dim) ** -0.5
        pool = cache["latent_pages"]
        dp = pool.shape[-1]
        lat_new = jnp.concatenate([ckv_t[:, 0], krope_t[:, 0]], axis=-1)
        lat_new = jnp.pad(lat_new, ((0, 0), (0, dp - lat_new.shape[-1])))
        if layout != "paged_mla":
            ctx, pool, scales = kops.paged_mla_decode_quant(
                q_abs, q_rope[:, :, 0], pool, cache["latent_scales"],
                cache["block_tables"], pos, lat_new, scale=scale,
                use_pallas=(impl == "pallas"))
            new_cache = dict(cache, latent_pages=pool, latent_scales=scales)
        else:
            ctx, pool = kops.paged_mla_decode(
                q_abs, q_rope[:, :, 0], pool, cache["block_tables"], pos,
                lat_new, scale=scale, use_pallas=(impl == "pallas"))
            new_cache = dict(cache, latent_pages=pool)
        w_uv = p["w_uv"]["w"].reshape(m.kv_lora_rank, h, m.v_head_dim)
        out = jnp.einsum("bhr,rhd->bhd", ctx, w_uv.astype(jnp.float32),
                         preferred_element_type=jnp.float32)
        out = out.reshape(b, 1, h * m.v_head_dim).astype(x.dtype)
        return common.dense(p["w_o"], out), new_cache
    # One-hot masked write (not a scatter): partitions cleanly when the
    # cache is sequence-sharded (see sharding/partition.py mla_cache="seq").
    s_len = cache["ckv"].shape[1]
    oh = (jnp.arange(s_len, dtype=jnp.int32)[None] == pos[:, None])[..., None]
    ckv_c = jnp.where(oh, ckv_t.astype(cache["ckv"].dtype), cache["ckv"])
    krope_c = jnp.where(oh, krope_t.astype(cache["krope"].dtype),
                        cache["krope"])
    # Absorb W_uk into the query: q_abs[b,h,r] = Σ_n q_nope · W_uk[r, h, n].
    # fp32 throughout: the absorbed path reorders contractions vs the train
    # path, so bf16 intermediates would not round identically.
    w_uk = p["w_uk"]["w"].reshape(m.kv_lora_rank, h, m.nope_head_dim)
    q_abs = jnp.einsum("bhn,rhn->bhr", q_nope[:, :, 0].astype(jnp.float32),
                       w_uk.astype(jnp.float32),
                       preferred_element_type=jnp.float32)
    scale = (m.nope_head_dim + m.rope_head_dim) ** -0.5
    logits = (jnp.einsum("bhr,bsr->bhs", q_abs, ckv_c,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bhr,bsr->bhs", q_rope[:, :, 0], krope_c,
                           preferred_element_type=jnp.float32)) * scale
    s = ckv_c.shape[1]
    valid = jnp.arange(s)[None, :] <= pos[:, None]
    logits = jnp.where(valid[:, None, :], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    ctx = jnp.einsum("bhs,bsr->bhr", probs, ckv_c.astype(jnp.float32),
                     preferred_element_type=jnp.float32)          # latent ctx
    w_uv = p["w_uv"]["w"].reshape(m.kv_lora_rank, h, m.v_head_dim)
    out = jnp.einsum("bhr,rhd->bhd", ctx, w_uv.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    out = out.reshape(b, 1, h * m.v_head_dim).astype(x.dtype)
    return common.dense(p["w_o"], out), {"ckv": ckv_c, "krope": krope_c}
