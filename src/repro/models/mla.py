"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

KV state is compressed to a shared latent c_kv (rank ``kv_lora_rank``) plus a
small decoupled-RoPE key shared across heads — the cache stores only
[B, S, r + rope_dim] instead of [B, S, 2·H·head_dim].

Decode uses the *weight-absorption* identity: q_nopeᵀ·(c_kv·W_uk) =
(q_nope·W_ukᵀ)ᵀ·c_kv, so attention runs directly against the compressed
cache with no per-step decompression — the paper's serving trick, and the
reason MLA decode is memory-roofline-friendly.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.config import ModelConfig

Params = Any


def init(key, cfg: ModelConfig) -> Params:
    m = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    ks = jax.random.split(key, 6)
    return {
        "w_dkv": common.dense_init(ks[0], d, m.kv_lora_rank),
        "w_kr": common.dense_init(ks[1], d, m.rope_head_dim),
        "w_uk": common.dense_init(ks[2], m.kv_lora_rank, h * m.nope_head_dim),
        "w_uv": common.dense_init(ks[3], m.kv_lora_rank, h * m.v_head_dim),
        "w_q": common.dense_init(ks[4], d, h * (m.nope_head_dim + m.rope_head_dim)),
        "w_o": common.dense_init(ks[5], h * m.v_head_dim, d),
        "kv_norm": common.norm_init(m.kv_lora_rank, "rmsnorm"),
    }


def _queries(p, cfg, x, positions):
    m = cfg.mla
    b, t, _ = x.shape
    h = cfg.num_heads
    q = common.dense(p["w_q"], x).reshape(b, t, h, m.nope_head_dim + m.rope_head_dim)
    q = q.transpose(0, 2, 1, 3)                                  # [B,H,T,*]
    q_nope, q_rope = jnp.split(q, [m.nope_head_dim], axis=-1)
    q_rope = common.apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _latents(p, cfg, x, positions):
    ckv = common.apply_norm(p["kv_norm"], common.dense(p["w_dkv"], x),
                            "rmsnorm", cfg.norm_eps)             # [B,T,r]
    krope = common.apply_rope(common.dense(p["w_kr"], x)[:, None],
                              positions, cfg.rope_theta)[:, 0]   # [B,T,rope]
    return ckv, krope


def forward(p: Params, cfg: ModelConfig, x: jax.Array,
            mask, positions: jax.Array, impl: str = "ref",
            chunked: bool = False, prefix_len: int = 0) -> jax.Array:
    """Train/prefill path (expanded keys/values).

    The two-term MLA logits (q_nope·k_nope + q_rope·k_rope) are expressed as
    one contraction over concat([nope; rope]) so the shared (chunked) SDPA —
    and its 32k-safe online softmax — applies unchanged.
    """
    from repro.models import attention
    m = cfg.mla
    b, t, _ = x.shape
    h = cfg.num_heads
    q_nope, q_rope = _queries(p, cfg, x, positions)
    ckv, krope = _latents(p, cfg, x, positions)
    k_nope = (ckv @ p["w_uk"]["w"].astype(ckv.dtype)).reshape(
        b, t, h, m.nope_head_dim).transpose(0, 2, 1, 3)
    v = (ckv @ p["w_uv"]["w"].astype(ckv.dtype)).reshape(
        b, t, h, m.v_head_dim).transpose(0, 2, 1, 3)
    qc = jnp.concatenate([q_nope, q_rope], axis=-1)
    kc = jnp.concatenate(
        [k_nope, jnp.broadcast_to(krope[:, None], (b, h, t, m.rope_head_dim)
                                  ).astype(k_nope.dtype)], axis=-1)
    scale = (m.nope_head_dim + m.rope_head_dim) ** -0.5
    out = attention._sdpa(qc, kc, v, mask, scale, impl, chunked=chunked,
                          prefix_len=prefix_len)
    out = out.transpose(0, 2, 1, 3).reshape(b, t, h * m.v_head_dim)
    return common.dense(p["w_o"], out.astype(x.dtype))


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> Params:
    m = cfg.mla
    return {
        "ckv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
        "krope": jnp.zeros((batch, max_len, m.rope_head_dim), dtype),
    }


def prefill(p, cfg, x, cache, mask, positions, impl="ref", chunked=False,
            prefix_len=0):
    y = forward(p, cfg, x, mask, positions, impl, chunked=chunked,
                prefix_len=prefix_len)
    ckv, krope = _latents(p, cfg, x, positions)
    cache = {
        "ckv": jax.lax.dynamic_update_slice(
            cache["ckv"], ckv.astype(cache["ckv"].dtype), (0, 0, 0)),
        "krope": jax.lax.dynamic_update_slice(
            cache["krope"], krope.astype(cache["krope"].dtype), (0, 0, 0)),
    }
    return y, cache


def decode_step(p: Params, cfg: ModelConfig, x: jax.Array, cache: Params,
                pos: jax.Array, impl: str = "ref") -> tuple[jax.Array, Params]:
    """Absorbed-weight decode against the compressed cache.  x: [B,1,d]."""
    m = cfg.mla
    b = x.shape[0]
    h = cfg.num_heads
    q_nope, q_rope = _queries(p, cfg, x, pos[:, None])            # [B,H,1,*]
    ckv_t, krope_t = _latents(p, cfg, x, pos[:, None])            # [B,1,*]
    # One-hot masked write (not a scatter): partitions cleanly when the
    # cache is sequence-sharded (see sharding/partition.py mla_cache="seq").
    s_len = cache["ckv"].shape[1]
    oh = (jnp.arange(s_len, dtype=jnp.int32)[None] == pos[:, None])[..., None]
    ckv_c = jnp.where(oh, ckv_t.astype(cache["ckv"].dtype), cache["ckv"])
    krope_c = jnp.where(oh, krope_t.astype(cache["krope"].dtype),
                        cache["krope"])
    # Absorb W_uk into the query: q_abs[b,h,r] = Σ_n q_nope · W_uk[r, h, n].
    # fp32 throughout: the absorbed path reorders contractions vs the train
    # path, so bf16 intermediates would not round identically.
    w_uk = p["w_uk"]["w"].reshape(m.kv_lora_rank, h, m.nope_head_dim)
    q_abs = jnp.einsum("bhn,rhn->bhr", q_nope[:, :, 0].astype(jnp.float32),
                       w_uk.astype(jnp.float32),
                       preferred_element_type=jnp.float32)
    scale = (m.nope_head_dim + m.rope_head_dim) ** -0.5
    logits = (jnp.einsum("bhr,bsr->bhs", q_abs, ckv_c,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bhr,bsr->bhs", q_rope[:, :, 0], krope_c,
                           preferred_element_type=jnp.float32)) * scale
    s = ckv_c.shape[1]
    valid = jnp.arange(s)[None, :] <= pos[:, None]
    logits = jnp.where(valid[:, None, :], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    ctx = jnp.einsum("bhs,bsr->bhr", probs, ckv_c.astype(jnp.float32),
                     preferred_element_type=jnp.float32)          # latent ctx
    w_uv = p["w_uv"]["w"].reshape(m.kv_lora_rank, h, m.v_head_dim)
    out = jnp.einsum("bhr,rhd->bhd", ctx, w_uv.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    out = out.reshape(b, 1, h * m.v_head_dim).astype(x.dtype)
    return common.dense(p["w_o"], out), {"ckv": ckv_c, "krope": krope_c}
