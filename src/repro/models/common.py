"""Shared model components: norms, RoPE, dense layers, init helpers.

Parameters are plain nested dicts of jnp arrays (bf16 storage by default;
compute promotes to fp32 where numerically required).  Everything here is a
pure function usable under jit / scan / shard_map.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

PARAM_DTYPE = jnp.bfloat16
Params = Any


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, use_bias: bool = False,
               scale: float | None = None) -> Params:
    scale = scale if scale is not None else d_in ** -0.5
    p = {"w": (jax.random.normal(key, (d_in, d_out), jnp.float32)
               * scale).astype(PARAM_DTYPE)}
    if use_bias:
        p["b"] = jnp.zeros((d_out,), PARAM_DTYPE)
    return p


def dense(p: Params, x: jax.Array) -> jax.Array:
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def norm_init(d: int, norm_type: str) -> Params:
    if norm_type == "nonparametric":
        return {}
    if norm_type == "layernorm":
        return {"scale": jnp.ones((d,), PARAM_DTYPE),
                "bias": jnp.zeros((d,), PARAM_DTYPE)}
    return {"scale": jnp.ones((d,), PARAM_DTYPE)}    # rmsnorm


def apply_norm(p: Params, x: jax.Array, norm_type: str,
               eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    if norm_type in ("layernorm", "nonparametric"):
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        if norm_type == "layernorm":
            y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
        return y.astype(x.dtype)
    # rmsnorm
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [B, H, T, D]; positions: [B, T] (or [T] broadcast)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                                 # [D/2]
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[:, None, :, None].astype(jnp.float32) * freqs  # [B,1,T,D/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Masks
# ---------------------------------------------------------------------------

def make_mask(tq: int, tk: int, *, causal: bool = True,
              window: int | None = None, prefix_len: int = 0) -> jax.Array:
    """bool[Tq, Tk] — True = attend.  Query rows end-aligned with keys."""
    qi = jnp.arange(tq)[:, None] + (tk - tq)
    ki = jnp.arange(tk)[None, :]
    mask = jnp.ones((tq, tk), bool)
    if causal:
        mask &= ki <= qi
    if window is not None:
        mask &= ki >= qi - window + 1
    if prefix_len > 0:       # bidirectional prefix (PaliGemma-style)
        mask |= (ki < prefix_len) & (qi < prefix_len)
        mask |= (qi >= prefix_len) & (ki < prefix_len)
    return mask


def softcap(logits: jax.Array, cap: float | None) -> jax.Array:
    if cap is None:
        return logits
    return cap * jnp.tanh(logits / cap)
