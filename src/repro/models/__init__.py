"""Model zoo: one generic stack covering all 10 assigned architectures."""
