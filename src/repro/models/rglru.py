"""Griffin recurrent block: causal depthwise conv + RG-LRU + gated output
(arXiv:2402.19427).  Used by recurrentgemma in a 1:2 attn:recurrent pattern.

Train path scans the diagonal recurrence with repro.kernels (Pallas chunked
scan on TPU, lax.scan reference elsewhere); decode is an O(1) state update —
the reason `long_500k` is runnable for this family.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.models import common
from repro.models.config import ModelConfig

Params = Any
C_GATE = 8.0


def init(key, cfg: ModelConfig) -> Params:
    d, w = cfg.d_model, cfg.rglru_width
    ks = jax.random.split(key, 7)
    return {
        "in_gate": common.dense_init(ks[0], d, w),        # GeLU branch
        "in_rec": common.dense_init(ks[1], d, w),         # recurrence branch
        "conv_w": (jax.random.normal(ks[2], (cfg.conv_width, w), jnp.float32)
                   * cfg.conv_width ** -0.5).astype(common.PARAM_DTYPE),
        "conv_b": jnp.zeros((w,), common.PARAM_DTYPE),
        "gate_i": common.dense_init(ks[3], w, w),         # input gate
        "gate_r": common.dense_init(ks[4], w, w),         # recurrence gate
        # softplus(log_lambda) ≈ decay; init so a^c ≈ 0.9..0.999
        "log_lambda": jnp.asarray(
            jax.random.uniform(ks[5], (w,), jnp.float32, -4.6, -0.7)),
        "out": common.dense_init(ks[6], w, d),
    }


def _causal_conv(p: Params, x: jax.Array, state: jax.Array | None):
    """Depthwise causal conv, width cw.  x: [B,T,W]; state: [B,cw-1,W]."""
    cw = p["conv_w"].shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)                  # [B, T+cw-1, W]
    y = sum(xp[:, i:i + x.shape[1]] * p["conv_w"][i].astype(x.dtype)
            for i in range(cw))
    new_state = xp[:, -(cw - 1):] if cw > 1 else pad[:, :0]
    return y + p["conv_b"].astype(x.dtype), new_state


def _rglru_coeffs(p: Params, u: jax.Array):
    """Decay a_t and driven input b_t for h_t = a_t h_{t-1} + b_t."""
    i_t = jax.nn.sigmoid(common.dense(p["gate_i"], u).astype(jnp.float32))
    r_t = jax.nn.sigmoid(common.dense(p["gate_r"], u).astype(jnp.float32))
    log_a = -C_GATE * r_t * jax.nn.softplus(p["log_lambda"])[None, None, :]
    a_t = jnp.exp(log_a)
    b_t = jnp.sqrt(jnp.clip(1.0 - a_t ** 2, 1e-9)) * (i_t * u.astype(jnp.float32))
    return a_t, b_t


def init_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> Params:
    w = cfg.rglru_width
    return {"h": jnp.zeros((batch, w), jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv_width - 1, w), dtype)}


def forward(p: Params, cfg: ModelConfig, x: jax.Array,
            cache: Params | None = None, impl: str = "ref",
            lengths: Optional[jax.Array] = None
            ) -> tuple[jax.Array, Params | None]:
    """Full-sequence path.  x: [B, T, d].

    ``lengths`` (i32[B]) marks a ragged right-padded batch: padding steps
    become exact identities (a_t = 1, b_t = 0, so h passes through
    bit-for-bit) and the conv state advances by exactly ``lengths[b]``
    tokens per row — rows with ``lengths[b] == 0`` keep their state
    untouched.  This is what lets recurrent blocks ride ragged admission
    and the mixed serve step's per-row spans.
    """
    gate = jax.nn.gelu(common.dense(p["in_gate"], x))
    u = common.dense(p["in_rec"], x)
    conv_state = None if cache is None else cache["conv"]
    u_conv, new_conv = _causal_conv(p, u, conv_state)
    if lengths is not None:
        # The conv state must hold the last cw-1 *valid* inputs: gather them
        # from concat([old_state; u]) at indices lengths + [0, cw-1) — for
        # lengths == 0 that is exactly the old state.
        cw = p["conv_w"].shape[0]
        if cw > 1:
            pad = (jnp.zeros((x.shape[0], cw - 1, u.shape[2]), u.dtype)
                   if conv_state is None else conv_state.astype(u.dtype))
            xp = jnp.concatenate([pad, u], axis=1)         # [B, cw-1+T, W]
            idx = (lengths[:, None] + jnp.arange(cw - 1)[None, :])
            new_conv = jnp.take_along_axis(
                xp, idx[:, :, None].astype(jnp.int32), axis=1)
    a_t, b_t = _rglru_coeffs(p, u_conv)
    if lengths is not None:
        valid = (jnp.arange(x.shape[1])[None, :]
                 < lengths[:, None])[..., None]             # [B, T, 1]
        a_t = jnp.where(valid, a_t, 1.0)                    # identity step
        b_t = jnp.where(valid, b_t, 0.0)
    h0 = (jnp.zeros((x.shape[0], cfg.rglru_width), jnp.float32)
          if cache is None else cache["h"])
    h, h_last = kops.linear_scan(a_t, b_t, h0, use_pallas=(impl == "pallas"))
    y = common.dense(p["out"], gate * h.astype(x.dtype))
    new_cache = None if cache is None else {"h": h_last, "conv": new_conv}
    return y, new_cache


def decode_step(p: Params, cfg: ModelConfig, x: jax.Array, cache: Params,
                pos: jax.Array, impl: str = "ref") -> tuple[jax.Array, Params]:
    """One-token step.  x: [B, 1, d] — O(1) state update."""
    gate = jax.nn.gelu(common.dense(p["in_gate"], x))
    u = common.dense(p["in_rec"], x)
    u, new_conv = _causal_conv(p, u, cache["conv"])
    a_t, b_t = _rglru_coeffs(p, u)                           # [B,1,W]
    h = a_t[:, 0] * cache["h"] + b_t[:, 0]
    y = common.dense(p["out"], gate * h[:, None].astype(x.dtype))
    return y, {"h": h, "conv": new_conv}
