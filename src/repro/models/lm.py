"""Top-level language model: embedding → scanned block groups → head.

Depth is folded into a ``lax.scan`` over stacked per-group parameters so the
HLO (and compile time at 512 devices) is O(1) in num_layers; pattern
remainders run unstacked as "tail" blocks.  Supports decoder-only, prefix-LM
(VLM stub embeddings), and encoder-decoder (whisper stub frames).

All entry points are pure functions over (params, cfg, inputs) — pjit them
with the partitioner in repro.sharding.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import blocks, common
from repro.models import cache as cache_mod
from repro.models.blocks import BlockCtx
from repro.models.config import ModelConfig
from repro.sharding import activation

Params = Any

# When True, the layer-group scans are fully unrolled.  Used only by the
# dry-run's cost-extrapolation lowers: XLA's HloCostAnalysis visits while
# bodies once, so unrolled small variants give exact per-group marginals.
_UNROLL = False


import contextlib


@contextlib.contextmanager
def unrolled_scans():
    global _UNROLL
    old, _UNROLL = _UNROLL, True
    try:
        yield
    finally:
        _UNROLL = old


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init(key, cfg: ModelConfig) -> Params:
    keys = jax.random.split(key, 8)
    d, v = cfg.d_model, cfg.vocab_size
    p: dict[str, Any] = {
        "embed": {"w": (jax.random.normal(keys[0], (v, d), jnp.float32)
                        * d ** -0.5).astype(common.PARAM_DTYPE)},
        "final_norm": common.norm_init(d, cfg.norm_type),
    }
    if not cfg.tie_embeddings:
        p["head"] = common.dense_init(keys[1], d, v)

    g = cfg.pattern_groups
    groups = {}
    for i, kind in enumerate(cfg.block_pattern):
        gkeys = jax.random.split(jax.random.fold_in(keys[2], i), g)
        groups[str(i)] = jax.vmap(
            lambda k, kd=kind: blocks.block_init(kd, k, cfg))(gkeys)
    p["groups"] = groups

    tail = {}
    for i, kind in enumerate(cfg.tail_blocks):
        tail[str(i)] = blocks.block_init(
            kind, jax.random.fold_in(keys[3], i), cfg)
    if tail:
        p["tail"] = tail

    if cfg.is_encdec:
        e = cfg.encoder
        ekeys = jax.random.split(keys[4], e.num_layers)
        p["encoder"] = {
            "pos": (jax.random.normal(keys[5], (e.seq_len, d), jnp.float32)
                    * 0.02).astype(common.PARAM_DTYPE),
            "blocks": jax.vmap(
                lambda k: blocks.block_init("attn", k, cfg))(ekeys),
            "norm": common.norm_init(d, cfg.norm_type),
        }
    return p


def abstract_params(cfg: ModelConfig) -> Params:
    """Parameter ShapeDtypeStructs without allocating (dry-run path)."""
    return jax.eval_shape(lambda: init(jax.random.PRNGKey(0), cfg))


# ---------------------------------------------------------------------------
# Helpers
# ---------------------------------------------------------------------------

def _embed(p: Params, cfg: ModelConfig, tokens: jax.Array) -> jax.Array:
    x = p["embed"]["w"][tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return x


def _head(p: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        logits = x @ p["embed"]["w"].T.astype(x.dtype)
    else:
        logits = common.dense(p["head"], x)
    # Keep logits vocab-sharded through the fp32 loss (see sharding/activation).
    logits = activation.constrain(logits, "batch", None, "vocab")
    return common.softcap(logits.astype(jnp.float32), cfg.logit_softcap)


def _encode(p: Params, cfg: ModelConfig, enc_frames: jax.Array,
            impl: str) -> jax.Array:
    """Bidirectional encoder over stub frame embeddings [B, Te, d]."""
    e = p["encoder"]
    x = enc_frames + e["pos"].astype(enc_frames.dtype)[None]
    te = x.shape[1]
    ctx = BlockCtx(positions=jnp.arange(te), mask_full=None, mask_local=None,
                   mode="full", impl=impl)

    def body(carry, bp):
        y, _, _ = blocks.block_apply("attn", bp, cfg.replace(window=None),
                                     carry, ctx, None)
        return y, None

    x, _ = jax.lax.scan(body, x, e["blocks"], unroll=_UNROLL)
    return common.apply_norm(e["norm"], x, cfg.norm_type, cfg.norm_eps)


CHUNKED_THRESHOLD = 8192


def _make_ctx(cfg: ModelConfig, t: int, enc_out, impl: str,
              prefix_len: int,
              lengths: Optional[jax.Array] = None) -> BlockCtx:
    if t > CHUNKED_THRESHOLD:
        if lengths is not None:
            raise NotImplementedError(
                "ragged prefill above the chunked-attention threshold")
        # Long sequences: lazy masks + blockwise online-softmax attention
        # (materialized T×T masks/scores would be GiB-scale at 32k+).
        return BlockCtx(positions=jnp.arange(t), mask_full=None,
                        mask_local=None, enc_out=enc_out, mode="full",
                        impl=impl, chunked=True, prefix_len=prefix_len)
    mask_full = common.make_mask(t, t, causal=True, prefix_len=prefix_len)
    mask_local = (common.make_mask(t, t, causal=True, window=cfg.window,
                                   prefix_len=prefix_len)
                  if "local" in cfg.block_pattern else None)
    if lengths is not None:
        # Per-row validity: padding keys are unattendable everywhere.
        valid = jnp.arange(t)[None, :] < lengths[:, None]       # [B, T]
        mask_full = mask_full[None] & valid[:, None, :]
        if mask_local is not None:
            mask_local = mask_local[None] & valid[:, None, :]
    return BlockCtx(positions=jnp.arange(t), mask_full=mask_full,
                    mask_local=mask_local, enc_out=enc_out, mode="full",
                    impl=impl, prefix_len=prefix_len, lengths=lengths)


def _run_blocks(p: Params, cfg: ModelConfig, x: jax.Array, ctx: BlockCtx,
                cache: Params | None, remat: bool = False
                ) -> tuple[jax.Array, Params | None, jax.Array]:
    """Scanned groups + tail.  cache=None for pure training forward.

    ``remat=True`` checkpoints each scanned group (activation recompute in
    the backward pass) — the standard memory/compute trade for deep stacks.
    """
    aux0 = jnp.zeros((), jnp.float32)

    if cache is None:
        def body(carry, gp):
            y, aux = carry
            # Sequence-parallel residual stream: the remat-saved scan input is
            # sharded over ("batch", seq->model); GSPMD all-gathers T in front
            # of attention and reduce-scatters after (Megatron-SP schedule),
            # shrinking the per-device saved-activation footprint by the
            # model-axis size.
            y = activation.constrain(y, "batch", "seq", None)
            for i, kind in enumerate(cfg.block_pattern):
                y, _, a = blocks.block_apply(kind, gp[str(i)], cfg, y, ctx,
                                             None)
                aux = aux + a
            return (y, aux), None

        if remat:
            body = jax.checkpoint(body, prevent_cse=False)
        (x, aux), _ = jax.lax.scan(body, (x, aux0), p["groups"],
                                    unroll=_UNROLL)
        new_cache = None
        tail_cache = {}
    else:
        def body_c(carry, inp):
            y, aux = carry
            gp, gc = inp
            new_gc = {}
            for i, kind in enumerate(cfg.block_pattern):
                y, c, a = blocks.block_apply(kind, gp[str(i)], cfg, y, ctx,
                                             gc[str(i)])
                new_gc[str(i)] = c
                aux = aux + a
            return (y, aux), new_gc

        (x, aux), new_groups = jax.lax.scan(
            body_c, (x, aux0), (p["groups"], cache["groups"]),
            unroll=_UNROLL)
        new_cache = dict(cache, groups=new_groups)
        tail_cache = cache.get("tail", {})

    if "tail" in p:
        new_tail = {}
        for i, kind in enumerate(cfg.tail_blocks):
            c_in = tail_cache.get(str(i)) if cache is not None else None
            x, c, a = blocks.block_apply(kind, p["tail"][str(i)], cfg, x,
                                         ctx, c_in)
            new_tail[str(i)] = c
            aux = aux + a
        if cache is not None:
            new_cache["tail"] = new_tail
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Training / full-sequence forward
# ---------------------------------------------------------------------------

def forward(p: Params, cfg: ModelConfig, tokens: jax.Array,
            prefix_embeds: Optional[jax.Array] = None,
            enc_frames: Optional[jax.Array] = None,
            impl: str = "ref", remat: bool = False
            ) -> tuple[jax.Array, jax.Array]:
    """tokens: [B, Tt] -> (logits [B, T, V], moe_aux)."""
    x = _embed(p, cfg, tokens)
    prefix_len = 0
    if cfg.num_prefix_tokens and prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
        prefix_len = cfg.num_prefix_tokens
    enc_out = (_encode(p, cfg, enc_frames, impl)
               if cfg.is_encdec and enc_frames is not None else None)
    ctx = _make_ctx(cfg, x.shape[1], enc_out, impl, prefix_len)
    x, _, aux = _run_blocks(p, cfg, x, ctx, None, remat=remat)
    x = common.apply_norm(p["final_norm"], x, cfg.norm_type, cfg.norm_eps)
    return _head(p, cfg, x), aux


def loss_fn(p: Params, cfg: ModelConfig, batch: dict[str, jax.Array],
            impl: str = "ref", aux_weight: float = 0.01, remat: bool = False
            ) -> tuple[jax.Array, dict[str, jax.Array]]:
    """batch: tokens [B,T], targets [B,T], loss_mask f32[B,T] (+ stub inputs)."""
    logits, aux = forward(
        p, cfg, batch["tokens"],
        prefix_embeds=batch.get("prefix_embeds"),
        enc_frames=batch.get("enc_frames"), impl=impl, remat=remat)
    # Prefix positions carry no next-token loss (logits cover prefix + text).
    logits = logits[:, -batch["tokens"].shape[1]:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    tgt = jnp.take_along_axis(logp, batch["targets"][..., None], axis=-1)[..., 0]
    mask = batch["loss_mask"].astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    xent = -jnp.sum(tgt * mask) / denom
    total = xent + aux_weight * aux
    return total, {"xent": xent, "moe_aux": aux,
                   "tokens": jnp.sum(mask)}


# ---------------------------------------------------------------------------
# Serving: prefill + single-token decode
# ---------------------------------------------------------------------------

def cache_specs(cfg: ModelConfig, batch: int, max_len: int,
                dtype=jnp.bfloat16, *, paged: bool = False,
                page_size: int = 64, num_pages: int | None = None,
                kv_quant: str = "off"):
    """The CacheSpec registry for this model — one spec per layer slot."""
    return cache_mod.model_cache_specs(cfg, batch, max_len, dtype,
                                      paged=paged, page_size=page_size,
                                      num_pages=num_pages, kv_quant=kv_quant)


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16, *, paged: bool = False,
               page_size: int = 64, num_pages: int | None = None,
               kv_quant: str = "off") -> Params:
    """``paged=True`` gives every full-attention layer (MHA pools, MLA
    latent pools) its own page pool + block tables; ``num_pages`` is per
    layer.  Layouts come from the CacheSpec registry (models/cache.py).
    ``kv_quant`` ("off" | "int8" | "fp8") swaps paged pools for quantized
    layouts carrying per-row scale leaves."""
    specs = cache_specs(cfg, batch, max_len, dtype, paged=paged,
                        page_size=page_size, num_pages=num_pages,
                        kv_quant=kv_quant)
    groups = {}
    for i, spec in specs["groups"].items():
        one = spec.init()
        groups[i] = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.pattern_groups,) + x.shape)
            .copy() if hasattr(x, "shape") else x, one)
    cache: dict[str, Any] = {"groups": groups}
    if "tail" in specs:
        cache["tail"] = {i: spec.init()
                         for i, spec in specs["tail"].items()}
    return cache


# Typed traversal / block-table plumbing live in models/cache.py; these
# re-exports keep the historical lm.* entry points working.
set_block_tables = cache_mod.set_block_tables
get_block_tables = cache_mod.get_block_tables
copy_pages = cache_mod.copy_pages
copy_pages_across = cache_mod.copy_pages_across
export_pages = cache_mod.export_pages
adopt_pages = cache_mod.adopt_pages


def prefill(p: Params, cfg: ModelConfig, tokens: jax.Array, cache: Params,
            prefix_embeds: Optional[jax.Array] = None,
            enc_frames: Optional[jax.Array] = None,
            impl: str = "ref",
            lengths: Optional[jax.Array] = None) -> tuple[jax.Array, Params]:
    """Uniform-length prompt [B, P] -> (last-position logits [B, V], cache).

    ``lengths`` (i32[B]) admits a *ragged* right-padded batch: row b's
    prompt is tokens[b, :lengths[b]], logits come from its last valid
    position, and rows with ``lengths[b] == 0`` pass through untouched
    (cache preserved, output garbage) — which is what lets the scheduler
    admit new requests into freed rows while the others keep decoding.
    """
    if lengths is not None:
        # Recurrent kinds ride ragged admission through masked state
        # carry-through (padding steps are exact identities per row).
        ragged_ok = {"attn", "local", "moe", "mla", "mla_moe",
                     "rglru", "slstm", "mlstm"}
        kinds = set(cfg.block_pattern) | set(cfg.tail_blocks)
        if (kinds - ragged_ok or cfg.num_prefix_tokens or cfg.is_encdec):
            raise NotImplementedError(
                f"ragged prefill supports decoder-only patterns without "
                f"prefix/encoder inputs, got {cfg.block_pattern}")
    x = _embed(p, cfg, tokens)
    prefix_len = 0
    if cfg.num_prefix_tokens and prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
        prefix_len = cfg.num_prefix_tokens
    enc_out = (_encode(p, cfg, enc_frames, impl)
               if cfg.is_encdec and enc_frames is not None else None)
    ctx = _make_ctx(cfg, x.shape[1], enc_out, impl, prefix_len,
                    lengths=lengths)
    ctx = ctx._replace(mode="prefill")
    x, cache, _ = _run_blocks(p, cfg, x, ctx, cache)
    if lengths is not None:
        last = jnp.clip(lengths - 1, 0)[:, None, None]
        x = jnp.take_along_axis(x, jnp.broadcast_to(
            last, (x.shape[0], 1, x.shape[2])), axis=1)
    else:
        x = x[:, -1:]
    x = common.apply_norm(p["final_norm"], x, cfg.norm_type, cfg.norm_eps)
    return _head(p, cfg, x)[:, 0], cache


def decode_step(p: Params, cfg: ModelConfig, token: jax.Array, cache: Params,
                pos: jax.Array, impl: str = "ref"
                ) -> tuple[jax.Array, Params]:
    """token: i32[B]; pos: i32[B] cache fill -> (logits [B, V], cache)."""
    x = _embed(p, cfg, token[:, None])
    ctx = BlockCtx(positions=pos[:, None], mask_full=None, mask_local=None,
                   mode="decode", pos=pos, impl=impl)
    x, cache, _ = _run_blocks(p, cfg, x, ctx, cache)
    x = common.apply_norm(p["final_norm"], x, cfg.norm_type, cfg.norm_eps)
    return _head(p, cfg, x)[:, 0], cache


MIXED_OK = {"attn", "local", "moe", "mla", "mla_moe",
            "rglru", "slstm", "mlstm"}


def mixed_step(p: Params, cfg: ModelConfig, tokens: jax.Array, cache: Params,
               start: jax.Array, span: jax.Array, impl: str = "ref",
               all_logits: bool = False) -> tuple[jax.Array, Params]:
    """Token-budget mixed step: per-row query spans in one batched call.

    tokens: i32[B, C] right-padded span tokens; start: i32[B] tokens already
    cached per row; span: i32[B] valid new tokens in [0, C].  Row b runs a
    span of ``span[b]`` queries at positions ``start[b] + [0, span[b])`` —
    span 1 decodes one token, span C admits one prompt chunk, span 0 leaves
    the row's cache bit-for-bit untouched.  Returns (logits [B, V] at each
    row's last valid span position, cache); span-0 rows' logits are garbage.
    With ``all_logits`` (the speculative-decoding verify mode) the head runs
    at EVERY span position and logits are [B, C, V] — position j's logits
    predict the token after span token j, so a drafted continuation can be
    verified wholesale in this one call (positions >= span[b] are garbage).

    Because every layer writes the span into the cache before attending,
    a query's math depends only on (its position, the cached prefix) —
    chunk partitioning cannot change the bits, which is what makes chunked
    admission bit-for-bit equivalent to a one-shot prefill.
    """
    kinds = set(cfg.block_pattern) | set(cfg.tail_blocks)
    if (kinds - MIXED_OK or cfg.num_prefix_tokens or cfg.is_encdec):
        raise NotImplementedError(
            f"mixed step supports decoder-only patterns without prefix or "
            f"encoder inputs, got {cfg.block_pattern}")
    if "local" in kinds and cfg.ring_local_cache and cfg.window:
        # A ring cache wraps under multi-token spans: a later span token can
        # overwrite a slot an earlier query's window still needs.  Windowed
        # layers over an UNBOUNDED dense cache are fine (masking handles the
        # window); only the ring layout is excluded.
        raise NotImplementedError(
            "mixed step over a ring local cache is unsupported — disable "
            "ring_local_cache (dense windowed cache) to serve chunked")
    b, c = tokens.shape
    x = _embed(p, cfg, tokens)
    positions = start[:, None] + jnp.arange(c, dtype=jnp.int32)[None, :]
    ctx = BlockCtx(positions=positions, mask_full=None, mask_local=None,
                   mode="mixed", pos=start, impl=impl, lengths=span)
    x, cache, _ = _run_blocks(p, cfg, x, ctx, cache)
    if all_logits:
        x = common.apply_norm(p["final_norm"], x, cfg.norm_type, cfg.norm_eps)
        return _head(p, cfg, x), cache
    last = jnp.clip(span - 1, 0)[:, None, None]
    x = jnp.take_along_axis(
        x, jnp.broadcast_to(last, (b, 1, x.shape[2])), axis=1)
    x = common.apply_norm(p["final_norm"], x, cfg.norm_type, cfg.norm_eps)
    return _head(p, cfg, x)[:, 0], cache


def verify_step(p: Params, cfg: ModelConfig, tokens: jax.Array, cache: Params,
                start: jax.Array, span: jax.Array, impl: str = "ref"
                ) -> tuple[jax.Array, jax.Array, Params]:
    """Speculative-decoding verify: one all-logits mixed step plus per-row
    greedy accept counts.

    ``tokens[b] = [last_committed, d_1 .. d_m, pad]`` with ``span = 1 + m``
    (plain decode/admission rows ride along with their usual spans and
    count 0).  Returns (preds i32[B, C] — argmax after every span
    position, accepted i32[B] — longest accepted draft prefix per
    ``kernels.ref.speculative_accept``, cache).  The cache afterwards
    holds the whole span's writes; the caller commits positions up to its
    accept point and rolls the rejected tail back bitwise via
    ``cache.snapshot_span`` / ``restore_span`` (+ ``restore_state_rows``
    and a committed-span replay for recurrent architectures).
    """
    from repro.kernels import ref as kref
    logits, cache = mixed_step(p, cfg, tokens, cache, start, span, impl=impl,
                               all_logits=True)
    preds = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    accepted = kref.speculative_accept(
        preds, jnp.asarray(tokens, jnp.int32), jnp.asarray(span, jnp.int32))
    return preds, accepted, cache


def reset_state_rows(cfg: ModelConfig, cache: Params, mask: jax.Array
                     ) -> Params:
    """Reset recurrent (state-layout) layers to fresh init for rows where
    ``mask`` is True — a freed row must not leak its h/conv/cell state into
    the next admitted request.  Attention caches need no reset: their writes
    overwrite and their reads are position-bounded."""
    mask = jnp.asarray(mask, bool)
    batch = int(mask.shape[0])

    def blend(kind, layer, stacked):
        spec = cache_mod.spec_for(kind, cfg, batch, 1)
        fresh = spec.init()

        def one(f, o):
            m = mask.reshape(((1,) if stacked else ()) + (batch,)
                             + (1,) * (f.ndim - 1))
            f = f.astype(o.dtype)
            return jnp.where(m, f[None] if stacked else f, o)

        return jax.tree.map(one, fresh, layer)

    out: dict[str, Any] = {"groups": dict(cache["groups"])}
    for i, kind in enumerate(cfg.block_pattern):
        if cache_mod.layout_for(kind, cfg, paged=False) == "state":
            out["groups"][str(i)] = blend(kind, cache["groups"][str(i)],
                                          stacked=True)
    if "tail" in cache:
        out["tail"] = dict(cache["tail"])
        for i, kind in enumerate(cfg.tail_blocks):
            if cache_mod.layout_for(kind, cfg, paged=False) == "state":
                out["tail"][str(i)] = blend(kind, cache["tail"][str(i)],
                                            stacked=False)
    return dict(cache, **out)


def snapshot_state_rows(cfg: ModelConfig, cache: Params) -> Params:
    """Copy the recurrent (state-layout) carries — the whole-row half of a
    speculative-decoding rollback snapshot (attention slots are per-span,
    see ``cache.snapshot_span``).  ``jnp.copy`` forces fresh buffers so the
    snapshot survives the verify call donating the live cache."""
    out: dict[str, Any] = {"groups": {}}
    for i, kind in enumerate(cfg.block_pattern):
        if cache_mod.layout_for(kind, cfg, paged=False) == "state":
            out["groups"][str(i)] = jax.tree.map(jnp.copy,
                                                 cache["groups"][str(i)])
    if "tail" in cache:
        tail = {}
        for i, kind in enumerate(cfg.tail_blocks):
            if cache_mod.layout_for(kind, cfg, paged=False) == "state":
                tail[str(i)] = jax.tree.map(jnp.copy, cache["tail"][str(i)])
        if tail:
            out["tail"] = tail
    return out


def restore_state_rows(cfg: ModelConfig, cache: Params, snap: Params,
                       mask: jax.Array) -> Params:
    """Blend ``snap`` (from :func:`snapshot_state_rows`) back into rows
    where ``mask`` is True — rejected-draft rollback for recurrent layers.

    Unlike attention slots, a recurrent carry folds every span token
    irreversibly, so a partial rejection restores the PRE-VERIFY carry and
    the caller then replays the committed prefix (a second mixed step over
    just the accepted tokens) to advance it; the replay's attention writes
    are bitwise idempotent with the verify step's, so only the state moves.
    """
    mask = jnp.asarray(mask, bool)
    batch = int(mask.shape[0])

    def blend(snap_layer, layer, stacked):
        def one(s, o):
            nd = o.ndim - 1 - (1 if stacked else 0)
            m = mask.reshape(((1,) if stacked else ()) + (batch,)
                             + (1,) * nd)
            return jnp.where(m, s, o)

        return jax.tree.map(one, snap_layer, layer)

    out: dict[str, Any] = {"groups": dict(cache["groups"])}
    for i, kind in enumerate(cfg.block_pattern):
        if cache_mod.layout_for(kind, cfg, paged=False) == "state":
            out["groups"][str(i)] = blend(snap["groups"][str(i)],
                                          cache["groups"][str(i)],
                                          stacked=True)
    if "tail" in cache:
        out["tail"] = dict(cache["tail"])
        for i, kind in enumerate(cfg.tail_blocks):
            if cache_mod.layout_for(kind, cfg, paged=False) == "state":
                out["tail"][str(i)] = blend(snap["tail"][str(i)],
                                            cache["tail"][str(i)],
                                            stacked=False)
    return dict(cache, **out)
