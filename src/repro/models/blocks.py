"""Block assembly: pre-norm residual wiring for every block kind, plus the
scan-over-groups driver that keeps HLO size O(1) in depth.

Block kinds (cfg.block_pattern):
  attn / local          attention (+FFN), full or sliding-window
  moe / mla / mla_moe   attention variants with MoE or latent-KV
  rglru                 Griffin temporal block (+FFN)
  slstm / mlstm         xLSTM blocks (self-contained, no extra FFN)
  xattn                 decoder block with cross-attention (whisper)
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import attention, common, ffn, mla, moe, rglru, xlstm
from repro.models.config import ModelConfig

Params = Any


class BlockCtx(NamedTuple):
    """Per-call context shared by all blocks."""
    positions: jax.Array                  # [B, T] (or [B] in decode)
    mask_full: Optional[jax.Array]        # bool[Tq, Tk] or None (lazy if chunked)
    mask_local: Optional[jax.Array]
    enc_out: Optional[jax.Array] = None   # [B, Te, d] (whisper decoder)
    mode: str = "full"                    # "full" | "prefill" | "decode"
    pos: Optional[jax.Array] = None       # i32[B] cache fill level (decode)
    impl: str = "ref"
    chunked: bool = False                 # blockwise attention (long T)
    prefix_len: int = 0                   # bidirectional prefix (VLM)
    lengths: Optional[jax.Array] = None   # i32[B] ragged prefill lengths


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def block_init(kind: str, key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 6)
    d = cfg.d_model
    if kind in ("attn", "local", "moe"):
        p = {"norm1": common.norm_init(d, cfg.norm_type),
             "attn": attention.init(ks[0], cfg)}
        if not cfg.parallel_block:
            p["norm2"] = common.norm_init(d, cfg.norm_type)
        p["ffn"] = (moe.init(ks[1], cfg) if kind == "moe"
                    else ffn.init(ks[1], cfg))
        return p
    if kind in ("mla", "mla_moe"):
        return {"norm1": common.norm_init(d, cfg.norm_type),
                "attn": mla.init(ks[0], cfg),
                "norm2": common.norm_init(d, cfg.norm_type),
                "ffn": (moe.init(ks[1], cfg) if kind == "mla_moe"
                        else ffn.init(ks[1], cfg))}
    if kind == "rglru":
        return {"norm1": common.norm_init(d, cfg.norm_type),
                "rec": rglru.init(ks[0], cfg),
                "norm2": common.norm_init(d, cfg.norm_type),
                "ffn": ffn.init(ks[1], cfg)}
    if kind == "slstm":
        return {"norm1": common.norm_init(d, cfg.norm_type),
                "cell": xlstm.slstm_init(ks[0], cfg)}
    if kind == "mlstm":
        return {"norm1": common.norm_init(d, cfg.norm_type),
                "cell": xlstm.mlstm_init(ks[0], cfg)}
    if kind == "xattn":
        return {"norm1": common.norm_init(d, cfg.norm_type),
                "attn": attention.init(ks[0], cfg),
                "norm_x": common.norm_init(d, cfg.norm_type),
                "xattn": attention.init(ks[1], cfg),
                "norm2": common.norm_init(d, cfg.norm_type),
                "ffn": ffn.init(ks[2], cfg)}
    raise ValueError(f"unknown block kind {kind}")


def cache_init(kind: str, cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16, *, paged: bool = False,
               page_size: int = 64, num_pages: int | None = None) -> Params:
    """``paged=True`` pools full-attention KV (MHA and MLA latent alike);
    sliding-window layers keep their dense/ring cache (already bounded by
    the window) and stateful kinds are untouched — a mixed-pattern model
    pages only what benefits.  All layouts come from the CacheSpec registry
    (models/cache.py), which is the single source of truth for shapes."""
    from repro.models import cache as cache_mod
    return cache_mod.spec_for(kind, cfg, batch, max_len, dtype, paged=paged,
                              page_size=page_size, num_pages=num_pages).init()


# ---------------------------------------------------------------------------
# Apply
# ---------------------------------------------------------------------------

def _norm(p, cfg, x):
    return common.apply_norm(p, x, cfg.norm_type, cfg.norm_eps)


def _cross_kv(p, cfg, enc_out):
    k = attention._split_heads(common.dense(p["wk"], enc_out), cfg.num_kv_heads)
    v = attention._split_heads(common.dense(p["wv"], enc_out), cfg.num_kv_heads)
    return k, v


def _cross_attend(p, cfg, x, k, v, impl):
    q = attention._split_heads(common.dense(p["wq"], x), cfg.num_heads)
    out = attention._sdpa(q, k, v, None, cfg.head_dim ** -0.5, "ref",
                          causal=False)
    return common.dense(p["wo"], attention._merge_heads(out).astype(x.dtype))


def block_apply(kind: str, p: Params, cfg: ModelConfig, x: jax.Array,
                ctx: BlockCtx, cache: Params | None
                ) -> tuple[jax.Array, Params | None, jax.Array]:
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    decode = ctx.mode == "decode"
    mixed = ctx.mode == "mixed"

    if kind in ("attn", "local", "moe"):
        h = _norm(p["norm1"], cfg, x)
        mask = ctx.mask_local if kind == "local" else ctx.mask_full
        local_cfg = cfg if kind == "local" else cfg.replace(window=None)
        if mixed:
            a, cache = attention.mixed_step(p["attn"], local_cfg, h, cache,
                                            ctx.pos, ctx.lengths,
                                            ctx.positions, ctx.impl)
        elif decode:
            a, cache = attention.decode_step(p["attn"], local_cfg, h, cache,
                                             ctx.pos, ctx.impl)
        elif cache is not None:
            a, cache = attention.prefill(p["attn"], local_cfg, h, cache, mask,
                                         ctx.positions, ctx.impl,
                                         chunked=ctx.chunked,
                                         prefix_len=ctx.prefix_len,
                                         lengths=ctx.lengths)
        else:
            a = attention.forward(p["attn"], local_cfg, h, mask,
                                  ctx.positions, ctx.impl,
                                  chunked=ctx.chunked,
                                  prefix_len=ctx.prefix_len)
        if cfg.parallel_block:
            f = ffn.forward(p["ffn"], cfg, h)
            return x + a + f, cache, aux
        x = x + a
        h2 = _norm(p["norm2"], cfg, x)
        if kind == "moe":
            f, aux = moe.forward(p["ffn"], cfg, h2)
        else:
            f = ffn.forward(p["ffn"], cfg, h2)
        return x + f, cache, aux

    if kind in ("mla", "mla_moe"):
        h = _norm(p["norm1"], cfg, x)
        if mixed:
            a, cache = mla.mixed_step(p["attn"], cfg, h, cache, ctx.pos,
                                      ctx.lengths, ctx.positions, ctx.impl)
        elif decode:
            a, cache = mla.decode_step(p["attn"], cfg, h, cache, ctx.pos,
                                       ctx.impl)
        elif cache is not None:
            a, cache = mla.prefill(p["attn"], cfg, h, cache, ctx.mask_full,
                                   ctx.positions, ctx.impl,
                                   chunked=ctx.chunked,
                                   prefix_len=ctx.prefix_len,
                                   lengths=ctx.lengths)
        else:
            a = mla.forward(p["attn"], cfg, h, ctx.mask_full, ctx.positions,
                            ctx.impl, chunked=ctx.chunked,
                            prefix_len=ctx.prefix_len)
        x = x + a
        h2 = _norm(p["norm2"], cfg, x)
        if kind == "mla_moe":
            f, aux = moe.forward(p["ffn"], cfg, h2)
        else:
            f = ffn.forward(p["ffn"], cfg, h2)
        return x + f, cache, aux

    # Recurrent kinds: the mixed mode is exactly a ragged forward — masked
    # state carry-through advances each row's state by its span, rows with
    # span 0 keep their state bit-for-bit.
    if kind == "rglru":
        h = _norm(p["norm1"], cfg, x)
        if decode:
            r, cache = rglru.decode_step(p["rec"], cfg, h, cache, ctx.pos,
                                         ctx.impl)
        else:
            r, cache = rglru.forward(p["rec"], cfg, h, cache, ctx.impl,
                                     lengths=ctx.lengths)
        x = x + r
        f = ffn.forward(p["ffn"], cfg, _norm(p["norm2"], cfg, x))
        return x + f, cache, aux

    if kind == "slstm":
        h = _norm(p["norm1"], cfg, x)
        if decode:
            y, cache = xlstm.slstm_decode(p["cell"], cfg, h, cache)
        else:
            y, cache = xlstm.slstm_forward(p["cell"], cfg, h, cache,
                                           lengths=ctx.lengths)
        return x + y, cache, aux

    if kind == "mlstm":
        h = _norm(p["norm1"], cfg, x)
        if decode:
            y, cache = xlstm.mlstm_decode(p["cell"], cfg, h, cache)
        else:
            y, cache = xlstm.mlstm_forward(p["cell"], cfg, h, cache,
                                           lengths=ctx.lengths)
        return x + y, cache, aux

    if kind == "xattn":
        h = _norm(p["norm1"], cfg, x)
        if decode:
            a, sc = attention.decode_step(
                p["attn"], cfg, h, {"k": cache["k"], "v": cache["v"]},
                ctx.pos, ctx.impl)
            cache = dict(cache, **sc)
            hx = _norm(p["norm_x"], cfg, x + a)
            q = attention._split_heads(common.dense(p["xattn"]["wq"], hx),
                                       cfg.num_heads)
            out = attention._sdpa(q, cache["xk"], cache["xv"], None,
                                  cfg.head_dim ** -0.5, "ref", causal=False)
            c = common.dense(p["xattn"]["wo"],
                             attention._merge_heads(out).astype(x.dtype))
        else:
            if cache is not None:
                a, sc = attention.prefill(
                    p["attn"], cfg, h, {"k": cache["k"], "v": cache["v"]},
                    ctx.mask_full, ctx.positions, ctx.impl,
                    chunked=ctx.chunked)
                xk, xv = _cross_kv(p["xattn"], cfg, ctx.enc_out)
                cache = dict(cache, **sc,
                             xk=xk.astype(cache["xk"].dtype),
                             xv=xv.astype(cache["xv"].dtype))
            else:
                a = attention.forward(p["attn"], cfg, h, ctx.mask_full,
                                      ctx.positions, ctx.impl,
                                      chunked=ctx.chunked)
                xk, xv = _cross_kv(p["xattn"], cfg, ctx.enc_out)
            hx = _norm(p["norm_x"], cfg, x + a)
            kx = cache["xk"] if cache is not None else xk
            vx = cache["xv"] if cache is not None else xv
            c = _cross_attend(p["xattn"], cfg, hx, kx.astype(x.dtype),
                              vx.astype(x.dtype), ctx.impl)
        x = x + a + c
        f = ffn.forward(p["ffn"], cfg, _norm(p["norm2"], cfg, x))
        return x + f, cache, aux

    raise ValueError(f"unknown block kind {kind}")
