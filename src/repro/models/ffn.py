"""Feed-forward blocks: SwiGLU / GeGLU / plain-GELU MLP."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.config import ModelConfig

Params = Any


def init(key, cfg: ModelConfig, d_ff: int | None = None) -> Params:
    d, h = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.ffn_activation == "gelu_mlp":
        return {"up": common.dense_init(ks[0], d, h, cfg.use_bias),
                "down": common.dense_init(ks[1], h, d, cfg.use_bias)}
    return {"gate": common.dense_init(ks[0], d, h, cfg.use_bias),
            "up": common.dense_init(ks[1], d, h, cfg.use_bias),
            "down": common.dense_init(ks[2], h, d, cfg.use_bias)}


def forward(p: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    if cfg.ffn_activation == "gelu_mlp":
        return common.dense(p["down"], jax.nn.gelu(common.dense(p["up"], x)))
    act = jax.nn.silu if cfg.ffn_activation == "silu" else jax.nn.gelu
    return common.dense(
        p["down"], act(common.dense(p["gate"], x)) * common.dense(p["up"], x))
