"""Mixture-of-Experts FFN: DeepSeekMoE-style shared + fine-grained routed
experts with top-k softmax routing.

Two dispatch modes (a §Perf hillclimb axis):

  * ``gather`` — GShard/Switch capacity-based dispatch: tokens are packed
    into [E, capacity] buffers with one-hot combine weights; expert matmuls
    see only their assigned tokens, so compiled FLOPs track *active* params
    (top_k/E of the expert pool, × capacity_factor slack).
  * ``dense`` — every token through every expert, gated combine.  FLOP-waste
    baseline (E/top_k× the compute) kept for roofline comparison.

Load-balancing auxiliary loss (Switch-style) is returned for the trainer.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.config import ModelConfig

Params = Any


def init(key, cfg: ModelConfig) -> Params:
    m = cfg.moe
    d, h = cfg.d_model, m.d_expert
    k_router, k_e, k_s = jax.random.split(key, 3)

    def expert_bank(key, n):
        kg, ku, kd = jax.random.split(key, 3)
        scale = d ** -0.5
        return {
            "gate": (jax.random.normal(kg, (n, d, h), jnp.float32) * scale
                     ).astype(common.PARAM_DTYPE),
            "up": (jax.random.normal(ku, (n, d, h), jnp.float32) * scale
                   ).astype(common.PARAM_DTYPE),
            "down": (jax.random.normal(kd, (n, h, d), jnp.float32) * h ** -0.5
                     ).astype(common.PARAM_DTYPE),
        }

    p = {
        "router": common.dense_init(k_router, d, m.num_experts, False),
        "experts": expert_bank(k_e, m.num_experts),
    }
    if m.num_shared:
        p["shared"] = expert_bank(k_s, m.num_shared)
    return p


def _expert_ffn(bank: Params, x: jax.Array) -> jax.Array:
    """x: [E, C, d] through per-expert SwiGLU: [E, C, d]."""
    g = jnp.einsum("ecd,edh->ech", x, bank["gate"].astype(x.dtype))
    u = jnp.einsum("ecd,edh->ech", x, bank["up"].astype(x.dtype))
    return jnp.einsum("ech,ehd->ecd", jax.nn.silu(g) * u,
                      bank["down"].astype(x.dtype))


def forward(p: Params, cfg: ModelConfig, x: jax.Array
            ) -> tuple[jax.Array, jax.Array]:
    """x: [B, T, d] -> (y, aux_loss)."""
    m = cfg.moe
    b, t, d = x.shape
    n = b * t
    xf = x.reshape(n, d)

    logits = common.dense(p["router"], xf).astype(jnp.float32)   # [N, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, top_idx = jax.lax.top_k(probs, m.top_k)           # [N, K]
    gate_vals = gate_vals / jnp.clip(
        jnp.sum(gate_vals, -1, keepdims=True), 1e-9)             # renorm

    # Switch aux loss: E * sum_e f_e * P_e.
    onehot = jax.nn.one_hot(top_idx, m.num_experts, dtype=jnp.float32)
    f_e = jnp.mean(jnp.sum(onehot, axis=1), axis=0)              # frac routed
    p_e = jnp.mean(probs, axis=0)
    aux = m.num_experts * jnp.sum(f_e * p_e)

    # Tiny token counts (single-token decode) route densely: capacity math
    # would drop tokens, and the dense pass is cheaper than the scatter.
    if m.dispatch == "dense" or n <= m.num_experts:
        all_out = _expert_ffn(p["experts"],
                              jnp.broadcast_to(xf, (m.num_experts, n, d)))
        combine = jnp.zeros((n, m.num_experts), xf.dtype)
        combine = combine.at[jnp.arange(n)[:, None], top_idx].add(
            gate_vals.astype(xf.dtype))
        y = jnp.einsum("end,ne->nd", all_out, combine)
    else:
        # GShard-style GROUP-LOCAL dispatch (group = batch row): capacity and
        # slot positions are computed within each row, so the scatter and the
        # expert matmul partition cleanly as [B(data), E(model), C, *].  A
        # global cumsum over all tokens would couple data shards and force
        # GSPMD to materialize global-capacity buffers on every device
        # (observed: ~100× FLOP inflation).
        tk = m.top_k
        capacity = max(int(m.capacity_factor * t * tk / m.num_experts), 1)
        e_bt = top_idx.reshape(b, t * tk)                        # [B, T*K]
        eq = jax.nn.one_hot(e_bt, m.num_experts, dtype=jnp.int32)
        pos = jnp.cumsum(eq, axis=1) - 1                         # within-row
        slot = jnp.take_along_axis(pos, e_bt[..., None], 2)[..., 0]
        keep = slot < capacity
        tok = jnp.repeat(jnp.arange(t), tk)                      # [T*K]

        def dispatch_row(x_row, e_row, slot_row, keep_row):
            buf = jnp.zeros((m.num_experts, capacity, d), x.dtype)
            return buf.at[e_row, jnp.where(keep_row, slot_row, capacity)
                          ].add(x_row[tok], mode="drop")

        buf = jax.vmap(dispatch_row)(x, e_bt, slot, keep)        # [B,E,C,d]
        gw = p["experts"]["gate"].astype(x.dtype)
        uw = p["experts"]["up"].astype(x.dtype)
        dw = p["experts"]["down"].astype(x.dtype)
        g = jnp.einsum("becd,edh->bech", buf, gw)
        u = jnp.einsum("becd,edh->bech", buf, uw)
        out = jnp.einsum("bech,ehd->becd", jax.nn.silu(g) * u, dw)

        def combine_row(out_row, e_row, slot_row, keep_row, gates_row):
            gathered = out_row[e_row, jnp.clip(slot_row, 0, capacity - 1)]
            w = (gates_row * keep_row).astype(out_row.dtype)
            return jax.ops.segment_sum(gathered * w[:, None], tok,
                                       num_segments=t)

        y = jax.vmap(combine_row)(out, e_bt, slot, keep,
                                  gate_vals.reshape(b, t * tk))  # [B,T,d]
        y = y.reshape(n, d)

    if m.num_shared:
        sh = _expert_ffn(p["shared"],
                         jnp.broadcast_to(xf, (m.num_shared, n, d)))
        y = y + jnp.sum(sh, axis=0)
    return y.reshape(b, t, d), aux
