"""GQA/MQA attention with optional sliding window, QK-norm, RoPE, and a
single-token decode path against a KV cache.

The portable path is pure jnp (what the dry-run lowers — XLA sees the true
attention FLOPs); ``impl="pallas"`` routes the contraction through the
repro.kernels flash kernels on TPU.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.models import cache as cache_mod
from repro.models import common
from repro.models.config import ModelConfig

Params = Any

# Every paged-MHA layout this module serves; the _q8/_fp8 variants carry
# int8/fp8 pools plus per-row f32 scale pools and route to the *_quant
# kernels (dequant fused into the block-table walk).
_PAGED_MHA = ("paged_mha", "paged_mha_q8", "paged_mha_fp8")


def init(key, cfg: ModelConfig, d_model: int | None = None) -> Params:
    d = d_model or cfg.d_model
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": common.dense_init(ks[0], d, hq * hd, cfg.use_bias),
        "wk": common.dense_init(ks[1], d, hkv * hd, cfg.use_bias),
        "wv": common.dense_init(ks[2], d, hkv * hd, cfg.use_bias),
        "wo": common.dense_init(ks[3], hq * hd, d, cfg.use_bias),
    }
    if cfg.qk_norm:
        p["q_norm"] = common.norm_init(hd, "rmsnorm")
        p["k_norm"] = common.norm_init(hd, "rmsnorm")
    return p


def _split_heads(x: jax.Array, n_heads: int) -> jax.Array:
    b, t, _ = x.shape
    return x.reshape(b, t, n_heads, -1).transpose(0, 2, 1, 3)   # [B,H,T,D]


def _merge_heads(x: jax.Array) -> jax.Array:
    b, h, t, d = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, t, h * d)


def _qkv(p: Params, cfg: ModelConfig, x: jax.Array, positions: jax.Array):
    q = _split_heads(common.dense(p["wq"], x), cfg.num_heads)
    k = _split_heads(common.dense(p["wk"], x), cfg.num_kv_heads)
    v = _split_heads(common.dense(p["wv"], x), cfg.num_kv_heads)
    if cfg.qk_norm:
        q = common.apply_norm(p["q_norm"], q, "rmsnorm", cfg.norm_eps)
        k = common.apply_norm(p["k_norm"], k, "rmsnorm", cfg.norm_eps)
    q = common.apply_rope(q, positions, cfg.rope_theta)
    k = common.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _block_mask(rows: jax.Array, cols: jax.Array, *, tk_true: int,
                causal: bool, window, prefix_len: int) -> jax.Array:
    """Lazy mask for a (rows × cols) tile; same semantics as common.make_mask."""
    r = rows[:, None]
    c = cols[None, :]
    mask = c < tk_true
    if causal:
        cm = c <= r
        if prefix_len > 0:
            cm |= (c < prefix_len)
        mask &= cm
    if window is not None:
        wm = c >= r - window + 1
        if prefix_len > 0:
            wm |= (c < prefix_len)
        mask &= wm
    return mask


def _sdpa_chunked(q, k, v, scale, *, causal=True, window=None, prefix_len=0,
                  chunk: int = 1024):
    """Blockwise online-softmax attention (portable flash structure).

    Never materializes [Tq, Tk] scores: a lax.scan over KV chunks carries
    (m, l, acc).  This is what makes prefill_32k lowerable — dense scores at
    32k would be ~4 GiB per head-row, f32.
    """
    b, hq, tq, d = q.shape
    _, hkv, tk, _ = k.shape
    dv = v.shape[-1]                      # may differ from d (MLA)
    group = hq // hkv
    nk = -(-tk // chunk)
    pad = nk * chunk - tk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    ks = jnp.moveaxis(k.reshape(b, hkv, nk, chunk, d), 2, 0)
    vs = jnp.moveaxis(v.reshape(b, hkv, nk, chunk, dv), 2, 0)
    rows = jnp.arange(tq, dtype=jnp.int32) + (tk - tq)
    qf = q.astype(jnp.float32)

    def body(carry, inp):
        m, l, acc, start = carry
        kc, vc = inp
        kb = jnp.repeat(kc, group, axis=1).astype(jnp.float32)
        vb = jnp.repeat(vc, group, axis=1).astype(jnp.float32)
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kb,
                       preferred_element_type=jnp.float32) * scale
        cols = start + jnp.arange(chunk, dtype=jnp.int32)
        mask = _block_mask(rows, cols, tk_true=tk, causal=causal,
                           window=window, prefix_len=prefix_len)
        s = jnp.where(mask[None, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bhkd->bhqd", p, vb, preferred_element_type=jnp.float32)
        return (m_new, l, acc, start + chunk), None

    m0 = jnp.full((b, hq, tq), -1e30, jnp.float32)
    l0 = jnp.zeros((b, hq, tq), jnp.float32)
    a0 = jnp.zeros((b, hq, tq, dv), jnp.float32)
    (m, l, acc, _), _ = jax.lax.scan(body, (m0, l0, a0, jnp.int32(0)),
                                     (ks, vs))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)


def _sdpa(q, k, v, mask, scale, impl: str, window=None, causal=True,
          chunked=False, prefix_len=0):
    """q: [B,H,Tq,D]; k,v: [B,Hkv,Tk,D]; mask: bool[Tq,Tk] / [B,Tq,Tk] / None.

    A 3-D mask carries per-row validity (ragged prefill) — rows with zero
    valid keys produce NaN outputs; callers discard those rows and masked
    cache writes drop them.
    """
    if chunked:
        return _sdpa_chunked(q, k, v, scale, causal=causal, window=window,
                             prefix_len=prefix_len)
    if impl == "pallas" and mask is None:
        return kops.flash_attention(q, k, v, causal=causal, scale=scale,
                                    window=window)
    group = q.shape[1] // k.shape[1]
    kb = jnp.repeat(k, group, axis=1)
    vb = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, kb,
                        preferred_element_type=jnp.float32) * scale
    if mask is not None:
        mask_b = mask[:, None] if mask.ndim == 3 else mask[None, None]
        logits = jnp.where(mask_b, logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, vb)


def forward(p: Params, cfg: ModelConfig, x: jax.Array,
            mask: Optional[jax.Array], positions: jax.Array,
            impl: str = "ref", chunked: bool = False,
            prefix_len: int = 0) -> jax.Array:
    """Full-sequence path (train / prefill-without-cache)."""
    q, k, v = _qkv(p, cfg, x, positions)
    scale = cfg.head_dim ** -0.5
    out = _sdpa(q, k, v, mask, scale, impl, window=cfg.window,
                chunked=chunked, prefix_len=prefix_len)
    return common.dense(p["wo"], _merge_heads(out))


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16, *, paged: bool = False,
               page_size: int = 64, num_pages: int | None = None,
               kv_quant: str = "off") -> Params:
    """Dense cache [B, Hkv, S, D], or a paged pool + per-row block tables.

    Paged mode: K/V live in a shared pool ``[P, Hkv, page_size, D]`` and each
    row maps logical positions to pages through ``block_tables[B, maxp]``
    (-1 = unallocated).  Resident memory scales with *allocated pages* (live
    tokens), not batch × max_len: ``num_pages`` can be far below
    ``batch * maxp`` when rows are ragged (a page allocator hands out pages
    on admission — see serving/scheduler.py).  Default is the dense-equal
    worst case so Engine can run without an allocator via
    ``default_block_tables``.

    Shapes are owned by the CacheSpec registry (models/cache.py); this is
    the thin per-module entry the block wiring calls.
    """
    return cache_mod.spec_for("attn", cfg, batch, max_len, dtype,
                              paged=paged, page_size=page_size,
                              num_pages=num_pages, kv_quant=kv_quant).init()


def default_block_tables(batch: int, max_len: int, page_size: int
                         ) -> jax.Array:
    """Identity mapping — row b owns contiguous pages [b*maxp, (b+1)*maxp).

    Needs the worst-case pool (num_pages == batch * maxp); real page reuse
    comes from the allocator in serving/scheduler.py.
    """
    maxp = -(-max_len // page_size)
    return jnp.arange(batch * maxp, dtype=jnp.int32).reshape(batch, maxp)


def _paged_prefill_write(cache: Params, k: jax.Array, v: jax.Array,
                         lengths: Optional[jax.Array]) -> Params:
    """Scatter a prompt's K/V ([B, Hkv, T, D]) into the row's pages.

    Positions >= lengths[b] (right-padding of a ragged batch) map to page -1
    and are dropped, so a prefill touches only the prefilled rows' pages —
    admission never disturbs in-flight rows.
    """
    bt = cache["block_tables"]
    ps = cache["k_pages"].shape[2]
    b, _, t, _ = k.shape
    tpos = jnp.arange(t, dtype=jnp.int32)
    num_pages = cache["k_pages"].shape[0]
    pg = bt[:, tpos // ps]                              # [B, T]
    # Dropped writes are routed OUT OF BOUNDS (= num_pages): a -1 sentinel
    # would wrap to the last page under jnp scatter semantics.  Dropped:
    # unallocated (-1) table entries, bucket padding past the table, and
    # positions beyond each row's ragged length.
    pg = jnp.where(pg < 0, num_pages, pg)
    pg = jnp.where(tpos[None, :] < bt.shape[1] * ps, pg, num_pages)
    if lengths is not None:
        pg = jnp.where(tpos[None, :] < lengths[:, None], pg, num_pages)
    slot = jnp.broadcast_to(tpos % ps, (b, t))
    if "k_scales" in cache:
        # Quantized pool: per-row scales ride alongside the values, written
        # through the exact same drop-routing so the pages/scales of
        # untouched rows stay bit-for-bit.
        kq, ks = kref.quantize_rows(k.transpose(0, 2, 1, 3),
                                    cache["k_pages"].dtype)
        vq, vs = kref.quantize_rows(v.transpose(0, 2, 1, 3),
                                    cache["v_pages"].dtype)
        return dict(
            cache,
            k_pages=cache["k_pages"].at[pg, :, slot, :].set(kq, mode="drop"),
            v_pages=cache["v_pages"].at[pg, :, slot, :].set(vq, mode="drop"),
            k_scales=cache["k_scales"].at[pg, :, slot].set(ks, mode="drop"),
            v_scales=cache["v_scales"].at[pg, :, slot].set(vs, mode="drop"))
    k_bt = k.transpose(0, 2, 1, 3).astype(cache["k_pages"].dtype)
    v_bt = v.transpose(0, 2, 1, 3).astype(cache["v_pages"].dtype)
    return dict(cache,
                k_pages=cache["k_pages"].at[pg, :, slot, :].set(
                    k_bt, mode="drop"),
                v_pages=cache["v_pages"].at[pg, :, slot, :].set(
                    v_bt, mode="drop"))


def prefill(p: Params, cfg: ModelConfig, x: jax.Array, cache: Params,
            mask: Optional[jax.Array], positions: jax.Array,
            impl: str = "ref", chunked: bool = False,
            prefix_len: int = 0,
            lengths: Optional[jax.Array] = None) -> tuple[jax.Array, Params]:
    """Full-prompt forward that also fills cache positions [0, T).

    ``lengths`` (i32[B]) marks a ragged right-padded batch: attention over
    padding is masked by the caller's 3-D mask and cache writes beyond each
    row's length are dropped, so rows with ``lengths[b] == 0`` keep their
    cache bit-for-bit (the admission path relies on this).
    """
    q, k, v = _qkv(p, cfg, x, positions)
    scale = cfg.head_dim ** -0.5
    out = _sdpa(q, k, v, mask, scale, impl, window=cfg.window,
                chunked=chunked, prefix_len=prefix_len)
    proj = common.dense(p["wo"], _merge_heads(out))
    if cache_mod.layout_of(cache) in _PAGED_MHA:
        return proj, _paged_prefill_write(cache, k, v, lengths)
    t = x.shape[1]
    s = cache["k"].shape[2]
    if t <= s:
        new_k = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0))
        new_v = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0))
        if lengths is not None:
            keep = jnp.arange(s)[None, :] < lengths[:, None]   # [B, S]
            oh = keep[:, None, :, None]
            new_k = jnp.where(oh, new_k, cache["k"])
            new_v = jnp.where(oh, new_v, cache["v"])
        new_cache = {"k": new_k, "v": new_v}
    else:
        if lengths is not None:
            raise NotImplementedError(
                "ragged prefill into a ring cache shorter than the padded "
                "prompt is unsupported — size the ring (window) >= the "
                "prompt bucket, or use a paged/dense cache")
        # Ring cache shorter than the prompt: slot s holds the LAST token
        # with absolute position ≡ s (mod S) — a deterministic gather (a
        # scatter with duplicate indices would have unspecified order).
        sl = jnp.arange(s, dtype=jnp.int32)
        p_last = (t - 1) - ((t - 1 - sl) % s)
        new_cache = {
            "k": k[:, :, p_last].astype(cache["k"].dtype),
            "v": v[:, :, p_last].astype(cache["v"].dtype),
        }
    return proj, new_cache


def mixed_step(p: Params, cfg: ModelConfig, x: jax.Array, cache: Params,
               start: jax.Array, span: jax.Array, positions: jax.Array,
               impl: str = "ref") -> tuple[jax.Array, Params]:
    """Per-row query spans against the cache (the mixed serve step).

    x: [B, C, D]; start: i32[B] tokens already cached per row; span: i32[B]
    valid new tokens in [0, C]; positions: i32[B, C] absolute positions
    (start + intra-span offset).  The span's K/V is written into the cache
    *before* the attend, so query j sees the whole cached prefix plus the
    span's keys up to itself — span 1 is a decode step, span C a prompt
    chunk, span 0 an idle row whose cache is untouched (output garbage).
    """
    b, c, _ = x.shape
    q, k, v = _qkv(p, cfg, x, positions)
    scale = cfg.head_dim ** -0.5
    layout = cache_mod.layout_of(cache)
    if layout in _PAGED_MHA:
        if layout != "paged_mha":
            out, k_pages, v_pages, k_scales, v_scales = (
                kops.paged_chunk_attention_quant(
                    q, cache["k_pages"], cache["k_scales"],
                    cache["v_pages"], cache["v_scales"],
                    cache["block_tables"], start, span, k, v, scale=scale,
                    window=cfg.window, use_pallas=(impl == "pallas")))
            return (common.dense(p["wo"], _merge_heads(out).astype(x.dtype)),
                    dict(cache, k_pages=k_pages, v_pages=v_pages,
                         k_scales=k_scales, v_scales=v_scales))
        out, k_pages, v_pages = kops.paged_chunk_attention(
            q, cache["k_pages"], cache["v_pages"], cache["block_tables"],
            start, span, k, v, scale=scale, window=cfg.window,
            use_pallas=(impl == "pallas"))
        return (common.dense(p["wo"], _merge_heads(out).astype(x.dtype)),
                dict(cache, k_pages=k_pages, v_pages=v_pages))
    s = cache["k"].shape[2]
    # Dense cache: the mixed path assumes no ring wrap (S >= start + span) —
    # lm.mixed_step rejects windowed/ring patterns up front.  Write the span
    # via a position gather (slot p takes span token p - start when that
    # offset lies in [0, span)), then attend with the same gathered-view
    # masks as the paged oracle.
    pidx = jnp.arange(s, dtype=jnp.int32)
    off = pidx[None, :] - start[:, None]                         # [B, S]
    wmask = (off >= 0) & (off < span[:, None])
    gidx = jnp.clip(off, 0, c - 1)[:, None, :, None]
    k_in = jnp.take_along_axis(
        k.astype(cache["k"].dtype),
        jnp.broadcast_to(gidx, (b, k.shape[1], s, k.shape[3])), axis=2)
    v_in = jnp.take_along_axis(
        v.astype(cache["v"].dtype),
        jnp.broadcast_to(gidx, (b, v.shape[1], s, v.shape[3])), axis=2)
    oh = wmask[:, None, :, None]
    k_cache = jnp.where(oh, k_in, cache["k"])
    v_cache = jnp.where(oh, v_in, cache["v"])
    group = cfg.num_heads // cfg.num_kv_heads
    kb = jnp.repeat(k_cache, group, axis=1)
    vb = jnp.repeat(v_cache, group, axis=1)
    logits = jnp.einsum("bhcd,bhsd->bhcs", q.astype(jnp.float32),
                        kb.astype(jnp.float32)) * scale
    valid = pidx[None, None, :] <= positions[:, :, None]         # [B, C, S]
    if cfg.window is not None:
        valid &= pidx[None, None, :] > (positions[:, :, None] - cfg.window)
    logits = jnp.where(valid[:, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhcs,bhsd->bhcd", probs, vb.astype(jnp.float32))
    out = out.astype(x.dtype)
    return (common.dense(p["wo"], _merge_heads(out)),
            {"k": k_cache, "v": v_cache})


def decode_step(p: Params, cfg: ModelConfig, x: jax.Array, cache: Params,
                pos: jax.Array, impl: str = "ref") -> tuple[jax.Array, Params]:
    """One-token step.  x: [B, 1, D]; pos: i32[B] tokens already cached."""
    b = x.shape[0]
    q, k, v = _qkv(p, cfg, x, pos[:, None])
    layout = cache_mod.layout_of(cache)
    if layout in _PAGED_MHA and layout != "paged_mha":
        scale = cfg.head_dim ** -0.5
        cap = cache["block_tables"].shape[-1] * cache["k_pages"].shape[-2]
        out, k_pages, v_pages, k_scales, v_scales = (
            kops.paged_decode_attention_quant(
                q[:, :, 0], cache["k_pages"], cache["k_scales"],
                cache["v_pages"], cache["v_scales"], cache["block_tables"],
                jnp.minimum(pos, cap - 1), k[:, :, 0], v[:, :, 0],
                scale=scale, window=cfg.window,
                use_pallas=(impl == "pallas")))
        out = out.reshape(b, 1, cfg.num_heads * cfg.head_dim).astype(x.dtype)
        return (common.dense(p["wo"], out),
                dict(cache, k_pages=k_pages, v_pages=v_pages,
                     k_scales=k_scales, v_scales=v_scales))
    if layout == "paged_mha":
        # Paged cache: O(page) write + block-table walk — no one-hot rewrite
        # of [B, Hkv, S, D].  The write is fused into the Pallas kernel; the
        # ref path is the gather oracle (kernels/ref.py).  pos is clamped to
        # the block table's capacity: past it the last slot is rewritten
        # (defined, still wrong output — callers bound generation, see
        # Engine.step / scheduler.submit) instead of an out-of-bounds
        # table read corrupting a live page.
        scale = cfg.head_dim ** -0.5
        cap = cache["block_tables"].shape[-1] * cache["k_pages"].shape[-2]
        out, k_pages, v_pages = kops.paged_decode_attention(
            q[:, :, 0], cache["k_pages"], cache["v_pages"],
            cache["block_tables"], jnp.minimum(pos, cap - 1),
            k[:, :, 0], v[:, :, 0],
            scale=scale, window=cfg.window, use_pallas=(impl == "pallas"))
        out = out.reshape(b, 1, cfg.num_heads * cfg.head_dim).astype(x.dtype)
        return (common.dense(p["wo"], out),
                dict(cache, k_pages=k_pages, v_pages=v_pages))
    # One-hot masked write instead of a scatter: a scatter at dynamic per-row
    # positions into a sequence-sharded cache forces SPMD "involuntary full
    # rematerialization" (replicates the whole cache).  The masked select is
    # elementwise, partitions along every axis, and XLA fuses it into the
    # cache-resident update.  The new-token K/V is resharded while tiny
    # ([B, Hkv, D], head-sharded from the projection) BEFORE broadcasting
    # against the cache — otherwise XLA broadcasts first and replicates the
    # full cache to reshard it.
    from repro.sharding import activation
    s = cache["k"].shape[2]
    k_tok = activation.constrain(k[:, :, 0], "batch", None, None)
    v_tok = activation.constrain(v[:, :, 0], "batch", None, None)
    # Ring indexing: token at absolute position p lives at slot p % S.  For
    # unbounded caches (S >= max pos) this is the identity; for ring caches
    # (S == window) it bounds memory while keeping exactly the attendable
    # window resident (keys carry their absolute-position RoPE).
    onehot = (jnp.arange(s, dtype=jnp.int32)[None] == (pos % s)[:, None])
    oh = onehot[:, None, :, None]
    k_cache = jnp.where(oh, k_tok[:, :, None].astype(cache["k"].dtype),
                        cache["k"])
    v_cache = jnp.where(oh, v_tok[:, :, None].astype(cache["v"].dtype),
                        cache["v"])
    kv_len = jnp.minimum(pos + 1, s)
    scale = cfg.head_dim ** -0.5
    # The dense flash-decode kernel has no window masking: only route to it
    # when no window applies or the cache IS the window (ring, sdim ==
    # window) — an unbounded cache under sliding-window attention must take
    # the masked einsum path or it would attend beyond the window.
    if impl == "pallas" and (cfg.window is None or s <= cfg.window):
        out = kops.decode_attention(q[:, :, 0], k_cache, v_cache, kv_len,
                                    scale=scale)
    else:
        # Grouped GQA einsum — no jnp.repeat: materializing broadcast KV
        # forces GSPMD to reshard the (seq-sharded) cache into head layout.
        # Contracting over the sharded seq axis instead lowers to partial
        # logits/softmax + tiny all-reduces (flash-decode schedule).
        group = cfg.num_heads // cfg.num_kv_heads
        qg = q[:, :, 0].reshape(b, cfg.num_kv_heads, group, cfg.head_dim)
        logits = jnp.einsum("bkgd,bksd->bkgs", qg, k_cache,
                            preferred_element_type=jnp.float32) * scale
        sdim = k_cache.shape[2]
        valid = jnp.arange(sdim)[None, :] < kv_len[:, None]
        if cfg.window is not None and sdim > cfg.window:
            # Unbounded cache: mask out slots older than the window.  Ring
            # caches (sdim == window) hold exactly the window — no mask.
            valid &= jnp.arange(sdim)[None, :] > (pos[:, None] - cfg.window)
        logits = jnp.where(valid[:, None, None, :], logits, -jnp.inf)
        probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
        out = jnp.einsum("bkgs,bksd->bkgd", probs, v_cache)
        out = out.reshape(b, cfg.num_heads, cfg.head_dim)
    out = out.reshape(b, 1, cfg.num_heads * cfg.head_dim).astype(x.dtype)
    return common.dense(p["wo"], out), {"k": k_cache, "v": v_cache}
