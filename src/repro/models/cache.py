"""CacheSpec: the typed registry of per-layer KV/state cache layouts.

Every block kind's cache is described by a :class:`CacheSpec` — its layout
name plus typed leaves (name, shape, dtype, role) — built by a registered
layout builder.  The spec is host-side metadata derived from the config; the
cache itself stays a plain pytree of arrays (jit/donation-friendly), but all
structural decisions (init shapes, which layers are paged, where the block
tables and pools live, how a pool leaf shards) are answered HERE instead of
by duck-typing dict keys at trace time.

Layouts:
  dense          [B, Hkv, S, D] K/V (ring when S == window < max_len)
  paged_mha      shared K/V pools [P, Hkv, ps, D] + block_tables [B, maxp]
  paged_mha_q8   int8 pools [P, Hkv, ps, D] + f32 scales [P, Hkv, ps]
  paged_mha_fp8  fp8 (e4m3) pools + the same scale leaves (dtype-gated)
  dense_mla      compressed latent stream [B, S, r] + RoPE key [B, S, rd]
  paged_mla      latent pool [P, ps, pad128(r + rd)] + block_tables [B, maxp]
  paged_mla_q8   int8 latent pool + f32 latent_scales [P, ps]
  paged_mla_fp8  fp8 latent pool + the same scale leaf (dtype-gated)
  state          recurrent carries (rglru/xLSTM) — opaque, never paged
  xattn          dense self-KV + once-filled cross-KV

Leaf roles drive the generic machinery:
  kv      per-row cache body (dense layouts)
  pool    shared page pool — resident memory unit, shards over heads or the
          latent-feature axis, COW page copies operate on dim 0
  scale   per-page quantization scales riding alongside a quantized pool —
          page-indexed like the pool (one f32 scale per pool row within
          each page), copied/snapshotted/restored WITH their pages so COW,
          speculative rollback and replication stay exact
  table   per-row block table — replicated, host-managed, validated shape
  state   recurrent carry

The MLA latent pool feature dim is padded to a multiple of 128 (TPU lane
width) at init so the fused kernel never pads per step; ``latent_width``
records the live width (kv_lora_rank + rope_head_dim).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Iterator

import jax
import jax.numpy as jnp
import numpy as np

Params = Any

ROLE_KV = "kv"
ROLE_POOL = "pool"
ROLE_SCALE = "scale"
ROLE_TABLE = "table"
ROLE_STATE = "state"

# fp8 support is gated on the dtype existing in the installed jax; int8 is
# always available.  Quantization maxima are the symmetric representable
# ranges the kernels/oracles scale into.
FP8_DTYPE = getattr(jnp, "float8_e4m3fn", None)
INT8_QMAX = 127.0
FP8_QMAX = 448.0                 # e4m3 finite max

KV_QUANT_MODES = ("off", "int8", "fp8")

# pool leaf -> its scale leaf (quantized layouts only)
SCALE_LEAF = {"k_pages": "k_scales", "v_pages": "v_scales",
              "latent_pages": "latent_scales"}


def pad128(n: int) -> int:
    return -(-n // 128) * 128


@dataclass(frozen=True)
class Leaf:
    """One typed cache array: its name, full shape, dtype, and role."""
    name: str
    shape: tuple[int, ...]
    dtype: Any
    role: str
    fill: float = 0.0            # block tables init to -1, arrays to 0

    def init(self) -> jax.Array:
        if self.fill == 0.0:
            return jnp.zeros(self.shape, self.dtype)
        return jnp.full(self.shape, self.fill, self.dtype)


@dataclass(frozen=True)
class CacheSpec:
    """Layout descriptor for one layer's cache."""
    kind: str                    # block kind ("attn", "mla", ...)
    layout: str                  # dense | paged_mha | dense_mla | paged_mla
    leaves: tuple[Leaf, ...]     #        | state | xattn
    page_size: int = 0
    num_pages: int = 0
    latent_width: int = 0        # live features of a padded latent pool
    # Recurrent carries keep their module-owned init (non-zero fills,
    # nested trees); Leaf-driven init covers every attention layout.
    init_fn: Callable[[], Params] | None = None

    @property
    def paged(self) -> bool:
        return self.layout.startswith("paged")

    def leaf(self, name: str) -> Leaf:
        for l in self.leaves:
            if l.name == name:
                return l
        raise KeyError(f"{self.layout} spec has no leaf {name!r}")

    def init(self) -> Params:
        if self.init_fn is not None:
            return self.init_fn()
        return {l.name: l.init() for l in self.leaves}

    def abstract(self) -> Params:
        if self.init_fn is not None:
            return jax.eval_shape(self.init_fn)
        return {l.name: jax.ShapeDtypeStruct(l.shape, l.dtype)
                for l in self.leaves}


# ---------------------------------------------------------------------------
# Layout builders (the registry)
# ---------------------------------------------------------------------------

_LAYOUTS: dict[str, Callable[..., CacheSpec]] = {}


def register_layout(name: str):
    def deco(fn):
        _LAYOUTS[name] = fn
        return fn
    return deco


@register_layout("dense")
def _dense(kind, cfg, batch, max_len, dtype, **_) -> CacheSpec:
    shape = (batch, cfg.num_kv_heads, max_len, cfg.head_dim)
    return CacheSpec(kind, "dense", (
        Leaf("k", shape, dtype, ROLE_KV),
        Leaf("v", shape, dtype, ROLE_KV),
    ))


@register_layout("paged_mha")
def _paged_mha(kind, cfg, batch, max_len, dtype, *, page_size=64,
               num_pages=None, **_) -> CacheSpec:
    maxp = -(-max_len // page_size)
    if num_pages is None:
        num_pages = batch * maxp
    pool = (num_pages, cfg.num_kv_heads, page_size, cfg.head_dim)
    return CacheSpec(kind, "paged_mha", (
        Leaf("k_pages", pool, dtype, ROLE_POOL),
        Leaf("v_pages", pool, dtype, ROLE_POOL),
        Leaf("block_tables", (batch, maxp), jnp.int32, ROLE_TABLE, fill=-1),
    ), page_size=page_size, num_pages=num_pages)


@register_layout("dense_mla")
def _dense_mla(kind, cfg, batch, max_len, dtype, **_) -> CacheSpec:
    m = cfg.mla
    return CacheSpec(kind, "dense_mla", (
        Leaf("ckv", (batch, max_len, m.kv_lora_rank), dtype, ROLE_KV),
        Leaf("krope", (batch, max_len, m.rope_head_dim), dtype, ROLE_KV),
    ))


@register_layout("paged_mla")
def _paged_mla(kind, cfg, batch, max_len, dtype, *, page_size=64,
               num_pages=None, **_) -> CacheSpec:
    m = cfg.mla
    width = m.kv_lora_rank + m.rope_head_dim
    maxp = -(-max_len // page_size)
    if num_pages is None:
        num_pages = batch * maxp
    return CacheSpec(kind, "paged_mla", (
        Leaf("latent_pages", (num_pages, page_size, pad128(width)), dtype,
             ROLE_POOL),
        Leaf("block_tables", (batch, maxp), jnp.int32, ROLE_TABLE, fill=-1),
    ), page_size=page_size, num_pages=num_pages, latent_width=width)


def _quantized(base: str, layout: str, qdtype, kind, cfg, batch, max_len,
               dtype, **kw) -> CacheSpec:
    """Derive a quantized layout from its fp layout: pool leaves store the
    quantized dtype and each gains an f32 scale leaf of the pool shape minus
    the feature axis (one scale per pool row within each page).  Scales init
    to 1.0 — a scale is never zero, even for untouched pages."""
    spec = _LAYOUTS[base](kind, cfg, batch, max_len, dtype, **kw)
    leaves: list[Leaf] = []
    for l in spec.leaves:
        if l.role != ROLE_POOL:
            leaves.append(l)
            continue
        leaves.append(Leaf(l.name, l.shape, qdtype, ROLE_POOL))
        leaves.append(Leaf(SCALE_LEAF[l.name], l.shape[:-1], jnp.float32,
                           ROLE_SCALE, fill=1.0))
    return CacheSpec(kind, layout, tuple(leaves), page_size=spec.page_size,
                     num_pages=spec.num_pages, latent_width=spec.latent_width)


@register_layout("paged_mha_q8")
def _paged_mha_q8(kind, cfg, batch, max_len, dtype, **kw) -> CacheSpec:
    return _quantized("paged_mha", "paged_mha_q8", jnp.int8, kind, cfg,
                      batch, max_len, dtype, **kw)


@register_layout("paged_mla_q8")
def _paged_mla_q8(kind, cfg, batch, max_len, dtype, **kw) -> CacheSpec:
    return _quantized("paged_mla", "paged_mla_q8", jnp.int8, kind, cfg,
                      batch, max_len, dtype, **kw)


@register_layout("paged_mha_fp8")
def _paged_mha_fp8(kind, cfg, batch, max_len, dtype, **kw) -> CacheSpec:
    if FP8_DTYPE is None:
        raise ValueError("kv_quant='fp8' needs jnp.float8_e4m3fn, which "
                         "this jax build lacks — use kv_quant='int8'")
    return _quantized("paged_mha", "paged_mha_fp8", FP8_DTYPE, kind, cfg,
                      batch, max_len, dtype, **kw)


@register_layout("paged_mla_fp8")
def _paged_mla_fp8(kind, cfg, batch, max_len, dtype, **kw) -> CacheSpec:
    if FP8_DTYPE is None:
        raise ValueError("kv_quant='fp8' needs jnp.float8_e4m3fn, which "
                         "this jax build lacks — use kv_quant='int8'")
    return _quantized("paged_mla", "paged_mla_fp8", FP8_DTYPE, kind, cfg,
                      batch, max_len, dtype, **kw)


@register_layout("xattn")
def _xattn(kind, cfg, batch, max_len, dtype, **_) -> CacheSpec:
    shape = (batch, cfg.num_kv_heads, max_len, cfg.head_dim)
    xshape = (batch, cfg.num_kv_heads, cfg.encoder.seq_len, cfg.head_dim)
    return CacheSpec(kind, "xattn", (
        Leaf("k", shape, dtype, ROLE_KV),
        Leaf("v", shape, dtype, ROLE_KV),
        Leaf("xk", xshape, dtype, ROLE_KV),
        Leaf("xv", xshape, dtype, ROLE_KV),
    ))


@register_layout("state")
def _state(kind, cfg, batch, max_len, dtype, **_) -> CacheSpec:
    # Recurrent carries keep their module-owned init (non-zero fills); the
    # spec records abstract leaves so generic traversals stay total.
    from repro.models import rglru, xlstm
    init = {"rglru": lambda: rglru.init_cache(cfg, batch, dtype),
            "slstm": lambda: xlstm.slstm_state(cfg, batch),
            "mlstm": lambda: xlstm.mlstm_state(cfg, batch)}[kind]
    tree = jax.eval_shape(init)
    leaves = tuple(Leaf(str(_key_str(path[-1])), tuple(x.shape), x.dtype,
                        ROLE_STATE)
                   for path, x in jax.tree_util.tree_flatten_with_path(tree)[0])
    return CacheSpec(kind, "state", leaves, init_fn=init)


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    return str(getattr(k, "name", getattr(k, "idx", k)))


# ---------------------------------------------------------------------------
# Kind -> layout routing
# ---------------------------------------------------------------------------

def layout_for(kind: str, cfg, *, paged: bool) -> str:
    """Which layout a block kind uses under the requested paging mode."""
    if kind in ("attn", "moe"):
        return "paged_mha" if paged else "dense"
    if kind == "local":
        # Ring/windowed layers stay dense: already bounded by the window.
        return "dense"
    if kind in ("mla", "mla_moe"):
        return "paged_mla" if paged else "dense_mla"
    if kind in ("rglru", "slstm", "mlstm"):
        return "state"
    if kind == "xattn":
        return "xattn"
    raise ValueError(f"unknown block kind {kind}")


def quant_layout(layout: str, kv_quant: str) -> str:
    """Quantized variant of a paged layout (identity for 'off' / non-paged:
    dense layouts rewrite whole rows per step, so quantizing them would
    re-quantize history every token — only page pools quantize)."""
    if kv_quant in (None, "", "off"):
        return layout
    if kv_quant not in KV_QUANT_MODES:
        raise ValueError(f"unknown kv_quant {kv_quant!r}: pick one of "
                         f"{KV_QUANT_MODES}")
    if layout not in ("paged_mha", "paged_mla"):
        return layout
    return layout + ("_q8" if kv_quant == "int8" else "_fp8")


def spec_for(kind: str, cfg, batch: int, max_len: int, dtype=jnp.bfloat16,
             *, paged: bool = False, page_size: int = 64,
             num_pages: int | None = None,
             kv_quant: str = "off") -> CacheSpec:
    layout = quant_layout(layout_for(kind, cfg, paged=paged), kv_quant)
    if kind == "local" and cfg.ring_local_cache and cfg.window:
        max_len = min(max_len, cfg.window)
    return _LAYOUTS[layout](kind, cfg, batch, max_len, dtype,
                            page_size=page_size, num_pages=num_pages)


def model_cache_specs(cfg, batch: int, max_len: int, dtype=jnp.bfloat16,
                      *, paged: bool = False, page_size: int = 64,
                      num_pages: int | None = None,
                      kv_quant: str = "off") -> dict[str, Any]:
    """The full registry for one model: {"groups": {i: spec}, "tail": ...}.

    Group specs describe ONE group's leaves; the stacked cache carries a
    leading [G] axis on every array (see lm.init_cache).
    """
    specs: dict[str, Any] = {"groups": {
        str(i): spec_for(kind, cfg, batch, max_len, dtype, paged=paged,
                         page_size=page_size, num_pages=num_pages,
                         kv_quant=kv_quant)
        for i, kind in enumerate(cfg.block_pattern)}}
    tail = {str(i): spec_for(kind, cfg, batch, max_len, dtype, paged=paged,
                             page_size=page_size, num_pages=num_pages,
                             kv_quant=kv_quant)
            for i, kind in enumerate(cfg.tail_blocks)}
    if tail:
        specs["tail"] = tail
    return specs


# ---------------------------------------------------------------------------
# Layout detection + typed traversal (replaces _map_paged_dicts duck-typing)
# ---------------------------------------------------------------------------

# A layer cache dict is identified by its leaf-name set: one entry per
# registered layout.  Detection is structural (the cache is a plain pytree
# under jit) but the *vocabulary* is owned by the registry — a new layout
# registers its leaf set here or traversals refuse it.
_LEAFSETS: dict[frozenset, str] = {
    frozenset({"k", "v"}): "dense",
    frozenset({"k_pages", "v_pages", "block_tables"}): "paged_mha",
    frozenset({"ckv", "krope"}): "dense_mla",
    frozenset({"latent_pages", "block_tables"}): "paged_mla",
    frozenset({"k", "v", "xk", "xv"}): "xattn",
    # int8 and fp8 share leaf names; layout_of disambiguates by pool dtype.
    frozenset({"k_pages", "v_pages", "k_scales", "v_scales",
               "block_tables"}): "paged_mha_q8",
    frozenset({"latent_pages", "latent_scales",
               "block_tables"}): "paged_mla_q8",
}


def layout_of(layer_cache: dict) -> str | None:
    """Layout name of one layer's cache dict (None if not a layer dict)."""
    if not isinstance(layer_cache, dict):
        return None
    name = _LEAFSETS.get(frozenset(layer_cache.keys()))
    if name in ("paged_mha_q8", "paged_mla_q8") and FP8_DTYPE is not None:
        pool = layer_cache["k_pages" if "k_pages" in layer_cache
                           else "latent_pages"]
        if pool.dtype == FP8_DTYPE:
            return name[:-len("_q8")] + "_fp8"
    return name


def iter_layers(cache: Params, path: tuple[str, ...] = ()
                ) -> Iterator[tuple[tuple[str, ...], str, dict]]:
    """Yield (path, layout, layer_dict) for every recognized layer cache."""
    if not isinstance(cache, dict):
        return
    layout = layout_of(cache)
    if layout is not None:
        yield path, layout, cache
        return
    for k, v in cache.items():
        yield from iter_layers(v, path + (str(k),))


def map_layers(cache: Params, fn, *, layouts: tuple[str, ...] | None = None
               ) -> Params:
    """Rebuild the cache tree with ``fn(path, layout, layer)`` applied to
    every layer dict (matching ``layouts`` when given, all otherwise)."""
    def rec(tree, path):
        if not isinstance(tree, dict):
            return tree
        layout = layout_of(tree)
        if layout is not None:
            if layouts is None or layout in layouts:
                return fn(path, layout, tree)
            return tree
        return {k: rec(v, path + (str(k),)) for k, v in tree.items()}

    return rec(cache, ())


# Per paged layout: every leaf that travels with its pages (pools AND their
# scale leaves) -> that leaf's unstacked ndim.  The generic page machinery
# (copy_pages / snapshot_span / restore_span / swap) iterates this, so scales
# ride along with zero special-casing at the call sites.
_POOL_LEAF_NDIM: dict[str, dict[str, int]] = {
    "paged_mha": {"k_pages": 4, "v_pages": 4},
    "paged_mha_q8": {"k_pages": 4, "v_pages": 4, "k_scales": 3,
                     "v_scales": 3},
    "paged_mha_fp8": {"k_pages": 4, "v_pages": 4, "k_scales": 3,
                      "v_scales": 3},
    "paged_mla": {"latent_pages": 3},
    "paged_mla_q8": {"latent_pages": 3, "latent_scales": 2},
    "paged_mla_fp8": {"latent_pages": 3, "latent_scales": 2},
}

PAGED_LAYOUTS = tuple(_POOL_LEAF_NDIM)
QUANT_LAYOUTS = tuple(l for l in PAGED_LAYOUTS if "_q8" in l or "_fp8" in l)

# Slot axis of every pool/scale leaf within one paged layer: MHA-family
# leaves are [P, Hkv, ps, ...] (slot axis 2), MLA-family [P, ps, ...]
# (slot axis 1) — scale leaves just drop the trailing feature axis.
_SPAN_SLOT_AXIS = {l: (2 if l.startswith("paged_mha") else 1)
                   for l in PAGED_LAYOUTS}


def pool_leaves(layer: dict, layout: str) -> list[str]:
    return list(_POOL_LEAF_NDIM.get(layout, {}))


# ---------------------------------------------------------------------------
# Block tables: install / read / validate
# ---------------------------------------------------------------------------

def set_block_tables(cache: Params, block_tables: jax.Array) -> Params:
    """Install one [B, maxp] block table into every paged layer.

    Layers share the mapping (same tokens, same pages-per-row); scanned
    groups carry it stacked [G, B, maxp].  The table shape is validated
    against every paged layer's own table — a mismatched table would
    silently broadcast into the wrong pages otherwise.
    """
    bt = jnp.asarray(block_tables).astype(jnp.int32)
    for path, layout, layer in iter_layers(cache):
        if layout not in PAGED_LAYOUTS:
            continue
        want = layer["block_tables"].shape[-2:]
        if bt.shape != want:
            raise ValueError(
                f"block table shape {tuple(bt.shape)} does not match layer "
                f"{'/'.join(path)} ({layout}): expected [B, maxp] = "
                f"{tuple(want)}")

    def install(path, layout, layer):
        return dict(layer, block_tables=jnp.broadcast_to(
            bt, layer["block_tables"].shape))

    return map_layers(cache, install, layouts=PAGED_LAYOUTS)


def get_block_tables(cache: Params) -> jax.Array | None:
    """The [B, maxp] block table shared by the paged layers (None if dense)."""
    for _, layout, layer in iter_layers(cache):
        if layout in PAGED_LAYOUTS:
            bt = layer["block_tables"]
            return bt[0] if bt.ndim == 3 else bt
    return None


# ---------------------------------------------------------------------------
# Page copy (COW) — device-side page duplication across every paged layer
# ---------------------------------------------------------------------------

def copy_pages(cache: Params, src: jax.Array, dst: jax.Array) -> Params:
    """Copy pool pages ``src[i] -> dst[i]`` in every paged layer.

    src/dst: i32[N] page ids (pad unused lanes with -1: those copies drop).
    The copy-on-write path: a row about to write a shared page gets a
    private duplicate, then its block table is remapped (host side).
    """
    src = jnp.asarray(src, jnp.int32)
    dst = jnp.asarray(dst, jnp.int32)
    if src.shape != dst.shape or src.ndim != 1:
        raise ValueError(
            f"copy_pages: src/dst page-id vectors must be matching 1-D "
            f"arrays: got src {tuple(src.shape)} vs dst {tuple(dst.shape)}")

    def cp(path, layout, layer):
        out = dict(layer)
        for name in pool_leaves(layer, layout):
            pool = layer[name]
            stacked = pool.ndim == _POOL_LEAF_NDIM[layout][name] + 1
            p = pool.shape[1] if stacked else pool.shape[0]
            safe_src = jnp.clip(src, 0, p - 1)
            tgt = jnp.where((src >= 0) & (dst >= 0), dst, p)
            if stacked:                                   # leading [G]
                rows = pool[:, safe_src]
                out[name] = pool.at[:, tgt].set(rows, mode="drop")
            else:
                rows = pool[safe_src]
                out[name] = pool.at[tgt].set(rows, mode="drop")
        return out

    return map_layers(cache, cp, layouts=PAGED_LAYOUTS)


# ---------------------------------------------------------------------------
# Cross-pool page transfer (prefill/decode disaggregation)
# ---------------------------------------------------------------------------
#
# Disaggregated serving moves *physical* page bytes between two engines'
# caches: the prefill replica fills pages and publishes them through the
# replicated prefix cache; the decode replica adopts the bytes instead of
# recomputing the prefix.  All three primitives below iterate CacheSpec
# pool leaves (_POOL_LEAF_NDIM), so dense/paged/MLA *and* quantized layouts
# move pool rows + scale rows bitwise with zero call-site special-casing.

def _leaf_mismatch(kind: str, path: tuple, layout: str, name: str,
                   src, dst) -> ValueError:
    return ValueError(
        f"{kind}: pool leaf '{name}' of layer {'/'.join(path)} ({layout}) "
        f"does not match: src {tuple(src.shape)} "
        f"({jnp.dtype(src.dtype).name}) vs dst {tuple(dst.shape)} "
        f"({jnp.dtype(dst.dtype).name})")


def export_pages(cache: Params, pages) -> dict:
    """Gather the physical rows of ``pages`` from every paged layer.

    pages: i32[N] page ids (no -1 lanes: exports are explicit).  Returns
    ``{'path/to/layer': {leaf: rows}}`` with rows shaped [N, ...] (stacked
    layers keep their leading [G] axis: [G, N, ...]) — the host-transport
    half of cross-pool adoption; pair with ``adopt_pages`` on the far side.
    Device-to-device transfers should use ``copy_pages_across`` instead,
    which never materializes the rows.
    """
    pages = jnp.asarray(pages, jnp.int32)
    out: dict = {}
    for path, layout, layer in iter_layers(cache):
        if layout not in PAGED_LAYOUTS:
            continue
        leaves = {}
        for name in pool_leaves(layer, layout):
            pool = layer[name]
            stacked = pool.ndim == _POOL_LEAF_NDIM[layout][name] + 1
            p = pool.shape[1] if stacked else pool.shape[0]
            safe = jnp.clip(pages, 0, p - 1)
            leaves[name] = pool[:, safe] if stacked else pool[safe]
        out["/".join(path)] = leaves
    return out


def adopt_pages(cache: Params, rows: dict, pages) -> Params:
    """Scatter ``rows`` (from a peer's ``export_pages`` at the same page
    ids) into ``pages`` of this cache's pools.  -1 lanes drop.  Raises with
    the offending layer name and both shapes on any leaf mismatch."""
    pages = jnp.asarray(pages, jnp.int32)

    def ad(path, layout, layer):
        key = "/".join(path)
        got = rows.get(key)
        if got is None:
            raise ValueError(
                f"adopt_pages: no exported rows for layer {key} ({layout})")
        out = dict(layer)
        for name in pool_leaves(layer, layout):
            pool = layer[name]
            src = got.get(name)
            if src is None:
                raise ValueError(
                    f"adopt_pages: exported rows for layer {key} ({layout}) "
                    f"are missing pool leaf '{name}'")
            src = jnp.asarray(src)
            stacked = pool.ndim == _POOL_LEAF_NDIM[layout][name] + 1
            p = pool.shape[1] if stacked else pool.shape[0]
            n = pages.shape[0]
            want = ((pool.shape[0], n) + pool.shape[2:]) if stacked \
                else ((n,) + pool.shape[1:])
            if tuple(src.shape) != want or src.dtype != pool.dtype:
                raise _leaf_mismatch("adopt_pages", path, layout, name,
                                     src, pool)
            tgt = jnp.where(pages >= 0, jnp.clip(pages, 0, p - 1), p)
            if stacked:
                out[name] = pool.at[:, tgt].set(src, mode="drop")
            else:
                out[name] = pool.at[tgt].set(src, mode="drop")
        return out

    return map_layers(cache, ad, layouts=PAGED_LAYOUTS)


def copy_pages_across(src_cache: Params, dst_cache: Params, src,
                      dst=None, *, use_pallas: bool = True
                      ) -> tuple[Params, int]:
    """Device-to-device page adoption: copy pool pages ``src[i]`` of
    ``src_cache`` into pages ``dst[i]`` of ``dst_cache`` in every paged
    layer (``dst`` defaults to ``src`` — the replicated server's pools
    share one global page-id space).  -1 lanes drop.

    Runs the batched Pallas gather-scatter transfer kernel per pool leaf
    (``ops.page_transfer``), so the bytes move pool-row-at-a-time without
    a host round-trip and land bitwise for every layout — quantized pools
    carry their scale leaves automatically.  The two caches must agree on
    layer structure and per-leaf row shape/dtype; page *counts* may differ.
    Returns ``(updated dst_cache, bytes_moved)``.
    """
    from repro.kernels import ops as kops

    src = jnp.asarray(src, jnp.int32)
    dst = src if dst is None else jnp.asarray(dst, jnp.int32)
    if src.shape != dst.shape or src.ndim != 1:
        raise ValueError(
            f"copy_pages_across: src/dst page-id vectors must be matching "
            f"1-D arrays: got src {tuple(src.shape)} vs dst "
            f"{tuple(dst.shape)}")
    n_valid = int(np.asarray((src >= 0) & (dst >= 0)).sum())
    src_layers = {path: (layout, layer)
                  for path, layout, layer in iter_layers(src_cache)
                  if layout in PAGED_LAYOUTS}
    moved = 0

    def xfer(path, layout, layer):
        nonlocal moved
        peer = src_layers.get(path)
        if peer is None or peer[0] != layout:
            raise ValueError(
                f"copy_pages_across: source cache has no "
                f"{layout} layer at {'/'.join(path)}"
                + (f" (found {peer[0]})" if peer else ""))
        s_layer = peer[1]
        out = dict(layer)
        for name in pool_leaves(layer, layout):
            dpool = layer[name]
            spool = s_layer.get(name)
            if spool is None or spool.ndim != dpool.ndim \
                    or spool.shape[1:] != dpool.shape[1:] \
                    or spool.dtype != dpool.dtype:
                raise _leaf_mismatch("copy_pages_across", path, layout,
                                     name, spool if spool is not None
                                     else jnp.zeros(()), dpool)
            stacked = dpool.ndim == _POOL_LEAF_NDIM[layout][name] + 1
            if stacked:
                # Flatten the leading [G] axis into the page axis with
                # per-group id offsets: one kernel call moves all groups.
                g, p_s = spool.shape[0], spool.shape[1]
                p_d = dpool.shape[1]
                row = dpool.shape[2:]
                off = jnp.arange(g, dtype=jnp.int32)[:, None]
                sids = jnp.where(src[None, :] >= 0,
                                 src[None, :] + off * p_s, -1).reshape(-1)
                dids = jnp.where(dst[None, :] >= 0,
                                 dst[None, :] + off * p_d, -1).reshape(-1)
                newp = kops.page_transfer(
                    spool.reshape((g * p_s,) + row),
                    dpool.reshape((g * p_d,) + row),
                    sids, dids, use_pallas=use_pallas)
                out[name] = newp.reshape(dpool.shape)
                page_bytes = g * int(np.prod(row, dtype=np.int64)) \
                    * dpool.dtype.itemsize
            else:
                out[name] = kops.page_transfer(spool, dpool, src, dst,
                                               use_pallas=use_pallas)
                page_bytes = int(np.prod(dpool.shape[1:], dtype=np.int64)) \
                    * dpool.dtype.itemsize
            moved += page_bytes * n_valid
        return out

    out_cache = map_layers(dst_cache, xfer, layouts=PAGED_LAYOUTS)
    return out_cache, moved


# ---------------------------------------------------------------------------
# Span snapshot / restore (speculative-decoding rollback)
# ---------------------------------------------------------------------------

def snapshot_span(cache: Params, start: jax.Array, width: int) -> Params:
    """Copy every cache slot a mixed step writing positions
    [start[b], start[b]+width) could touch — the rollback snapshot taken
    before a speculative verify step (after page growth, so the block
    tables already map the window).

    Dense layouts gather along the sequence axis; paged layouts walk the
    block tables via the ``kernels.ref`` span oracles.  The returned tree
    mirrors the cache's nesting but keeps only attention slots: recurrent
    state carries are whole-row, not per-slot — snapshot those with
    ``lm.snapshot_state_rows``.  xattn layers (not mixed-step servable)
    and unrecognized leaves are pruned so the snapshot never aliases a
    buffer that a later donated verify call would invalidate.
    """
    from repro.kernels import ref as kref

    start = jnp.asarray(start, jnp.int32)
    batch = start.shape[0]
    tpos = start[:, None] + jnp.arange(width, dtype=jnp.int32)[None, :]
    bidx = jnp.broadcast_to(jnp.arange(batch, dtype=jnp.int32)[:, None],
                            (batch, width))

    def snap_layer(layout, layer):
        out = {}
        if layout in PAGED_LAYOUTS:
            bt = layer["block_tables"]
            bt2 = bt[0] if bt.ndim == 3 else bt
            slot_axis = _SPAN_SLOT_AXIS[layout]
            for name in pool_leaves(layer, layout):
                pool = layer[name]
                core = _POOL_LEAF_NDIM[layout][name]
                if pool.ndim == core + 1:                 # leading [G]
                    out[name] = jax.vmap(
                        lambda p: kref.paged_span_gather(
                            p, bt2, start, width,
                            slot_axis=slot_axis))(pool)
                else:
                    out[name] = kref.paged_span_gather(pool, bt2, start,
                                                       width,
                                                       slot_axis=slot_axis)
            return out
        # dense / dense_mla: sequence axis is -2
        for name, arr in layer.items():
            core = 4 if layout == "dense" else 3
            stacked = arr.ndim == core + 1
            seq = arr.shape[-2]
            spos = jnp.clip(tpos, 0, seq - 1)
            if layout == "dense":
                out[name] = (arr[:, bidx, :, spos, :] if stacked
                             else arr[bidx, :, spos, :])
            else:
                out[name] = (arr[:, bidx, spos] if stacked
                             else arr[bidx, spos])
        return out

    def rec(tree):
        if not isinstance(tree, dict):
            return None                                   # prune raw leaves
        layout = layout_of(tree)
        if layout is not None:
            return snap_layer(layout, tree) if layout != "xattn" else {}
        out = {k: rec(v) for k, v in tree.items()}
        return {k: v for k, v in out.items() if v is not None}

    return rec(cache)


def restore_span(cache: Params, snap: Params, start: jax.Array,
                 lo: jax.Array, hi: jax.Array) -> Params:
    """Scatter ``snap`` (from :func:`snapshot_span`, same ``start``) back
    for positions in [lo[b], hi[b)) — the rejected-tail rollback.

    Must run against the SAME block tables the snapshot saw (i.e. before
    the host frees the tail's grown pages).  Lanes outside the window are
    routed out of bounds and dropped, so accepted positions keep the
    verify step's writes bit-for-bit.  Rows with lo == hi are untouched.
    """
    from repro.kernels import ref as kref

    start = jnp.asarray(start, jnp.int32)
    lo = jnp.asarray(lo, jnp.int32)
    hi = jnp.asarray(hi, jnp.int32)
    batch = start.shape[0]

    def restore_layer(layout, layer, s):
        out = dict(layer)
        if layout in PAGED_LAYOUTS:
            bt = layer["block_tables"]
            bt2 = bt[0] if bt.ndim == 3 else bt
            slot_axis = _SPAN_SLOT_AXIS[layout]
            for name in pool_leaves(layer, layout):
                pool = layer[name]
                core = _POOL_LEAF_NDIM[layout][name]
                if pool.ndim == core + 1:
                    out[name] = jax.vmap(
                        lambda p, sn: kref.paged_span_restore(
                            p, sn, bt2, start, lo, hi,
                            slot_axis=slot_axis))(pool, s[name])
                else:
                    out[name] = kref.paged_span_restore(
                        pool, s[name], bt2, start, lo, hi,
                        slot_axis=slot_axis)
            return out
        for name, arr in layer.items():
            core = 4 if layout == "dense" else 3
            stacked = arr.ndim == core + 1
            seq = arr.shape[-2]
            # snapshot leaf layout: dense gathers have non-adjacent advanced
            # indices so [B, W, ...] always; dense_mla's are adjacent, which
            # keeps the leading [G] in place — [G, B, W, r] when stacked.
            w = s[name].shape[2 if layout != "dense" and stacked else 1]
            tpos = start[:, None] + jnp.arange(w, dtype=jnp.int32)[None, :]
            keep = (tpos >= lo[:, None]) & (tpos < hi[:, None]) \
                & (tpos < seq)
            sidx = jnp.where(keep, jnp.clip(tpos, 0, seq - 1), seq)
            bidx = jnp.broadcast_to(
                jnp.arange(batch, dtype=jnp.int32)[:, None], (batch, w))
            if layout == "dense":
                out[name] = (arr.at[:, bidx, :, sidx, :]
                             .set(s[name], mode="drop") if stacked
                             else arr.at[bidx, :, sidx, :]
                             .set(s[name], mode="drop"))
            else:
                out[name] = (arr.at[:, bidx, sidx]
                             .set(s[name], mode="drop") if stacked
                             else arr.at[bidx, sidx]
                             .set(s[name], mode="drop"))
        return out

    def rec(tree, s):
        if not isinstance(tree, dict) or not isinstance(s, dict) or not s:
            return tree
        layout = layout_of(tree)
        if layout is not None:
            return (restore_layer(layout, tree, s)
                    if layout != "xattn" else tree)
        return {k: rec(v, s.get(k)) for k, v in tree.items()}

    return rec(cache, snap)


# ---------------------------------------------------------------------------
# Tiered page memory: host-buffer swap pool (copy_pages across tiers)
# ---------------------------------------------------------------------------

def make_swap_pool(cache: Params, n_slots: int
                   ) -> dict[tuple[str, ...], dict[str, np.ndarray]]:
    """Host-memory mirror of every paged layer's pool (and scale) leaves.

    ``{layer_path: {leaf_name: np[..., n_slots, ...]}}`` — each leaf keeps
    its device shape with the page axis replaced by ``n_slots`` swap slots.
    Quantized layouts swap their int8/fp8 bytes, so a swapped page costs the
    same host bytes as its resident form (and swap-in is bit-exact).
    """
    pool: dict[tuple[str, ...], dict[str, np.ndarray]] = {}
    for path, layout, layer in iter_layers(cache):
        if layout not in PAGED_LAYOUTS:
            continue
        leaves = {}
        for name in pool_leaves(layer, layout):
            arr = layer[name]
            stacked = arr.ndim == _POOL_LEAF_NDIM[layout][name] + 1
            shape = ((arr.shape[0], n_slots) + arr.shape[2:] if stacked
                     else (n_slots,) + arr.shape[1:])
            leaves[name] = np.zeros(shape, arr.dtype)
        pool[path] = leaves
    return pool


def swap_out_pages(cache: Params, swap_pool: dict, pages, slots) -> int:
    """Copy device pool pages -> host swap slots (``pages[i] -> slots[i]``).

    The cross-tier half of :func:`copy_pages`: same page-axis gather, but the
    destination is the host swap pool.  Mutates ``swap_pool`` in place and
    returns the bytes moved (one device→host transfer per leaf).
    """
    pages = np.asarray(pages, np.int32)
    slots = np.asarray(slots, np.int32)
    moved = 0
    for path, layout, layer in iter_layers(cache):
        if layout not in PAGED_LAYOUTS:
            continue
        host = swap_pool[path]
        for name in pool_leaves(layer, layout):
            arr = layer[name]
            stacked = arr.ndim == _POOL_LEAF_NDIM[layout][name] + 1
            rows = np.asarray(arr[:, pages] if stacked else arr[pages])
            if stacked:
                host[name][:, slots] = rows
            else:
                host[name][slots] = rows
            moved += rows.nbytes
    return moved


def swap_in_pages(cache: Params, swap_pool: dict, slots, pages) -> Params:
    """Copy host swap slots -> device pool pages (``slots[i] -> pages[i]``).

    Returns the updated cache tree; the swapped bytes land bit-exactly
    (values AND scales for quantized layouts), so a swap-in victim resumes
    decoding from the identical cache it was preempted with.
    """
    slots = np.asarray(slots, np.int32)
    idx = jnp.asarray(np.asarray(pages, np.int32))

    def fn(path, layout, layer):
        host = swap_pool[path]
        out = dict(layer)
        for name in pool_leaves(layer, layout):
            arr = layer[name]
            stacked = arr.ndim == _POOL_LEAF_NDIM[layout][name] + 1
            if stacked:
                rows = jnp.asarray(host[name][:, slots])
                out[name] = arr.at[:, idx].set(rows)
            else:
                rows = jnp.asarray(host[name][slots])
                out[name] = arr.at[idx].set(rows)
        return out

    return map_layers(cache, fn, layouts=PAGED_LAYOUTS)
