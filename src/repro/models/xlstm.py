"""xLSTM blocks: sLSTM (scalar memory, recurrent gates) and mLSTM (matrix
memory, parallelizable) — arXiv:2405.04517.  xlstm-125m alternates them.

Both use the paper's stabilized exponential gating (running max m_t keeps
exp() bounded).  sLSTM has true recurrent weight matrices (block-diagonal per
head), so it scans serially; mLSTM has no hidden-to-gate recurrence and keeps
a [H, Dh, Dh] matrix state.  Decode is an O(1) state update for both —
xlstm runs the `long_500k` shape for exactly this reason.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.config import ModelConfig

Params = Any


def _masked_state(valid_t: jax.Array, new: Params, old: Params) -> Params:
    """Per-row select: rows where ``valid_t`` is False keep ``old`` exactly
    (bit-for-bit) — the masked carry-through that lets the recurrent cells
    ride ragged prefill and the mixed serve step's per-row spans."""
    return jax.tree.map(
        lambda n, o: jnp.where(
            valid_t.reshape((-1,) + (1,) * (n.ndim - 1)), n, o), new, old)

# Chunked time scan: a flat lax.scan saves every per-step carry for the
# backward pass — for mLSTM that is a [B, H, dh, dh] matrix PER TOKEN
# (≈150 GB/device at train_4k).  Nesting the scan (outer over chunks, inner
# rematerialized) keeps only chunk-boundary carries and recomputes inside,
# cutting saved-carry memory by ~SCAN_CHUNK× for one extra forward of the
# cell.  Exact same math (§Perf extra iteration in EXPERIMENTS.md).
SCAN_CHUNK = 64


def _time_scan(step, state, xs):
    """lax.scan over time with chunk-remat when T divides SCAN_CHUNK."""
    t = jax.tree.leaves(xs)[0].shape[0]
    if t <= SCAN_CHUNK or t % SCAN_CHUNK != 0:
        return jax.lax.scan(step, state, xs)
    n_chunks = t // SCAN_CHUNK
    xs_c = jax.tree.map(
        lambda x: x.reshape((n_chunks, SCAN_CHUNK) + x.shape[1:]), xs)

    @jax.checkpoint
    def inner(st, xc):
        return jax.lax.scan(step, st, xc)

    state, ys_c = jax.lax.scan(inner, state, xs_c)
    ys = jax.tree.map(
        lambda y: y.reshape((t,) + y.shape[2:]), ys_c)
    return state, ys


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_init(key, cfg: ModelConfig) -> Params:
    d, h = cfg.d_model, cfg.num_heads
    dh = d // h
    ks = jax.random.split(key, 3)
    scale = d ** -0.5
    # 4 gates (i, f, z, o): input weights [d, 4d]; recurrent weights are
    # block-diagonal per head [H, dh, 4*dh].
    return {
        "w_in": common.dense_init(ks[0], d, 4 * d),
        "r": (jax.random.normal(ks[1], (h, dh, 4 * dh), jnp.float32)
              * dh ** -0.5).astype(common.PARAM_DTYPE),
        "out": common.dense_init(ks[2], d, d),
        "norm": common.norm_init(d, "rmsnorm"),
    }


def slstm_state(cfg: ModelConfig, batch: int) -> Params:
    d = cfg.d_model
    z = lambda: jnp.zeros((batch, d), jnp.float32)
    return {"c": z(), "n": z(), "m": z() - 10.0, "h": z()}


def _slstm_cell(p, cfg, wx_t, state):
    """wx_t: [B, 4d] precomputed input contribution; state dict of [B, d]."""
    b = wx_t.shape[0]
    h_heads = state["h"].reshape(b, cfg.num_heads, -1).astype(jnp.float32)
    rh = jnp.einsum("bhd,hde->bhe", h_heads,
                    p["r"].astype(jnp.float32)).reshape(b, -1)   # [B, 4d]
    pre = wx_t.astype(jnp.float32) + rh
    i_p, f_p, z_p, o_p = jnp.split(pre, 4, axis=-1)
    m_new = jnp.maximum(f_p + state["m"], i_p)                   # log-space
    i_g = jnp.exp(i_p - m_new)
    f_g = jnp.exp(f_p + state["m"] - m_new)
    c = f_g * state["c"] + i_g * jnp.tanh(z_p)
    n = f_g * state["n"] + i_g
    h = jax.nn.sigmoid(o_p) * c / jnp.maximum(n, 1.0)
    return {"c": c, "n": n, "m": m_new, "h": h}


def slstm_forward(p: Params, cfg: ModelConfig, x: jax.Array,
                  state: Params | None = None,
                  lengths: Optional[jax.Array] = None
                  ) -> tuple[jax.Array, Params | None]:
    """``lengths`` (i32[B]): ragged right-padded batch — padding steps keep
    each row's state bit-for-bit (rows with ``lengths[b] == 0`` untouched)."""
    b, t, d = x.shape
    keep_state = state is not None
    if state is None:
        state = slstm_state(cfg, b)
    wx = common.dense(p["w_in"], x)                              # [B,T,4d]

    if lengths is None:
        def step(s, wx_t):
            s = _slstm_cell(p, cfg, wx_t, s)
            return s, s["h"]

        state, hs = _time_scan(step, state, jnp.moveaxis(wx, 1, 0))
    else:
        valid = (jnp.arange(t)[:, None] < lengths[None, :])      # [T, B]

        def step(s, inp):
            wx_t, v_t = inp
            s = _masked_state(v_t, _slstm_cell(p, cfg, wx_t, s), s)
            return s, s["h"]

        state, hs = _time_scan(step, state, (jnp.moveaxis(wx, 1, 0), valid))
    y = jnp.moveaxis(hs, 0, 1).astype(x.dtype)                   # [B,T,d]
    y = common.apply_norm(p["norm"], y, "rmsnorm", cfg.norm_eps)
    return common.dense(p["out"], y), (state if keep_state else None)


def slstm_decode(p, cfg, x, state, pos=None):
    wx = common.dense(p["w_in"], x)[:, 0]
    state = _slstm_cell(p, cfg, wx, state)
    y = state["h"][:, None].astype(x.dtype)
    y = common.apply_norm(p["norm"], y, "rmsnorm", cfg.norm_eps)
    return common.dense(p["out"], y), state


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_init(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    up = int(cfg.proj_factor * d)
    ks = jax.random.split(key, 7)
    return {
        "up_mlstm": common.dense_init(ks[0], d, up),
        "up_gate": common.dense_init(ks[1], d, up),
        "wq": common.dense_init(ks[2], up, up),
        "wk": common.dense_init(ks[3], up, up),
        "wv": common.dense_init(ks[4], up, up),
        "w_if": common.dense_init(ks[5], up, 2 * cfg.num_heads),
        "down": common.dense_init(ks[6], up, d),
        "norm": common.norm_init(up, "rmsnorm"),
    }


def mlstm_state(cfg: ModelConfig, batch: int) -> Params:
    h = cfg.num_heads
    dh = int(cfg.proj_factor * cfg.d_model) // h
    return {
        "C": jnp.zeros((batch, h, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, h, dh), jnp.float32),
        "m": jnp.zeros((batch, h), jnp.float32) - 10.0,
    }


def _mlstm_cell(state, q_t, k_t, v_t, i_p, f_p):
    """One step.  q/k/v: [B,H,dh]; i_p/f_p: [B,H] pre-activations."""
    f_log = jax.nn.log_sigmoid(f_p.astype(jnp.float32))
    m_new = jnp.maximum(f_log + state["m"], i_p.astype(jnp.float32))
    i_g = jnp.exp(i_p - m_new)[..., None]                        # [B,H,1]
    f_g = jnp.exp(f_log + state["m"] - m_new)[..., None]
    kf, vf, qf = (k_t.astype(jnp.float32), v_t.astype(jnp.float32),
                  q_t.astype(jnp.float32))
    c = f_g[..., None] * state["C"] + i_g[..., None] * (
        vf[..., :, None] * kf[..., None, :])                     # [B,H,dh,dh]
    n = f_g * state["n"] + i_g * kf
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, qf)), 1.0)
    h_t = jnp.einsum("bhde,bhe->bhd", c, qf) / denom[..., None]
    return {"C": c, "n": n, "m": m_new}, h_t


def _mlstm_qkvif(p, cfg, xu):
    b, t, up = xu.shape
    h = cfg.num_heads
    dh = up // h
    split = lambda z: z.reshape(b, t, h, dh)
    q = split(common.dense(p["wq"], xu))
    k = split(common.dense(p["wk"], xu)) * dh ** -0.5
    v = split(common.dense(p["wv"], xu))
    gates = common.dense(p["w_if"], xu).reshape(b, t, 2, h)
    return q, k, v, gates[:, :, 0], gates[:, :, 1]


def mlstm_forward(p: Params, cfg: ModelConfig, x: jax.Array,
                  state: Params | None = None,
                  lengths: Optional[jax.Array] = None
                  ) -> tuple[jax.Array, Params | None]:
    """``lengths`` (i32[B]): ragged right-padded batch — padding steps keep
    each row's state bit-for-bit (rows with ``lengths[b] == 0`` untouched)."""
    b, t, d = x.shape
    keep_state = state is not None
    if state is None:
        state = mlstm_state(cfg, b)
    xu = common.dense(p["up_mlstm"], x)
    gate = jax.nn.silu(common.dense(p["up_gate"], x))
    q, k, v, i_p, f_p = _mlstm_qkvif(p, cfg, xu)

    if lengths is None:
        def step(s, inp):
            q_t, k_t, v_t, ip_t, fp_t = inp
            s, h_t = _mlstm_cell(s, q_t, k_t, v_t, ip_t, fp_t)
            return s, h_t

        xs = tuple(jnp.moveaxis(z, 1, 0) for z in (q, k, v, i_p, f_p))
    else:
        valid = (jnp.arange(t)[:, None] < lengths[None, :])      # [T, B]

        def step(s, inp):
            q_t, k_t, v_t, ip_t, fp_t, v_m = inp
            s_new, h_t = _mlstm_cell(s, q_t, k_t, v_t, ip_t, fp_t)
            return _masked_state(v_m, s_new, s), h_t

        xs = tuple(jnp.moveaxis(z, 1, 0)
                   for z in (q, k, v, i_p, f_p)) + (valid,)
    state, hs = _time_scan(step, state, xs)
    h = jnp.moveaxis(hs, 0, 1).reshape(b, t, -1).astype(x.dtype)
    h = common.apply_norm(p["norm"], h, "rmsnorm", cfg.norm_eps)
    y = common.dense(p["down"], h * gate)
    return y, (state if keep_state else None)


def mlstm_decode(p, cfg, x, state, pos=None):
    xu = common.dense(p["up_mlstm"], x)
    gate = jax.nn.silu(common.dense(p["up_gate"], x))
    q, k, v, i_p, f_p = _mlstm_qkvif(p, cfg, xu)
    state, h_t = _mlstm_cell(state, q[:, 0], k[:, 0], v[:, 0],
                             i_p[:, 0], f_p[:, 0])
    b = x.shape[0]
    h = h_t.reshape(b, 1, -1).astype(x.dtype)
    h = common.apply_norm(p["norm"], h, "rmsnorm", cfg.norm_eps)
    return common.dense(p["down"], h * gate), state
