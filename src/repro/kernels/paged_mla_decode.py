"""Pallas TPU kernel: paged MLA decode over the compressed latent stream,
with the current token's latent write fused in.

MLA decode (weight-absorbed form) attends directly against the compressed
cache: logits = q_abs·ckv + q_rope·krope, context = probs·ckv.  Both terms
are one contraction against the row-wise concat ``[ckv; krope]`` — exactly
what the latent page pool stores: ``[P, page_size, Dp]`` where the first
``latent_width = kv_lora_rank + rope_head_dim`` features are live and Dp is
padded to the TPU lane width at init (never per step).

Per step this kernel:
  * DMAs the token's latent row into page ``bt[b, pos//ps]`` slot ``pos%ps``
    (O(Dp) bytes — the dense path's one-hot rewrite of [B, S, r] vanishes);
  * walks the row's live pages via scalar-prefetched block tables,
    double-buffering each page HBM→VMEM, with split-K online softmax;
  * accumulates the latent context from the ckv half of each page.

Grid is (B,): the latent stream is shared across query heads (that is the
point of MLA), so one program serves the whole head group of one row.  The
pool is an ANY-space ref aliased input→output for the in-place write.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.paged_decode_attention import _quantize_rows

NEG_INF = -1e30


def _kernel(bt_ref, pos_ref, q_ref, ln_ref, lp_in, o_ref, lp,
            buf, tok, dsem, wsem, *, ps: int, r: int, width: int,
            scale: float):
    b = pl.program_id(0)
    pos = pos_ref[b]
    kv_len = pos + 1
    n_pages = (kv_len + ps - 1) // ps

    # -- fused write: current latent row -> one page slot -------------------
    page_raw = bt_ref[b, pos // ps]
    page_w = jnp.maximum(page_raw, 0)
    slot_w = pos % ps
    tok[0, 0, :] = ln_ref[0]

    @pl.when(page_raw >= 0)
    def _write():
        w = pltpu.make_async_copy(
            tok, lp.at[pl.ds(page_w, 1), pl.ds(slot_w, 1), :], wsem)
        w.start()
        # The written page is also read below (self-attention of the new
        # token) — the copy must land before the walk reaches it.
        w.wait()

    # -- split-K online softmax over the row's live pages -------------------
    def page_dma(i, slot):
        pg = jnp.maximum(bt_ref[b, i], 0)
        return pltpu.make_async_copy(
            lp.at[pl.ds(pg, 1)], buf.at[pl.ds(slot, 1)], dsem.at[slot])

    page_dma(0, 0).start()

    q = q_ref[0].astype(jnp.float32)                      # [H, width]
    h = q.shape[0]

    def body(i, carry):
        m, l, acc = carry
        slot = jax.lax.rem(i, 2)
        nxt = jax.lax.rem(i + 1, 2)

        @pl.when(i + 1 < n_pages)
        def _prefetch():
            page_dma(i + 1, nxt).start()

        page_dma(i, slot).wait()
        lat = buf[slot].astype(jnp.float32)               # [ps, Dp]
        s = jax.lax.dot_general(
            q, lat[:, :width], (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # [H, ps]
        cols = i * ps + jax.lax.broadcasted_iota(jnp.int32, (1, ps), 1)
        s = jnp.where(cols < kv_len, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l * alpha + jnp.sum(p, axis=1)
        acc_new = acc * alpha[:, None] + jax.lax.dot_general(
            p, lat[:, :r], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)           # [H, r]
        return m_new, l_new, acc_new

    m0 = jnp.full((h,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((h,), jnp.float32)
    a0 = jnp.zeros((h, r), jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, n_pages, body, (m0, l0, a0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("r", "scale", "interpret"))
def paged_mla_decode(q: jax.Array, latent_pages: jax.Array,
                     block_tables: jax.Array, pos: jax.Array,
                     latent_new: jax.Array, *, r: int, scale: float,
                     interpret: bool = False
                     ) -> tuple[jax.Array, jax.Array]:
    """q: [B, H, width] absorbed queries concat([q_abs; q_rope]);
    latent_pages: [P, ps, Dp] (Dp >= width, first r features are ckv);
    block_tables: i32[B, maxp]; pos: i32[B]; latent_new: [B, Dp].
    Returns (ctx [B, H, r] f32, latent_pages) with the token's latent row
    written at slot ``pos`` (pool updated in place via aliasing)."""
    b, h, width = q.shape
    _, ps, dp = latent_pages.shape
    grid = (b,)

    q_spec = pl.BlockSpec((1, h, width), lambda i, *_: (i, 0, 0))
    tok_spec = pl.BlockSpec((1, dp), lambda i, *_: (i, 0))
    out_spec = pl.BlockSpec((1, h, r), lambda i, *_: (i, 0, 0))
    any_spec = pl.BlockSpec(memory_space=pltpu.ANY)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,              # block_tables, pos
        grid=grid,
        in_specs=[q_spec, tok_spec, any_spec],
        out_specs=[out_spec, any_spec],
        scratch_shapes=[
            pltpu.VMEM((2, ps, dp), latent_pages.dtype),     # double buffer
            pltpu.VMEM((1, 1, dp), latent_pages.dtype),      # staged write
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA(()),
        ],
    )
    kernel = functools.partial(_kernel, ps=ps, r=r, width=width, scale=scale)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, h, r), jnp.float32),
            jax.ShapeDtypeStruct(latent_pages.shape, latent_pages.dtype),
        ],
        # Input indices count the scalar-prefetch operands (0, 1).
        input_output_aliases={4: 1},
        interpret=interpret,
    )(block_tables, pos, q, latent_new, latent_pages)


def _kernel_quant(bt_ref, pos_ref, q_ref, ln_ref, lp_in, ls_in, o_ref,
                  lp, ls, buf, sbuf, tok, toks, dsem, ssem, wsem,
                  *, ps: int, r: int, width: int, scale: float,
                  qmax: float, qdtype):
    """Quantized twin of ``_kernel``: latent pool int8/fp8 + per-row f32
    scales [P, ps].  The token's latent row quantizes in-kernel; value and
    scale share the fused write phase, the walk DMAs each page's scale row
    alongside the page, and dequant is one multiply post-load."""
    b = pl.program_id(0)
    pos = pos_ref[b]
    kv_len = pos + 1
    n_pages = (kv_len + ps - 1) // ps

    # -- fused write: quantize the latent row, stage value + scale ----------
    page_raw = bt_ref[b, pos // ps]
    page_w = jnp.maximum(page_raw, 0)
    slot_w = pos % ps
    lq, lscale = _quantize_rows(ln_ref[0].astype(jnp.float32), qdtype, qmax)
    tok[0, 0, :] = lq
    toks[0, 0] = lscale

    @pl.when(page_raw >= 0)
    def _write():
        w = pltpu.make_async_copy(
            tok, lp.at[pl.ds(page_w, 1), pl.ds(slot_w, 1), :], wsem.at[0])
        wsc = pltpu.make_async_copy(
            toks, ls.at[pl.ds(page_w, 1), pl.ds(slot_w, 1)], wsem.at[1])
        w.start()
        wsc.start()
        w.wait()
        wsc.wait()

    # -- split-K online softmax, dequant fused into the walk ----------------
    def page_dma(i, slot):
        pg = jnp.maximum(bt_ref[b, i], 0)
        return pltpu.make_async_copy(
            lp.at[pl.ds(pg, 1)], buf.at[pl.ds(slot, 1)], dsem.at[slot])

    def scale_dma(i, slot):
        pg = jnp.maximum(bt_ref[b, i], 0)
        return pltpu.make_async_copy(
            ls.at[pl.ds(pg, 1)], sbuf.at[pl.ds(slot, 1)], ssem.at[slot])

    page_dma(0, 0).start()
    scale_dma(0, 0).start()

    q = q_ref[0].astype(jnp.float32)                      # [H, width]
    h = q.shape[0]

    def body(i, carry):
        m, l, acc = carry
        slot = jax.lax.rem(i, 2)
        nxt = jax.lax.rem(i + 1, 2)

        @pl.when(i + 1 < n_pages)
        def _prefetch():
            page_dma(i + 1, nxt).start()
            scale_dma(i + 1, nxt).start()

        page_dma(i, slot).wait()
        scale_dma(i, slot).wait()
        lat = buf[slot].astype(jnp.float32) * sbuf[slot][:, None]
        s = jax.lax.dot_general(
            q, lat[:, :width], (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # [H, ps]
        cols = i * ps + jax.lax.broadcasted_iota(jnp.int32, (1, ps), 1)
        s = jnp.where(cols < kv_len, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l * alpha + jnp.sum(p, axis=1)
        acc_new = acc * alpha[:, None] + jax.lax.dot_general(
            p, lat[:, :r], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)           # [H, r]
        return m_new, l_new, acc_new

    m0 = jnp.full((h,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((h,), jnp.float32)
    a0 = jnp.zeros((h, r), jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, n_pages, body, (m0, l0, a0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("r", "scale", "qmax", "interpret"))
def paged_mla_decode_quant(q: jax.Array, latent_pages: jax.Array,
                           latent_scales: jax.Array,
                           block_tables: jax.Array, pos: jax.Array,
                           latent_new: jax.Array, *, r: int, scale: float,
                           qmax: float, interpret: bool = False):
    """Quantized-pool MLA decode: latent_pages [P, ps, Dp] int8/fp8 with
    latent_scales [P, ps] f32; latent_new arrives FLOAT [B, Dp] and is
    quantized in-kernel.  Returns (ctx [B, H, r] f32, latent_pages,
    latent_scales) — pool + scales updated in place via aliasing."""
    b, h, width = q.shape
    _, ps, dp = latent_pages.shape
    grid = (b,)

    q_spec = pl.BlockSpec((1, h, width), lambda i, *_: (i, 0, 0))
    tok_spec = pl.BlockSpec((1, dp), lambda i, *_: (i, 0))
    out_spec = pl.BlockSpec((1, h, r), lambda i, *_: (i, 0, 0))
    any_spec = pl.BlockSpec(memory_space=pltpu.ANY)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,              # block_tables, pos
        grid=grid,
        in_specs=[q_spec, tok_spec, any_spec, any_spec],
        out_specs=[out_spec, any_spec, any_spec],
        scratch_shapes=[
            pltpu.VMEM((2, ps, dp), latent_pages.dtype),     # double buffer
            pltpu.VMEM((2, ps), jnp.float32),                # page scales
            pltpu.VMEM((1, 1, dp), latent_pages.dtype),      # staged write
            pltpu.VMEM((1, 1), jnp.float32),                 # staged scale
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((2,)),
        ],
    )
    kernel = functools.partial(_kernel_quant, ps=ps, r=r, width=width,
                               scale=scale, qmax=qmax,
                               qdtype=latent_pages.dtype)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, h, r), jnp.float32),
            jax.ShapeDtypeStruct(latent_pages.shape, latent_pages.dtype),
            jax.ShapeDtypeStruct(latent_scales.shape, latent_scales.dtype),
        ],
        # Input indices count the scalar-prefetch operands (0, 1).
        input_output_aliases={4: 1, 5: 2},
        interpret=interpret,
    )(block_tables, pos, q, latent_new, latent_pages, latent_scales)
