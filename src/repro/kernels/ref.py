"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each function is the mathematical definition with no tiling/blocking —
tests/test_kernels.py sweeps shapes and dtypes asserting the kernels match
these to tolerance.  The model zoo also uses these as its portable path (the
dry-run lowers reference math so XLA cost analysis sees the real FLOPs).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# LWW merge (the paper's coordination hot-spot)
# ---------------------------------------------------------------------------

def lww_merge(key_a: jax.Array, payload_a: jax.Array,
              key_b: jax.Array, payload_b: jax.Array
              ) -> tuple[jax.Array, jax.Array]:
    """Per-register join: winner = larger packed (clock, client) key.

    key_*: i32[K]; payload_*: [K, D] (any dtype).
    """
    b_wins = key_b > key_a
    out_key = jnp.where(b_wins, key_b, key_a)
    out_payload = jnp.where(b_wins[:, None], payload_b, payload_a)
    return out_key, out_payload


# ---------------------------------------------------------------------------
# Delta scatter-apply (delta-state sync hot path)
# ---------------------------------------------------------------------------

def delta_apply(key: jax.Array, payload: jax.Array, d_idx: jax.Array,
                d_key: jax.Array, d_payload: jax.Array
                ) -> tuple[jax.Array, jax.Array]:
    """Apply an LWW delta buffer: lane j writes register ``d_idx[j]`` iff its
    key wins.  Empty lanes carry ``d_idx = -1``; target indices must be
    unique (core/delta.py extraction guarantees it — the kernel additionally
    resolves duplicates by sequential max, which jnp scatter cannot).

    key: i32[K]; payload: [K, D]; d_idx/d_key: i32[Dc]; d_payload: [Dc, D].
    """
    k = key.shape[0]
    safe = jnp.clip(d_idx, 0, k - 1)
    wins = (d_idx >= 0) & (d_key > key[safe])
    tgt = jnp.where(wins, d_idx, k)          # losers routed out of bounds
    out_key = key.at[tgt].set(d_key, mode="drop")
    out_payload = payload.at[tgt].set(d_payload.astype(payload.dtype),
                                      mode="drop")
    return out_key, out_payload


# ---------------------------------------------------------------------------
# Per-page-row KV quantization (the quantized-pool contract)
# ---------------------------------------------------------------------------
#
# Quantized page pools store one scale per pool row within each page (MHA:
# per (page, head, slot); MLA latent: per (page, slot)), symmetric over the
# feature axis.  Writing a row quantizes it against its own abs-max; the
# kernels dequantize inside the block-table walk by multiplying each page's
# rows by its scale block.  Guarantees the property suite pins down:
#
#   * the scale is never zero (an all-zero row takes scale 1.0);
#   * int8 round-to-nearest keeps the worst-case abs error <= scale / 2;
#   * dequantize(quantize(x)) is deterministic, so snapshot/restore of the
#     (values, scales) pair is bitwise.

INT8_QMAX = 127.0
FP8_QMAX = 448.0                    # e4m3 finite max
_FP8 = getattr(jnp, "float8_e4m3fn", None)


def quant_qmax(dtype) -> float:
    """Symmetric representable max the row scale maps abs-max onto."""
    if dtype == jnp.int8:
        return INT8_QMAX
    if _FP8 is not None and dtype == _FP8:
        return FP8_QMAX
    raise ValueError(f"unsupported quantized pool dtype {dtype}")


def quantize_rows(x: jax.Array, dtype) -> tuple[jax.Array, jax.Array]:
    """Quantize rows of ``x`` ([..., D] float) along the last axis.

    Returns ``(q [..., D] dtype, scale [...] f32)`` with
    ``x ~= q * scale[..., None]``.  Scale = abs-max / qmax (1.0 for all-zero
    rows, so it is never zero); int8 rounds to nearest.
    """
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1)
    qmax = quant_qmax(dtype)
    # Multiply by the reciprocal EXPLICITLY (not amax / qmax): XLA rewrites
    # constant division into it in some compilation paths but not others;
    # the explicit form keeps oracle and Pallas-kernel scales bit-identical.
    scale = jnp.where(amax > 0, amax * np.float32(1.0 / qmax), 1.0)
    scaled = xf / scale[..., None]
    if dtype == jnp.int8:
        q = jnp.clip(jnp.round(scaled), -qmax, qmax).astype(jnp.int8)
    else:
        q = scaled.astype(dtype)
    return q, scale.astype(jnp.float32)


def dequantize_rows(q: jax.Array, scale: jax.Array) -> jax.Array:
    """Inverse of :func:`quantize_rows`: ``q [..., D] * scale [...]`` -> f32."""
    return q.astype(jnp.float32) * scale.astype(jnp.float32)[..., None]


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def _broadcast_kv(k: jax.Array, n_q_heads: int) -> jax.Array:
    """[B, Hkv, T, D] -> [B, Hq, T, D] by repeating groups (GQA)."""
    b, hkv, t, d = k.shape
    group = n_q_heads // hkv
    return jnp.repeat(k, group, axis=1)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, scale: float | None = None,
                    window: int | None = None) -> jax.Array:
    """Full-precision reference attention.

    q: [B, Hq, Tq, D]; k, v: [B, Hkv, Tk, D] (Hq % Hkv == 0).
    ``window``: optional local-attention window (keys within [i-window, i]).
    """
    b, hq, tq, d = q.shape
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    kb = _broadcast_kv(k, hq)
    vb = _broadcast_kv(v, hq)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        kb.astype(jnp.float32)) * scale
    tk = k.shape[2]
    qi = jnp.arange(tq)[:, None] + (tk - tq)   # align ends (prefill/extend)
    ki = jnp.arange(tk)[None, :]
    mask = jnp.ones((tq, tk), bool)
    if causal:
        mask &= ki <= qi
    if window is not None:
        mask &= ki >= qi - window + 1
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vb.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     kv_len: jax.Array, scale: float | None = None) -> jax.Array:
    """Single-token decode attention against a (padded) KV cache.

    q: [B, Hq, D]; k, v: [B, Hkv, S, D]; kv_len: i32[B] — valid prefix.
    """
    b, hq, d = q.shape
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    kb = _broadcast_kv(k, hq)
    vb = _broadcast_kv(v, hq)
    logits = jnp.einsum("bhd,bhsd->bhs", q.astype(jnp.float32),
                        kb.astype(jnp.float32)) * scale
    s = k.shape[2]
    mask = jnp.arange(s)[None, :] < kv_len[:, None]
    logits = jnp.where(mask[:, None, :], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhs,bhsd->bhd", p, vb.astype(jnp.float32))
    return out.astype(q.dtype)


def paged_decode_attention(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, block_tables: jax.Array,
                           pos: jax.Array, k_new: jax.Array,
                           v_new: jax.Array, scale: float | None = None,
                           window: int | None = None
                           ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Single-token decode against a paged KV cache, write included.

    q: [B, Hq, D]; k_pages, v_pages: [P, Hkv, ps, D] shared page pool;
    block_tables: i32[B, maxp] page ids per row (-1 = unallocated);
    pos: i32[B] tokens already cached; k_new, v_new: [B, Hkv, D].

    Semantics (the kernel contract): write the new token's K/V into page
    ``block_tables[b, pos // ps]`` slot ``pos % ps``, then attend over the
    row's ``pos + 1`` live tokens.  This reference gathers the row's pages
    into a contiguous view — O(B·maxp·ps) reads, the thing the kernel
    avoids — but is the bit-level definition of the math.
    """
    b, hq, d = q.shape
    _, hkv, ps, _ = k_pages.shape
    scale = scale if scale is not None else 1.0 / (d ** 0.5)

    pg_w = jnp.take_along_axis(block_tables, (pos // ps)[:, None], axis=1)[:, 0]
    # -1 must DROP, but negative scatter indices wrap in jnp — route them
    # out of bounds so mode="drop" actually discards the write.
    pg_w = jnp.where(pg_w < 0, k_pages.shape[0], pg_w)
    slot_w = pos % ps
    k_pages = k_pages.at[pg_w, :, slot_w, :].set(
        k_new.astype(k_pages.dtype), mode="drop")
    v_pages = v_pages.at[pg_w, :, slot_w, :].set(
        v_new.astype(v_pages.dtype), mode="drop")

    safe_bt = jnp.maximum(block_tables, 0)
    # [B, maxp, Hkv, ps, D] -> [B, Hkv, maxp*ps, D]
    kg = jnp.moveaxis(k_pages[safe_bt], 2, 1).reshape(b, hkv, -1, d)
    vg = jnp.moveaxis(v_pages[safe_bt], 2, 1).reshape(b, hkv, -1, d)
    kb = _broadcast_kv(kg, hq)
    vb = _broadcast_kv(vg, hq)
    logits = jnp.einsum("bhd,bhsd->bhs", q.astype(jnp.float32),
                        kb.astype(jnp.float32)) * scale
    cols = jnp.arange(kg.shape[2])[None, :]
    valid = cols < (pos + 1)[:, None]
    if window is not None:
        valid &= cols > (pos - window)[:, None]
    logits = jnp.where(valid[:, None, :], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhs,bhsd->bhd", p, vb.astype(jnp.float32))
    return out.astype(q.dtype), k_pages, v_pages


def paged_chunk_attention(q: jax.Array, k_pages: jax.Array,
                          v_pages: jax.Array, block_tables: jax.Array,
                          start: jax.Array, span: jax.Array,
                          k_new: jax.Array, v_new: jax.Array,
                          scale: float | None = None,
                          window: int | None = None
                          ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Chunked mixed-step attention against a paged KV cache, writes included.

    q: [B, Hq, C, D] per-row query spans; k_pages, v_pages: [P, Hkv, ps, D]
    shared page pool; block_tables: i32[B, maxp]; start: i32[B] tokens
    already cached per row; span: i32[B] valid new tokens in [0, C];
    k_new, v_new: [B, Hkv, C, D] the span's K/V.

    Semantics (the kernel contract): write the span's K/V into pages
    ``block_tables[b, (start+j) // ps]`` slot ``(start+j) % ps`` for
    j < span[b], then each query j attends over the row's ``start + j + 1``
    live tokens (causal within the span, whole cached prefix before it).
    Rows with span 0 write nothing and return garbage.  Because the span is
    written *before* the attend, every query's math depends only on (query
    position, cached prefix) — chunk partitioning cannot change the bits,
    which is what makes chunked admission ≡ one-shot prefill.
    """
    b, hq, c, d = q.shape
    num_pages, hkv, ps, _ = k_pages.shape
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    maxp = block_tables.shape[1]

    tpos = start[:, None] + jnp.arange(c, dtype=jnp.int32)[None, :]  # [B, C]
    pg = jnp.take_along_axis(block_tables,
                             jnp.clip(tpos // ps, 0, maxp - 1), axis=1)
    # Dropped writes are routed OUT OF BOUNDS (= num_pages): unallocated
    # (-1) table entries, positions past the table, and chunk padding
    # beyond each row's span.
    pg = jnp.where(pg < 0, num_pages, pg)
    pg = jnp.where(tpos < maxp * ps, pg, num_pages)
    pg = jnp.where(jnp.arange(c)[None, :] < span[:, None], pg, num_pages)
    slot = tpos % ps
    k_bt = k_new.transpose(0, 2, 1, 3).astype(k_pages.dtype)  # [B, C, Hkv, D]
    v_bt = v_new.transpose(0, 2, 1, 3).astype(v_pages.dtype)
    k_pages = k_pages.at[pg, :, slot, :].set(k_bt, mode="drop")
    v_pages = v_pages.at[pg, :, slot, :].set(v_bt, mode="drop")

    safe_bt = jnp.maximum(block_tables, 0)
    # [B, maxp, Hkv, ps, D] -> [B, Hkv, maxp*ps, D]
    kg = jnp.moveaxis(k_pages[safe_bt], 2, 1).reshape(b, hkv, -1, d)
    vg = jnp.moveaxis(v_pages[safe_bt], 2, 1).reshape(b, hkv, -1, d)
    kb = _broadcast_kv(kg, hq)
    vb = _broadcast_kv(vg, hq)
    logits = jnp.einsum("bhcd,bhsd->bhcs", q.astype(jnp.float32),
                        kb.astype(jnp.float32)) * scale
    cols = jnp.arange(kg.shape[2])[None, None, :]
    valid = cols <= tpos[:, :, None]                    # causal to query pos
    if window is not None:
        valid &= cols > (tpos[:, :, None] - window)
    logits = jnp.where(valid[:, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhcs,bhsd->bhcd", p, vb.astype(jnp.float32))
    return out.astype(q.dtype), k_pages, v_pages


def paged_mla_chunk(q_abs: jax.Array, q_rope: jax.Array,
                    latent_pages: jax.Array, block_tables: jax.Array,
                    start: jax.Array, span: jax.Array,
                    latent_new: jax.Array, *, r: int, scale: float
                    ) -> tuple[jax.Array, jax.Array]:
    """Chunked mixed-step MLA decode against a paged latent cache.

    q_abs: [B, H, C, r] absorbed queries; q_rope: [B, H, C, rd];
    latent_pages: [P, ps, Dp]; block_tables: i32[B, maxp]; start/span:
    i32[B]; latent_new: [B, C, Dp].  Same write-then-attend contract as
    ``paged_chunk_attention``, same absorbed-weight contractions as
    ``paged_mla_decode`` (to which it degenerates at span == 1).
    """
    b, h, c, _ = q_abs.shape
    num_pages, ps, dp = latent_pages.shape
    rd = q_rope.shape[-1]
    maxp = block_tables.shape[1]

    tpos = start[:, None] + jnp.arange(c, dtype=jnp.int32)[None, :]
    pg = jnp.take_along_axis(block_tables,
                             jnp.clip(tpos // ps, 0, maxp - 1), axis=1)
    pg = jnp.where(pg < 0, num_pages, pg)
    pg = jnp.where(tpos < maxp * ps, pg, num_pages)
    pg = jnp.where(jnp.arange(c)[None, :] < span[:, None], pg, num_pages)
    slot = tpos % ps
    latent_pages = latent_pages.at[pg, slot, :].set(
        latent_new.astype(latent_pages.dtype), mode="drop")

    safe_bt = jnp.maximum(block_tables, 0)
    lg = latent_pages[safe_bt].reshape(b, -1, dp)        # [B, maxp*ps, Dp]
    ckv_g = lg[..., :r]
    krope_g = lg[..., r:r + rd]
    logits = (jnp.einsum("bhcr,bsr->bhcs", q_abs, ckv_g,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bhcr,bsr->bhcs", q_rope, krope_g,
                           preferred_element_type=jnp.float32)) * scale
    valid = jnp.arange(lg.shape[1])[None, None, :] <= tpos[:, :, None]
    logits = jnp.where(valid[:, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    ctx = jnp.einsum("bhcs,bsr->bhcr", probs, ckv_g.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    return ctx, latent_pages


def paged_mla_decode(q_abs: jax.Array, q_rope: jax.Array,
                     latent_pages: jax.Array, block_tables: jax.Array,
                     pos: jax.Array, latent_new: jax.Array, *,
                     r: int, scale: float
                     ) -> tuple[jax.Array, jax.Array]:
    """Single-token MLA decode against a paged latent cache, write included.

    q_abs: [B, H, r] absorbed queries; q_rope: [B, H, rd];
    latent_pages: [P, ps, Dp] pool storing concat([ckv; krope]) rows in the
    first ``r + rd`` features (Dp is lane-padded); block_tables: i32[B, maxp];
    pos: i32[B]; latent_new: [B, Dp].

    Gathers the row's pages into logical-position order and then runs the
    *identical* contractions as the dense absorbed-weight decode
    (mla.decode_step): same einsums, same fp32 promotion, same masking —
    bit-for-bit with the dense oracle whenever maxp·ps == the dense S.
    """
    b, h, _ = q_abs.shape
    _, ps, dp = latent_pages.shape
    rd = q_rope.shape[-1]

    pg_w = jnp.take_along_axis(block_tables, (pos // ps)[:, None], axis=1)[:, 0]
    # -1 must DROP, but negative scatter indices wrap in jnp — route them
    # out of bounds so mode="drop" actually discards the write.
    pg_w = jnp.where(pg_w < 0, latent_pages.shape[0], pg_w)
    slot_w = pos % ps
    latent_pages = latent_pages.at[pg_w, slot_w, :].set(
        latent_new.astype(latent_pages.dtype), mode="drop")

    safe_bt = jnp.maximum(block_tables, 0)
    lg = latent_pages[safe_bt].reshape(b, -1, dp)        # [B, maxp*ps, Dp]
    ckv_g = lg[..., :r]
    krope_g = lg[..., r:r + rd]
    logits = (jnp.einsum("bhr,bsr->bhs", q_abs, ckv_g,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bhr,bsr->bhs", q_rope, krope_g,
                           preferred_element_type=jnp.float32)) * scale
    valid = jnp.arange(lg.shape[1])[None, :] <= pos[:, None]
    logits = jnp.where(valid[:, None, :], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    ctx = jnp.einsum("bhs,bsr->bhr", probs, ckv_g.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    return ctx, latent_pages


# ---------------------------------------------------------------------------
# Quantized paged attention oracles
# ---------------------------------------------------------------------------
#
# Each quantized oracle is its fp32 oracle with the write quantized and the
# gather dequantized: the token/span rows are quantized per row
# (quantize_rows), the int8/fp8 values and their scales land in the pools,
# and the attend runs the IDENTICAL fp32 math over the dequantized pools.
# Tolerance vs the fp32 path is therefore exactly the per-row quantization
# error (<= scale/2 per element for int8), never a different softmax.

def paged_decode_attention_quant(q, k_pages, k_scales, v_pages, v_scales,
                                 block_tables, pos, k_new, v_new, *,
                                 scale=None, window=None):
    """Quantized ``paged_decode_attention``: pools [P, Hkv, ps, D] int8/fp8
    + scales [P, Hkv, ps]; k/v_new arrive float and are quantized into slot
    ``pos``.  Returns (out, k_pages, v_pages, k_scales, v_scales)."""
    ps = k_pages.shape[2]
    kq, ks = quantize_rows(k_new, k_pages.dtype)         # [B,Hkv,D],[B,Hkv]
    vq, vs = quantize_rows(v_new, v_pages.dtype)
    pg_w = jnp.take_along_axis(block_tables, (pos // ps)[:, None],
                               axis=1)[:, 0]
    pg_w = jnp.where(pg_w < 0, k_pages.shape[0], pg_w)
    slot_w = pos % ps
    k_pages = k_pages.at[pg_w, :, slot_w, :].set(kq, mode="drop")
    v_pages = v_pages.at[pg_w, :, slot_w, :].set(vq, mode="drop")
    k_scales = k_scales.at[pg_w, :, slot_w].set(ks, mode="drop")
    v_scales = v_scales.at[pg_w, :, slot_w].set(vs, mode="drop")
    out, _, _ = paged_decode_attention(
        q, dequantize_rows(k_pages, k_scales),
        dequantize_rows(v_pages, v_scales), block_tables, pos,
        dequantize_rows(kq, ks), dequantize_rows(vq, vs),
        scale=scale, window=window)
    return out, k_pages, v_pages, k_scales, v_scales


def paged_chunk_attention_quant(q, k_pages, k_scales, v_pages, v_scales,
                                block_tables, start, span, k_new, v_new, *,
                                scale=None, window=None):
    """Quantized ``paged_chunk_attention``: the span's K/V rows quantize per
    (row, token, head); returns (out, k_pages, v_pages, k_scales,
    v_scales)."""
    c = q.shape[2]
    num_pages, _, ps, _ = k_pages.shape
    maxp = block_tables.shape[1]
    kq, ks = quantize_rows(k_new.transpose(0, 2, 1, 3), k_pages.dtype)
    vq, vs = quantize_rows(v_new.transpose(0, 2, 1, 3), v_pages.dtype)
    tpos = start[:, None] + jnp.arange(c, dtype=jnp.int32)[None, :]
    pg = jnp.take_along_axis(block_tables,
                             jnp.clip(tpos // ps, 0, maxp - 1), axis=1)
    pg = jnp.where(pg < 0, num_pages, pg)
    pg = jnp.where(tpos < maxp * ps, pg, num_pages)
    pg = jnp.where(jnp.arange(c)[None, :] < span[:, None], pg, num_pages)
    slot = tpos % ps
    k_pages = k_pages.at[pg, :, slot, :].set(kq, mode="drop")
    v_pages = v_pages.at[pg, :, slot, :].set(vq, mode="drop")
    k_scales = k_scales.at[pg, :, slot].set(ks, mode="drop")
    v_scales = v_scales.at[pg, :, slot].set(vs, mode="drop")
    out, _, _ = paged_chunk_attention(
        q, dequantize_rows(k_pages, k_scales),
        dequantize_rows(v_pages, v_scales), block_tables, start, span,
        dequantize_rows(kq, ks).transpose(0, 2, 1, 3),
        dequantize_rows(vq, vs).transpose(0, 2, 1, 3),
        scale=scale, window=window)
    return out, k_pages, v_pages, k_scales, v_scales


def paged_mla_chunk_quant(q_abs, q_rope, latent_pages, latent_scales,
                          block_tables, start, span, latent_new, *,
                          r: int, scale: float):
    """Quantized ``paged_mla_chunk``: latent pool [P, ps, Dp] int8/fp8 +
    scales [P, ps]; returns (ctx, latent_pages, latent_scales)."""
    c = latent_new.shape[1]
    num_pages, ps, _ = latent_pages.shape
    maxp = block_tables.shape[1]
    lq, ls = quantize_rows(latent_new, latent_pages.dtype)   # [B,C,Dp],[B,C]
    tpos = start[:, None] + jnp.arange(c, dtype=jnp.int32)[None, :]
    pg = jnp.take_along_axis(block_tables,
                             jnp.clip(tpos // ps, 0, maxp - 1), axis=1)
    pg = jnp.where(pg < 0, num_pages, pg)
    pg = jnp.where(tpos < maxp * ps, pg, num_pages)
    pg = jnp.where(jnp.arange(c)[None, :] < span[:, None], pg, num_pages)
    slot = tpos % ps
    latent_pages = latent_pages.at[pg, slot, :].set(lq, mode="drop")
    latent_scales = latent_scales.at[pg, slot].set(ls, mode="drop")
    ctx, _ = paged_mla_chunk(
        q_abs, q_rope, dequantize_rows(latent_pages, latent_scales),
        block_tables, start, span, dequantize_rows(lq, ls),
        r=r, scale=scale)
    return ctx, latent_pages, latent_scales


def paged_mla_decode_quant(q_abs, q_rope, latent_pages, latent_scales,
                           block_tables, pos, latent_new, *,
                           r: int, scale: float):
    """Quantized ``paged_mla_decode``: the token's latent row quantizes into
    slot ``pos``; returns (ctx, latent_pages, latent_scales)."""
    ps = latent_pages.shape[1]
    lq, ls = quantize_rows(latent_new, latent_pages.dtype)   # [B,Dp],[B]
    pg_w = jnp.take_along_axis(block_tables, (pos // ps)[:, None],
                               axis=1)[:, 0]
    pg_w = jnp.where(pg_w < 0, latent_pages.shape[0], pg_w)
    slot_w = pos % ps
    latent_pages = latent_pages.at[pg_w, slot_w, :].set(lq, mode="drop")
    latent_scales = latent_scales.at[pg_w, slot_w].set(ls, mode="drop")
    ctx, _ = paged_mla_decode(
        q_abs, q_rope, dequantize_rows(latent_pages, latent_scales),
        block_tables, pos, dequantize_rows(lq, ls), r=r, scale=scale)
    return ctx, latent_pages, latent_scales


# ---------------------------------------------------------------------------
# Diagonal gated linear recurrence (RG-LRU / generic h_t = a_t h_{t-1} + b_t)
# ---------------------------------------------------------------------------

def linear_scan(a: jax.Array, b: jax.Array, h0: jax.Array) -> jax.Array:
    """h_t = a_t ⊙ h_{t-1} + b_t, returned for all t.  a,b: [B,T,D]; h0: [B,D]."""
    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    a_t = jnp.moveaxis(a.astype(jnp.float32), 1, 0)
    b_t = jnp.moveaxis(b.astype(jnp.float32), 1, 0)
    _, hs = jax.lax.scan(step, h0.astype(jnp.float32), (a_t, b_t))
    return jnp.moveaxis(hs, 0, 1).astype(b.dtype)


def rglru(x: jax.Array, input_gate: jax.Array, rec_gate: jax.Array,
          log_lambda: jax.Array, h0: jax.Array, c: float = 8.0
          ) -> tuple[jax.Array, jax.Array]:
    """Griffin RG-LRU (arXiv:2402.19427 eq. 3-4).

    x, input_gate, rec_gate: [B, T, D] (gates pre-activation);
    log_lambda: [D] (learnt, param is softplus-domain); h0: [B, D].
    Returns (y [B,T,D], h_T [B,D]).
    """
    i_t = jax.nn.sigmoid(input_gate.astype(jnp.float32))
    r_t = jax.nn.sigmoid(rec_gate.astype(jnp.float32))
    log_a = -c * r_t * jax.nn.softplus(log_lambda.astype(jnp.float32))[None, None, :]
    a_t = jnp.exp(log_a)
    gated_x = i_t * x.astype(jnp.float32)
    b_t = jnp.sqrt(jnp.clip(1.0 - a_t ** 2, 1e-9)) * gated_x
    hs = linear_scan(a_t, b_t, h0)
    return hs.astype(x.dtype), hs[:, -1].astype(jnp.float32)


# ---------------------------------------------------------------------------
# Speculative-decoding verify path (span acceptance + rollback oracles)
# ---------------------------------------------------------------------------

def speculative_accept(preds: jax.Array, tokens: jax.Array,
                       span: jax.Array) -> jax.Array:
    """Greedy longest-accepted-prefix count per row.

    preds: i32[B, C] argmax at every span position (verify-mode mixed
    step); tokens: i32[B, C] the span that was fed, ``tokens[b] =
    [last_committed, d_1 .. d_m, pad]``; span: i32[B] = 1 + m.

    Draft token d_{j+1} is accepted iff every earlier draft was and the
    verifier's argmax after span position j reproduces it:
    ``preds[b, j] == tokens[b, j+1]``.  Returned count is in [0, m];
    rows with span <= 1 (plain decode / admission / idle) count 0.
    The *bonus* token ``preds[b, accepted[b]]`` is by construction the
    token non-speculative greedy decode would emit next, so acceptance
    plus bonus is token-identical to unspeculated decoding.
    """
    b, c = tokens.shape
    if c == 1:
        return jnp.zeros((b,), jnp.int32)
    ok = (preds[:, :-1] == tokens[:, 1:]) \
        & (jnp.arange(c - 1, dtype=jnp.int32)[None, :] < span[:, None] - 1)
    return jnp.where(ok.all(axis=1), c - 1,
                     jnp.argmin(ok, axis=1)).astype(jnp.int32)


def paged_span_gather(pool: jax.Array, block_tables: jax.Array,
                      start: jax.Array, width: int,
                      slot_axis: int | None = None) -> jax.Array:
    """Snapshot the pool slots a mixed-step write window covers.

    ``out[b, w] = pool[block_tables[b, (start[b]+w) // ps], ...,
    (start[b]+w) % ps, ...]`` — the pre-verify bytes of every slot a span
    write at [start, start+width) could touch.  pool: [P, Hkv, ps, D]
    (MHA K/V, slot axis 2) or [P, ps, Dp] (MLA latent, slot axis 1).
    Quantized scale leaves drop the trailing feature axis but keep the
    slot axis: [P, Hkv, ps] (MHA scales, slot axis 2) or [P, ps] (MLA
    scales, slot axis 1) — pass ``slot_axis`` explicitly for those.
    Positions past the table / unallocated (-1) entries are clamped; their
    lanes hold garbage and are masked out by ``paged_span_restore``.
    """
    if slot_axis is None:
        slot_axis = 2 if pool.ndim == 4 else 1
    ps = pool.shape[slot_axis]
    maxp = block_tables.shape[-1]
    tpos = start[:, None] + jnp.arange(width, dtype=jnp.int32)[None, :]
    pg = jnp.take_along_axis(block_tables,
                             jnp.clip(tpos // ps, 0, maxp - 1), axis=1)
    pg = jnp.clip(pg, 0, pool.shape[0] - 1)
    slot = tpos % ps
    if slot_axis == 2:
        return pool[pg, :, slot]             # [B, W, Hkv, (D)]
    return pool[pg, slot]                    # [B, W, (Dp)]


def paged_span_restore(pool: jax.Array, snap: jax.Array,
                       block_tables: jax.Array, start: jax.Array,
                       lo: jax.Array, hi: jax.Array,
                       slot_axis: int | None = None) -> jax.Array:
    """Rejected-tail rollback: scatter ``snap`` (from paged_span_gather,
    same ``start``) back for positions in [lo[b], hi[b]).

    Lanes outside the per-row window — accepted positions, rows that
    drafted nothing (lo == hi), positions past the table, unallocated
    entries — are routed out of bounds and dropped, so committed slots
    keep the verify step's writes bit-for-bit while the rejected tail
    reverts to its pre-verify bytes.  ``slot_axis`` as in
    ``paged_span_gather`` (pass explicitly for scale leaves).
    """
    if slot_axis is None:
        slot_axis = 2 if pool.ndim == 4 else 1
    ps = pool.shape[slot_axis]
    maxp = block_tables.shape[-1]
    width = snap.shape[1]
    tpos = start[:, None] + jnp.arange(width, dtype=jnp.int32)[None, :]
    keep = (tpos >= lo[:, None]) & (tpos < hi[:, None])
    keep &= tpos // ps < maxp
    pg = jnp.take_along_axis(block_tables,
                             jnp.clip(tpos // ps, 0, maxp - 1), axis=1)
    keep &= pg >= 0
    tgt = jnp.where(keep, jnp.clip(pg, 0, pool.shape[0] - 1),
                    pool.shape[0])
    slot = tpos % ps
    if slot_axis == 2:
        return pool.at[tgt, :, slot].set(snap.astype(pool.dtype),
                                         mode="drop")
    return pool.at[tgt, slot].set(snap.astype(pool.dtype), mode="drop")


def page_transfer(src_pool: jax.Array, dst_pool: jax.Array,
                  src_ids: jax.Array, dst_ids: jax.Array) -> jax.Array:
    """Cross-pool page-row transfer oracle: lane i copies
    ``src_pool[src_ids[i]]`` into ``dst_pool[dst_ids[i]]``; -1 on either
    side drops the lane.  Pure gather + mode="drop" scatter, so the moved
    rows are bitwise for any pool dtype and the rest of ``dst_pool`` is
    untouched.  Pools need the same row shape/dtype but may differ in
    page count.
    """
    p_src = src_pool.shape[0]
    rows = src_pool[jnp.clip(src_ids, 0, p_src - 1)]
    keep = (src_ids >= 0) & (dst_ids >= 0) & (dst_ids < dst_pool.shape[0])
    tgt = jnp.where(keep, jnp.clip(dst_ids, 0, dst_pool.shape[0] - 1),
                    dst_pool.shape[0])
    return dst_pool.at[tgt].set(rows, mode="drop")
