"""Pallas TPU kernel: batched cross-pool page-row transfer (gather-scatter).

The disaggregated serving path moves *physical* page bytes between two
engines' pools: a prefill replica fills pages and publishes them; a decode
replica adopts the bytes instead of recomputing the prefix.  Per transfer a
batch of page rows moves ``src_pool[src_ids[i]] -> dst_pool[dst_ids[i]]``.

The copy is pure DMA — no compute touches the rows, so the transfer is
bitwise for every pool dtype (bf16/f32 KV rows, int8/fp8 quantized rows,
f32 scale rows) by construction.  Each grid program stages one page row
HBM -> VMEM -> HBM with double-buffered DMA so lane i+1's read overlaps
lane i's write.  Both pools are ANY-space (HBM) refs; the destination pool
is aliased input -> output, so XLA updates it in place and the moved rows
are the only destination bytes that change.

Negative ids drop the lane (same semantics as ``cache.copy_pages`` and the
oracle's mode="drop" scatter), so callers pad the transfer batch to a fixed
width with -1 and keep one compiled kernel per pool shape.

Alignment: on real TPU the pool row must be tileable (the ops wrapper
validates page_size against the dtype's sublane count and the trailing dim
against the 128-lane width); off-TPU the kernel runs in interpret mode at
any shape.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(src_ref, dst_ref, src_pool_in, dst_pool_in, dst_pool, buf, sem,
            *, num_src: int, num_dst: int):
    i = pl.program_id(0)
    n = pl.num_programs(0)
    slot = jax.lax.rem(i, 2)

    def row_read(lane, buf_slot):
        pg = jnp.clip(src_ref[lane], 0, num_src - 1)
        return pltpu.make_async_copy(
            src_pool_in.at[pl.ds(pg, 1)], buf.at[pl.ds(buf_slot, 1)],
            sem.at[buf_slot])

    def lane_live(lane):
        return (src_ref[lane] >= 0) & (dst_ref[lane] >= 0) \
            & (dst_ref[lane] < num_dst)

    # Lane 0's read is issued by program 0; every later program issued its
    # own read as the "prefetch" of the previous program, so steady state
    # overlaps lane i's write-back with lane i+1's read.
    @pl.when((i == 0) & lane_live(0))
    def _first():
        row_read(0, 0).start()

    @pl.when((i + 1 < n) & lane_live(i + 1))
    def _prefetch():
        row_read(i + 1, jax.lax.rem(i + 1, 2)).start()

    @pl.when(lane_live(i))
    def _move():
        row_read(i, slot).wait()
        dst = dst_ref[i]
        wr = pltpu.make_async_copy(
            buf.at[pl.ds(slot, 1)], dst_pool.at[pl.ds(dst, 1)],
            sem.at[slot])
        wr.start()
        wr.wait()


@functools.partial(jax.jit, static_argnames=("interpret",))
def page_transfer(src_pool: jax.Array, dst_pool: jax.Array,
                  src_ids: jax.Array, dst_ids: jax.Array, *,
                  interpret: bool = False) -> jax.Array:
    """src_pool: [Ps, ...row]; dst_pool: [Pd, ...row] (same row shape and
    dtype); src_ids/dst_ids: i32[N] (lane i copies row src_ids[i] into row
    dst_ids[i]; -1 on either side drops the lane).  Returns the updated
    destination pool (in place on TPU via aliasing)."""
    n = src_ids.shape[0]
    row = src_pool.shape[1:]
    kernel = functools.partial(_kernel, num_src=src_pool.shape[0],
                               num_dst=dst_pool.shape[0])
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,              # src_ids, dst_ids
        grid=(n,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY),
                  pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=pl.BlockSpec(memory_space=pltpu.ANY),
        scratch_shapes=[
            pltpu.VMEM((2,) + row, src_pool.dtype),     # staging double-buffer
            pltpu.SemaphoreType.DMA((2,)),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(dst_pool.shape, dst_pool.dtype),
        # Input indices count the scalar-prefetch operands (0, 1).
        input_output_aliases={3: 0},
        interpret=interpret,
    )(src_ids, dst_ids, src_pool, dst_pool)
