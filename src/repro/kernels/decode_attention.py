"""Pallas TPU kernel: flash-decode attention (single-token query).

Decode is HBM-bandwidth-bound: one query row must stream the whole KV cache.
The kernel splits the KV length across the innermost grid axis (split-K),
keeping per-tile partial online-softmax state (m, l, acc) in VMEM scratch and
normalizing on the final tile — so the cache is read exactly once at full
bandwidth and no [S]-sized logits buffer ever exists in HBM.

Padding rows (>= kv_len) are masked with a per-(batch,head) valid length
passed as a tiny i32 input block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                   *, scale: float, bs: int, ns: int):
    isb = pl.program_id(1)

    @pl.when(isb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    kv_len = len_ref[0]
    s_start = isb * bs

    @pl.when(s_start < kv_len)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                    # [1, D] row
        k = k_ref[0].astype(jnp.float32)                    # [Bs, D]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale     # [1, Bs]
        cols = s_start + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
        s = jnp.where(cols < kv_len, s, NEG_INF)
        m_prev = m_scr[...]                                 # [1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(isb == ns - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("scale", "block_s", "interpret", "num_q_heads"))
def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     kv_len: jax.Array, *, scale: float, num_q_heads: int,
                     block_s: int = 512, interpret: bool = False) -> jax.Array:
    """q: [BHq, 1, D]; k, v: [BHkv, S, D]; kv_len: i32[BHq] (valid prefix)."""
    bhq, _, d = q.shape
    bhkv, s_pad, _ = k.shape
    batch = bhq // num_q_heads
    num_kv_heads = bhkv // batch
    group = num_q_heads // num_kv_heads
    if s_pad % block_s:
        # The grid would silently drop the tail s_pad % block_s slots —
        # tokens in them would never be attended.  Callers must pad S
        # (ops.decode_attention does) or pick a dividing block_s.
        raise ValueError(
            f"decode_attention: KV length {s_pad} is not a multiple of "
            f"block_s={block_s}; pad the cache or choose a dividing block_s")
    ns = s_pad // block_s
    grid = (bhq, ns)

    def kv_row(bh):
        b = bh // num_q_heads
        h = bh % num_q_heads
        return b * num_kv_heads + h // group

    len_spec = pl.BlockSpec((1,), lambda bh, isb: (bh,))
    q_spec = pl.BlockSpec((1, 1, d), lambda bh, isb: (bh, 0, 0))
    kv_spec = pl.BlockSpec((1, block_s, d), lambda bh, isb: (kv_row(bh), isb, 0))
    o_spec = pl.BlockSpec((1, 1, d), lambda bh, isb: (bh, 0, 0))

    kernel = functools.partial(_decode_kernel, scale=scale, bs=block_s, ns=ns)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[len_spec, q_spec, kv_spec, kv_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
        interpret=interpret,
    )(kv_len, q, k, v)
