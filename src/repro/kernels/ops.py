"""Jit'd public wrappers around the Pallas kernels.

Handles padding to TPU-aligned block shapes, layout flattening, backend
dispatch (interpret=True off-TPU so kernels execute correctly on CPU), and
an escape hatch to the pure-jnp reference path (used by the dry-run so XLA
cost analysis sees portable HLO).

    from repro.kernels import ops
    out = ops.flash_attention(q, k, v, causal=True)          # [B,H,T,D]
    out = ops.decode_attention(q, k, v, kv_len)              # [B,H,D]
    key, pay = ops.lww_merge(key_a, pay_a, key_b, pay_b)
    h, h_T  = ops.linear_scan(a, b, h0)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import decode_attention as _dec
from repro.kernels import delta_apply as _da
from repro.kernels import flash_attention as _fa
from repro.kernels import lww_merge as _lww
from repro.kernels import page_transfer as _pxfer
from repro.kernels import paged_chunk_attention as _pchunk
from repro.kernels import paged_decode_attention as _pdec
from repro.kernels import paged_mla_decode as _pmla
from repro.kernels import ref
from repro.kernels import rglru_scan as _rg


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# Minimum sublane count per pool dtype (second-to-last dim of the TPU tile;
# the lane dim is always 128).  f32 default 8; narrow dtypes pack more rows.
_SUBLANE = {jnp.dtype(jnp.bfloat16): 16, jnp.dtype(jnp.int8): 32}
_FP8 = getattr(jnp, "float8_e4m3fn", None)
if _FP8 is not None:
    _SUBLANE[jnp.dtype(_FP8)] = 32


def _check_tileable(kernel: str, dtype, **dims) -> None:
    """Shared TPU tileability guard for the paged kernels (the pool is
    deliberately never padded per step, so it must be tileable at init).

    ``dims`` maps dimension names to (size, multiple); pass the pool's
    ``page_size`` with multiple=None to check it against the dtype's
    sublane count, and lane dims (head_dim / pool width) with multiple=128.
    Raises naming the offending kernel and dimension.
    """
    sublane = _SUBLANE.get(jnp.dtype(dtype), 8)
    bad = []
    for name, (size, mult) in dims.items():
        mult = sublane if mult is None else mult
        if size % mult:
            bad.append(f"{name}={size} must be a multiple of {mult}")
    if bad:
        raise ValueError(
            f"{kernel}: paged cache layout is not TPU-tileable for "
            f"{jnp.dtype(dtype).name} pools: " + "; ".join(bad) + ". "
            "Pick aligned shapes at init_cache time — the pool is "
            "deliberately never padded per step.")


def _pad_to(x: jax.Array, axis: int, mult: int, value=0) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def lww_merge(key_a, pay_a, key_b, pay_b, *, block_k: int = 1024,
              use_pallas: bool = True):
    """key: i32[K]; payload: [K, D] — see kernels/lww_merge.py."""
    if not use_pallas:
        return ref.lww_merge(key_a, pay_a, key_b, pay_b)
    k = key_a.shape[0]
    blk = min(block_k, max(128, 1 << (k - 1).bit_length()))
    ka = _pad_to(key_a, 0, blk, value=np.iinfo(np.int32).min)
    kb = _pad_to(key_b, 0, blk, value=np.iinfo(np.int32).min)
    pa = _pad_to(_pad_to(pay_a, 0, blk), 1, 128)
    pb = _pad_to(_pad_to(pay_b, 0, blk), 1, 128)
    ok, op = _lww.lww_merge(ka, pa, kb, pb, block_k=blk,
                            interpret=not _on_tpu())
    return ok[:k], op[:k, :pay_a.shape[1]]


def delta_apply(key, pay, d_idx, d_key, d_pay, *, block_k: int = 1024,
                use_pallas: bool = True):
    """Scatter-apply an LWW delta buffer — see kernels/delta_apply.py.

    key: i32[K]; pay: [K, D]; d_idx/d_key: i32[Dc]; d_pay: [Dc, D].
    Empty delta lanes hold d_idx = -1.
    """
    if not use_pallas:
        return ref.delta_apply(key, pay, d_idx, d_key, d_pay)
    k = key.shape[0]
    # Clamp to >= 128 (TPU lane width): the kernel's blocks must stay
    # 128-aligned even for caller-supplied smaller block_k.
    blk = max(128, min(block_k, 1 << (k - 1).bit_length()))
    kk = _pad_to(key, 0, blk, value=np.iinfo(np.int32).min)
    pp = _pad_to(_pad_to(pay, 0, blk), 1, 128)
    # Padded delta lanes target row -1: they can never match a register.
    di = _pad_to(d_idx, 0, 8, value=-1)
    dk = _pad_to(d_key, 0, 8, value=0)
    dp = _pad_to(_pad_to(d_pay, 0, 8), 1, 128)
    ok, op = _da.delta_apply(kk, pp, di, dk, dp, block_k=blk,
                             interpret=not _on_tpu())
    return ok[:k], op[:k, :pay.shape[1]]


def flash_attention(q, k, v, *, causal: bool = True, scale: float | None = None,
                    window: int | None = None, block_q: int = 256,
                    block_k: int = 256, use_pallas: bool = True):
    """q: [B, Hq, Tq, D]; k, v: [B, Hkv, Tk, D] -> [B, Hq, Tq, D]."""
    if not use_pallas:
        return ref.flash_attention(q, k, v, causal=causal, scale=scale,
                                   window=window)
    b, hq, tq, d = q.shape
    _, hkv, tk, _ = k.shape
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    bq = min(block_q, max(128, 1 << (tq - 1).bit_length()))
    bk = min(block_k, max(128, 1 << (tk - 1).bit_length()))
    qf = _pad_to(_pad_to(q.reshape(b * hq, tq, d), 1, bq), 2, 128)
    kf = _pad_to(_pad_to(k.reshape(b * hkv, tk, d), 1, bk), 2, 128)
    vf = _pad_to(_pad_to(v.reshape(b * hkv, tk, d), 1, bk), 2, 128)
    # Padded query rows produce garbage and are sliced away below.
    out = _fa.flash_attention(
        qf, kf, vf, causal=causal, scale=scale, window=window,
        num_q_heads=hq, tq_true=tq, tk_true=tk,
        block_q=bq, block_k=bk, interpret=not _on_tpu())
    return out[:, :tq, :d].reshape(b, hq, tq, d)


def decode_attention(q, k, v, kv_len, *, scale: float | None = None,
                     block_s: int = 512, use_pallas: bool = True):
    """q: [B, Hq, D]; k, v: [B, Hkv, S, D]; kv_len: i32[B] -> [B, Hq, D]."""
    if not use_pallas:
        return ref.decode_attention(q, k, v, kv_len, scale=scale)
    b, hq, d = q.shape
    _, hkv, s, _ = k.shape
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    bs = min(block_s, max(128, 1 << (s - 1).bit_length()))
    qf = _pad_to(q.reshape(b * hq, 1, d), 2, 128)
    kf = _pad_to(_pad_to(k.reshape(b * hkv, s, d), 1, bs), 2, 128)
    vf = _pad_to(_pad_to(v.reshape(b * hkv, s, d), 1, bs), 2, 128)
    len_f = jnp.repeat(kv_len.astype(jnp.int32), hq)
    out = _dec.decode_attention(
        qf, kf, vf, len_f, scale=scale, num_q_heads=hq, block_s=bs,
        interpret=not _on_tpu())
    return out[:, 0, :d].reshape(b, hq, d)


def paged_decode_attention(q, k_pages, v_pages, block_tables, pos,
                           k_new, v_new, *, scale: float | None = None,
                           window: int | None = None,
                           use_pallas: bool = True):
    """Fused write-attend decode over a paged KV cache.

    q: [B, Hq, D]; k_pages, v_pages: [P, Hkv, ps, D]; block_tables:
    i32[B, maxp]; pos: i32[B]; k_new, v_new: [B, Hkv, D].
    Returns (out [B, Hq, D], k_pages, v_pages) — pools carry the new token
    at slot ``pos`` (in place on TPU via input/output aliasing).

    Unlike the dense wrappers this one never pads the pool: a pad/slice
    round-trip would copy the whole cache every step, which is exactly the
    cost the paged path removes.  On TPU the pool must therefore already be
    tileable; off-TPU the kernel runs in interpret mode at any shape.
    """
    ps = k_pages.shape[2]
    # Clamp pos to table capacity on BOTH paths (one contract): past it the
    # kernel would read the block table out of bounds and DMA the token
    # into an arbitrary live page; the oracle would write a different slot.
    # Clamped, both rewrite the table's last slot.
    pos = jnp.minimum(pos, block_tables.shape[1] * ps - 1)
    if not use_pallas:
        return ref.paged_decode_attention(q, k_pages, v_pages, block_tables,
                                          pos, k_new, v_new, scale=scale,
                                          window=window)
    d = q.shape[-1]
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    on_tpu = _on_tpu()
    if on_tpu:
        _check_tileable("paged_decode_attention", k_pages.dtype,
                        page_size=(ps, None), head_dim=(d, 128))
    return _pdec.paged_decode_attention(
        q, k_pages, v_pages, block_tables.astype(jnp.int32),
        pos.astype(jnp.int32), k_new.astype(k_pages.dtype),
        v_new.astype(v_pages.dtype), scale=scale, window=window,
        interpret=not on_tpu)


def _quant_qmax(dtype) -> float:
    """Symmetric-quant max magnitude for a quantized pool dtype."""
    if jnp.dtype(dtype) == jnp.dtype(jnp.int8):
        return 127.0
    if _FP8 is not None and jnp.dtype(dtype) == jnp.dtype(_FP8):
        return 448.0            # e4m3 finite max
    raise ValueError(f"not a quantized pool dtype: {jnp.dtype(dtype).name}")


def paged_decode_attention_quant(q, k_pages, k_scales, v_pages, v_scales,
                                 block_tables, pos, k_new, v_new, *,
                                 scale: float | None = None,
                                 window: int | None = None,
                                 use_pallas: bool = True):
    """Quantized-pool fused write-attend decode.

    Same contract as ``paged_decode_attention`` with int8/fp8 pools and
    per-row f32 scale pools (k/v_scales: [P, Hkv, ps]) riding alongside;
    k/v_new arrive FLOAT and quantize inside the kernel's fused write.
    Returns (out, k_pages, v_pages, k_scales, v_scales).
    """
    ps = k_pages.shape[2]
    pos = jnp.minimum(pos, block_tables.shape[1] * ps - 1)
    if not use_pallas:
        return ref.paged_decode_attention_quant(
            q, k_pages, k_scales, v_pages, v_scales, block_tables, pos,
            k_new, v_new, scale=scale, window=window)
    d = q.shape[-1]
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    on_tpu = _on_tpu()
    if on_tpu:
        _check_tileable("paged_decode_attention_quant", k_pages.dtype,
                        page_size=(ps, None), head_dim=(d, 128))
    return _pdec.paged_decode_attention_quant(
        q, k_pages, k_scales.astype(jnp.float32), v_pages,
        v_scales.astype(jnp.float32), block_tables.astype(jnp.int32),
        pos.astype(jnp.int32), k_new.astype(jnp.float32),
        v_new.astype(jnp.float32), scale=scale,
        qmax=_quant_qmax(k_pages.dtype), window=window,
        interpret=not on_tpu)


def paged_chunk_attention(q, k_pages, v_pages, block_tables, start, span,
                          k_new, v_new, *, scale: float | None = None,
                          window: int | None = None, use_pallas: bool = True):
    """Chunked mixed-step attention over a paged KV cache, writes fused.

    q: [B, Hq, C, D] per-row query spans; k_pages, v_pages: [P, Hkv, ps, D];
    block_tables: i32[B, maxp]; start: i32[B] tokens already cached; span:
    i32[B] valid new tokens in [0, C]; k_new, v_new: [B, Hkv, C, D].
    Returns (out [B, Hq, C, D], k_pages, v_pages) — the span's K/V written
    at slots ``start..start+span`` (in place on TPU via aliasing).

    Span 1 is the fused decode step; span C is one prompt chunk.  Like the
    decode wrapper, the pool is never padded per step — on TPU it must be
    tileable at init; off-TPU the kernel runs in interpret mode.
    """
    ps = k_pages.shape[2]
    maxp = block_tables.shape[1]
    # Clamp start to table capacity on BOTH paths (one contract with the
    # decode wrapper): writes past the table drop and the walk stays in
    # bounds instead of reading the block table out of range.
    start = jnp.minimum(start, maxp * ps - 1)
    span = jnp.clip(span, 0, q.shape[2])
    if not use_pallas:
        return ref.paged_chunk_attention(q, k_pages, v_pages, block_tables,
                                         start, span, k_new, v_new,
                                         scale=scale, window=window)
    d = q.shape[-1]
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    on_tpu = _on_tpu()
    if on_tpu:
        _check_tileable("paged_chunk_attention", k_pages.dtype,
                        page_size=(ps, None), head_dim=(d, 128))
    return _pchunk.paged_chunk_attention(
        q, k_pages, v_pages, block_tables.astype(jnp.int32),
        start.astype(jnp.int32), span.astype(jnp.int32),
        k_new.astype(k_pages.dtype), v_new.astype(v_pages.dtype),
        scale=scale, window=window, interpret=not on_tpu)


def paged_chunk_attention_quant(q, k_pages, k_scales, v_pages, v_scales,
                                block_tables, start, span, k_new, v_new, *,
                                scale: float | None = None,
                                window: int | None = None,
                                use_pallas: bool = True):
    """Quantized-pool chunked mixed-step attention.

    Same contract as ``paged_chunk_attention`` with int8/fp8 pools and
    per-row f32 scale pools; k/v_new arrive FLOAT [B, Hkv, C, D] and
    quantize inside the kernel's fused multi-slot write.  Returns
    (out, k_pages, v_pages, k_scales, v_scales).
    """
    ps = k_pages.shape[2]
    maxp = block_tables.shape[1]
    start = jnp.minimum(start, maxp * ps - 1)
    span = jnp.clip(span, 0, q.shape[2])
    if not use_pallas:
        return ref.paged_chunk_attention_quant(
            q, k_pages, k_scales, v_pages, v_scales, block_tables, start,
            span, k_new, v_new, scale=scale, window=window)
    d = q.shape[-1]
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    on_tpu = _on_tpu()
    if on_tpu:
        _check_tileable("paged_chunk_attention_quant", k_pages.dtype,
                        page_size=(ps, None), head_dim=(d, 128))
    return _pchunk.paged_chunk_attention_quant(
        q, k_pages, k_scales.astype(jnp.float32), v_pages,
        v_scales.astype(jnp.float32), block_tables.astype(jnp.int32),
        start.astype(jnp.int32), span.astype(jnp.int32),
        k_new.astype(jnp.float32), v_new.astype(jnp.float32),
        scale=scale, qmax=_quant_qmax(k_pages.dtype), window=window,
        interpret=not on_tpu)


def paged_mla_chunk(q_abs, q_rope, latent_pages, block_tables, start, span,
                    latent_new, *, scale: float, use_pallas: bool = True):
    """Chunked mixed-step MLA decode over a paged latent cache.

    q_abs: [B, H, C, r] (f32 absorbed queries); q_rope: [B, H, C, rd];
    latent_pages: [P, ps, Dp] with Dp >= r + rd; block_tables: i32[B, maxp];
    start/span: i32[B]; latent_new: [B, C, Dp].
    Returns (ctx [B, H, C, r] f32, latent_pages updated in place on TPU).
    """
    r = q_abs.shape[-1]
    rd = q_rope.shape[-1]
    ps = latent_pages.shape[1]
    dp = latent_pages.shape[2]
    maxp = block_tables.shape[1]
    if dp < r + rd:
        raise ValueError(f"latent pool width {dp} < kv_lora_rank + rope_dim "
                         f"= {r + rd}")
    start = jnp.minimum(start, maxp * ps - 1)
    span = jnp.clip(span, 0, q_abs.shape[2])
    if not use_pallas:
        return ref.paged_mla_chunk(q_abs, q_rope, latent_pages,
                                   block_tables, start, span, latent_new,
                                   r=r, scale=scale)
    on_tpu = _on_tpu()
    if on_tpu:
        _check_tileable("paged_mla_chunk", latent_pages.dtype,
                        page_size=(ps, None), latent_width=(dp, 128))
    qc = jnp.concatenate([q_abs.astype(jnp.float32),
                          q_rope.astype(jnp.float32)], axis=-1)
    return _pchunk.paged_mla_chunk(
        qc, latent_pages, block_tables.astype(jnp.int32),
        start.astype(jnp.int32), span.astype(jnp.int32),
        latent_new.astype(latent_pages.dtype), r=r, scale=scale,
        interpret=not on_tpu)


def paged_mla_chunk_quant(q_abs, q_rope, latent_pages, latent_scales,
                          block_tables, start, span, latent_new, *,
                          scale: float, use_pallas: bool = True):
    """Quantized-pool chunked MLA decode.

    Same contract as ``paged_mla_chunk`` with an int8/fp8 latent pool and
    a per-row f32 scale pool (latent_scales: [P, ps]); latent_new arrives
    FLOAT [B, C, Dp] and quantizes inside the kernel's fused write.
    Returns (ctx, latent_pages, latent_scales).
    """
    r = q_abs.shape[-1]
    rd = q_rope.shape[-1]
    ps = latent_pages.shape[1]
    dp = latent_pages.shape[2]
    maxp = block_tables.shape[1]
    if dp < r + rd:
        raise ValueError(f"latent pool width {dp} < kv_lora_rank + rope_dim "
                         f"= {r + rd}")
    start = jnp.minimum(start, maxp * ps - 1)
    span = jnp.clip(span, 0, q_abs.shape[2])
    if not use_pallas:
        return ref.paged_mla_chunk_quant(
            q_abs, q_rope, latent_pages, latent_scales, block_tables,
            start, span, latent_new, r=r, scale=scale)
    on_tpu = _on_tpu()
    if on_tpu:
        _check_tileable("paged_mla_chunk_quant", latent_pages.dtype,
                        page_size=(ps, None), latent_width=(dp, 128))
    qc = jnp.concatenate([q_abs.astype(jnp.float32),
                          q_rope.astype(jnp.float32)], axis=-1)
    return _pchunk.paged_mla_chunk_quant(
        qc, latent_pages, latent_scales.astype(jnp.float32),
        block_tables.astype(jnp.int32), start.astype(jnp.int32),
        span.astype(jnp.int32), latent_new.astype(jnp.float32),
        r=r, scale=scale, qmax=_quant_qmax(latent_pages.dtype),
        interpret=not on_tpu)


def paged_mla_decode(q_abs, q_rope, latent_pages, block_tables, pos,
                     latent_new, *, scale: float, use_pallas: bool = True):
    """Fused write-attend MLA decode over a paged latent cache.

    q_abs: [B, H, r] (f32 absorbed queries); q_rope: [B, H, rd];
    latent_pages: [P, ps, Dp] with Dp >= r + rd (lane-padded at init);
    block_tables: i32[B, maxp]; pos: i32[B]; latent_new: [B, Dp].
    Returns (ctx [B, H, r] f32, latent_pages updated in place on TPU).

    Like the MHA paged wrapper, the pool is never padded per step: a
    pad/slice round-trip would copy the whole latent cache each token —
    exactly the cost the paged path removes.  The pool's feature dim is
    therefore padded once at init_cache (models/cache.py pad128); here we
    only validate.
    """
    r = q_abs.shape[-1]
    rd = q_rope.shape[-1]
    ps = latent_pages.shape[1]
    dp = latent_pages.shape[2]
    if dp < r + rd:
        raise ValueError(f"latent pool width {dp} < kv_lora_rank + rope_dim "
                         f"= {r + rd}")
    # Clamp pos to table capacity on BOTH paths (one contract with the MHA
    # wrapper): past it, both rewrite the table's last slot instead of
    # reading the block table out of bounds.
    pos = jnp.minimum(pos, block_tables.shape[1] * ps - 1)
    if not use_pallas:
        return ref.paged_mla_decode(q_abs, q_rope, latent_pages,
                                    block_tables, pos, latent_new,
                                    r=r, scale=scale)
    on_tpu = _on_tpu()
    if on_tpu:
        _check_tileable("paged_mla_decode", latent_pages.dtype,
                        page_size=(ps, None), latent_width=(dp, 128))
    qc = jnp.concatenate([q_abs.astype(jnp.float32),
                          q_rope.astype(jnp.float32)], axis=-1)
    return _pmla.paged_mla_decode(
        qc, latent_pages, block_tables.astype(jnp.int32),
        pos.astype(jnp.int32), latent_new.astype(latent_pages.dtype),
        r=r, scale=scale, interpret=not on_tpu)


def paged_mla_decode_quant(q_abs, q_rope, latent_pages, latent_scales,
                           block_tables, pos, latent_new, *,
                           scale: float, use_pallas: bool = True):
    """Quantized-pool fused write-attend MLA decode.

    Same contract as ``paged_mla_decode`` with an int8/fp8 latent pool and
    a per-row f32 scale pool (latent_scales: [P, ps]); latent_new arrives
    FLOAT [B, Dp] and quantizes inside the kernel's fused write.  Returns
    (ctx, latent_pages, latent_scales).
    """
    r = q_abs.shape[-1]
    rd = q_rope.shape[-1]
    ps = latent_pages.shape[1]
    dp = latent_pages.shape[2]
    if dp < r + rd:
        raise ValueError(f"latent pool width {dp} < kv_lora_rank + rope_dim "
                         f"= {r + rd}")
    pos = jnp.minimum(pos, block_tables.shape[1] * ps - 1)
    if not use_pallas:
        return ref.paged_mla_decode_quant(
            q_abs, q_rope, latent_pages, latent_scales, block_tables, pos,
            latent_new, r=r, scale=scale)
    on_tpu = _on_tpu()
    if on_tpu:
        _check_tileable("paged_mla_decode_quant", latent_pages.dtype,
                        page_size=(ps, None), latent_width=(dp, 128))
    qc = jnp.concatenate([q_abs.astype(jnp.float32),
                          q_rope.astype(jnp.float32)], axis=-1)
    return _pmla.paged_mla_decode_quant(
        qc, latent_pages, latent_scales.astype(jnp.float32),
        block_tables.astype(jnp.int32), pos.astype(jnp.int32),
        latent_new.astype(jnp.float32), r=r, scale=scale,
        qmax=_quant_qmax(latent_pages.dtype), interpret=not on_tpu)


def _row_tileable(row: tuple, dtype) -> bool:
    """True when a pool row can be VMEM-staged on TPU: lane dim a multiple
    of 128 and sublane dim a multiple of the dtype's sublane count."""
    if len(row) < 2:
        return False
    sublane = _SUBLANE.get(jnp.dtype(dtype), 8)
    return row[-1] % 128 == 0 and row[-2] % sublane == 0


def page_transfer(src_pool, dst_pool, src_ids, dst_ids, *,
                  use_pallas: bool = True):
    """Batched cross-pool page-row transfer (disaggregated adoption path).

    src_pool: [Ps, ...row]; dst_pool: [Pd, ...row] (same row shape and
    dtype); src_ids/dst_ids: i32[N] — lane i copies row ``src_ids[i]`` into
    row ``dst_ids[i]``; -1 on either side drops the lane.  Returns the
    updated destination pool; the copy is pure DMA, bitwise for any dtype.

    The pool is never padded (same rationale as the paged attention
    wrappers); rows the TPU cannot VMEM-stage — e.g. tiny scale leaves
    [ps] / [Hkv, ps] — take the reference gather-scatter instead of
    raising, since a DMA kernel buys nothing at that size.
    """
    if src_pool.shape[1:] != dst_pool.shape[1:] \
            or src_pool.dtype != dst_pool.dtype:
        raise ValueError(
            f"page_transfer: pool rows do not match: src "
            f"{tuple(src_pool.shape)} ({jnp.dtype(src_pool.dtype).name}) vs "
            f"dst {tuple(dst_pool.shape)} ({jnp.dtype(dst_pool.dtype).name})")
    if src_ids.shape != dst_ids.shape or src_ids.ndim != 1:
        raise ValueError(
            f"page_transfer: id vectors must be matching 1-D arrays, got "
            f"src_ids {tuple(src_ids.shape)} vs dst_ids "
            f"{tuple(dst_ids.shape)}")
    if src_ids.shape[0] == 0:
        return dst_pool
    on_tpu = _on_tpu()
    if not use_pallas or (on_tpu and not _row_tileable(src_pool.shape[1:],
                                                       src_pool.dtype)):
        return ref.page_transfer(src_pool, dst_pool,
                                 src_ids.astype(jnp.int32),
                                 dst_ids.astype(jnp.int32))
    return _pxfer.page_transfer(src_pool, dst_pool,
                                src_ids.astype(jnp.int32),
                                dst_ids.astype(jnp.int32),
                                interpret=not on_tpu)


def linear_scan(a, b, h0, *, block_t: int = 128, use_pallas: bool = True):
    """h_t = a_t*h_{t-1} + b_t.  a, b: [B, T, D]; h0: [B, D]."""
    if not use_pallas:
        y = ref.linear_scan(a, b, h0)
        return y, y[:, -1].astype(jnp.float32)
    batch, t, d = a.shape
    bt = min(block_t, max(8, 1 << (t - 1).bit_length()))
    # Pad time with identity steps (a=1, b=0) so the carry passes through.
    ap = _pad_to(a, 1, bt, value=1)
    bp = _pad_to(b, 1, bt, value=0)
    y, h_t = _rg.linear_scan(ap, bp, h0, block_t=bt, interpret=not _on_tpu())
    return y[:, :t], h_t
