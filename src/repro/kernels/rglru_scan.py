"""Pallas TPU kernel: chunked diagonal linear recurrence (RG-LRU path).

Computes h_t = a_t ⊙ h_{t-1} + b_t over time, the core of Griffin's RG-LRU
(and reusable for any diagonal gated recurrence).  The recurrence is serial
in t but elementwise in channels, so the TPU-native schedule is:

  grid = (batch, T/Bt) — time chunks visit the same scratch carry in order;
  within a chunk the scan is computed with a Blelloch-style associative scan
  over the [Bt, D] tile in VMEM (log2(Bt) VPU sweeps, no MXU needed),
  then shifted by the carried state:  h_t = A_(1..t) ⊙ h_carry + S_t.

HBM traffic is exactly one read of (a, b) and one write of h — the kernel is
bandwidth-optimal; the associative scan removes the length-T serial latency
chain that a naive fori over rows would pay.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _combine(c1, c2):
    a1, b1 = c1
    a2, b2 = c2
    return a1 * a2, b1 * a2 + b2


def _rglru_kernel(a_ref, b_ref, h0_ref, y_ref, hT_ref, carry_scr,
                  *, bt: int, nt: int):
    it = pl.program_id(1)

    @pl.when(it == 0)
    def _init():
        carry_scr[...] = h0_ref[0].astype(jnp.float32)

    a = a_ref[0].astype(jnp.float32)          # [Bt, D]
    b = b_ref[0].astype(jnp.float32)
    # Inclusive associative scan along time within the chunk.
    acc_a, acc_b = jax.lax.associative_scan(_combine, (a, b), axis=0)
    h = acc_a * carry_scr[...][None, :] + acc_b
    y_ref[0] = h.astype(y_ref.dtype)
    carry_scr[...] = h[bt - 1]

    @pl.when(it == nt - 1)
    def _final():
        hT_ref[0] = carry_scr[...]


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def linear_scan(a: jax.Array, b: jax.Array, h0: jax.Array,
                *, block_t: int = 128, interpret: bool = False
                ) -> tuple[jax.Array, jax.Array]:
    """a, b: [B, T, D]; h0: [B, D].  T must be a multiple of block_t.

    Returns (h for all t [B, T, D], final state [B, D] fp32).
    """
    batch, t, d = a.shape
    nt = t // block_t
    grid = (batch, nt)
    ab_spec = pl.BlockSpec((1, block_t, d), lambda ib, it: (ib, it, 0))
    h0_spec = pl.BlockSpec((1, d), lambda ib, it: (ib, 0))
    kernel = functools.partial(_rglru_kernel, bt=block_t, nt=nt)
    y, h_t = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[ab_spec, ab_spec, h0_spec],
        out_specs=[ab_spec, h0_spec],
        out_shape=[
            jax.ShapeDtypeStruct(a.shape, b.dtype),
            jax.ShapeDtypeStruct((batch, d), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((d,), jnp.float32)],
        interpret=interpret,
    )(a, b, h0)
    return y, h_t
