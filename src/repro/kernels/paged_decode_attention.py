"""Pallas TPU kernel: paged flash-decode attention with a fused KV write.

The KV cache lives in a shared page pool ``[P, Hkv, page_size, D]``; each
batch row owns an ordered list of pages (its block table).  Decode is
HBM-bandwidth-bound, and the dense-cache step additionally pays an
O(B·max_len) one-hot *write* per layer just to place one token.  This kernel
removes both costs:

  * the current token's K/V is DMA'd into exactly one page slot (O(D) bytes)
    before the attend — the write is fused, so the step touches the cache
    once and the one-hot full-cache rewrite disappears;
  * the attend walks only the row's live pages (block-table indirection via
    scalar prefetch), streaming each page HBM→VMEM once with double-buffered
    DMA and split-K online softmax in VMEM carries.

Grid is (B, Hkv); each program handles one row's GQA group of query heads
against one KV head.  The page pools are ANY-space (HBM) refs aliased
input→output, so XLA updates them in place — the kernel's writes are the
only pool bytes that move.

Alignment: on real TPU the pool layout must be tileable — ``page_size``
a multiple of the sublane count and ``head_dim`` a multiple of 128.  The
ops wrapper enforces this with a clear error; off-TPU (interpret mode) any
shape runs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _quantize_rows(x, qdtype, qmax):
    """Per-row symmetric quantization — the in-kernel twin of
    ``kernels.ref.quantize_rows`` (identical ops, so the pool bytes the
    kernel writes match the oracle's bit for bit)."""
    amax = jnp.max(jnp.abs(x), axis=-1)
    # Multiply by the reciprocal EXPLICITLY: XLA rewrites division by a
    # constant into it anyway, but only in some compilation paths — an
    # explicit multiply keeps kernel and oracle scales bit-identical.
    scale = jnp.where(amax > 0, amax * np.float32(1.0 / qmax), 1.0)
    scaled = x / scale[..., None]
    if qdtype == jnp.int8:
        q = jnp.clip(jnp.round(scaled), -qmax, qmax).astype(jnp.int8)
    else:
        q = scaled.astype(qdtype)
    return q, scale.astype(jnp.float32)


def _kernel(bt_ref, pos_ref, q_ref, kn_ref, vn_ref, kp_in, vp_in,
            o_ref, kp, vp, kbuf, vbuf, tokk, tokv, ksem, vsem, wsem,
            *, ps: int, scale: float, window: int | None):
    b = pl.program_id(0)
    h = pl.program_id(1)
    pos = pos_ref[b]
    kv_len = pos + 1
    n_pages = (kv_len + ps - 1) // ps

    # -- fused write: current token's K/V -> one page slot ------------------
    page_raw = bt_ref[b, pos // ps]
    page_w = jnp.maximum(page_raw, 0)
    slot_w = pos % ps
    tokk[0, 0, 0, :] = kn_ref[0, 0]
    tokv[0, 0, 0, :] = vn_ref[0, 0]

    # An unallocated (-1) entry drops the write — same semantics as the
    # oracle's mode="drop" scatter — so an idle row never corrupts page 0.
    @pl.when(page_raw >= 0)
    def _write():
        wk = pltpu.make_async_copy(
            tokk, kp.at[pl.ds(page_w, 1), pl.ds(h, 1), pl.ds(slot_w, 1), :],
            wsem.at[0])
        wv = pltpu.make_async_copy(
            tokv, vp.at[pl.ds(page_w, 1), pl.ds(h, 1), pl.ds(slot_w, 1), :],
            wsem.at[1])
        wk.start()
        wv.start()
        # The write page is also read below (the new token attends to
        # itself); both copies must land before the walk starts.
        wk.wait()
        wv.wait()

    # -- split-K online softmax over the row's live pages -------------------
    def page_dma(pool, buf, sem, i, slot):
        pg = jnp.maximum(bt_ref[b, i], 0)
        return pltpu.make_async_copy(
            pool.at[pl.ds(pg, 1), pl.ds(h, 1)], buf.at[pl.ds(slot, 1)],
            sem.at[slot])

    page_dma(kp, kbuf, ksem, 0, 0).start()
    page_dma(vp, vbuf, vsem, 0, 0).start()

    q = q_ref[0].astype(jnp.float32)                       # [group, D]
    group, d = q.shape

    def body(i, carry):
        m, l, acc = carry
        slot = jax.lax.rem(i, 2)
        nxt = jax.lax.rem(i + 1, 2)

        @pl.when(i + 1 < n_pages)
        def _prefetch():
            page_dma(kp, kbuf, ksem, i + 1, nxt).start()
            page_dma(vp, vbuf, vsem, i + 1, nxt).start()

        page_dma(kp, kbuf, ksem, i, slot).wait()
        page_dma(vp, vbuf, vsem, i, slot).wait()
        k = kbuf[slot, 0].astype(jnp.float32)              # [ps, D]
        v = vbuf[slot, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale    # [group, ps]
        cols = i * ps + jax.lax.broadcasted_iota(jnp.int32, (1, ps), 1)
        valid = cols < kv_len
        if window is not None:
            valid &= cols > pos - window
        s = jnp.where(valid, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l * alpha + jnp.sum(p, axis=1)
        acc_new = acc * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    m0 = jnp.full((group,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((group,), jnp.float32)
    a0 = jnp.zeros((group, d), jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, n_pages, body, (m0, l0, a0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("scale", "window", "interpret"))
def paged_decode_attention(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, block_tables: jax.Array,
                           pos: jax.Array, k_new: jax.Array,
                           v_new: jax.Array, *, scale: float,
                           window: int | None = None,
                           interpret: bool = False
                           ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """q: [B, Hq, D]; k/v_pages: [P, Hkv, ps, D]; block_tables: i32[B, maxp];
    pos: i32[B] (tokens already cached); k/v_new: [B, Hkv, D] (pool dtype).
    Returns (out [B, Hq, D], k_pages, v_pages) with the token written at
    slot ``pos`` of each row (pools updated in place via aliasing)."""
    b, hq, d = q.shape
    _, hkv, ps, _ = k_pages.shape
    group = hq // hkv
    grid = (b, hkv)

    q_spec = pl.BlockSpec((1, group, d), lambda i, j, *_: (i, j, 0))
    tok_spec = pl.BlockSpec((1, 1, d), lambda i, j, *_: (i, j, 0))
    any_spec = pl.BlockSpec(memory_space=pltpu.ANY)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,              # block_tables, pos
        grid=grid,
        in_specs=[q_spec, tok_spec, tok_spec, any_spec, any_spec],
        out_specs=[q_spec, any_spec, any_spec],
        scratch_shapes=[
            pltpu.VMEM((2, 1, ps, d), k_pages.dtype),   # k page double-buffer
            pltpu.VMEM((2, 1, ps, d), v_pages.dtype),
            pltpu.VMEM((1, 1, 1, d), k_pages.dtype),    # staged token write
            pltpu.VMEM((1, 1, 1, d), v_pages.dtype),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((2,)),
        ],
    )
    kernel = functools.partial(_kernel, ps=ps, scale=scale, window=window)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct(q.shape, q.dtype),
            jax.ShapeDtypeStruct(k_pages.shape, k_pages.dtype),
            jax.ShapeDtypeStruct(v_pages.shape, v_pages.dtype),
        ],
        # Input indices count the scalar-prefetch operands (0, 1).
        input_output_aliases={5: 1, 6: 2},
        interpret=interpret,
    )(block_tables, pos, q, k_new, v_new, k_pages, v_pages)


def _kernel_quant(bt_ref, pos_ref, q_ref, kn_ref, vn_ref, kp_in, vp_in,
                  ks_in, vs_in, o_ref, kp, vp, ks, vs,
                  kbuf, vbuf, ksbuf, vsbuf, tokk, tokv, tokks, tokvs,
                  ksem, vsem, kssem, vssem, wsem,
                  *, ps: int, scale: float, window: int | None,
                  qmax: float, qdtype):
    """Quantized twin of ``_kernel``: pools hold int8/fp8 rows + a per-row
    f32 scale pool riding alongside.  The current token is quantized
    in-kernel and its value row AND scale land in the same fused write
    phase; the page walk DMAs the scale block with its page and dequant is
    a single multiply after the VMEM load — the HBM bytes/step are the
    quantized page plus ps floats of scale."""
    b = pl.program_id(0)
    h = pl.program_id(1)
    pos = pos_ref[b]
    kv_len = pos + 1
    n_pages = (kv_len + ps - 1) // ps

    # -- fused write: quantize the token, stage value row + scale -----------
    page_raw = bt_ref[b, pos // ps]
    page_w = jnp.maximum(page_raw, 0)
    slot_w = pos % ps
    kq, kscale = _quantize_rows(kn_ref[0, 0].astype(jnp.float32),
                                qdtype, qmax)
    vq, vscale = _quantize_rows(vn_ref[0, 0].astype(jnp.float32),
                                qdtype, qmax)
    tokk[0, 0, 0, :] = kq
    tokv[0, 0, 0, :] = vq
    tokks[0, 0, 0] = kscale
    tokvs[0, 0, 0] = vscale

    @pl.when(page_raw >= 0)
    def _write():
        copies = (
            pltpu.make_async_copy(
                tokk,
                kp.at[pl.ds(page_w, 1), pl.ds(h, 1), pl.ds(slot_w, 1), :],
                wsem.at[0]),
            pltpu.make_async_copy(
                tokv,
                vp.at[pl.ds(page_w, 1), pl.ds(h, 1), pl.ds(slot_w, 1), :],
                wsem.at[1]),
            pltpu.make_async_copy(
                tokks,
                ks.at[pl.ds(page_w, 1), pl.ds(h, 1), pl.ds(slot_w, 1)],
                wsem.at[2]),
            pltpu.make_async_copy(
                tokvs,
                vs.at[pl.ds(page_w, 1), pl.ds(h, 1), pl.ds(slot_w, 1)],
                wsem.at[3]),
        )
        for cp in copies:
            cp.start()
        for cp in copies:
            cp.wait()

    # -- split-K online softmax, dequant fused into the walk ----------------
    def page_dma(pool, buf, sem, i, slot):
        pg = jnp.maximum(bt_ref[b, i], 0)
        return pltpu.make_async_copy(
            pool.at[pl.ds(pg, 1), pl.ds(h, 1)], buf.at[pl.ds(slot, 1)],
            sem.at[slot])

    page_dma(kp, kbuf, ksem, 0, 0).start()
    page_dma(vp, vbuf, vsem, 0, 0).start()
    page_dma(ks, ksbuf, kssem, 0, 0).start()
    page_dma(vs, vsbuf, vssem, 0, 0).start()

    q = q_ref[0].astype(jnp.float32)                       # [group, D]
    group, d = q.shape

    def body(i, carry):
        m, l, acc = carry
        slot = jax.lax.rem(i, 2)
        nxt = jax.lax.rem(i + 1, 2)

        @pl.when(i + 1 < n_pages)
        def _prefetch():
            page_dma(kp, kbuf, ksem, i + 1, nxt).start()
            page_dma(vp, vbuf, vsem, i + 1, nxt).start()
            page_dma(ks, ksbuf, kssem, i + 1, nxt).start()
            page_dma(vs, vsbuf, vssem, i + 1, nxt).start()

        page_dma(kp, kbuf, ksem, i, slot).wait()
        page_dma(vp, vbuf, vsem, i, slot).wait()
        page_dma(ks, ksbuf, kssem, i, slot).wait()
        page_dma(vs, vsbuf, vssem, i, slot).wait()
        k = kbuf[slot, 0].astype(jnp.float32) * ksbuf[slot, 0][:, None]
        v = vbuf[slot, 0].astype(jnp.float32) * vsbuf[slot, 0][:, None]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale    # [group, ps]
        cols = i * ps + jax.lax.broadcasted_iota(jnp.int32, (1, ps), 1)
        valid = cols < kv_len
        if window is not None:
            valid &= cols > pos - window
        s = jnp.where(valid, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l * alpha + jnp.sum(p, axis=1)
        acc_new = acc * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    m0 = jnp.full((group,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((group,), jnp.float32)
    a0 = jnp.zeros((group, d), jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, n_pages, body, (m0, l0, a0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("scale", "window", "qmax", "interpret"))
def paged_decode_attention_quant(q: jax.Array, k_pages: jax.Array,
                                 k_scales: jax.Array, v_pages: jax.Array,
                                 v_scales: jax.Array,
                                 block_tables: jax.Array, pos: jax.Array,
                                 k_new: jax.Array, v_new: jax.Array, *,
                                 scale: float, qmax: float,
                                 window: int | None = None,
                                 interpret: bool = False):
    """Quantized-pool decode: k/v_pages [P, Hkv, ps, D] int8/fp8 with
    k/v_scales [P, Hkv, ps] f32; k/v_new arrive FLOAT and are quantized
    in-kernel.  Returns (out, k_pages, v_pages, k_scales, v_scales) with
    pools + scales updated in place via aliasing."""
    b, hq, d = q.shape
    _, hkv, ps, _ = k_pages.shape
    group = hq // hkv
    grid = (b, hkv)
    qdtype = k_pages.dtype

    q_spec = pl.BlockSpec((1, group, d), lambda i, j, *_: (i, j, 0))
    tok_spec = pl.BlockSpec((1, 1, d), lambda i, j, *_: (i, j, 0))
    any_spec = pl.BlockSpec(memory_space=pltpu.ANY)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,              # block_tables, pos
        grid=grid,
        in_specs=[q_spec, tok_spec, tok_spec,
                  any_spec, any_spec, any_spec, any_spec],
        out_specs=[q_spec, any_spec, any_spec, any_spec, any_spec],
        scratch_shapes=[
            pltpu.VMEM((2, 1, ps, d), k_pages.dtype),   # quantized pages
            pltpu.VMEM((2, 1, ps, d), v_pages.dtype),
            pltpu.VMEM((2, 1, ps), jnp.float32),        # page scale rows
            pltpu.VMEM((2, 1, ps), jnp.float32),
            pltpu.VMEM((1, 1, 1, d), k_pages.dtype),    # staged token write
            pltpu.VMEM((1, 1, 1, d), v_pages.dtype),
            pltpu.VMEM((1, 1, 1), jnp.float32),         # staged token scale
            pltpu.VMEM((1, 1, 1), jnp.float32),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((4,)),
        ],
    )
    kernel = functools.partial(_kernel_quant, ps=ps, scale=scale,
                               window=window, qmax=qmax, qdtype=qdtype)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct(q.shape, q.dtype),
            jax.ShapeDtypeStruct(k_pages.shape, k_pages.dtype),
            jax.ShapeDtypeStruct(v_pages.shape, v_pages.dtype),
            jax.ShapeDtypeStruct(k_scales.shape, k_scales.dtype),
            jax.ShapeDtypeStruct(v_scales.shape, v_scales.dtype),
        ],
        # Input indices count the scalar-prefetch operands (0, 1).
        input_output_aliases={5: 1, 6: 2, 7: 3, 8: 4},
        interpret=interpret,
    )(block_tables, pos, q, k_new, v_new,
      k_pages, v_pages, k_scales, v_scales)
