"""Pallas TPU kernel: blocked causal flash attention (prefill path).

Online-softmax attention with explicit VMEM tiling:
  grid = (batch*q_heads, Tq/Bq, Tk/Bk); the innermost grid axis revisits the
  same output block, carrying (m, l, acc) in VMEM scratch — the canonical TPU
  flash pattern.  GQA is handled in the K/V BlockSpec index maps (a q-head
  reads its kv-group's rows; no jnp.repeat materialization).

Block shapes default to (Bq, Bk) = (256, 256) with head_dim padded to a
multiple of 128 so the q·kᵀ and p·v contractions land on MXU-aligned shapes.
VMEM working set per step ≈ (Bq·D + 2·Bk·D + Bq·Bk + Bq·D) fp32
≈ 1.3 MB at D=128 — comfortably inside the ~16 MB v5e VMEM budget.

Causal masking skips fully-masked K blocks via pl.when (no FLOPs burned on
the upper triangle).  Local (sliding-window) masking is supported for the
recurrentgemma path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                 *, scale: float, causal: bool, window: int | None,
                 bq: int, bk: int, tk_true: int, offset: int, nk: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    iq = pl.program_id(1)
    q_start = iq * bq
    k_start = ik * bk

    def compute():
        q = q_ref[0].astype(jnp.float32)                   # [Bq, D]
        k = k_ref[0].astype(jnp.float32)                   # [Bk, D]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale    # [Bq, Bk]
        rows = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + offset
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = cols < tk_true                              # true key length
        if causal:
            mask &= cols <= rows
        if window is not None:
            mask &= cols >= rows - window + 1
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                                # [Bq]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    if causal and window is None:
        # Skip blocks entirely above the diagonal.
        pl.when(k_start <= q_start + offset + bq - 1)(compute)
    elif window is not None:
        live = (k_start <= q_start + offset + bq - 1) if causal else True
        live_lo = k_start + bk - 1 >= q_start + offset - (window - 1)
        pl.when(jnp.logical_and(live, live_lo))(compute)
    else:
        compute()

    @pl.when(ik == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "window", "block_q", "block_k",
                     "interpret", "num_q_heads", "tq_true", "tk_true"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    *, causal: bool = True, scale: float,
                    window: int | None = None, num_q_heads: int,
                    tq_true: int, tk_true: int,
                    block_q: int = 256, block_k: int = 256,
                    interpret: bool = False) -> jax.Array:
    """q: [BHq, Tq, D]; k, v: [BHkv, Tk, D] — flattened (batch, head) rows.

    Tq, Tk, D already padded to block/lane multiples (ops.py does);
    ``tq_true``/``tk_true`` are the pre-padding lengths used for masking and
    for the end-aligned causal offset (query row i sits at key position
    i + tk_true - tq_true — the chunked-prefill convention).
    """
    bhq, tq_pad, d = q.shape
    bhkv, tk_pad, _ = k.shape
    batch = bhq // num_q_heads
    num_kv_heads = bhkv // batch
    group = num_q_heads // num_kv_heads

    nq = tq_pad // block_q
    nk = tk_pad // block_k
    grid = (bhq, nq, nk)

    def kv_row(bh):
        b = bh // num_q_heads
        h = bh % num_q_heads
        return b * num_kv_heads + h // group

    q_spec = pl.BlockSpec((1, block_q, d), lambda bh, iq, ik: (bh, iq, 0))
    k_spec = pl.BlockSpec((1, block_k, d), lambda bh, iq, ik: (kv_row(bh), ik, 0))
    o_spec = pl.BlockSpec((1, block_q, d), lambda bh, iq, ik: (bh, iq, 0))

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        bq=block_q, bk=block_k, tk_true=tk_true,
        offset=tk_true - tq_true, nk=nk)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[q_spec, k_spec, k_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
