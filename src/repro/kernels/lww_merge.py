"""Pallas TPU kernel: LWW register-bank merge (the coordination hot-spot).

The paper's replicas pay O(N×U) observation work in JavaScript callbacks; on
TPU the per-replica join is a single fused pass over the register bank.  This
kernel merges two banks (packed int32 keys + payload matrix) tile-by-tile in
VMEM.  Keys and payloads stream through once — the op is bandwidth-bound, so
the win over unfused jnp is one pass instead of three (compare, select key,
select payload) and no HBM round-trip for the ``wins`` mask.

Blocks are 128-aligned (TPU lane width); the ops.py wrapper pads.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _merge_kernel(key_a_ref, pay_a_ref, key_b_ref, pay_b_ref,
                  key_o_ref, pay_o_ref):
    ka = key_a_ref[...]
    kb = key_b_ref[...]
    wins = kb > ka
    key_o_ref[...] = jnp.where(wins, kb, ka)
    pay_o_ref[...] = jnp.where(wins[:, None], pay_b_ref[...], pay_a_ref[...])


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def lww_merge(key_a: jax.Array, pay_a: jax.Array,
              key_b: jax.Array, pay_b: jax.Array,
              *, block_k: int = 1024, interpret: bool = False
              ) -> tuple[jax.Array, jax.Array]:
    """key_*: i32[K]; pay_*: [K, D].  K, D already padded by ops.py."""
    k_dim, d = pay_a.shape
    grid = (k_dim // block_k,)
    key_spec = pl.BlockSpec((block_k,), lambda i: (i,))
    pay_spec = pl.BlockSpec((block_k, d), lambda i: (i, 0))
    return pl.pallas_call(
        _merge_kernel,
        grid=grid,
        in_specs=[key_spec, pay_spec, key_spec, pay_spec],
        out_specs=[key_spec, pay_spec],
        out_shape=[
            jax.ShapeDtypeStruct(key_a.shape, key_a.dtype),
            jax.ShapeDtypeStruct(pay_a.shape, pay_a.dtype),
        ],
        interpret=interpret,
    )(key_a, pay_a, key_b, pay_b)
