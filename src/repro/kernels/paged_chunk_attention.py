"""Pallas TPU kernels: chunked paged attention with a fused multi-slot write.

The token-budget mixed serve step composes, per batch row, a query *span* —
1 token for rows that are decoding, up to C tokens for rows whose prompt is
being admitted chunk by chunk.  These kernels are the hot path of that step:

  * **fused multi-slot KV write** — the span's K/V rows are DMA'd into their
    page slots (pages ``bt[b, (start+j)//ps]``, slots ``(start+j) % ps``,
    j < span) *before* the attend, so intra-span causality falls out of the
    ordinary block-table walk: by the time query j reads a page, every key
    at a position ≤ start+j is already resident.  A span may straddle page
    boundaries — each token targets its own slot, ``-1`` table entries drop;
  * **block-table walk over the cached prefix** — double-buffered page DMA
    HBM→VMEM with split-K online softmax, exactly the decode kernel's
    schedule, but carrying [group·C] query rows instead of [group];
  * **causal intra-chunk masking** — query j masks columns > start + j (and
    below the sliding window, when one applies), so one kernel serves spans
    of any width: span 1 degenerates to the fused decode kernel.

MHA variant: grid (B, Hkv), pools ``[P, Hkv, ps, D]``.  MLA-latent variant:
grid (B,), pool ``[P, ps, Dp]`` storing concat([ckv; krope]) rows; queries
arrive pre-absorbed (concat([q_abs; q_rope])) so both logits terms are one
contraction, as in kernels/paged_mla_decode.py.

The pools are ANY-space refs aliased input→output (in-place update on TPU).
Alignment follows the decode kernels: ``page_size`` a multiple of the
sublane count and the lane dim a multiple of 128 on real TPU; interpret
mode runs any shape.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.paged_decode_attention import _quantize_rows

NEG_INF = -1e30


def _mha_kernel(bt_ref, start_ref, span_ref, q_ref, kn_ref, vn_ref,
                kp_in, vp_in, o_ref, kp, vp, kbuf, vbuf, tokk, tokv,
                ksem, vsem, wksem, wvsem, *, ps: int, c: int, scale: float,
                window: int | None):
    b = pl.program_id(0)
    h = pl.program_id(1)
    start = start_ref[b]
    span = span_ref[b]
    kv_len = start + span                      # tokens resident after write
    maxp = bt_ref.shape[1]
    n_pages = jnp.minimum((jnp.maximum(kv_len, 1) + ps - 1) // ps, maxp)

    # -- fused multi-slot write: span tokens -> their page slots ------------
    # All valid copies start first (distinct slots, so order is free), then
    # all are waited: the walk below reads the pages the span just wrote.
    tokk[:, 0, 0, :] = kn_ref[0, 0]
    tokv[:, 0, 0, :] = vn_ref[0, 0]

    def _start_write(j, _):
        pos = start + j
        page_raw = bt_ref[b, jnp.minimum(pos // ps, maxp - 1)]
        page_w = jnp.maximum(page_raw, 0)
        slot_w = pos % ps

        @pl.when((j < span) & (page_raw >= 0) & (pos < maxp * ps))
        def _():
            pltpu.make_async_copy(
                tokk.at[pl.ds(j, 1)],
                kp.at[pl.ds(page_w, 1), pl.ds(h, 1), pl.ds(slot_w, 1), :],
                wksem.at[j]).start()
            pltpu.make_async_copy(
                tokv.at[pl.ds(j, 1)],
                vp.at[pl.ds(page_w, 1), pl.ds(h, 1), pl.ds(slot_w, 1), :],
                wvsem.at[j]).start()
        return 0

    def _wait_write(j, _):
        pos = start + j
        page_raw = bt_ref[b, jnp.minimum(pos // ps, maxp - 1)]
        page_w = jnp.maximum(page_raw, 0)
        slot_w = pos % ps

        @pl.when((j < span) & (page_raw >= 0) & (pos < maxp * ps))
        def _():
            pltpu.make_async_copy(
                tokk.at[pl.ds(j, 1)],
                kp.at[pl.ds(page_w, 1), pl.ds(h, 1), pl.ds(slot_w, 1), :],
                wksem.at[j]).wait()
            pltpu.make_async_copy(
                tokv.at[pl.ds(j, 1)],
                vp.at[pl.ds(page_w, 1), pl.ds(h, 1), pl.ds(slot_w, 1), :],
                wvsem.at[j]).wait()
        return 0

    jax.lax.fori_loop(0, c, _start_write, 0)
    jax.lax.fori_loop(0, c, _wait_write, 0)

    # -- split-K online softmax over the row's live pages -------------------
    def page_dma(pool, buf, sem, i, slot):
        pg = jnp.maximum(bt_ref[b, i], 0)
        return pltpu.make_async_copy(
            pool.at[pl.ds(pg, 1), pl.ds(h, 1)], buf.at[pl.ds(slot, 1)],
            sem.at[slot])

    page_dma(kp, kbuf, ksem, 0, 0).start()
    page_dma(vp, vbuf, vsem, 0, 0).start()

    q = q_ref[0].astype(jnp.float32)                   # [group, C, D]
    group, _, d = q.shape
    qf = q.reshape(group * c, d)
    # Query row g*C + j carries intra-span offset j -> absolute start + j.
    qpos = start + jax.lax.broadcasted_iota(jnp.int32, (group * c, ps), 0) % c

    def body(i, carry):
        m, l, acc = carry
        slot = jax.lax.rem(i, 2)
        nxt = jax.lax.rem(i + 1, 2)

        @pl.when(i + 1 < n_pages)
        def _prefetch():
            page_dma(kp, kbuf, ksem, i + 1, nxt).start()
            page_dma(vp, vbuf, vsem, i + 1, nxt).start()

        page_dma(kp, kbuf, ksem, i, slot).wait()
        page_dma(vp, vbuf, vsem, i, slot).wait()
        k = kbuf[slot, 0].astype(jnp.float32)          # [ps, D]
        v = vbuf[slot, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            qf, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale    # [group*C, ps]
        cols = i * ps + jax.lax.broadcasted_iota(jnp.int32, (group * c, ps), 1)
        valid = cols <= qpos
        if window is not None:
            valid &= cols > qpos - window
        s = jnp.where(valid, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l * alpha + jnp.sum(p, axis=1)
        acc_new = acc * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    m0 = jnp.full((group * c,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((group * c,), jnp.float32)
    a0 = jnp.zeros((group * c, d), jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, n_pages, body, (m0, l0, a0))
    out = acc / jnp.maximum(l, 1e-30)[:, None]
    o_ref[0] = out.reshape(group, c, d).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("scale", "window", "interpret"))
def paged_chunk_attention(q: jax.Array, k_pages: jax.Array,
                          v_pages: jax.Array, block_tables: jax.Array,
                          start: jax.Array, span: jax.Array,
                          k_new: jax.Array, v_new: jax.Array, *,
                          scale: float, window: int | None = None,
                          interpret: bool = False
                          ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """q: [B, Hq, C, D]; k/v_pages: [P, Hkv, ps, D]; block_tables: i32[B,
    maxp]; start/span: i32[B]; k/v_new: [B, Hkv, C, D] (pool dtype).
    Returns (out [B, Hq, C, D], k_pages, v_pages) with the span written at
    slots ``start..start+span`` (pools updated in place via aliasing)."""
    b, hq, c, d = q.shape
    _, hkv, ps, _ = k_pages.shape
    group = hq // hkv
    grid = (b, hkv)

    q_spec = pl.BlockSpec((1, group, c, d), lambda i, j, *_: (i, j, 0, 0))
    tok_spec = pl.BlockSpec((1, 1, c, d), lambda i, j, *_: (i, j, 0, 0))
    any_spec = pl.BlockSpec(memory_space=pltpu.ANY)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,              # block_tables, start, span
        grid=grid,
        in_specs=[q_spec, tok_spec, tok_spec, any_spec, any_spec],
        out_specs=[q_spec, any_spec, any_spec],
        scratch_shapes=[
            pltpu.VMEM((2, 1, ps, d), k_pages.dtype),   # k page double-buffer
            pltpu.VMEM((2, 1, ps, d), v_pages.dtype),
            pltpu.VMEM((c, 1, 1, d), k_pages.dtype),    # staged span writes
            pltpu.VMEM((c, 1, 1, d), v_pages.dtype),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((c,)),
            pltpu.SemaphoreType.DMA((c,)),
        ],
    )
    kernel = functools.partial(_mha_kernel, ps=ps, c=c, scale=scale,
                               window=window)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct(q.shape, q.dtype),
            jax.ShapeDtypeStruct(k_pages.shape, k_pages.dtype),
            jax.ShapeDtypeStruct(v_pages.shape, v_pages.dtype),
        ],
        # Input indices count the scalar-prefetch operands (0, 1, 2).
        input_output_aliases={6: 1, 7: 2},
        interpret=interpret,
    )(block_tables, start, span, q, k_new, v_new, k_pages, v_pages)


def _mha_kernel_quant(bt_ref, start_ref, span_ref, q_ref, kn_ref, vn_ref,
                      kp_in, vp_in, ks_in, vs_in, o_ref, kp, vp, ks, vs,
                      kbuf, vbuf, ksbuf, vsbuf, tokk, tokv, tokks, tokvs,
                      ksem, vsem, kssem, vssem, wksem, wvsem, wkssem, wvssem,
                      *, ps: int, c: int, scale: float, window: int | None,
                      qmax: float, qdtype):
    """Quantized twin of ``_mha_kernel``: the span's K/V rows quantize
    in-kernel (one scale per token per KV head), values and scales land in
    the same fused multi-slot write phase, and the walk dequantizes each
    page with its DMA'd scale row."""
    b = pl.program_id(0)
    h = pl.program_id(1)
    start = start_ref[b]
    span = span_ref[b]
    kv_len = start + span
    maxp = bt_ref.shape[1]
    n_pages = jnp.minimum((jnp.maximum(kv_len, 1) + ps - 1) // ps, maxp)

    # -- fused multi-slot write: quantize span rows, stage values + scales --
    kq, kscales = _quantize_rows(kn_ref[0, 0].astype(jnp.float32),
                                 qdtype, qmax)               # [C, D], [C]
    vq, vscales = _quantize_rows(vn_ref[0, 0].astype(jnp.float32),
                                 qdtype, qmax)
    tokk[:, 0, 0, :] = kq
    tokv[:, 0, 0, :] = vq
    tokks[:, 0, 0] = kscales
    tokvs[:, 0, 0] = vscales

    def _copies(j):
        pos = start + j
        page_raw = bt_ref[b, jnp.minimum(pos // ps, maxp - 1)]
        page_w = jnp.maximum(page_raw, 0)
        slot_w = pos % ps
        dst = (pl.ds(page_w, 1), pl.ds(h, 1), pl.ds(slot_w, 1))
        return page_raw, pos, (
            pltpu.make_async_copy(
                tokk.at[pl.ds(j, 1)], kp.at[dst + (slice(None),)],
                wksem.at[j]),
            pltpu.make_async_copy(
                tokv.at[pl.ds(j, 1)], vp.at[dst + (slice(None),)],
                wvsem.at[j]),
            pltpu.make_async_copy(
                tokks.at[pl.ds(j, 1)], ks.at[dst], wkssem.at[j]),
            pltpu.make_async_copy(
                tokvs.at[pl.ds(j, 1)], vs.at[dst], wvssem.at[j]),
        )

    def _start_write(j, _):
        page_raw, pos, copies = _copies(j)

        @pl.when((j < span) & (page_raw >= 0) & (pos < maxp * ps))
        def _():
            for cp in copies:
                cp.start()
        return 0

    def _wait_write(j, _):
        page_raw, pos, copies = _copies(j)

        @pl.when((j < span) & (page_raw >= 0) & (pos < maxp * ps))
        def _():
            for cp in copies:
                cp.wait()
        return 0

    jax.lax.fori_loop(0, c, _start_write, 0)
    jax.lax.fori_loop(0, c, _wait_write, 0)

    # -- split-K online softmax, dequant fused into the walk ----------------
    def page_dma(pool, buf, sem, i, slot):
        pg = jnp.maximum(bt_ref[b, i], 0)
        return pltpu.make_async_copy(
            pool.at[pl.ds(pg, 1), pl.ds(h, 1)], buf.at[pl.ds(slot, 1)],
            sem.at[slot])

    page_dma(kp, kbuf, ksem, 0, 0).start()
    page_dma(vp, vbuf, vsem, 0, 0).start()
    page_dma(ks, ksbuf, kssem, 0, 0).start()
    page_dma(vs, vsbuf, vssem, 0, 0).start()

    q = q_ref[0].astype(jnp.float32)                   # [group, C, D]
    group, _, d = q.shape
    qf = q.reshape(group * c, d)
    qpos = start + jax.lax.broadcasted_iota(jnp.int32, (group * c, ps), 0) % c

    def body(i, carry):
        m, l, acc = carry
        slot = jax.lax.rem(i, 2)
        nxt = jax.lax.rem(i + 1, 2)

        @pl.when(i + 1 < n_pages)
        def _prefetch():
            page_dma(kp, kbuf, ksem, i + 1, nxt).start()
            page_dma(vp, vbuf, vsem, i + 1, nxt).start()
            page_dma(ks, ksbuf, kssem, i + 1, nxt).start()
            page_dma(vs, vsbuf, vssem, i + 1, nxt).start()

        page_dma(kp, kbuf, ksem, i, slot).wait()
        page_dma(vp, vbuf, vsem, i, slot).wait()
        page_dma(ks, ksbuf, kssem, i, slot).wait()
        page_dma(vs, vsbuf, vssem, i, slot).wait()
        k = kbuf[slot, 0].astype(jnp.float32) * ksbuf[slot, 0][:, None]
        v = vbuf[slot, 0].astype(jnp.float32) * vsbuf[slot, 0][:, None]
        s = jax.lax.dot_general(
            qf, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale    # [group*C, ps]
        cols = i * ps + jax.lax.broadcasted_iota(jnp.int32, (group * c, ps), 1)
        valid = cols <= qpos
        if window is not None:
            valid &= cols > qpos - window
        s = jnp.where(valid, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l * alpha + jnp.sum(p, axis=1)
        acc_new = acc * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    m0 = jnp.full((group * c,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((group * c,), jnp.float32)
    a0 = jnp.zeros((group * c, d), jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, n_pages, body, (m0, l0, a0))
    out = acc / jnp.maximum(l, 1e-30)[:, None]
    o_ref[0] = out.reshape(group, c, d).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("scale", "window", "qmax", "interpret"))
def paged_chunk_attention_quant(q: jax.Array, k_pages: jax.Array,
                                k_scales: jax.Array, v_pages: jax.Array,
                                v_scales: jax.Array,
                                block_tables: jax.Array, start: jax.Array,
                                span: jax.Array, k_new: jax.Array,
                                v_new: jax.Array, *, scale: float,
                                qmax: float, window: int | None = None,
                                interpret: bool = False):
    """Quantized-pool chunked attention: k/v_pages [P, Hkv, ps, D] int8/fp8
    with k/v_scales [P, Hkv, ps] f32; k/v_new arrive FLOAT [B, Hkv, C, D]
    and quantize in-kernel.  Returns (out, k_pages, v_pages, k_scales,
    v_scales) — pools + scales updated in place via aliasing."""
    b, hq, c, d = q.shape
    _, hkv, ps, _ = k_pages.shape
    group = hq // hkv
    grid = (b, hkv)

    q_spec = pl.BlockSpec((1, group, c, d), lambda i, j, *_: (i, j, 0, 0))
    tok_spec = pl.BlockSpec((1, 1, c, d), lambda i, j, *_: (i, j, 0, 0))
    any_spec = pl.BlockSpec(memory_space=pltpu.ANY)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,              # block_tables, start, span
        grid=grid,
        in_specs=[q_spec, tok_spec, tok_spec,
                  any_spec, any_spec, any_spec, any_spec],
        out_specs=[q_spec, any_spec, any_spec, any_spec, any_spec],
        scratch_shapes=[
            pltpu.VMEM((2, 1, ps, d), k_pages.dtype),   # quantized pages
            pltpu.VMEM((2, 1, ps, d), v_pages.dtype),
            pltpu.VMEM((2, 1, ps), jnp.float32),        # page scale rows
            pltpu.VMEM((2, 1, ps), jnp.float32),
            pltpu.VMEM((c, 1, 1, d), k_pages.dtype),    # staged span writes
            pltpu.VMEM((c, 1, 1, d), v_pages.dtype),
            pltpu.VMEM((c, 1, 1), jnp.float32),         # staged span scales
            pltpu.VMEM((c, 1, 1), jnp.float32),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((c,)),
            pltpu.SemaphoreType.DMA((c,)),
            pltpu.SemaphoreType.DMA((c,)),
            pltpu.SemaphoreType.DMA((c,)),
        ],
    )
    kernel = functools.partial(_mha_kernel_quant, ps=ps, c=c, scale=scale,
                               window=window, qmax=qmax,
                               qdtype=k_pages.dtype)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct(q.shape, q.dtype),
            jax.ShapeDtypeStruct(k_pages.shape, k_pages.dtype),
            jax.ShapeDtypeStruct(v_pages.shape, v_pages.dtype),
            jax.ShapeDtypeStruct(k_scales.shape, k_scales.dtype),
            jax.ShapeDtypeStruct(v_scales.shape, v_scales.dtype),
        ],
        # Input indices count the scalar-prefetch operands (0, 1, 2).
        input_output_aliases={6: 1, 7: 2, 8: 3, 9: 4},
        interpret=interpret,
    )(block_tables, start, span, q, k_new, v_new,
      k_pages, v_pages, k_scales, v_scales)


def _mla_kernel(bt_ref, start_ref, span_ref, q_ref, ln_ref, lp_in,
                o_ref, lp, buf, tok, dsem, wsem, *, ps: int, c: int,
                r: int, width: int, scale: float):
    b = pl.program_id(0)
    start = start_ref[b]
    span = span_ref[b]
    kv_len = start + span
    maxp = bt_ref.shape[1]
    n_pages = jnp.minimum((jnp.maximum(kv_len, 1) + ps - 1) // ps, maxp)

    # -- fused multi-slot write: span latent rows -> their page slots -------
    tok[:, 0, :] = ln_ref[0]

    def _start_write(j, _):
        pos = start + j
        page_raw = bt_ref[b, jnp.minimum(pos // ps, maxp - 1)]
        page_w = jnp.maximum(page_raw, 0)
        slot_w = pos % ps

        @pl.when((j < span) & (page_raw >= 0) & (pos < maxp * ps))
        def _():
            pltpu.make_async_copy(
                tok.at[pl.ds(j, 1)],
                lp.at[pl.ds(page_w, 1), pl.ds(slot_w, 1), :],
                wsem.at[j]).start()
        return 0

    def _wait_write(j, _):
        pos = start + j
        page_raw = bt_ref[b, jnp.minimum(pos // ps, maxp - 1)]
        page_w = jnp.maximum(page_raw, 0)
        slot_w = pos % ps

        @pl.when((j < span) & (page_raw >= 0) & (pos < maxp * ps))
        def _():
            pltpu.make_async_copy(
                tok.at[pl.ds(j, 1)],
                lp.at[pl.ds(page_w, 1), pl.ds(slot_w, 1), :],
                wsem.at[j]).wait()
        return 0

    jax.lax.fori_loop(0, c, _start_write, 0)
    jax.lax.fori_loop(0, c, _wait_write, 0)

    # -- split-K online softmax over the row's live pages -------------------
    def page_dma(i, slot):
        pg = jnp.maximum(bt_ref[b, i], 0)
        return pltpu.make_async_copy(
            lp.at[pl.ds(pg, 1)], buf.at[pl.ds(slot, 1)], dsem.at[slot])

    page_dma(0, 0).start()

    q = q_ref[0].astype(jnp.float32)                   # [H, C, width]
    h = q.shape[0]
    qf = q.reshape(h * c, width)
    qpos = start + jax.lax.broadcasted_iota(jnp.int32, (h * c, ps), 0) % c

    def body(i, carry):
        m, l, acc = carry
        slot = jax.lax.rem(i, 2)
        nxt = jax.lax.rem(i + 1, 2)

        @pl.when(i + 1 < n_pages)
        def _prefetch():
            page_dma(i + 1, nxt).start()

        page_dma(i, slot).wait()
        lat = buf[slot].astype(jnp.float32)            # [ps, Dp]
        s = jax.lax.dot_general(
            qf, lat[:, :width], (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale    # [H*C, ps]
        cols = i * ps + jax.lax.broadcasted_iota(jnp.int32, (h * c, ps), 1)
        s = jnp.where(cols <= qpos, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l * alpha + jnp.sum(p, axis=1)
        acc_new = acc * alpha[:, None] + jax.lax.dot_general(
            p, lat[:, :r], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)        # [H*C, r]
        return m_new, l_new, acc_new

    m0 = jnp.full((h * c,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((h * c,), jnp.float32)
    a0 = jnp.zeros((h * c, r), jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, n_pages, body, (m0, l0, a0))
    out = acc / jnp.maximum(l, 1e-30)[:, None]
    o_ref[0] = out.reshape(h, c, r).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("r", "scale", "interpret"))
def paged_mla_chunk(q: jax.Array, latent_pages: jax.Array,
                    block_tables: jax.Array, start: jax.Array,
                    span: jax.Array, latent_new: jax.Array, *, r: int,
                    scale: float, interpret: bool = False
                    ) -> tuple[jax.Array, jax.Array]:
    """q: [B, H, C, width] absorbed queries concat([q_abs; q_rope]);
    latent_pages: [P, ps, Dp] (Dp >= width, first r features are ckv);
    block_tables: i32[B, maxp]; start/span: i32[B]; latent_new: [B, C, Dp].
    Returns (ctx [B, H, C, r] f32, latent_pages) with the span's latent rows
    written at slots ``start..start+span`` (pool updated in place)."""
    b, h, c, width = q.shape
    _, ps, dp = latent_pages.shape
    grid = (b,)

    q_spec = pl.BlockSpec((1, h, c, width), lambda i, *_: (i, 0, 0, 0))
    tok_spec = pl.BlockSpec((1, c, dp), lambda i, *_: (i, 0, 0))
    out_spec = pl.BlockSpec((1, h, c, r), lambda i, *_: (i, 0, 0, 0))
    any_spec = pl.BlockSpec(memory_space=pltpu.ANY)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,              # block_tables, start, span
        grid=grid,
        in_specs=[q_spec, tok_spec, any_spec],
        out_specs=[out_spec, any_spec],
        scratch_shapes=[
            pltpu.VMEM((2, ps, dp), latent_pages.dtype),     # double buffer
            pltpu.VMEM((c, 1, dp), latent_pages.dtype),      # staged writes
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((c,)),
        ],
    )
    kernel = functools.partial(_mla_kernel, ps=ps, c=c, r=r, width=width,
                               scale=scale)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, h, c, r), jnp.float32),
            jax.ShapeDtypeStruct(latent_pages.shape, latent_pages.dtype),
        ],
        # Input indices count the scalar-prefetch operands (0, 1, 2).
        input_output_aliases={5: 1},
        interpret=interpret,
    )(block_tables, start, span, q, latent_new, latent_pages)


def _mla_kernel_quant(bt_ref, start_ref, span_ref, q_ref, ln_ref, lp_in,
                      ls_in, o_ref, lp, ls, buf, sbuf, tok, toks,
                      dsem, ssem, wsem, wssem, *, ps: int, c: int,
                      r: int, width: int, scale: float, qmax: float, qdtype):
    """Quantized twin of ``_mla_kernel``: span latent rows quantize
    in-kernel (one scale per token), write fused with their scales, and the
    walk dequantizes each page with its DMA'd scale row."""
    b = pl.program_id(0)
    start = start_ref[b]
    span = span_ref[b]
    kv_len = start + span
    maxp = bt_ref.shape[1]
    n_pages = jnp.minimum((jnp.maximum(kv_len, 1) + ps - 1) // ps, maxp)

    # -- fused multi-slot write: quantize span rows, stage values + scales --
    lq, lscales = _quantize_rows(ln_ref[0].astype(jnp.float32),
                                 qdtype, qmax)               # [C, Dp], [C]
    tok[:, 0, :] = lq
    toks[:, 0] = lscales

    def _copies(j):
        pos = start + j
        page_raw = bt_ref[b, jnp.minimum(pos // ps, maxp - 1)]
        page_w = jnp.maximum(page_raw, 0)
        slot_w = pos % ps
        return page_raw, pos, (
            pltpu.make_async_copy(
                tok.at[pl.ds(j, 1)],
                lp.at[pl.ds(page_w, 1), pl.ds(slot_w, 1), :],
                wsem.at[j]),
            pltpu.make_async_copy(
                toks.at[pl.ds(j, 1)],
                ls.at[pl.ds(page_w, 1), pl.ds(slot_w, 1)],
                wssem.at[j]),
        )

    def _start_write(j, _):
        page_raw, pos, copies = _copies(j)

        @pl.when((j < span) & (page_raw >= 0) & (pos < maxp * ps))
        def _():
            for cp in copies:
                cp.start()
        return 0

    def _wait_write(j, _):
        page_raw, pos, copies = _copies(j)

        @pl.when((j < span) & (page_raw >= 0) & (pos < maxp * ps))
        def _():
            for cp in copies:
                cp.wait()
        return 0

    jax.lax.fori_loop(0, c, _start_write, 0)
    jax.lax.fori_loop(0, c, _wait_write, 0)

    # -- split-K online softmax, dequant fused into the walk ----------------
    def page_dma(i, slot):
        pg = jnp.maximum(bt_ref[b, i], 0)
        return pltpu.make_async_copy(
            lp.at[pl.ds(pg, 1)], buf.at[pl.ds(slot, 1)], dsem.at[slot])

    def scale_dma(i, slot):
        pg = jnp.maximum(bt_ref[b, i], 0)
        return pltpu.make_async_copy(
            ls.at[pl.ds(pg, 1)], sbuf.at[pl.ds(slot, 1)], ssem.at[slot])

    page_dma(0, 0).start()
    scale_dma(0, 0).start()

    q = q_ref[0].astype(jnp.float32)                   # [H, C, width]
    h = q.shape[0]
    qf = q.reshape(h * c, width)
    qpos = start + jax.lax.broadcasted_iota(jnp.int32, (h * c, ps), 0) % c

    def body(i, carry):
        m, l, acc = carry
        slot = jax.lax.rem(i, 2)
        nxt = jax.lax.rem(i + 1, 2)

        @pl.when(i + 1 < n_pages)
        def _prefetch():
            page_dma(i + 1, nxt).start()
            scale_dma(i + 1, nxt).start()

        page_dma(i, slot).wait()
        scale_dma(i, slot).wait()
        lat = buf[slot].astype(jnp.float32) * sbuf[slot][:, None]
        s = jax.lax.dot_general(
            qf, lat[:, :width], (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale    # [H*C, ps]
        cols = i * ps + jax.lax.broadcasted_iota(jnp.int32, (h * c, ps), 1)
        s = jnp.where(cols <= qpos, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l * alpha + jnp.sum(p, axis=1)
        acc_new = acc * alpha[:, None] + jax.lax.dot_general(
            p, lat[:, :r], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)        # [H*C, r]
        return m_new, l_new, acc_new

    m0 = jnp.full((h * c,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((h * c,), jnp.float32)
    a0 = jnp.zeros((h * c, r), jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, n_pages, body, (m0, l0, a0))
    out = acc / jnp.maximum(l, 1e-30)[:, None]
    o_ref[0] = out.reshape(h, c, r).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("r", "scale", "qmax", "interpret"))
def paged_mla_chunk_quant(q: jax.Array, latent_pages: jax.Array,
                          latent_scales: jax.Array,
                          block_tables: jax.Array, start: jax.Array,
                          span: jax.Array, latent_new: jax.Array, *,
                          r: int, scale: float, qmax: float,
                          interpret: bool = False):
    """Quantized-pool chunked MLA: latent_pages [P, ps, Dp] int8/fp8 with
    latent_scales [P, ps] f32; latent_new arrives FLOAT [B, C, Dp] and
    quantizes in-kernel.  Returns (ctx [B, H, C, r] f32, latent_pages,
    latent_scales) — pool + scales updated in place via aliasing."""
    b, h, c, width = q.shape
    _, ps, dp = latent_pages.shape
    grid = (b,)

    q_spec = pl.BlockSpec((1, h, c, width), lambda i, *_: (i, 0, 0, 0))
    tok_spec = pl.BlockSpec((1, c, dp), lambda i, *_: (i, 0, 0))
    out_spec = pl.BlockSpec((1, h, c, r), lambda i, *_: (i, 0, 0, 0))
    any_spec = pl.BlockSpec(memory_space=pltpu.ANY)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,              # block_tables, start, span
        grid=grid,
        in_specs=[q_spec, tok_spec, any_spec, any_spec],
        out_specs=[out_spec, any_spec, any_spec],
        scratch_shapes=[
            pltpu.VMEM((2, ps, dp), latent_pages.dtype),     # double buffer
            pltpu.VMEM((2, ps), jnp.float32),                # page scales
            pltpu.VMEM((c, 1, dp), latent_pages.dtype),      # staged writes
            pltpu.VMEM((c, 1), jnp.float32),                 # staged scales
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((c,)),
            pltpu.SemaphoreType.DMA((c,)),
        ],
    )
    kernel = functools.partial(_mla_kernel_quant, ps=ps, c=c, r=r,
                               width=width, scale=scale, qmax=qmax,
                               qdtype=latent_pages.dtype)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, h, c, r), jnp.float32),
            jax.ShapeDtypeStruct(latent_pages.shape, latent_pages.dtype),
            jax.ShapeDtypeStruct(latent_scales.shape, latent_scales.dtype),
        ],
        # Input indices count the scalar-prefetch operands (0, 1, 2).
        input_output_aliases={5: 1, 6: 2},
        interpret=interpret,
    )(block_tables, start, span, q, latent_new,
      latent_pages, latent_scales)
