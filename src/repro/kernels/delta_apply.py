"""Pallas TPU kernel: scatter-apply an LWW delta buffer into a register bank.

Delta-state sync (core/delta.py) ships changed registers as a compact buffer
of (idx, key, payload-row) lanes.  Applying it is a scatter guarded by the
LWW win test — irregular memory traffic that XLA lowers to a serial scatter
loop over HBM.  This kernel instead streams the bank once, tile-by-tile in
VMEM, and for each tile sweeps the (small, VMEM-resident) delta buffer:
lane j hits a tile row when ``idx[j]`` falls inside it AND its key beats the
current register key.  Bank tiles are read and written once; the delta
buffer is broadcast-compared on the VPU — bandwidth-bound in the bank, like
kernels/lww_merge.py on which it is modeled.

Sweeping lanes in order gives sequential-max semantics, so duplicate target
indices resolve to the largest key (core/delta.py extraction emits unique
indices; duplicates would hit XLA's unspecified scatter order on the jnp
path).  Empty lanes carry ``idx = -1`` and can never match a row.

``idx``/``key`` live in SMEM (scalar loop reads); payload rows load via a
dynamic sublane slice.  Blocks are 128-aligned (the ops.py wrapper pads).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _apply_kernel(idx_ref, dkey_ref, dpay_ref, key_ref, pay_ref,
                  key_o_ref, pay_o_ref, *, block_k: int):
    # 2-D iota (Mosaic rejects rank-1 iota on TPU), flattened to the
    # rank-1 row-id vector the block layout uses.
    rows = (pl.program_id(0) * block_k
            + jax.lax.broadcasted_iota(jnp.int32, (block_k, 1), 0)[:, 0])
    n_lanes = dkey_ref.shape[0]

    def lane(j, carry):
        key, pay = carry
        tgt = idx_ref[j]
        dk = dkey_ref[j]
        hit = (rows == tgt) & (dk > key)
        drow = pl.load(dpay_ref, (pl.dslice(j, 1), slice(None)))     # [1, D]
        key = jnp.where(hit, dk, key)
        pay = jnp.where(hit[:, None], drow, pay)
        return key, pay

    key, pay = jax.lax.fori_loop(
        0, n_lanes, lane, (key_ref[...], pay_ref[...]))
    key_o_ref[...] = key
    pay_o_ref[...] = pay


@functools.partial(jax.jit,
                   static_argnames=("block_k", "interpret"))
def delta_apply(key: jax.Array, pay: jax.Array, d_idx: jax.Array,
                d_key: jax.Array, d_pay: jax.Array, *, block_k: int = 1024,
                interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """key: i32[K]; pay: [K, D]; d_idx/d_key: i32[Dc]; d_pay: [Dc, D].

    K, D, Dc already padded by ops.py (empty delta lanes hold idx = -1).
    """
    k_dim, d = pay.shape
    dc = d_idx.shape[0]
    grid = (k_dim // block_k,)
    key_spec = pl.BlockSpec((block_k,), lambda i: (i,))
    pay_spec = pl.BlockSpec((block_k, d), lambda i: (i, 0))
    lane_spec = pl.BlockSpec((dc,), lambda i: (0,),
                             memory_space=pltpu.SMEM)
    dpay_spec = pl.BlockSpec((dc, d), lambda i: (0, 0))
    return pl.pallas_call(
        functools.partial(_apply_kernel, block_k=block_k),
        grid=grid,
        in_specs=[lane_spec, lane_spec, dpay_spec, key_spec, pay_spec],
        out_specs=[key_spec, pay_spec],
        out_shape=[
            jax.ShapeDtypeStruct(key.shape, key.dtype),
            jax.ShapeDtypeStruct(pay.shape, pay.dtype),
        ],
        interpret=interpret,
    )(d_idx, d_key, d_pay, key, pay)
