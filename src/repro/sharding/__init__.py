"""repro.sharding subsystem."""
