"""Parameter/activation partitioner: param-tree path -> PartitionSpec.

Megatron-style tensor parallelism on the ``model`` axis, (pod×)data
parallelism on the batch dims, expert parallelism for MoE banks, with
divisibility guards (a dim that doesn't divide the mesh axis is replicated —
e.g. MQA kv projections with one head, whisper's odd vocab).

Layer-stacked ("groups") params carry a leading scan dim that is never
sharded.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Params = Any


class Partitioner:
    def __init__(self, mesh: Mesh, *, model_axis: str = "model",
                 fsdp: bool = True, mla_cache: str = "latent"):
        self.mesh = mesh
        self.model_axis = model_axis
        self.model_size = mesh.shape[model_axis]
        # Batch shards over every non-model axis ("pod" included if present).
        self.batch_axes = tuple(a for a in mesh.axis_names if a != model_axis)
        self.data_size = 1
        for a in self.batch_axes:
            self.data_size *= mesh.shape[a]
        # FSDP: additionally shard each parameter's largest remaining dim over
        # the data axes (params are all-gathered per layer inside the scan;
        # grads reduce-scatter back — the standard fully-sharded schedule).
        self.fsdp = fsdp
        # §Perf variants for the MLA latent cache sharding:
        #   "latent"     shard r over model (baseline; logits all-reduce)
        #   "replicated" replicate (no collectives; full cache read/device)
        #   "seq"        shard S over model (local r-contraction, partial
        #                softmax with tiny [B,H] reductions — the winner)
        self.mla_cache = mla_cache

    # -- helpers -----------------------------------------------------------

    def _m(self, dim_size: int):
        """'model' if it divides, else replicated."""
        return self.model_axis if dim_size % self.model_size == 0 else None

    def batch_spec(self, extra_dims: int = 1) -> P:
        return P(self.batch_axes, *([None] * extra_dims))

    # -- parameter rules ---------------------------------------------------

    _COL = {"wq", "wk", "wv", "gate", "up", "in_gate", "in_rec", "up_mlstm",
            "up_gate", "w_in", "w_q", "w_uk", "w_uv", "head", "gate_i",
            "gate_r", "conv_w"}
    _ROW = {"wo", "down", "out", "w_o", "xattn_out"}
    _REPL = {"router", "w_dkv", "w_kr", "w_if", "kv_norm"}

    def param_spec(self, path: tuple[str, ...], shape: tuple[int, ...]) -> P:
        stacked = "groups" in path or "blocks" in path
        core = shape[1:] if stacked else shape
        base = self._base_spec(path, core)
        if self.fsdp and len(core) >= 2:
            base = self._fsdpify(base, core)
        if stacked:
            base = P(None, *base)
        assert len(base) <= len(shape), (path, shape, base)
        return base

    def _fsdpify(self, spec: P, shape) -> P:
        parts = list(spec) + [None] * (len(shape) - len(spec))
        # Largest replicated, divisible dim gets the data axes.
        best, best_dim = None, 0
        for i, (ax, dim) in enumerate(zip(parts, shape)):
            if ax is None and dim % self.data_size == 0 and dim > best_dim:
                best, best_dim = i, dim
        if best is not None:
            parts[best] = self.batch_axes
        return P(*parts)

    def _base_spec(self, path: tuple[str, ...], shape) -> P:
        name = path[-1]
        parent = path[-2] if len(path) > 1 else ""
        grandparent = path[-3] if len(path) > 2 else ""

        if name == "w" and parent == "embed":
            return P(self._m(shape[0]), None)            # vocab rows
        if parent in self._REPL or name in self._REPL:
            return P(*([None] * len(shape)))
        # MoE expert banks: expert-parallel on dim 0.
        if parent == "experts":
            return P(self._m(shape[0]), None, None)
        if parent == "shared":
            if name == "down":
                return P(None, self._m(shape[1]), None)
            return P(None, None, self._m(shape[2]))
        if name == "r" and len(shape) == 3:               # sLSTM recurrent
            return P(self._m(shape[0]), None, None)
        if name == "log_lambda":
            return P(self._m(shape[0]))
        if name == "conv_w":
            return P(None, self._m(shape[1]))
        if name == "w":
            key = parent
            if key in self._COL:
                return P(None, self._m(shape[1]))
            if key in self._ROW:
                return P(self._m(shape[0]), None)
        if name == "b":
            if parent in self._COL:
                return P(self._m(shape[0]))
            return P(None)
        # norms, pos embeddings, scalars: replicated.
        return P(*([None] * len(shape)))

    def params_specs(self, params: Params) -> Params:
        def spec(path, leaf):
            keys = tuple(_key_str(k) for k in path)
            return self.param_spec(keys, np.shape(leaf))

        return jax.tree_util.tree_map_with_path(spec, params)

    def params_shardings(self, params: Params) -> Params:
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s),
                            self.params_specs(params))

    # -- cache rules (serving) ---------------------------------------------

    def cache_entry_spec(self, path: tuple[str, ...], shape,
                         *, shard_batch: bool, stacked: bool) -> P:
        """KV/recurrent cache sharding.

        Preference order per entry: shard heads on 'model' when divisible;
        otherwise shard the sequence axis (flash-decode handles partial
        softmax); batch on the data axes when divisible (long_500k has
        batch=1 -> sequence sharding carries the parallelism).
        """
        name = path[-1]
        core = shape[1:] if stacked else shape
        b_ax = self.batch_axes if shard_batch else None
        # Paged pools are shared across rows: dim 0 is pages, NOT batch —
        # never sharded over the data axes.  MHA pools shard over heads,
        # MLA latent pools over the latent-feature axis; block tables (and
        # the tiny coordination frontiers they travel with) replicate so
        # every device can walk any row's pages.
        if name in ("k_pages", "v_pages"):                # [P, Hkv, ps, D]
            spec = P(None, self._m(core[1]), None, None)
            return P(None, *spec) if stacked else spec
        if name == "latent_pages":                        # [P, ps, Dp]
            spec = P(None, None, self._m(core[2]))
            return P(None, *spec) if stacked else spec
        if name == "block_tables":                        # [B, maxp]
            spec = P(None, None)
            return P(None, *spec) if stacked else spec
        if name in ("k", "v", "xk", "xv"):                # [B, Hkv, S, D]
            h_ax = self._m(core[1])
            s_ax = self._m(core[2]) if h_ax is None else None
            spec = P(b_ax, h_ax, s_ax, None)
        elif name in ("ckv", "krope"):                    # [B, S, r]
            if self.mla_cache == "seq":
                spec = P(b_ax, self._m(core[1]), None)
            elif self.mla_cache == "replicated":
                spec = P(b_ax, None, None)
            else:                                          # "latent"
                spec = P(b_ax, None, self._m(core[2]))
        elif name == "C":                                 # [B, H, dh, dh]
            spec = P(b_ax, self._m(core[1]), None, None)
        elif name in ("h", "n", "c", "m") and len(core) == 2:
            spec = P(b_ax, self._m(core[1]))
        elif name == "conv":                              # [B, cw-1, W]
            spec = P(b_ax, None, self._m(core[2]))
        elif name in ("n",) and len(core) == 3:           # mLSTM n [B,H,dh]
            spec = P(b_ax, self._m(core[1]), None)
        elif name == "m" and len(core) == 2:
            spec = P(b_ax, None)
        else:
            spec = P(b_ax, *([None] * (len(core) - 1)))
        if stacked:
            spec = P(None, *spec)
        return spec

    def cache_shardings(self, cache: Params, *, shard_batch: bool = True
                        ) -> Params:
        def spec(path, leaf):
            keys = tuple(_key_str(k) for k in path)
            stacked = "groups" in keys
            return NamedSharding(
                self.mesh,
                self.cache_entry_spec(keys, np.shape(leaf),
                                      shard_batch=shard_batch,
                                      stacked=stacked))

        return jax.tree_util.tree_map_with_path(spec, cache)


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "name"):
        return str(k.name)
    return str(getattr(k, "idx", k))
