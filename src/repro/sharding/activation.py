"""Logical activation-sharding constraints.

Model code stays mesh-agnostic: it annotates activations with *logical* axis
names; the launcher binds logical names to mesh axes before lowering.  With
no binding active (CPU smoke tests) the constraint is a no-op.

The one constraint that matters most: logits stay vocab-sharded through the
fp32 softmax/cross-entropy — without it GSPMD materializes an unsharded
[B, T, V] fp32 buffer per device (observed: 13 GiB/device for olmo train_4k).
"""
from __future__ import annotations

import contextlib
from typing import Optional

import jax
from jax.sharding import PartitionSpec as P

_BINDING: dict[str, Optional[str | tuple[str, ...]]] = {}


@contextlib.contextmanager
def bind(mapping: dict[str, Optional[str | tuple[str, ...]]]):
    """Bind logical axis names -> mesh axes for the enclosed lowering."""
    global _BINDING
    old = _BINDING
    _BINDING = dict(mapping)
    try:
        yield
    finally:
        _BINDING = old


def constrain(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    if not _BINDING:
        return x
    spec = P(*[_BINDING.get(name) if name else None for name in logical])
    return jax.lax.with_sharding_constraint(x, spec)


def standard_binding(dp_axes: tuple[str, ...], model_axis: str = "model",
                     seq_parallel: bool = True):
    return {"batch": dp_axes, "vocab": model_axis, "heads": model_axis,
            "ffn": model_axis, "seq": model_axis if seq_parallel else None}
