"""Deterministic synthetic data pipeline with sharded work units.

Work is divided into numbered *shards*; shard -> tokens is a pure function
of (seed, shard_id), which is what makes the CRDT elastic work queue safe:
a shard re-claimed from a dead worker reproduces identical batches, so
duplicated work merges idempotently (runtime/elastic.py).

The host pipeline packs documents to fixed seq_len with next-token targets
and runs a double-buffered prefetch thread.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    batch_size: int            # per-worker batch
    seed: int = 0
    shard_size_batches: int = 8
    mean_doc_len: int = 512


def shard_batches(cfg: DataConfig, shard_id: int) -> list[dict[str, np.ndarray]]:
    """All batches of one shard — pure function of (cfg.seed, shard_id)."""
    rng = np.random.default_rng((cfg.seed << 20) ^ shard_id)
    out = []
    for _ in range(cfg.shard_size_batches):
        toks = _packed_tokens(rng, cfg)
        batch = {
            "tokens": toks[:, :-1].astype(np.int32),
            "targets": toks[:, 1:].astype(np.int32),
            "loss_mask": (toks[:, 1:] != 0).astype(np.float32),
        }
        out.append(batch)
    return out


def _packed_tokens(rng: np.random.Generator, cfg: DataConfig) -> np.ndarray:
    """Pack variable-length 'documents' into [B, seq_len+1] rows.

    Documents are Zipf-ish token streams separated by 1 (BOS); padding is 0.
    """
    b, t = cfg.batch_size, cfg.seq_len + 1
    rows = np.zeros((b, t), np.int64)
    for i in range(b):
        pos = 0
        while pos < t:
            doc_len = min(int(rng.exponential(cfg.mean_doc_len)) + 8, t - pos)
            doc = rng.zipf(1.3, size=doc_len)
            doc = np.clip(doc, 2, cfg.vocab_size - 1)
            rows[i, pos] = 1
            rows[i, pos + 1: pos + doc_len] = doc[: doc_len - 1]
            pos += doc_len
    return rows


class Prefetcher:
    """Background-thread double buffering over a shard iterator."""

    def __init__(self, it: Iterator[dict[str, np.ndarray]], depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._done = object()

        def worker():
            for item in it:
                self._q.put(item)
            self._q.put(self._done)

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._done:
            raise StopIteration
        return item


def shard_iterator(cfg: DataConfig, shard_ids: Iterator[int]
                   ) -> Iterator[dict[str, np.ndarray]]:
    for sid in shard_ids:
        yield from shard_batches(cfg, sid)
