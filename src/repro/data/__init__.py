"""repro.data subsystem."""
