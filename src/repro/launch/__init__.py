"""repro.launch subsystem."""
