"""Multi-pod dry-run: prove every (arch × shape × mesh) lowers, compiles,
fits, and records its roofline terms — without hardware.

For each cell this script:
  1. builds abstract params/optimizer/cache/batch (jax.eval_shape — nothing
     is allocated),
  2. ``jax.jit(step, in_shardings=...).lower(...).compile()`` on the
     production mesh (16×16 single-pod, 2×16×16 multi-pod),
  3. records ``compiled.memory_analysis()`` (fits?), ``cost_analysis()``
     (FLOPs/bytes), and collective bytes parsed from the optimized HLO,
  4. writes one JSON per cell under experiments/dryrun/ (incremental:
     existing cells are skipped unless --force).

Usage:
  python -m repro.launch.dryrun --arch olmo-1b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both
  python -m repro.launch.dryrun --arch deepseek-moe-16b --shape train_4k \
      --mesh single --variant dense_dispatch --moe-dispatch dense
"""
from __future__ import annotations

import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# ^ MUST run before any jax import: jax locks the device count on first init.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

import repro.configs as configs
from repro.configs.shapes import SHAPES, ShapeSpec, applicable
from repro.launch import mesh as mesh_mod
from repro.models import lm
from repro.serving import engine as engine_mod
from repro.sharding.partition import Partitioner
from repro.training import optimizer as opt_mod
from repro.training.train_step import make_train_step

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
                "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLL_RE = re.compile(
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_SHAPE_RE = re.compile(r"\b(f64|f32|bf16|f16|s64|s32|s16|s8|u64|u32|u16|u8|"
                       r"pred|f8e4m3fn|f8e5m2)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Bytes through each device's links, per collective op (ring model).

    Refines the brief's "sum operand sizes": operand-only counting
    undercounts all-gather by the group size (each device streams the full
    output through its links in a ring) and all-reduce by 2× (reduce-scatter
    + all-gather phases).  Counted per op:
        all-gather           output bytes
        all-reduce           2 × operand bytes
        reduce-scatter       operand bytes
        all-to-all           operand bytes
        collective-permute   operand bytes
    """
    totals: dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m or "= " not in line:
            continue
        op = m.group(1)
        operand_part = line[m.end():]
        out_part = line[: m.start()]
        operands = _SHAPE_RE.findall(operand_part)
        outputs = _SHAPE_RE.findall(out_part)
        op_bytes = sum(_shape_bytes(dt, dims) for dt, dims in operands)
        out_bytes = sum(_shape_bytes(dt, dims) for dt, dims in outputs)
        if op == "all-gather":
            b = out_bytes or op_bytes
        elif op == "all-reduce":
            b = 2 * (op_bytes or out_bytes)
        else:
            b = op_bytes or out_bytes
        totals[op] = totals.get(op, 0.0) + b
        totals["total"] = totals.get("total", 0.0) + b
    return totals


# ---------------------------------------------------------------------------
# Abstract inputs per (arch, shape)
# ---------------------------------------------------------------------------

def batch_specs(cfg, shape: ShapeSpec):
    """ShapeDtypeStructs for a training batch."""
    b, t = shape.global_batch, shape.seq_len
    sd = jax.ShapeDtypeStruct
    batch = {
        "tokens": sd((b, t), jnp.int32),
        "targets": sd((b, t), jnp.int32),
        "loss_mask": sd((b, t), jnp.float32),
    }
    if cfg.num_prefix_tokens:
        batch["prefix_embeds"] = sd((b, cfg.num_prefix_tokens, cfg.d_model),
                                    jnp.bfloat16)
    if cfg.is_encdec:
        batch["enc_frames"] = sd((b, cfg.encoder.seq_len, cfg.d_model),
                                 jnp.bfloat16)
    return batch


def input_specs(arch: str, shape_name: str):
    """Public helper: abstract inputs for one cell (no allocation)."""
    cfg = configs.get(arch)
    return batch_specs(cfg, SHAPES[shape_name])


def _abstract(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree,
        is_leaf=lambda x: hasattr(x, "shape") and hasattr(x, "dtype"))


def _sharding_tree(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Cell runners
# ---------------------------------------------------------------------------

def lower_cell(cfg, shape: ShapeSpec, mesh, *, merge_strategy="pmax",
               fused_coord=False, microbatches=1, remat=True,
               seq_parallel=True, mla_cache="latent",
               merge_every=1, delta_capacity=64, kv_layout="dense",
               page_size=64):
    """Returns the lowered computation. Never allocates device memory.

    Training cells use FSDP (fully-sharded params/grads/optimizer — the
    at-scale default); serving cells keep params tensor-parallel only
    (per-token FSDP all-gathers would destroy decode latency).
    """
    from repro.sharding import activation
    dp_for_bind = mesh_mod.dp_axes(mesh)
    binding = activation.standard_binding(dp_for_bind,
                                          seq_parallel=seq_parallel)
    with activation.bind(binding):
        return _lower_cell_inner(cfg, shape, mesh,
                                 merge_strategy=merge_strategy,
                                 fused_coord=fused_coord,
                                 microbatches=microbatches, remat=remat,
                                 mla_cache=mla_cache,
                                 merge_every=merge_every,
                                 delta_capacity=delta_capacity,
                                 kv_layout=kv_layout, page_size=page_size)


def _lower_cell_inner(cfg, shape: ShapeSpec, mesh, *, merge_strategy="pmax",
                      fused_coord=False, microbatches=1, remat=True,
                      mla_cache="latent", merge_every=1, delta_capacity=64,
                      kv_layout="dense", page_size=64):
    part = Partitioner(mesh, fsdp=(shape.kind == "train"),
                       mla_cache=mla_cache)
    p_abs = lm.abstract_params(cfg)
    p_shard = part.params_shardings(p_abs)
    dp = mesh_mod.dp_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]

    if shape.kind == "train":
        opt = opt_mod.AdamW()
        o_abs = jax.eval_shape(opt.init, p_abs)
        # FSDP already spreads params over data axes; Adam moments follow the
        # param sharding exactly (this IS ZeRO: opt state fully sharded).
        p_specs = part.params_specs(p_abs)
        o_specs = opt_mod.AdamWState(step=P(), mu=p_specs, nu=p_specs)
        o_shard = _sharding_tree(mesh, o_specs)
        batch = batch_specs(cfg, shape)
        b_shard = jax.tree.map(
            lambda x: NamedSharding(
                mesh, P(dp if x.shape[0] % dp_size == 0 else None,
                        *([None] * (len(x.shape) - 1)))), batch)
        step_fn = make_train_step(cfg, opt, remat=remat,
                                  microbatches=microbatches)
        jitted = jax.jit(step_fn, in_shardings=(p_shard, o_shard, b_shard),
                         donate_argnums=(0, 1))
        with mesh:
            lowered = jitted.lower(p_abs, o_abs, batch)
        return lowered

    b = shape.global_batch
    shard_batch = b % dp_size == 0
    # VLM prefix tokens occupy cache positions too.
    max_len = shape.seq_len + cfg.num_prefix_tokens
    # kv_layout="paged" lowers the fused paged step: pool leaves shard over
    # heads (MHA) / the latent-feature axis (MLA), block tables replicate
    # (see sharding/partition.py) — the multi-host proof for the paged path.
    cache_abs = jax.eval_shape(
        lambda: lm.init_cache(cfg, b, max_len,
                              paged=(kv_layout == "paged"),
                              page_size=page_size))
    c_shard = part.cache_shardings(cache_abs, shard_batch=shard_batch)
    bspec = NamedSharding(mesh, P(dp if shard_batch else None))

    if shape.kind == "prefill":
        sd = jax.ShapeDtypeStruct
        tokens = sd((b, shape.seq_len), jnp.int32)
        tok_shard = NamedSharding(
            mesh, P(dp if shard_batch else None, None))
        # Stub frontends enter as positional args (pjit rejects kwargs when
        # in_shardings is given).
        stub_args = []
        stub_shards = []
        stub_sharding = NamedSharding(
            mesh, P(dp if shard_batch else None, None, None))
        if cfg.num_prefix_tokens:
            stub_args.append(sd((b, cfg.num_prefix_tokens, cfg.d_model),
                                jnp.bfloat16))
            stub_shards.append(stub_sharding)
        if cfg.is_encdec:
            stub_args.append(sd((b, cfg.encoder.seq_len, cfg.d_model),
                                jnp.bfloat16))
            stub_shards.append(stub_sharding)
        prefill_fn = engine_mod.make_prefill_fn(cfg)

        if cfg.num_prefix_tokens:
            def fn(params, cache, tokens, prefix_embeds):
                return prefill_fn(params, cache, tokens,
                                  prefix_embeds=prefix_embeds)
        elif cfg.is_encdec:
            def fn(params, cache, tokens, enc_frames):
                return prefill_fn(params, cache, tokens,
                                  enc_frames=enc_frames)
        else:
            def fn(params, cache, tokens):
                return prefill_fn(params, cache, tokens)

        jitted = jax.jit(
            fn, in_shardings=(p_shard, c_shard, tok_shard, *stub_shards),
            donate_argnums=(1,))
        with mesh:
            lowered = jitted.lower(p_abs, cache_abs, tokens, *stub_args)
        return lowered

    # decode
    sd = jax.ShapeDtypeStruct
    token = sd((b,), jnp.int32)
    pos = sd((b,), jnp.int32)
    if fused_coord:
        n_rep = dp_size
        from repro.core import doc as doc_mod, gset

        def coord_template():
            base = {"doc": doc_mod.empty(64, 2048),
                    "heartbeats": gset.GCounter.zeros(n_rep)}
            if merge_strategy == "delta":
                base = engine_mod.with_delta_frontier(base)
            return engine_mod.replicate_coord(base, n_rep)

        coord_abs = jax.eval_shape(coord_template)
        coord_shard = jax.tree.map(
            lambda x: NamedSharding(mesh, P(dp, *([None] * (x.ndim - 1)))),
            coord_abs)
        step_fn = engine_mod.make_fused_serve_step(
            cfg, mesh, dp, merge_strategy=merge_strategy,
            merge_every=merge_every, delta_capacity=delta_capacity)
        slots = sd((b,), jnp.int32)
        active = sd((b,), jnp.bool_)
        stepi = sd((), jnp.int32)
        jitted = jax.jit(
            step_fn,
            in_shardings=(p_shard, c_shard, bspec, bspec, bspec, bspec,
                          coord_shard, NamedSharding(mesh, P())),
            donate_argnums=(1,))
        with mesh:
            lowered = jitted.lower(p_abs, cache_abs, token, pos, slots,
                                   active, coord_abs, stepi)
        return lowered

    serve_fn = engine_mod.make_serve_step(cfg)
    rng = sd((2,), jnp.uint32)
    jitted = jax.jit(
        serve_fn,
        in_shardings=(p_shard, c_shard, bspec, bspec,
                      NamedSharding(mesh, P(None))),
        donate_argnums=(1,))
    with mesh:
        lowered = jitted.lower(p_abs, cache_abs, token, pos, rng)
    return lowered


def analytic_memory(cfg, shape: ShapeSpec, mesh) -> dict[str, int]:
    """Exact per-device bytes of persistent state from the real shardings.

    The CPU backend's memory_analysis over-reports temp (it materializes f32
    copies of every bf16 weight for matmuls — no native bf16 FMA on host;
    TPU MXUs consume bf16 directly), so the fits-in-HBM judgement uses these
    analytic numbers plus the HLO-inspected transient (EXPERIMENTS.md).
    """
    part = Partitioner(mesh, fsdp=(shape.kind == "train"))
    p_abs = lm.abstract_params(cfg)
    p_shard = part.params_shardings(p_abs)

    def shard_bytes(abs_tree, shardings):
        total = 0
        for leaf, sh in zip(jax.tree.leaves(abs_tree),
                            jax.tree.leaves(shardings)):
            local = sh.shard_shape(leaf.shape)
            n = 1
            for d in local:
                n *= d
            total += n * jnp.dtype(leaf.dtype).itemsize
        return int(total)

    out = {"params_per_device": shard_bytes(p_abs, p_shard)}
    if shape.kind == "train":
        opt = opt_mod.AdamW()
        o_abs = jax.eval_shape(opt.init, p_abs)
        p_specs = part.params_specs(p_abs)
        o_shard = _sharding_tree(
            mesh, opt_mod.AdamWState(step=P(), mu=p_specs, nu=p_specs))
        out["opt_per_device"] = shard_bytes(o_abs, o_shard)
    else:
        dp = mesh_mod.dp_axes(mesh)
        dp_size = 1
        for a in dp:
            dp_size *= mesh.shape[a]
        cache_abs = jax.eval_shape(
            lambda: lm.init_cache(cfg, shape.global_batch,
                                  shape.seq_len + cfg.num_prefix_tokens))
        c_shard = part.cache_shardings(
            cache_abs, shard_batch=shape.global_batch % dp_size == 0)
        out["cache_per_device"] = shard_bytes(cache_abs, c_shard)
    out["total_per_device"] = sum(out.values())
    return out


def _costs_of(compiled) -> dict:
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    coll = collective_bytes(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": coll}


def extrapolated_costs(cfg, shape, mesh, **kw) -> dict:
    """True per-cell costs via two-point extrapolation over layer groups.

    XLA's HloCostAnalysis visits while-loop bodies ONCE (verified), so the
    full model's scan-over-groups undercounts FLOPs/bytes/collective bytes by
    ~G×.  Lowering 1-group and 2-group variants gives the exact marginal cost
    of one group (identical HLO body); total = f(1) + (G-1)·(f(2)-f(1)).
    Inner time-recurrence scans (xLSTM cells, RG-LRU) keep their heavy
    matmuls outside the loop, so their residual undercount is <1% (noted in
    EXPERIMENTS.md).
    """
    g = cfg.pattern_groups
    pat, tail = len(cfg.block_pattern), len(cfg.tail_blocks)

    def variant(groups):
        kw_c = {"num_layers": groups * pat + tail}
        if cfg.encoder is not None:
            kw_c["encoder"] = cfg.encoder.__class__(
                num_layers=groups, num_heads=cfg.encoder.num_heads,
                seq_len=cfg.encoder.seq_len)
        return cfg.replace(**kw_c)

    with lm.unrolled_scans():
        c1 = _costs_of(lower_cell(variant(1), shape, mesh, **kw).compile())
        if g < 2:
            return {"flops": c1["flops"], "bytes": c1["bytes"],
                    "coll_total": c1["coll"].get("total", 0.0),
                    "coll": c1["coll"], "method": "direct-unrolled"}
        c2 = _costs_of(lower_cell(variant(2), shape, mesh, **kw).compile())
    est = {
        "flops": c1["flops"] + (g - 1) * (c2["flops"] - c1["flops"]),
        "bytes": c1["bytes"] + (g - 1) * (c2["bytes"] - c1["bytes"]),
        "method": "two-point group extrapolation",
    }
    coll = {}
    for k in set(c1["coll"]) | set(c2["coll"]):
        a, b = c1["coll"].get(k, 0.0), c2["coll"].get(k, 0.0)
        coll[k] = a + (g - 1) * (b - a)
    est["coll"] = coll
    est["coll_total"] = coll.get("total", 0.0)
    return est


def run_cell(arch: str, shape_name: str, mesh_kind: str, *,
             variant: str = "baseline", force: bool = False,
             merge_strategy: str = "pmax", fused_coord: bool = False,
             moe_dispatch: str | None = None, remat: bool = True,
             microbatches: int = 1, capacity_factor: float | None = None,
             mla_cache: str = "latent", merge_every: int = 1,
             delta_capacity: int = 64, ring_cache: bool = False,
             kv_layout: str = "dense", page_size: int = 64) -> dict:
    shape = SHAPES[shape_name]
    cfg = configs.get(arch)
    if ring_cache:
        cfg = cfg.replace(ring_local_cache=True)
    if cfg.moe and (moe_dispatch or capacity_factor is not None):
        kw = dict(cfg.moe.__dict__)
        if moe_dispatch:
            kw["dispatch"] = moe_dispatch
        if capacity_factor is not None:
            kw["capacity_factor"] = capacity_factor
        cfg = cfg.replace(moe=cfg.moe.__class__(**kw))

    out_dir = OUT_DIR / mesh_kind
    out_dir.mkdir(parents=True, exist_ok=True)
    out_path = out_dir / f"{arch}__{shape_name}__{variant}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    record: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                    "variant": variant, "kind": shape.kind}
    ok, reason = applicable(cfg, shape)
    if not ok:
        record["status"] = "skipped"
        record["reason"] = reason
        out_path.write_text(json.dumps(record, indent=2))
        return record

    mesh = mesh_mod.make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = mesh.devices.size
    t0 = time.time()
    try:
        lowered = lower_cell(cfg, shape, mesh, merge_strategy=merge_strategy,
                             fused_coord=fused_coord, remat=remat,
                             microbatches=microbatches,
                             mla_cache=mla_cache,
                             merge_every=merge_every,
                             delta_capacity=delta_capacity,
                             kv_layout=kv_layout, page_size=page_size)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        raw = _costs_of(compiled)
        # Cost lowers use microbatches=1: FLOPs/bytes are microbatch-
        # invariant and the mb-scan would hide costs from HloCostAnalysis.
        est = extrapolated_costs(
            cfg, shape, mesh, merge_strategy=merge_strategy,
            fused_coord=fused_coord, remat=remat, microbatches=1,
            mla_cache=mla_cache,
            merge_every=merge_every, delta_capacity=delta_capacity,
            kv_layout=kv_layout, page_size=page_size)
        record.update(
            status="ok", n_devices=int(n_dev),
            lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
            memory={k: int(getattr(mem, k))
                    for k in ("argument_size_in_bytes",
                              "output_size_in_bytes",
                              "temp_size_in_bytes",
                              "generated_code_size_in_bytes")
                    if hasattr(mem, k)},
            # Raw full-compile numbers (scan bodies counted once — see
            # extrapolated_costs docstring) kept for reference:
            raw_flops_per_device=raw["flops"],
            raw_bytes_per_device=raw["bytes"],
            # Extrapolated per-device costs (the roofline inputs):
            flops_per_device=est["flops"],
            bytes_per_device=est["bytes"],
            collective_bytes_per_device=est["coll"],
            cost_method=est["method"],
            model_flops_est=_model_flops(cfg, shape),
            memory_analytic=analytic_memory(cfg, shape, mesh),
        )
    except Exception as e:  # record the failure; the suite reports it
        record.update(status="error", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-4000:])
    out_path.write_text(json.dumps(record, indent=2))
    return record


def _model_flops(cfg, shape: ShapeSpec) -> float:
    """MODEL_FLOPS = 6·N_active·D tokens (train) / 2·N_active·D (inference)."""
    n = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    factor = 6 if shape.kind == "train" else 2
    return float(factor * n * tokens)


# Per-arch microbatch counts for train_4k: chosen so the full compile fits
# 16 GB/chip (per-device batch 16 is split into this many accumulation
# steps; see EXPERIMENTS.md §Dry-run).
TRAIN_MICROBATCHES = {
    "command-r-plus-104b": 8,
    "granite-34b": 4,
    "starcoder2-15b": 2,
    "paligemma-3b": 2,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=[*SHAPES, None])
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--merge-strategy", default="pmax",
                    choices=["pmax", "allgather", "delta"])
    ap.add_argument("--fused-coord", action="store_true")
    ap.add_argument("--moe-dispatch", default=None,
                    choices=[None, "gather", "dense"])
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--capacity-factor", type=float, default=None)
    ap.add_argument("--mla-cache", default="latent",
                    choices=["latent", "replicated", "seq"])
    ap.add_argument("--merge-every", type=int, default=1)
    ap.add_argument("--delta-capacity", type=int, default=64)
    ap.add_argument("--ring-cache", action="store_true")
    ap.add_argument("--kv", default="dense", choices=["dense", "paged"],
                    help="KV cache layout for serving cells")
    ap.add_argument("--page-size", type=int, default=64)
    args = ap.parse_args()

    archs = sorted(configs.ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    for mesh_kind in meshes:
        for arch in archs:
            for shape_name in shapes:
                t0 = time.time()
                mb = args.microbatches
                if mb == 1 and shape_name == "train_4k":
                    mb = TRAIN_MICROBATCHES.get(arch, 1)
                rec = run_cell(
                    arch, shape_name, mesh_kind, variant=args.variant,
                    force=args.force, merge_strategy=args.merge_strategy,
                    fused_coord=args.fused_coord,
                    moe_dispatch=args.moe_dispatch,
                    remat=not args.no_remat,
                    microbatches=mb,
                    capacity_factor=args.capacity_factor,
                    mla_cache=args.mla_cache,
                    merge_every=args.merge_every,
                    delta_capacity=args.delta_capacity,
                    ring_cache=args.ring_cache,
                    kv_layout=args.kv, page_size=args.page_size)
                status = rec.get("status")
                extra = (rec.get("reason") or rec.get("error", "")
                         )[:80] if status != "ok" else (
                    f"flops/dev={rec['flops_per_device']:.3e} "
                    f"coll={rec['collective_bytes_per_device'].get('total', 0):.3e}B")
                print(f"[{mesh_kind}] {arch} × {shape_name} ({args.variant}): "
                      f"{status} ({time.time()-t0:.1f}s) {extra}", flush=True)


if __name__ == "__main__":
    main()
