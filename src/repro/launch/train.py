"""Training launcher: elastic, fault-tolerant, checkpointed.

CPU-scale driver for any registered arch (reduced config by default — the
full configs are exercised through the dry-run).  On a real pod this same
entry point runs per-host with jax.distributed initialization; the CRDT
work queue replaces the central data scheduler.

  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --steps 100 \\
      --workers 2 [--fail-worker1-at 30] [--full-config]
"""
from __future__ import annotations

import argparse
import tempfile

import repro.configs as configs
from repro.data.pipeline import DataConfig
from repro.runtime.elastic import Worker, make_queue, make_shared_fold_sync
from repro.training.optimizer import AdamW
from repro.training.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b", choices=sorted(configs.ARCHS))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--workers", type=int, default=1)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--fail-worker1-at", type=int, default=None,
                    help="inject a worker-1 crash after N steps")
    ap.add_argument("--full-config", action="store_true",
                    help="use the full arch config (needs real hardware)")
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    cfg = configs.get(args.arch)
    if not args.full_config:
        cfg = configs.reduced(cfg, d_model=128, vocab=1024)
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                          batch_size=args.batch)
    ckpt = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_train_")
    tcfg = TrainerConfig(steps=args.steps, checkpoint_every=args.ckpt_every,
                         checkpoint_dir=ckpt)
    opt = AdamW(lr_peak=args.lr, warmup=max(args.steps // 10, 1),
                total_steps=args.steps)

    shared: dict = {}
    sync = make_shared_fold_sync(shared)
    queue = make_queue(num_shards=max(args.steps // 4 + 2, 8),
                       num_workers=args.workers)

    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"steps={args.steps} workers={args.workers} ckpt={ckpt}")

    state = queue
    for w_id in range(1, args.workers + 1):
        worker = Worker(w_id, state, sync)
        trainer = Trainer(cfg, data_cfg, tcfg, opt=opt)
        trainer.maybe_restore()
        fail = args.fail_worker1_at if w_id == 1 else None
        out = trainer.run(worker, now_fn=lambda w=w_id: w * 1000,
                          fail_after_steps=fail)
        last = out["metrics"][-1] if out["metrics"] else {}
        print(f"worker{w_id}: crashed={out['crashed']} step={out['step']} "
              f"loss={last.get('loss', float('nan')):.4f} "
              f"grad_norm={last.get('grad_norm', float('nan')):.3f}")
        state = shared["state"]
        if not out["crashed"] and out["step"] >= args.steps:
            break
    print("done")


if __name__ == "__main__":
    main()
