"""Production mesh builders.

Functions (not module-level constants) so importing never touches jax device
state.  Production target: TPU v5e pods, 256 chips each.

  single-pod:  (data=16, model=16)            = 256 chips
  multi-pod:   (pod=2, data=16, model=16)     = 512 chips
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 2, model: int = 2) -> jax.sharding.Mesh:
    """Small host-device mesh for CPU integration tests."""
    return jax.make_mesh((data, model), ("data", "model"))


def dp_axes(mesh: jax.sharding.Mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a != "model")


# v5e hardware constants for the roofline (per chip).
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW_PER_LINK = 50e9          # B/s  (~per link)
