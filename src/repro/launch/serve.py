"""Serving launcher: batched multi-agent generation service.

Runs the CodeCRDT serving stack for an arch: N agent streams on one decode
batch, CRDT coordination, convergence report.  This is the CPU-scale entry;
the production mesh path is exercised by launch/dryrun.py (--fused-coord
lowers the decode+coordination step on 256/512 chips).

  PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b \\
      --task dashboard --mode parallel --agents 4
"""
from __future__ import annotations

import argparse
import json

import jax

import repro.configs as configs
from repro.agents.orchestrator import run_task
from repro.agents.tasks import TASKS
from repro.models import lm


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b", choices=sorted(configs.ARCHS))
    ap.add_argument("--task", default="dashboard", choices=sorted(TASKS))
    ap.add_argument("--mode", default="parallel",
                    choices=["sequential", "parallel", "both"])
    ap.add_argument("--agents", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--d-model", type=int, default=64,
                    help="reduced model width (CPU)")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()

    cfg = configs.reduced(configs.get(args.arch), d_model=args.d_model,
                          vocab=512)
    params = lm.init(jax.random.PRNGKey(args.seed), cfg)

    modes = (["sequential", "parallel"] if args.mode == "both"
             else [args.mode])
    out = {}
    for mode in modes:
        r = run_task(cfg, params, TASKS[args.task], mode=mode,
                     n_agents=args.agents, seed=args.seed)
        out[mode] = {
            "steps": r.steps, "wall_s": round(r.wall_s, 3),
            "tokens": r.gen_tokens, "invalidations": r.invalidations,
            "claim_collisions": r.claim_collisions,
            "semantic_conflicts": r.semantic_conflicts,
            "converged": r.converged,
        }
        if not args.json:
            print(f"[{cfg.name} × {args.task} × {mode}] "
                  f"steps={r.steps} wall={r.wall_s:.2f}s "
                  f"tokens={r.gen_tokens} conflicts={r.semantic_conflicts} "
                  f"converged={r.converged}")
    if args.json:
        print(json.dumps(out, indent=2))


if __name__ == "__main__":
    main()
