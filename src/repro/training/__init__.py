"""repro.training subsystem."""
