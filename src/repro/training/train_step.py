"""The jittable training step: loss → grads → AdamW, with optional
microbatch gradient accumulation and activation rematerialization.

Distribution is entirely declarative: the caller pjits this function with
the partitioner's param/opt/batch shardings; XLA inserts the gradient
all-reduce over the (pod, data) axes, the tensor-parallel collectives on
"model", and the ZeRO-1 reduce-scatter/all-gather from the opt-state specs.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.models.config import ModelConfig
from repro.training.optimizer import AdamW, AdamWState

Params = Any


def make_train_step(cfg: ModelConfig, opt: AdamW, *, impl: str = "ref",
                    remat: bool = True, microbatches: int = 1,
                    aux_weight: float = 0.01):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics)."""

    def loss_for(p, mb):
        return lm.loss_fn(p, cfg, mb, impl=impl, aux_weight=aux_weight,
                          remat=remat)

    def train_step(params: Params, opt_state: AdamWState,
                   batch: dict[str, jax.Array]):
        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_for, has_aux=True)(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                return x.reshape(microbatches, b // microbatches, *x.shape[1:])

            mbs = jax.tree.map(split, batch)

            def acc_body(carry, mb):
                g_acc, l_acc = carry
                (l, m), g = jax.value_and_grad(loss_for, has_aux=True)(
                    params, mb)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (g_acc, l_acc + l), m

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), ms = jax.lax.scan(
                acc_body, (g0, jnp.zeros((), jnp.float32)), mbs)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss_sum / microbatches
            metrics = jax.tree.map(lambda x: x[-1], ms)

        params, opt_state, opt_metrics = opt.update(grads, opt_state, params)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return params, opt_state, metrics

    return train_step


def make_eval_step(cfg: ModelConfig, *, impl: str = "ref"):
    def eval_step(params: Params, batch):
        loss, metrics = lm.loss_fn(params, cfg, batch, impl=impl)
        return dict(metrics, loss=loss)

    return eval_step
