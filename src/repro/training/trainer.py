"""Fault-tolerant training driver.

Composes: CRDT elastic work queue (shard claims) + deterministic data
pipeline + jitted train step + async checkpointing + crash/restart recovery.
``run`` survives injected worker failures: a failed worker's claimed shard
times out, is reclaimed by a survivor, and training resumes from the last
checkpoint with bit-identical data (tested in tests/test_trainer.py).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from pathlib import Path
from typing import Callable, Optional

import jax
import numpy as np

from repro.data.pipeline import DataConfig, shard_batches
from repro.models import lm
from repro.models.config import ModelConfig
from repro.runtime import checkpoint as ckpt_mod
from repro.runtime.elastic import Worker, WorkQueueState, make_queue
from repro.training.optimizer import AdamW
from repro.training.train_step import make_train_step


@dataclass
class TrainerConfig:
    steps: int = 100
    checkpoint_every: int = 20
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep: int = 2
    shard_timeout: int = 120


class Trainer:
    def __init__(self, cfg: ModelConfig, data_cfg: DataConfig,
                 tcfg: TrainerConfig, opt: Optional[AdamW] = None,
                 seed: int = 0):
        self.cfg = cfg
        self.data_cfg = data_cfg
        self.tcfg = tcfg
        self.opt = opt or AdamW(warmup=10, total_steps=tcfg.steps)
        self.params = lm.init(jax.random.PRNGKey(seed), cfg)
        self.opt_state = self.opt.init(self.params)
        self.step = 0
        self._train_step = jax.jit(make_train_step(cfg, self.opt,
                                                   remat=False))
        self.ckpt = ckpt_mod.AsyncCheckpointer(tcfg.checkpoint_dir,
                                               keep=tcfg.keep)

    # -- checkpoint/restart -------------------------------------------------

    def save(self) -> None:
        self.ckpt.save(self.step, {"params": self.params,
                                   "opt": self.opt_state})

    def maybe_restore(self) -> bool:
        latest = ckpt_mod.latest_step(self.tcfg.checkpoint_dir)
        if latest is None:
            return False
        tree, step = ckpt_mod.restore(
            self.tcfg.checkpoint_dir,
            {"params": self.params, "opt": self.opt_state})
        self.params, self.opt_state = tree["params"], tree["opt"]
        self.step = step
        return True

    # -- elastic training loop ----------------------------------------------

    def run(self, worker: Worker, *, now_fn: Callable[[], int] = None,
            fail_after_steps: Optional[int] = None) -> dict:
        """Train until the queue is drained or tcfg.steps is reached.

        ``fail_after_steps`` injects a crash (for fault-tolerance tests):
        the worker simply stops, leaving its claim to go stale.
        """
        now_fn = now_fn or (lambda: int(time.time()))
        metrics_hist = []
        steps_done = 0
        while self.step < self.tcfg.steps and not worker.done():
            worker.heartbeat(now_fn())
            worker.reclaim_stale(now_fn())
            shard = worker.try_claim_shard(now_fn())
            if shard is None:
                if worker.done():
                    break
                continue
            for batch in shard_batches(self.data_cfg, shard):
                self.params, self.opt_state, m = self._train_step(
                    self.params, self.opt_state, batch)
                self.step += 1
                steps_done += 1
                metrics_hist.append({k: float(v) for k, v in m.items()})
                if self.step % self.tcfg.checkpoint_every == 0:
                    self.save()
                if fail_after_steps is not None and steps_done >= fail_after_steps:
                    return {"crashed": True, "step": self.step,
                            "metrics": metrics_hist}
                if self.step >= self.tcfg.steps:
                    break
            worker.complete_shard(shard)
        self.save()
        self.ckpt.wait()
        return {"crashed": False, "step": self.step, "metrics": metrics_hist}
