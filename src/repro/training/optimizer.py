"""AdamW with global-norm clipping, warmup+cosine schedule, and ZeRO-1
optimizer-state sharding.

ZeRO-1 here is purely declarative: ``zero1_specs`` extends each parameter's
PartitionSpec by sharding the first replicated, divisible dimension of the
Adam moments over the data axes.  Under pjit, XLA then materializes the
reduce-scatter(grads) → local moment update → all-gather(params) schedule
automatically — the standard ZeRO-1 communication pattern without manual
collectives.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Params = Any


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Params        # fp32
    nu: Params        # fp32


class AdamW(NamedTuple):
    lr_peak: float = 3e-4
    warmup: int = 200
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0

    def init(self, params: Params) -> AdamWState:
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          mu=jax.tree.map(z, params),
                          nu=jax.tree.map(z, params))

    def lr(self, step: jax.Array) -> jax.Array:
        step = step.astype(jnp.float32)
        warm = step / max(self.warmup, 1)
        decay_steps = max(self.total_steps - self.warmup, 1)
        t = jnp.clip((step - self.warmup) / decay_steps, 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
        return self.lr_peak * jnp.where(step < self.warmup, warm,
                                        0.1 + 0.9 * cos)

    def update(self, grads: Params, state: AdamWState, params: Params
               ) -> tuple[Params, AdamWState, dict[str, jax.Array]]:
        gf = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g))
                             for g in jax.tree.leaves(gf)))
        scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9))
        step = state.step + 1
        lr = self.lr(step)
        b1c = 1 - self.b1 ** step.astype(jnp.float32)
        b2c = 1 - self.b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g = g * scale
            m = self.b1 * m + (1 - self.b1) * g
            v = self.b2 * v + (1 - self.b2) * jnp.square(g)
            mhat = m / b1c
            vhat = v / b2c
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            delta = delta + self.weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
            return new_p, m, v

        g_l, td = jax.tree.flatten(gf)
        outs = [upd(g, m, v, p) for g, m, v, p in zip(
            g_l, jax.tree.leaves(state.mu), jax.tree.leaves(state.nu),
            jax.tree.leaves(params))]
        new_params = td.unflatten([o[0] for o in outs])
        new_mu = td.unflatten([o[1] for o in outs])
        new_nu = td.unflatten([o[2] for o in outs])
        return new_params, AdamWState(step, new_mu, new_nu), {
            "grad_norm": gnorm, "lr": lr}


def zero1_specs(param_specs: Params, params: Params,
                data_axes: tuple[str, ...], data_size: int) -> Params:
    """Adam-moment PartitionSpecs: param spec + shard the first replicated,
    divisible dim over the data axes (ZeRO-1)."""
    def moment_spec(spec: P, leaf) -> P:
        shape = jnp.shape(leaf) if hasattr(leaf, "shape") else leaf.shape
        parts = list(spec) + [None] * (len(shape) - len(spec))
        for i, (ax, dim) in enumerate(zip(parts, shape)):
            if ax is None and dim % data_size == 0 and dim >= data_size:
                parts[i] = data_axes
                break
        return P(*parts)

    return jax.tree.map(moment_spec, param_specs, params)
