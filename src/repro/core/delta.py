"""Delta-state CRDT sync: ship O(Δ) deltas instead of O(S) full state.

Every CRDT in repro.core is a join-semilattice, so any *delta* — a small
state fragment — merges into a replica through the same join that full
states use (Almeida et al. 2018, "Delta state replicated data types").
This module adds, for each registered CRDT, three operations:

  ``frontier(state)``            a compact watermark of what has been
                                 observed/shipped so far:
                                   * log-structured types (GLog, RGA):
                                     per-client op-count watermark i32[C],
                                   * SlotDoc: per-slot length watermark i32[K],
                                   * LWWBank / TodoBoard: per-register packed
                                     (clock, client) key watermark i32[K],
                                   * GCounter / GSet: the (tiny) state itself.

  ``extract(state, frontier, capacity)``
                                 the ops beyond ``frontier``, compacted into a
                                 FIXED-CAPACITY buffer (shapes are static, so
                                 extraction jits and ships over collectives).
                                 Returns ``(delta, shipped_frontier)`` where
                                 ``shipped_frontier`` advances only over ops
                                 that actually fit — overflow is not lost, it
                                 ships on the next sync round.

  ``apply(state, delta)``        joins the delta into a replica.  Deltas are
                                 (sub-)states, so apply inherits the join's
                                 idempotence/commutativity: re-applying a
                                 delta, or applying it to a replica that has
                                 already seen some of its ops, is a no-op for
                                 the overlap.

The frontier/delta model
------------------------

A sync round between replicas that share a frontier F (the previous sync
point) ships ``extract(state_i, F)`` from every replica i and applies every
delta everywhere.  Because rows (GLog/RGA) and slots (SlotDoc) are
single-writer between syncs, deltas touch disjoint regions and contiguity
holds: each delta's ``start`` is at or below every receiver's watermark, so
watermark advancement never skips unobserved ops (the *causal-delta-merging*
guard — `apply` rejects watermark advancement across a gap, keeping the
result a valid CRDT state under arbitrary delivery).

The next shared frontier is the max-join of every replica's
``shipped_frontier`` — all frontier leaves are monotone (counts, lengths,
packed LWW keys, member bits), so ``join_frontiers`` is an elementwise
max/OR and, on a mesh, a bare ``lax.pmax``.

Wire-cost model: a full SlotDoc is O(K·S) bytes per sync; a delta is
O(K·Δcap) with Δcap sized to the edit rate between syncs — the O(N×U)
observation overhead of the paper becomes O(N×Δ).  RGA tombstones are not
log-structured (any replica may tombstone any op), so they ship as a full
bit-packed bitmap: L/8 bytes per client row versus 12+ bytes per op for the
log fields — still o(state).  GCounter/GSet states are already watermarks;
their "deltas" are the (bit-packed) state and cost the same O(C) / O(N/8).

See ``core/merge.py::delta_merge`` for the ring-exchange collective built on
these primitives and ``benchmarks/bench_merge.py`` for the measured bytes.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import counter as counter_mod
from repro.core import doc as doc_mod
from repro.core import gset, lww, rga, todo
from repro.core.clock import pack_key

# ---------------------------------------------------------------------------
# Frontier / delta containers (all fixed-shape pytrees)
# ---------------------------------------------------------------------------


class LogFrontier(NamedTuple):
    count: jax.Array          # i32[C] — ops observed per client row


class KeyFrontier(NamedTuple):
    key: jax.Array            # i32[K] — packed (clock, client) per register


class SlotFrontier(NamedTuple):
    length: jax.Array         # i32[K] — tokens observed per slot


class LogDelta(NamedTuple):
    """New ops of a GLog beyond a LogFrontier, one run per client row."""

    start: jax.Array          # i32[C]
    num: jax.Array            # i32[C] — ops shipped (<= capacity)
    fields: dict[str, Any]    # field -> [C, capacity, ...]


class RGADelta(NamedTuple):
    """New ops of an RGA plus the full (bit-packed) tombstone set."""

    start: jax.Array          # i32[C]
    num: jax.Array            # i32[C]
    op_clock: jax.Array       # i32[C, capacity]
    origin: jax.Array         # i32[C, capacity]
    token: jax.Array          # i32[C, capacity]
    deleted_bits: jax.Array   # u8[C, ceil(L/8)] — tombstones OR on apply


class LWWDelta(NamedTuple):
    """Changed registers of an LWWBank, left-packed into ``capacity`` lanes.

    ``idx`` holds the register index per lane, -1 for empty lanes.  Lanes are
    unique by construction (each register appears at most once per extract).
    """

    idx: jax.Array            # i32[capacity]
    clock: jax.Array          # i32[capacity]
    client: jax.Array         # i32[capacity]
    payload: dict[str, Any]   # field -> [capacity, ...]


class SlotDelta(NamedTuple):
    """New tokens of a SlotDoc beyond a SlotFrontier, one run per slot."""

    start: jax.Array          # i32[K]
    num: jax.Array            # i32[K]
    tokens: jax.Array         # i32[K, capacity]
    owner: jax.Array          # i32[K] — joins by max (tiny, shipped whole)


class CounterDelta(NamedTuple):
    counts: jax.Array         # i32[C] — the state IS the watermark


class SetDelta(NamedTuple):
    bits: jax.Array           # u8[ceil(N/8)] — bit-packed membership


class PNFrontier(NamedTuple):
    inc: jax.Array            # i32[R, K] — cell values observed/shipped
    dec: jax.Array            # i32[R, K]


class PNDelta(NamedTuple):
    """Changed cells of a PNCounter, left-packed into ``capacity`` lanes.

    ``idx`` is the flattened lane*K+key index, -1 for empty lanes.  Values
    are ABSOLUTE cumulative counts (not increments): every cell is monotone,
    so apply is a scatter-max and re-delivery/reordering are no-ops.
    """

    idx: jax.Array            # i32[capacity]
    inc: jax.Array            # i32[capacity]
    dec: jax.Array            # i32[capacity]


# ---------------------------------------------------------------------------
# Row-run helpers (shared by GLog / RGA / SlotDoc)
# ---------------------------------------------------------------------------


def _gather_runs(arr: jax.Array, start: jax.Array, num: jax.Array,
                 capacity: int) -> jax.Array:
    """arr [C, L, ...] -> [C, capacity, ...]: per-row slice from ``start``."""
    c, l = arr.shape[:2]
    j = jnp.arange(capacity, dtype=jnp.int32)
    src = jnp.clip(start[:, None] + j[None, :], 0, l - 1)
    vals = arr[jnp.arange(c)[:, None], src]
    mask = j[None, :] < num[:, None]
    m = mask.reshape(mask.shape + (1,) * (arr.ndim - 2))
    return jnp.where(m, vals, jnp.zeros((), arr.dtype))


def _scatter_runs(arr: jax.Array, start: jax.Array, num: jax.Array,
                  vals: jax.Array) -> jax.Array:
    """Write [C, capacity, ...] runs back at ``start``; masked lanes are
    routed out of bounds and dropped (never clipped onto live slots)."""
    c, l = arr.shape[:2]
    capacity = vals.shape[1]
    j = jnp.arange(capacity, dtype=jnp.int32)
    write = j[None, :] < num[:, None]
    pos = jnp.where(write, start[:, None] + j[None, :], l)
    return arr.at[jnp.arange(c)[:, None], pos].set(
        vals.astype(arr.dtype), mode="drop")


def _advance_watermark(current: jax.Array, start: jax.Array,
                       num: jax.Array) -> jax.Array:
    """Causal-delta-merging guard: only advance over contiguous runs.

    A delta starting beyond the local watermark would mark unobserved ops as
    valid; its payload is still written (harmless — rows are append-only and
    deterministic per writer) but the watermark waits for the gap-filler.
    """
    return jnp.where(start <= current,
                     jnp.maximum(current, start + num), current)


# ---------------------------------------------------------------------------
# Per-type frontier / extract / apply
# ---------------------------------------------------------------------------

# -- GLog -------------------------------------------------------------------

def _glog_frontier(state: gset.GLog) -> LogFrontier:
    return LogFrontier(count=state.count)


def _glog_extract(state: gset.GLog, fr: LogFrontier, capacity: int
                  ) -> tuple[LogDelta, LogFrontier]:
    start = jnp.minimum(fr.count, state.count)
    num = jnp.clip(state.count - start, 0, capacity)
    fields = {name: _gather_runs(arr, start, num, capacity)
              for name, arr in state.fields.items()}
    return (LogDelta(start=start, num=num, fields=fields),
            LogFrontier(count=start + num))


def _glog_apply(state: gset.GLog, d: LogDelta) -> gset.GLog:
    fields = {name: _scatter_runs(arr, d.start, d.num, d.fields[name])
              for name, arr in state.fields.items()}
    return gset.GLog(count=_advance_watermark(state.count, d.start, d.num),
                     fields=fields)


# -- RGA --------------------------------------------------------------------

def _rga_frontier(state: rga.RGA) -> LogFrontier:
    return LogFrontier(count=state.count)


def _rga_extract(state: rga.RGA, fr: LogFrontier, capacity: int
                 ) -> tuple[RGADelta, LogFrontier]:
    start = jnp.minimum(fr.count, state.count)
    num = jnp.clip(state.count - start, 0, capacity)
    delta = RGADelta(
        start=start, num=num,
        op_clock=_gather_runs(state.op_clock, start, num, capacity),
        origin=_gather_runs(state.origin, start, num, capacity),
        token=_gather_runs(state.token, start, num, capacity),
        deleted_bits=jnp.packbits(state.deleted, axis=1),
    )
    return delta, LogFrontier(count=start + num)


def _rga_apply(state: rga.RGA, d: RGADelta) -> rga.RGA:
    l = state.capacity
    deleted = state.deleted | jnp.unpackbits(
        d.deleted_bits, axis=1, count=l).astype(jnp.bool_)
    return rga.RGA(
        count=_advance_watermark(state.count, d.start, d.num),
        op_clock=_scatter_runs(state.op_clock, d.start, d.num, d.op_clock),
        origin=_scatter_runs(state.origin, d.start, d.num, d.origin),
        token=_scatter_runs(state.token, d.start, d.num, d.token),
        deleted=deleted,
    )


# -- LWWBank ----------------------------------------------------------------

def _lww_frontier(bank: lww.LWWBank) -> KeyFrontier:
    return KeyFrontier(key=bank.key)


def _lww_extract(bank: lww.LWWBank, fr: KeyFrontier, capacity: int
                 ) -> tuple[LWWDelta, KeyFrontier]:
    k = bank.clock.shape[0]
    cap = min(capacity, k)
    changed = bank.key > fr.key
    # Oldest (smallest-key) changed registers ship first: a starved write's
    # key is fixed while churning writers' keys keep growing, so every
    # pending register is eventually among the ``cap`` smallest — overflow
    # delays shipping but can never starve a register indefinitely.
    priority = jnp.where(changed, bank.key, jnp.iinfo(jnp.int32).max)
    order = jnp.argsort(priority).astype(jnp.int32)[:cap]
    take = changed[order]
    idx = jnp.where(take, order, -1)
    safe = jnp.clip(order, 0, k - 1)
    zero = lambda arr, v: jnp.where(
        take.reshape(take.shape + (1,) * (v.ndim - 1)), v,
        jnp.zeros((), arr.dtype))
    payload = {name: zero(arr, arr[safe]) for name, arr in bank.payload.items()}
    delta = LWWDelta(idx=idx,
                     clock=jnp.where(take, bank.clock[safe], 0),
                     client=jnp.where(take, bank.client[safe], 0),
                     payload=payload)
    shipped = jnp.zeros((k,), jnp.bool_).at[
        jnp.where(take, order, k)].set(True, mode="drop")
    return delta, KeyFrontier(key=jnp.where(shipped, bank.key, fr.key))


def _lww_apply(bank: lww.LWWBank, d: LWWDelta) -> lww.LWWBank:
    k = bank.clock.shape[0]
    dkey = pack_key(d.clock, d.client)
    safe = jnp.clip(d.idx, 0, k - 1)
    wins = (d.idx >= 0) & (dkey > bank.key[safe])
    tgt = jnp.where(wins, d.idx, k)       # losers routed out of bounds
    payload = {
        name: arr.at[tgt].set(d.payload[name].astype(arr.dtype), mode="drop")
        for name, arr in bank.payload.items()
    }
    return lww.LWWBank(
        clock=bank.clock.at[tgt].set(d.clock, mode="drop"),
        client=bank.client.at[tgt].set(d.client, mode="drop"),
        payload=payload,
    )


# -- SlotDoc ----------------------------------------------------------------

def _slot_frontier(doc: doc_mod.SlotDoc) -> SlotFrontier:
    return SlotFrontier(length=doc.length)


def _slot_extract(doc: doc_mod.SlotDoc, fr: SlotFrontier, capacity: int
                  ) -> tuple[SlotDelta, SlotFrontier]:
    start = jnp.minimum(fr.length, doc.length)
    num = jnp.clip(doc.length - start, 0, capacity)
    delta = SlotDelta(start=start, num=num,
                      tokens=_gather_runs(doc.tokens, start, num, capacity),
                      owner=doc.owner)
    return delta, SlotFrontier(length=start + num)


def _slot_apply(doc: doc_mod.SlotDoc, d: SlotDelta) -> doc_mod.SlotDoc:
    return doc_mod.SlotDoc(
        tokens=_scatter_runs(doc.tokens, d.start, d.num, d.tokens),
        length=_advance_watermark(doc.length, d.start, d.num),
        owner=jnp.maximum(doc.owner, d.owner),
    )


# -- GCounter / GSet --------------------------------------------------------

def _gcounter_frontier(state: gset.GCounter) -> jax.Array:
    return state.counts


def _gcounter_extract(state: gset.GCounter, fr: jax.Array, capacity: int
                      ) -> tuple[CounterDelta, jax.Array]:
    return CounterDelta(counts=state.counts), state.counts


def _gcounter_apply(state: gset.GCounter, d: CounterDelta) -> gset.GCounter:
    return gset.GCounter(jnp.maximum(state.counts, d.counts))


def _gset_frontier(state: gset.GSet) -> jax.Array:
    return state.member


def _gset_extract(state: gset.GSet, fr: jax.Array, capacity: int
                  ) -> tuple[SetDelta, jax.Array]:
    return SetDelta(bits=jnp.packbits(state.member)), state.member


def _gset_apply(state: gset.GSet, d: SetDelta) -> gset.GSet:
    n = state.member.shape[0]
    return gset.GSet(state.member
                     | jnp.unpackbits(d.bits, count=n).astype(jnp.bool_))


# -- PNCounter --------------------------------------------------------------

def _pn_frontier(state: counter_mod.PNCounter) -> PNFrontier:
    return PNFrontier(inc=state.inc, dec=state.dec)


def _pn_extract(state: counter_mod.PNCounter, fr: PNFrontier, capacity: int
                ) -> tuple[PNDelta, PNFrontier]:
    r, k = state.inc.shape
    n = r * k
    cap = min(capacity, n)
    inc_f, dec_f = state.inc.reshape(-1), state.dec.reshape(-1)
    changed = (inc_f > fr.inc.reshape(-1)) | (dec_f > fr.dec.reshape(-1))
    # Smallest-total changed cells ship first: a starved cell's cumulative
    # count is fixed while hot cells keep growing, so every pending cell is
    # eventually among the ``cap`` smallest (same argument as _lww_extract).
    priority = jnp.where(changed, inc_f + dec_f, jnp.iinfo(jnp.int32).max)
    order = jnp.argsort(priority).astype(jnp.int32)[:cap]
    take = changed[order]
    idx = jnp.where(take, order, -1)
    delta = PNDelta(idx=idx,
                    inc=jnp.where(take, inc_f[order], 0),
                    dec=jnp.where(take, dec_f[order], 0))
    shipped = jnp.zeros((n,), jnp.bool_).at[
        jnp.where(take, order, n)].set(True, mode="drop")
    return delta, PNFrontier(
        inc=jnp.where(shipped, inc_f, fr.inc.reshape(-1)).reshape(r, k),
        dec=jnp.where(shipped, dec_f, fr.dec.reshape(-1)).reshape(r, k))


def _pn_apply(state: counter_mod.PNCounter, d: PNDelta
              ) -> counter_mod.PNCounter:
    r, k = state.inc.shape
    tgt = jnp.where(d.idx >= 0, d.idx, r * k)   # empty lanes routed OOB
    inc = state.inc.reshape(-1).at[tgt].max(d.inc, mode="drop").reshape(r, k)
    dec = state.dec.reshape(-1).at[tgt].max(d.dec, mode="drop").reshape(r, k)
    return counter_mod.PNCounter(inc=inc, dec=dec)


# -- TodoBoard --------------------------------------------------------------

def _board_frontier(board: todo.TodoBoard) -> KeyFrontier:
    return _lww_frontier(board.bank)


def _board_extract(board: todo.TodoBoard, fr: KeyFrontier, capacity: int
                   ) -> tuple[LWWDelta, KeyFrontier]:
    return _lww_extract(board.bank, fr, capacity)


def _board_apply(board: todo.TodoBoard, d: LWWDelta) -> todo.TodoBoard:
    return todo.TodoBoard(_lww_apply(board.bank, d))


# ---------------------------------------------------------------------------
# Registry + public dispatch (mirrors merge._JOINS)
# ---------------------------------------------------------------------------

_FRONTIER = {
    gset.GLog: _glog_frontier,
    rga.RGA: _rga_frontier,
    lww.LWWBank: _lww_frontier,
    doc_mod.SlotDoc: _slot_frontier,
    gset.GCounter: _gcounter_frontier,
    gset.GSet: _gset_frontier,
    todo.TodoBoard: _board_frontier,
    counter_mod.PNCounter: _pn_frontier,
}

_EXTRACT = {
    gset.GLog: _glog_extract,
    rga.RGA: _rga_extract,
    lww.LWWBank: _lww_extract,
    doc_mod.SlotDoc: _slot_extract,
    gset.GCounter: _gcounter_extract,
    gset.GSet: _gset_extract,
    todo.TodoBoard: _board_extract,
    counter_mod.PNCounter: _pn_extract,
}

_APPLY = {
    gset.GLog: _glog_apply,
    rga.RGA: _rga_apply,
    lww.LWWBank: _lww_apply,
    doc_mod.SlotDoc: _slot_apply,
    gset.GCounter: _gcounter_apply,
    gset.GSet: _gset_apply,
    todo.TodoBoard: _board_apply,
    counter_mod.PNCounter: _pn_apply,
}


def is_delta_crdt(x: Any) -> bool:
    return type(x) in _FRONTIER


def frontier(state: Any) -> Any:
    """Watermark of everything ``state`` has observed.  Dict containers of
    CRDTs (e.g. the fused serving step's coord dict) recurse per key."""
    fn = _FRONTIER.get(type(state))
    if fn is not None:
        return fn(state)
    if isinstance(state, dict):
        return {k: frontier(v) for k, v in state.items()}
    raise TypeError(f"no delta support for {type(state).__name__}")


def _cap_for(capacity: Any, key: str) -> Any:
    """Resolve a per-key delta capacity.  ``capacity`` is either a plain int
    (every leaf ships that many slots) or a hashable tuple of ``(key, cap)``
    pairs with a ``"*"`` default — so one chatty leaf (e.g. the request
    journal) can ship bigger deltas without inflating every other leaf's
    fixed-size packet.  Tuples stay hashable for ``extract_jit``'s static
    argnum."""
    if isinstance(capacity, int):
        return capacity
    spec = dict(capacity)
    return spec.get(key, spec["*"])


def extract(state: Any, fr: Any, capacity: Any) -> tuple[Any, Any]:
    """Delta of ``state`` beyond ``fr`` plus the frontier actually shipped.

    ``capacity`` is an int, or a tuple of ``(key, cap)`` pairs (see
    ``_cap_for``) resolved at each dict level."""
    fn = _EXTRACT.get(type(state))
    if fn is not None:
        if not isinstance(capacity, int):
            capacity = _cap_for(capacity, "*")
        return fn(state, fr, capacity)
    if isinstance(state, dict):
        pairs = {k: extract(v, fr[k], _cap_for(capacity, k))
                 for k, v in state.items()}
        return ({k: p[0] for k, p in pairs.items()},
                {k: p[1] for k, p in pairs.items()})
    raise TypeError(f"no delta support for {type(state).__name__}")


def apply(state: Any, delta: Any) -> Any:
    """Join a delta into a replica (idempotent, order-insensitive)."""
    fn = _APPLY.get(type(state))
    if fn is not None:
        return fn(state, delta)
    if isinstance(state, dict):
        return {k: apply(v, delta[k]) for k, v in state.items()}
    raise TypeError(f"no delta support for {type(state).__name__}")


def join_frontiers(a: Any, b: Any) -> Any:
    """Frontiers are monotone watermarks: the join is elementwise max/OR."""
    return jax.tree.map(
        lambda x, y: x | y if x.dtype == jnp.bool_ else jnp.maximum(x, y),
        a, b)


frontier_jit = jax.jit(frontier)
extract_jit = jax.jit(extract, static_argnums=2)
apply_jit = jax.jit(apply)


# ---------------------------------------------------------------------------
# Host-side accounting + gossip driver
# ---------------------------------------------------------------------------


def nbytes(tree: Any) -> int:
    """Wire size of a pytree: the fixed-capacity buffers ARE the payload."""
    return int(sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree)))


def full_state_wire_bytes(strategy: str, n: int, state_bytes: int) -> int:
    """Wire bytes for one full-state sync of N replicas (cost model shared
    by the orchestrator's accounting and benchmarks/bench_merge.py).

    allgather: every replica ships its full state to N-1 peers (the paper-
    faithful everyone-observes-everyone relay).  pmax: ring all-reduce —
    reduce-scatter + all-gather phases each move ~state_bytes across the
    ring.  The delta strategy is accounted exactly (``nbytes`` of the
    buffers actually shipped) rather than modeled.
    """
    if strategy == "allgather":
        return n * (n - 1) * state_bytes
    if strategy == "pmax":
        return 2 * (n - 1) * state_bytes
    raise ValueError(f"no full-state wire model for strategy: {strategy}")


class DeltaSync:
    """Host-side delta gossip among N replicas sharing a frontier.

    The orchestrator's replica sync: every replica extracts its delta against
    the shared frontier (the previous sync point), every delta is applied to
    every other replica, and the frontier advances to the join of what was
    shipped.  Overflowing ops (beyond ``capacity``) stay local and ship on a
    later round — convergence is delayed, never lost.

    ``bytes_shipped`` accumulates the ring-model wire cost: each delta
    traverses N-1 links.
    """

    def __init__(self, template: Any, capacity: int = 64):
        self.capacity = capacity
        self.frontier = frontier_jit(template)
        self.bytes_shipped = 0
        self.syncs = 0

    def sync(self, replicas: list[Any]) -> list[Any]:
        n = len(replicas)
        pairs = [extract_jit(r, self.frontier, self.capacity)
                 for r in replicas]
        deltas = [d for d, _ in pairs]
        self.bytes_shipped += sum(nbytes(d) for d in deltas) * (n - 1)
        self.syncs += 1
        outs = []
        for i, r in enumerate(replicas):
            for j, d in enumerate(deltas):
                if j != i:
                    r = apply_jit(r, d)
            outs.append(r)
        self.frontier = functools.reduce(join_frontiers,
                                         [f for _, f in pairs])
        return outs
