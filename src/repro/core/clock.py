"""Lamport clocks and version vectors.

Clients (agents / workers / pods) are identified by small positive integers
``1 .. MAX_CLIENTS-1``; client 0 is reserved for "unset".  Lamport clocks are
positive int32 values bounded by ``MAX_CLOCK`` so that the pair
``(clock, client)`` packs losslessly into a single int32 key — this is what
lets the whole coordination state merge with plain ``lax.pmax`` collectives
(see core/merge.py and DESIGN.md §2).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

CLIENT_BITS = 10
MAX_CLIENTS = 1 << CLIENT_BITS          # 1024
MAX_CLOCK = (1 << 20) - 1               # packed key stays < 2^30 (int32-safe)


def pack_key(clock: jax.Array, client: jax.Array) -> jax.Array:
    """Pack (clock, client) into one int32, preserving lexicographic order."""
    return clock.astype(jnp.int32) * MAX_CLIENTS + client.astype(jnp.int32)


def unpack_key(key: jax.Array) -> tuple[jax.Array, jax.Array]:
    return key // MAX_CLIENTS, key % MAX_CLIENTS


class Lamport(NamedTuple):
    """Per-client Lamport clock."""

    time: jax.Array      # i32 scalar
    client: jax.Array    # i32 scalar, in [1, MAX_CLIENTS)

    @classmethod
    def create(cls, client: int) -> "Lamport":
        return cls(time=jnp.int32(0), client=jnp.int32(client))

    def tick(self) -> "Lamport":
        return self._replace(time=self.time + 1)

    def observe(self, other_time: jax.Array) -> "Lamport":
        """Lamport receive rule: local = max(local, observed) + 1."""
        return self._replace(time=jnp.maximum(self.time, other_time) + 1)

    @property
    def key(self) -> jax.Array:
        return pack_key(self.time, self.client)


class VersionVector(NamedTuple):
    """How many ops of each client this replica has observed."""

    counts: jax.Array    # i32[MAX? C]

    @classmethod
    def zeros(cls, num_clients: int) -> "VersionVector":
        return cls(counts=jnp.zeros((num_clients,), jnp.int32))

    def join(self, other: "VersionVector") -> "VersionVector":
        return VersionVector(jnp.maximum(self.counts, other.counts))

    def dominates(self, other: "VersionVector") -> jax.Array:
        return jnp.all(self.counts >= other.counts)

    def advance(self, client: jax.Array, count: jax.Array) -> "VersionVector":
        return VersionVector(self.counts.at[client].max(count))
