"""repro.core — CodeCRDT's contribution as composable JAX modules.

Observation-driven coordination over join-semilattice (CRDT) state:

  clock     Lamport clocks, packed (clock, client) keys, version vectors
  lww       LWW register banks (Y.Map analogue) — the TODO board substrate
  gset      G-counter / G-set / per-client append-only logs (Y.Array analogue)
  counter   PN-counters with per-replica lanes (replicated page refcounts)
  rga       sequence CRDT with deterministic materialization (Y.Text analogue)
  doc       SlotDoc — fixed-shape production code document
  todo      TodoBoard + status/dependency semantics
  protocol  optimistic write-verify claim protocol (at-most-one-winner)
  observe   version-vector subscriptions, invalidation signals
  delta     delta-state sync: frontiers, O(Δ) extraction, join-apply
  merge     replica joins: local fold, all-gather, O(S) pmax, O(Δ) delta ring
"""
from repro.core import (clock, counter, delta, doc, gset, lww, merge,
                        observe, protocol, rga, todo)

__all__ = ["clock", "counter", "delta", "doc", "gset", "lww", "merge",
           "observe", "protocol", "rga", "todo"]
