"""Grow/shrink counters with per-replica lanes (PN-counters).

A ``PNCounter`` holds ``K`` keyed counters replicated across ``R`` writer
lanes.  Lane ``r`` is single-writer: only replica ``r`` ever bumps
``inc[r, :]`` / ``dec[r, :]``, so every cell is monotone non-decreasing and
the join is an elementwise max — the same G-type shape as ``gset.GCounter``
but with a *decrement* side, which makes the observed value

    value[k] = sum_r (inc[r, k] - dec[r, k])

able to go both up and down while the state itself stays a join-semilattice
(Shapiro et al. 2011, §3.1.3).  This is the distributed serving tier's page
*refcount*: allocation/share increments the caller's lane, free decrements
it, and a replica may free only references its own lane holds — which makes
"no double-free" a per-lane invariant (``dec <= inc`` cellwise) that any
observer can audit on any (partially) merged state.

Delta support (frontier / O(Δ) extract / join-apply) lives in
``core/delta.py`` next to the other registered CRDTs.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class PNCounter(NamedTuple):
    inc: jax.Array    # i32[R, K] — per-lane cumulative increments
    dec: jax.Array    # i32[R, K] — per-lane cumulative decrements

    @classmethod
    def zeros(cls, num_lanes: int, num_keys: int) -> "PNCounter":
        return cls(inc=jnp.zeros((num_lanes, num_keys), jnp.int32),
                   dec=jnp.zeros((num_lanes, num_keys), jnp.int32))

    @property
    def num_lanes(self) -> int:
        return self.inc.shape[0]

    @property
    def num_keys(self) -> int:
        return self.inc.shape[1]

    def add(self, lane: jax.Array, key: jax.Array,
            amount: jax.Array = 1) -> "PNCounter":
        """Increment ``key`` on ``lane`` (call only from lane's owner)."""
        return self._replace(
            inc=self.inc.at[lane, key].add(jnp.int32(amount)))

    def sub(self, lane: jax.Array, key: jax.Array,
            amount: jax.Array = 1) -> "PNCounter":
        """Decrement ``key`` on ``lane``.  The caller must hold the
        references it releases (``dec <= inc`` cellwise is the auditable
        no-double-free invariant); this is a semantic contract of the lane
        owner, not a shape guard."""
        return self._replace(
            dec=self.dec.at[lane, key].add(jnp.int32(amount)))

    def join(self, other: "PNCounter") -> "PNCounter":
        return PNCounter(inc=jnp.maximum(self.inc, other.inc),
                         dec=jnp.maximum(self.dec, other.dec))

    @property
    def value(self) -> jax.Array:
        """Observed per-key value: i32[K]."""
        return jnp.sum(self.inc - self.dec, axis=0)

    def value_masked(self, lanes: jax.Array) -> jax.Array:
        """Per-key value counting only ``lanes`` (bool[R]) — e.g. the live
        (non-retired) replicas, so a crashed replica's zombie references
        stop pinning pages once its retirement is observed."""
        m = lanes[:, None]
        return jnp.sum(jnp.where(m, self.inc - self.dec, 0), axis=0)

    def lane_value(self, lane: jax.Array) -> jax.Array:
        """One lane's per-key holdings: i32[K]."""
        return self.inc[lane] - self.dec[lane]
