"""Grow-only CRDTs: counters, flag sets, and per-client append-only logs.

All three are join-semilattices whose join is an elementwise max (with
masking), which means they merge across replicas with a bare ``lax.pmax``
collective — see core/merge.py.

``GLog`` is the array-backed analogue of Yjs Y.Array used as an audit trail:
each client owns a row and only ever appends to it; rows are immutable
prefixes, so the entry at (client, i) is identical on every replica that has
observed it and the join is exact.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class GCounter(NamedTuple):
    counts: jax.Array    # i32[C] — per-client monotone count

    @classmethod
    def zeros(cls, num_clients: int) -> "GCounter":
        return cls(jnp.zeros((num_clients,), jnp.int32))

    def increment(self, client: jax.Array, amount: jax.Array = 1) -> "GCounter":
        return GCounter(self.counts.at[client].add(jnp.int32(amount)))

    def bump_to(self, client: jax.Array, value: jax.Array) -> "GCounter":
        """Monotone set (e.g. heartbeat timestamps)."""
        return GCounter(self.counts.at[client].max(jnp.int32(value)))

    def join(self, other: "GCounter") -> "GCounter":
        return GCounter(jnp.maximum(self.counts, other.counts))

    @property
    def value(self) -> jax.Array:
        return jnp.sum(self.counts)


class GSet(NamedTuple):
    """Grow-only flag set over a fixed universe of N elements."""

    member: jax.Array    # bool[N]

    @classmethod
    def empty(cls, universe: int) -> "GSet":
        return cls(jnp.zeros((universe,), jnp.bool_))

    def add(self, idx: jax.Array) -> "GSet":
        return GSet(self.member.at[idx].set(True))

    def add_mask(self, mask: jax.Array) -> "GSet":
        return GSet(self.member | mask)

    def join(self, other: "GSet") -> "GSet":
        return GSet(self.member | other.member)


class GLog(NamedTuple):
    """Per-client append-only log with arbitrary int payload fields."""

    count: jax.Array          # i32[C] entries valid at row c are [0, count[c])
    fields: dict[str, Any]    # field -> i32/f32 [C, L, ...]

    @classmethod
    def empty(cls, num_clients: int, capacity: int,
              field_spec: dict[str, tuple[tuple[int, ...], Any]]) -> "GLog":
        fields = {
            name: jnp.zeros((num_clients, capacity, *shape), dtype)
            for name, (shape, dtype) in field_spec.items()
        }
        return cls(count=jnp.zeros((num_clients,), jnp.int32), fields=fields)

    @property
    def capacity(self) -> int:
        return next(iter(self.fields.values())).shape[1]

    def append(self, client: jax.Array, **values: jax.Array) -> "GLog":
        """Append one entry to ``client``'s own row (drops silently if full)."""
        pos = jnp.minimum(self.count[client], self.capacity - 1)
        ok = self.count[client] < self.capacity
        fields = {}
        for name, arr in self.fields.items():
            val = jnp.asarray(values[name], arr.dtype)
            fields[name] = arr.at[client, pos].set(jnp.where(ok, val, arr[client, pos]))
        return GLog(count=self.count.at[client].add(jnp.where(ok, 1, 0)), fields=fields)

    def valid_mask(self) -> jax.Array:
        """bool[C, L] — which slots hold observed entries."""
        idx = jnp.arange(self.capacity, dtype=jnp.int32)[None, :]
        return idx < self.count[:, None]

    def join(self, other: "GLog") -> "GLog":
        mine = self.valid_mask()
        fields = {}
        for name, arr in self.fields.items():
            ob = other.fields[name]
            m = mine.reshape(mine.shape + (1,) * (arr.ndim - 2))
            fields[name] = jnp.where(m, arr, ob)
        return GLog(count=jnp.maximum(self.count, other.count), fields=fields)
