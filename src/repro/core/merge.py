"""Replica merge strategies — "the collective IS the relay" (DESIGN.md §2).

The paper syncs replicas through a Hocuspocus WebSocket relay (median 50 ms).
On a TPU mesh the natural substitute is a collective over the replica axis.
Because every CRDT in repro.core is a join-semilattice whose join is an
elementwise (masked) max, two strategies are available:

  * ``allgather_merge`` — gather all N replicas, fold the exact join locally.
    O(N·S) bytes on the interconnect.  This is the paper-faithful baseline:
    every agent observes every other replica's full state (the O(N×U)
    observation overhead made literal).

  * ``pmax_merge`` — express the join directly as ``lax.pmax``:
      - G-types (counter/set/log/RGA/SlotDoc): masked elementwise max is the
        join itself;
      - LWW banks: pack (clock, client) into one int32 key, pmax resolves the
        lexicographic winner, then a second masked pmax carries each payload
        field (exact since (clock, client) pairs are unique across writers).
    O(S) bytes independent of N — the beyond-paper optimization of the
    coordination layer.

  * ``delta_merge`` — delta-state sync (core/delta.py): each replica extracts
    the ops beyond a shared frontier into a fixed-capacity buffer, the
    buffers circulate the replica ring via ``lax.ppermute`` (N-1 hops), and
    every hop joins the received delta locally.  O(Δ) bytes per link per
    sync — the winning strategy when edits per sync interval are small
    relative to state size (measured in benchmarks/bench_merge.py).

All three are exact joins: they commute, associate, and are idempotent, so
the merged state is identical on every replica — strong eventual consistency
with *bounded* (one-collective) staleness.  ``delta_merge`` additionally
threads a frontier: overflowing deltas (edits beyond the buffer capacity)
stay local and ship on a later sync, delaying convergence without ever
losing it.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import counter as counter_mod
from repro.core import delta as delta_mod
from repro.core import doc as doc_mod
from repro.core import gset, lww, rga, todo
from repro.core.clock import unpack_key

INT32_MIN = jnp.iinfo(jnp.int32).min

# ---------------------------------------------------------------------------
# Local (pairwise) joins — registry keyed by CRDT type.
# ---------------------------------------------------------------------------

_JOINS: dict[type, Callable[[Any, Any], Any]] = {
    lww.LWWBank: lww.merge,
    gset.GCounter: lambda a, b: a.join(b),
    gset.GSet: lambda a, b: a.join(b),
    gset.GLog: lambda a, b: a.join(b),
    rga.RGA: rga.merge,
    doc_mod.SlotDoc: doc_mod.merge,
    todo.TodoBoard: lambda a, b: todo.TodoBoard(lww.merge(a.bank, b.bank)),
    counter_mod.PNCounter: lambda a, b: a.join(b),
}


def is_crdt(x: Any) -> bool:
    return type(x) in _JOINS


def join(a: Any, b: Any) -> Any:
    """Pairwise join of two replica states (any registered CRDT or a
    container pytree whose CRDT nodes are treated atomically)."""
    fn = _JOINS.get(type(a))
    if fn is not None:
        return fn(a, b)
    return jax.tree.map(join, a, b, is_leaf=is_crdt)


def fold_join(states: list[Any]) -> Any:
    """Exact join of many replicas (host-side list)."""
    return functools.reduce(join, states)


def tree_join_stacked(stacked: Any) -> Any:
    """Join replicas stacked on a leading axis (from all_gather)."""
    n = jax.tree.leaves(stacked)[0].shape[0]
    take = lambda s, i: jax.tree.map(lambda x: x[i], s)

    def body(i, acc):
        return join(acc, take(stacked, i))

    return jax.lax.fori_loop(1, n, body, take(stacked, 0))


# ---------------------------------------------------------------------------
# Collective merges (use inside shard_map over ``axis_name``).
# ---------------------------------------------------------------------------

def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` across jax versions (older jax ships it under
    jax.experimental with ``check_rep`` instead of ``check_vma``)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma)

def allgather_merge(state: Any, axis_name: str) -> Any:
    """Paper-faithful: every replica observes every replica, folds locally."""
    gathered = jax.tree.map(
        lambda x: jax.lax.all_gather(x, axis_name, axis=0), state)
    return tree_join_stacked(gathered)


def _pmax(x: jax.Array, axis_name: str) -> jax.Array:
    if x.dtype == jnp.bool_:
        return jax.lax.pmax(x.astype(jnp.int32), axis_name).astype(jnp.bool_)
    return jax.lax.pmax(x, axis_name)


def _masked_pmax(x: jax.Array, valid: jax.Array, axis_name: str) -> jax.Array:
    """pmax where invalid lanes contribute the identity (-inf / INT_MIN)."""
    v = valid.reshape(valid.shape + (1,) * (x.ndim - valid.ndim))
    if x.dtype == jnp.bool_:
        # Non-winners contribute False, so OR returns exactly the winner's bits.
        return _pmax(x & v, axis_name)
    if jnp.issubdtype(x.dtype, jnp.floating):
        neutral = jnp.asarray(-jnp.inf, x.dtype)
    else:
        neutral = jnp.asarray(jnp.iinfo(x.dtype).min, x.dtype)
    out = jax.lax.pmax(jnp.where(v, x, neutral), axis_name)
    # Lanes no replica has observed keep their (identical) local default so
    # the merged state is bit-equal to the fold join.  Payloads never carry
    # the neutral value themselves (tokens/clocks/lengths are >= -1).
    return jnp.where(out == neutral, x, out)


def _pmax_lww(bank: lww.LWWBank, axis_name: str) -> lww.LWWBank:
    key = bank.key
    win_key = jax.lax.pmax(key, axis_name)
    i_win = key == win_key
    payload = {
        name: _masked_pmax(arr, i_win, axis_name)
        for name, arr in bank.payload.items()
    }
    clock, client = unpack_key(win_key)
    return lww.LWWBank(clock=clock, client=client, payload=payload)


def pmax_merge(state: Any, axis_name: str) -> Any:
    """O(S)-byte join via pmax collectives (see module docstring)."""
    t = type(state)
    if t is lww.LWWBank:
        return _pmax_lww(state, axis_name)
    if t is todo.TodoBoard:
        return todo.TodoBoard(_pmax_lww(state.bank, axis_name))
    if t in (gset.GCounter, gset.GSet, counter_mod.PNCounter):
        return jax.tree.map(lambda x: _pmax(x, axis_name), state)
    if t is gset.GLog:
        valid = state.valid_mask()
        fields = {k: _masked_pmax(v, valid, axis_name)
                  for k, v in state.fields.items()}
        return gset.GLog(count=_pmax(state.count, axis_name), fields=fields)
    if t is rga.RGA:
        valid = state.valid_mask()
        return rga.RGA(
            count=_pmax(state.count, axis_name),
            op_clock=_masked_pmax(state.op_clock, valid, axis_name),
            origin=_masked_pmax(state.origin, valid, axis_name),
            token=_masked_pmax(state.token, valid, axis_name),
            deleted=_pmax(state.deleted, axis_name),
        )
    if t is doc_mod.SlotDoc:
        valid = doc_mod.valid_mask(state)
        return doc_mod.SlotDoc(
            tokens=_masked_pmax(state.tokens, valid, axis_name),
            length=_pmax(state.length, axis_name),
            owner=_pmax(state.owner, axis_name),
        )
    # Container pytree: recurse into CRDT nodes.
    return jax.tree.map(lambda s: pmax_merge(s, axis_name), state, is_leaf=is_crdt)


def _pmin(x: jax.Array, axis_name) -> jax.Array:
    if x.dtype == jnp.bool_:
        # AND across replicas: only bits everyone has set survive.
        return ~_pmax(~x, axis_name)
    return jax.lax.pmin(x, axis_name)


def delta_merge(state: Any, frontier: Any, axis_names, axis_sizes,
                *, capacity: int = 64) -> tuple[Any, Any]:
    """Delta-state ring sync across the replica axis (use inside shard_map).

    ``frontier`` must be the SHARED frontier of the previous sync round
    (identical on every replica; initially ``delta.frontier(initial_state)``
    replicated).  Each replica extracts its delta beyond the frontier, the
    deltas circulate the ring in N-1 ``ppermute`` hops, and each hop joins
    the received delta.  Multi-axis replica grids (e.g. ("pod", "data"))
    sync as sequential per-axis rings — after the first axis' ring all
    members of that axis agree, so the next axis' ring forwards the already-
    combined deltas.

    Returns ``(merged_state, new_frontier)``.  The new frontier is the pmin
    of every replica's post-merge observation watermark — exactly the ops
    that reached EVERY replica — so it is identical everywhere and anything
    that overflowed ``capacity`` on any hop (and therefore missed some
    replicas) stays ahead of the frontier and re-ships next round.
    """
    if isinstance(axis_names, str):
        axis_names = (axis_names,)
    if isinstance(axis_sizes, int):
        axis_sizes = (axis_sizes,)

    for axis_name, n in zip(axis_names, axis_sizes):
        d, _ = delta_mod.extract(state, frontier, capacity)
        perm = [(i, (i + 1) % n) for i in range(n)]
        for _ in range(n - 1):
            d = jax.tree.map(
                lambda x: jax.lax.ppermute(x, axis_name, perm), d)
            state = delta_mod.apply(state, d)
    new_frontier = jax.tree.map(
        lambda x: _pmin(x, axis_names), delta_mod.frontier(state))
    return state, new_frontier


def collective_merge(state: Any, axis_name: str, strategy: str = "pmax") -> Any:
    if strategy == "pmax":
        return pmax_merge(state, axis_name)
    if strategy == "allgather":
        return allgather_merge(state, axis_name)
    if strategy == "delta":
        raise ValueError(
            "delta merge threads a frontier — call merge.delta_merge (or "
            "engine.make_coord_merge(strategy='delta')) instead")
    raise ValueError(f"unknown merge strategy: {strategy}")
