"""Array-backed RGA sequence CRDT — the Y.Text analogue (DESIGN.md §2).

State = per-client append-only op logs.  An op is identified by its stable
slot ``oid = client * capacity + index`` (rows are append-only and immutable,
so slots are stable ids).  Each op carries:

  * ``op_clock``  — Lamport timestamp (orders same-origin siblings),
  * ``origin``    — oid of the element it was inserted after (HEAD for doc start),
  * ``token``     — payload token id,
  * ``deleted``   — tombstone (2P-set: any replica may set; join = OR).

The *join* of two states is trivial (per-slot "whoever knows it" union +
tombstone OR), hence strong eventual consistency.  The *document* is a pure
deterministic function ``materialize(state)`` of the op set:

  RGA tree order: an op is a child of its origin; siblings sort by
  descending (clock, client); document = preorder traversal.

``materialize`` exploits the classic insight that inserting ops in ascending
(clock, client) order, each immediately after its origin in a linked list,
reconstructs exactly this preorder (each new op is the largest-key child of
its origin at insertion time, i.e. its first child).  That gives an
O(n log n) sort + O(n) linked-list build with fixed shapes — no recursion,
no dynamic allocation, fully jittable.

Lamport clocks respect causality (clients tick past everything they have
observed), so an op's origin always has a smaller key and is inserted first.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.clock import MAX_CLIENTS, pack_key

INT32_MAX = jnp.iinfo(jnp.int32).max


class RGA(NamedTuple):
    count: jax.Array      # i32[C]    valid ops in row c are [0, count[c])
    op_clock: jax.Array   # i32[C, L]
    origin: jax.Array     # i32[C, L] dense oid of left neighbour at insert; HEAD = C*L
    token: jax.Array      # i32[C, L]
    deleted: jax.Array    # bool[C, L]

    @property
    def num_clients(self) -> int:
        return self.op_clock.shape[0]

    @property
    def capacity(self) -> int:
        return self.op_clock.shape[1]

    @property
    def head_oid(self) -> int:
        return self.num_clients * self.capacity

    def valid_mask(self) -> jax.Array:
        idx = jnp.arange(self.capacity, dtype=jnp.int32)[None, :]
        return idx < self.count[:, None]

    def max_clock(self) -> jax.Array:
        """Largest observed Lamport time (for Lamport receive rule)."""
        return jnp.max(jnp.where(self.valid_mask(), self.op_clock, 0))


def empty(num_clients: int, capacity: int) -> RGA:
    shape = (num_clients, capacity)
    return RGA(
        count=jnp.zeros((num_clients,), jnp.int32),
        op_clock=jnp.zeros(shape, jnp.int32),
        origin=jnp.zeros(shape, jnp.int32),
        token=jnp.zeros(shape, jnp.int32),
        deleted=jnp.zeros(shape, jnp.bool_),
    )


def insert(state: RGA, client: jax.Array, clock: jax.Array,
           origin_oid: jax.Array, token: jax.Array) -> RGA:
    """Append one insert-op to ``client``'s own row."""
    pos = jnp.minimum(state.count[client], state.capacity - 1)
    ok = state.count[client] < state.capacity
    upd = lambda arr, v: arr.at[client, pos].set(
        jnp.where(ok, jnp.asarray(v, arr.dtype), arr[client, pos]))
    return RGA(
        count=state.count.at[client].add(jnp.where(ok, 1, 0)),
        op_clock=upd(state.op_clock, clock),
        origin=upd(state.origin, origin_oid),
        token=upd(state.token, token),
        deleted=state.deleted,
    )


def insert_run(state: RGA, client: jax.Array, clock0: jax.Array,
               origin_oid: jax.Array, tokens: jax.Array,
               length: jax.Array) -> RGA:
    """Insert a contiguous run of ``length`` tokens after ``origin_oid``.

    Each token's origin is its predecessor in the run, so a run is a chain in
    the RGA tree and can never be interleaved by a concurrent run (tested).
    This is the common fast path — an agent committing a generated chunk is a
    single O(run) slice write, no per-token host loop.
    """
    run_cap = tokens.shape[0]
    c = jnp.asarray(client, jnp.int32)
    pos0 = state.count[c]
    room = jnp.clip(state.capacity - pos0, 0, run_cap)
    n = jnp.minimum(jnp.asarray(length, jnp.int32), room)
    j = jnp.arange(run_cap, dtype=jnp.int32)
    write = j < n
    # Masked lanes are routed out of bounds and dropped — clipping them onto a
    # valid slot would create duplicate scatter indices that can clobber the
    # real write (XLA scatter order is unspecified).
    pos = jnp.where(write, pos0 + j, state.capacity)
    oid_prev = c * state.capacity + (pos0 + j) - 1
    origins = jnp.where(j == 0, jnp.asarray(origin_oid, jnp.int32), oid_prev)
    clocks = jnp.asarray(clock0, jnp.int32) + j
    row_upd = lambda arr, vals: arr.at[c, pos].set(
        vals.astype(arr.dtype), mode="drop")
    return RGA(
        count=state.count.at[c].add(n),
        op_clock=row_upd(state.op_clock, clocks),
        origin=row_upd(state.origin, origins),
        token=row_upd(state.token, jnp.asarray(tokens, jnp.int32)),
        deleted=state.deleted,
    )


def delete(state: RGA, oid: jax.Array) -> RGA:
    c, i = oid // state.capacity, oid % state.capacity
    return state._replace(deleted=state.deleted.at[c, i].set(True))


def merge(a: RGA, b: RGA) -> RGA:
    """Join: per-slot union of observed ops; tombstones OR."""
    mine = a.valid_mask()
    pick = lambda x, y: jnp.where(mine, x, y)
    return RGA(
        count=jnp.maximum(a.count, b.count),
        op_clock=pick(a.op_clock, b.op_clock),
        origin=pick(a.origin, b.origin),
        token=pick(a.token, b.token),
        deleted=a.deleted | b.deleted,
    )


def materialize(state: RGA) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Deterministic document: (tokens i32[N], oids i32[N], visible_len).

    ``tokens``/``oids`` are left-packed over *visible* (non-tombstoned) ops;
    entries at index >= visible_len are -1.  ``oids`` lets callers name an
    insertion origin for subsequent edits.
    """
    C, L = state.op_clock.shape
    N = C * L
    HEAD = N

    valid = state.valid_mask().reshape(-1)                      # [N]
    clock_f = state.op_clock.reshape(-1)
    client_f = jnp.repeat(jnp.arange(C, dtype=jnp.int32), L)
    origin_f = state.origin.reshape(-1)
    key = jnp.where(valid, pack_key(clock_f, client_f), INT32_MAX)

    order = jnp.argsort(key)                                    # ascending
    # Linked list over oids; slot HEAD is the document start sentinel.
    nxt0 = jnp.full((N + 2,), -1, jnp.int32)                    # [-1] tail

    def body(k, nxt):
        x = order[k]
        ok = valid[x]
        o = jnp.where(ok, origin_f[x], N + 1)                   # scratch slot if invalid
        succ = nxt[o]
        nxt = nxt.at[x].set(jnp.where(ok, succ, nxt[x]))
        nxt = nxt.at[o].set(jnp.where(ok, x, nxt[o]))
        return nxt

    nxt = jax.lax.fori_loop(0, N, body, nxt0)

    deleted_f = state.deleted.reshape(-1)

    def walk(k, carry):
        cur, out_tok, out_oid, pos = carry
        live = cur >= 0
        cur_c = jnp.clip(cur, 0, N - 1)
        vis = live & ~deleted_f[cur_c]
        out_tok = out_tok.at[pos].set(
            jnp.where(vis, state.token.reshape(-1)[cur_c], out_tok[pos]))
        out_oid = out_oid.at[pos].set(jnp.where(vis, cur_c, out_oid[pos]))
        pos = pos + jnp.where(vis, 1, 0)
        cur = jnp.where(live, nxt[cur_c], -1)
        return cur, out_tok, out_oid, pos

    out_tok = jnp.full((N,), -1, jnp.int32)
    out_oid = jnp.full((N,), -1, jnp.int32)
    cur0 = nxt[HEAD]
    cur, out_tok, out_oid, pos = jax.lax.fori_loop(
        0, N, walk, (cur0, out_tok, out_oid, jnp.int32(0)))
    return out_tok, out_oid, pos


materialize_jit = jax.jit(materialize)
