"""Last-writer-wins register bank — the array-backed analogue of Yjs Y.Map.

A bank holds ``K`` registers.  Each register carries a Lamport ``(clock,
client)`` pair plus an arbitrary pytree of int32/float32 payload fields, all
shaped ``[K, ...]``.  The merge is the join of the total order on
``(clock, client)`` — a join-semilattice, hence strong eventual consistency
(Shapiro et al. 2011): commutative, associative, idempotent.  Ties on
``(clock, client)`` are impossible between well-behaved clients (a client
never reuses a clock), which makes the winner's payload well-defined.

Hot-path merge has a Pallas kernel (repro/kernels/lww_merge.py); this module
is the pure-jnp semantics used everywhere else.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.clock import pack_key


class LWWBank(NamedTuple):
    clock: jax.Array     # i32[K]   0 = never written
    client: jax.Array    # i32[K]   0 = never written
    payload: Any         # pytree of arrays, each [K, ...]

    @property
    def key(self) -> jax.Array:
        return pack_key(self.clock, self.client)

    @property
    def written(self) -> jax.Array:
        return self.clock > 0


def empty(num_keys: int, payload_spec: dict[str, tuple[tuple[int, ...], Any]]) -> LWWBank:
    """payload_spec: field -> (trailing_shape, dtype)."""
    payload = {
        name: jnp.zeros((num_keys, *shape), dtype)
        for name, (shape, dtype) in payload_spec.items()
    }
    return LWWBank(
        clock=jnp.zeros((num_keys,), jnp.int32),
        client=jnp.zeros((num_keys,), jnp.int32),
        payload=payload,
    )


def write(bank: LWWBank, key: jax.Array, clock: jax.Array, client: jax.Array,
          **fields: jax.Array) -> LWWBank:
    """Local write: set register ``key`` if (clock, client) beats current.

    Well-behaved writers tick their Lamport clock past anything they observed,
    so local writes normally win; the guard keeps writes monotone even for
    stale writers (their write is simply dropped — LWW semantics).
    """
    new_key = pack_key(clock, client)
    wins = new_key > bank.key[key]
    new_payload = dict(bank.payload)
    for name, value in fields.items():
        cur = bank.payload[name]
        new_payload[name] = cur.at[key].set(
            jnp.where(wins, jnp.asarray(value, cur.dtype), cur[key]))
    return LWWBank(
        clock=bank.clock.at[key].set(jnp.where(wins, clock, bank.clock[key])),
        client=bank.client.at[key].set(jnp.where(wins, client, bank.client[key])),
        payload=new_payload,
    )


def write_masked(bank: LWWBank, mask: jax.Array, clock: jax.Array,
                 client: jax.Array, **fields: jax.Array) -> LWWBank:
    """Vectorized write to every register where ``mask`` (bool[K]) holds."""
    new_key = pack_key(jnp.broadcast_to(clock, mask.shape),
                       jnp.broadcast_to(client, mask.shape))
    wins = mask & (new_key > bank.key)
    new_payload = dict(bank.payload)
    for name, value in fields.items():
        cur = bank.payload[name]
        val = jnp.broadcast_to(jnp.asarray(value, cur.dtype), cur.shape)
        w = wins.reshape(wins.shape + (1,) * (cur.ndim - 1))
        new_payload[name] = jnp.where(w, val, cur)
    return LWWBank(
        clock=jnp.where(wins, clock, bank.clock),
        client=jnp.where(wins, client, bank.client),
        payload=new_payload,
    )


def merge(a: LWWBank, b: LWWBank) -> LWWBank:
    """Join: per-register lexicographic max of (clock, client); winner's payload."""
    b_wins = b.key > a.key
    payload = {}
    for name, av in a.payload.items():
        bv = b.payload[name]
        w = b_wins.reshape(b_wins.shape + (1,) * (av.ndim - 1))
        payload[name] = jnp.where(w, bv, av)
    return LWWBank(
        clock=jnp.where(b_wins, b.clock, a.clock),
        client=jnp.where(b_wins, b.client, a.client),
        payload=payload,
    )


def read(bank: LWWBank, field: str, key: jax.Array) -> jax.Array:
    return bank.payload[field][key]
