"""TodoBoard: the paper's Y.Map TODO coordination state (§3.5).

A fixed bank of K TODO registers over an LWWBank.  Each register packs the
paper's record {status, assignedTo, logicalClock} plus claim_time (for the
120 s stale-claim liveness rule) and a dependency mask (task coupling
structure, §5.2.1).  All writes go through LWW semantics, so the at-most-one
-winner safety theorem (paper §A.5) holds verbatim: concurrent claims resolve
by lexicographic (clock, client) order, identically on every replica.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import lww

# Status enum (monotone in intent, enforced by protocol not by type).
EMPTY, PENDING, CLAIMED, DONE = 0, 1, 2, 3


class TodoBoard(NamedTuple):
    bank: lww.LWWBank     # payload: status, assignee, claim_time i32[K]; deps bool[K, K]

    @property
    def num_todos(self) -> int:
        return self.bank.clock.shape[0]

    @property
    def status(self) -> jax.Array:
        return self.bank.payload["status"]

    @property
    def assignee(self) -> jax.Array:
        return self.bank.payload["assignee"]

    @property
    def claim_time(self) -> jax.Array:
        return self.bank.payload["claim_time"]

    @property
    def deps(self) -> jax.Array:
        return self.bank.payload["deps"]

    def max_clock(self) -> jax.Array:
        return jnp.max(self.bank.clock)


def empty(num_todos: int) -> TodoBoard:
    spec = {
        "status": ((), jnp.int32),
        "assignee": ((), jnp.int32),
        "claim_time": ((), jnp.int32),
        "deps": ((num_todos,), jnp.bool_),
    }
    return TodoBoard(bank=lww.empty(num_todos, spec))


def post(board: TodoBoard, k: jax.Array, deps_row: jax.Array,
         clock: jax.Array, client: jax.Array) -> TodoBoard:
    """Outliner publishes TODO k with its dependency row (bool[K])."""
    return TodoBoard(lww.write(
        board.bank, k, clock, client,
        status=PENDING, assignee=0, claim_time=0, deps=deps_row))


def claim(board: TodoBoard, k: jax.Array, agent: jax.Array,
          clock: jax.Array, now: jax.Array) -> TodoBoard:
    return TodoBoard(lww.write(
        board.bank, k, clock, agent,
        status=CLAIMED, assignee=agent, claim_time=now,
        deps=board.deps[k]))


def complete(board: TodoBoard, k: jax.Array, agent: jax.Array,
             clock: jax.Array) -> TodoBoard:
    return TodoBoard(lww.write(
        board.bank, k, clock, agent,
        status=DONE, assignee=agent, claim_time=board.claim_time[k],
        deps=board.deps[k]))


def reset_stale(board: TodoBoard, now: jax.Array, timeout: jax.Array,
                clock: jax.Array, client: jax.Array) -> TodoBoard:
    """Liveness: claims whose holder went silent revert to PENDING.

    Mirrors the paper's 120 s timeout + status reset.  Safe because shard/TODO
    completion is idempotent (LWW/G-set), so duplicated work merges cleanly.
    """
    stale = (board.status == CLAIMED) & (now - board.claim_time > timeout)
    return TodoBoard(lww.write_masked(
        board.bank, stale, clock, client,
        status=PENDING, assignee=0, claim_time=0, deps=board.deps))


def done_mask(board: TodoBoard) -> jax.Array:
    return board.status == DONE


def ready_mask(board: TodoBoard) -> jax.Array:
    """PENDING and every dependency DONE."""
    done = done_mask(board)
    deps_ok = jnp.all(~board.deps | done[None, :], axis=1)
    return (board.status == PENDING) & deps_ok


def pick(board: TodoBoard, agent: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Deterministic next-TODO choice, rotated per agent to de-collide claims.

    Returns (k, found).  Rotation is a heuristic only — safety never depends
    on it (colliding claims are resolved by LWW; losers re-pick).
    """
    k_count = board.num_todos
    ready = ready_mask(board)
    idx = jnp.arange(k_count, dtype=jnp.int32)
    rot = (idx - jnp.asarray(agent, jnp.int32) * 3) % k_count
    score = jnp.where(ready, k_count - rot, -1)
    k = jnp.argmax(score)
    return k.astype(jnp.int32), ready[k]


def all_done(board: TodoBoard) -> jax.Array:
    posted = board.status != EMPTY
    return jnp.all(~posted | (board.status == DONE))
