"""SlotDoc: the production-path shared code document (DESIGN.md §2).

The outliner's skeleton fixes an ordered set of K regions (one per TODO).
After a TODO is claimed, exactly one agent appends tokens into its region —
so each region is a single-writer append-only buffer and the document is the
in-order concatenation of regions.  The join is exact and pmax-compatible
(lengths: max; tokens: identical where observed).  Character-level
convergence is therefore structural, matching the paper's "0% character-level
conflicts"; *semantic* conflicts (duplicate declarations across regions) can
and do still occur and are detected by the evaluator agent.

The general concurrent-editing path (arbitrary interleaved inserts) is
core/rga.py; SlotDoc is the fixed-shape fast path that serving fuses with
decode steps.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class SlotDoc(NamedTuple):
    tokens: jax.Array    # i32[K, S]
    length: jax.Array    # i32[K]   monotone, owner-only writes
    owner: jax.Array     # i32[K]   informational (set by claim winner)

    @property
    def num_slots(self) -> int:
        return self.tokens.shape[0]

    @property
    def slot_capacity(self) -> int:
        return self.tokens.shape[1]

    @property
    def version(self) -> jax.Array:
        """Per-slot content version — observation-driven invalidation key."""
        return self.length


def empty(num_slots: int, slot_capacity: int) -> SlotDoc:
    return SlotDoc(
        tokens=jnp.zeros((num_slots, slot_capacity), jnp.int32),
        length=jnp.zeros((num_slots,), jnp.int32),
        owner=jnp.zeros((num_slots,), jnp.int32),
    )


def set_owner(doc: SlotDoc, slot: jax.Array, agent: jax.Array) -> SlotDoc:
    return doc._replace(owner=doc.owner.at[slot].max(jnp.asarray(agent, jnp.int32)))


def append(doc: SlotDoc, slot: jax.Array, tokens: jax.Array,
           length: jax.Array) -> SlotDoc:
    """Owner appends ``length`` tokens (from a fixed-size staging buffer)."""
    run_cap = tokens.shape[0]
    pos0 = doc.length[slot]
    room = jnp.clip(doc.slot_capacity - pos0, 0, run_cap)
    n = jnp.minimum(jnp.asarray(length, jnp.int32), room)
    j = jnp.arange(run_cap, dtype=jnp.int32)
    # Masked lanes go out of bounds and are dropped (no duplicate indices).
    pos = jnp.where(j < n, pos0 + j, doc.slot_capacity)
    new_tokens = doc.tokens.at[slot, pos].set(
        jnp.asarray(tokens, jnp.int32), mode="drop")
    return doc._replace(tokens=new_tokens, length=doc.length.at[slot].add(n))


def append_token(doc: SlotDoc, slot: jax.Array, token: jax.Array) -> SlotDoc:
    """One-token append (the per-decode-step fused path)."""
    pos = jnp.minimum(doc.length[slot], doc.slot_capacity - 1)
    ok = doc.length[slot] < doc.slot_capacity
    return doc._replace(
        tokens=doc.tokens.at[slot, pos].set(
            jnp.where(ok, jnp.asarray(token, jnp.int32), doc.tokens[slot, pos])),
        length=doc.length.at[slot].add(jnp.where(ok, 1, 0)),
    )


def append_token_batch(doc: SlotDoc, slots: jax.Array, tokens: jax.Array,
                       active: jax.Array) -> SlotDoc:
    """N agents each append one token to their own slot (vectorized).

    ``slots`` i32[N] must be distinct where ``active`` — guaranteed by the
    claim protocol's at-most-one-winner invariant.
    """
    pos = jnp.minimum(doc.length[slots], doc.slot_capacity - 1)
    ok = active & (doc.length[slots] < doc.slot_capacity)
    cur = doc.tokens[slots, pos]
    return doc._replace(
        tokens=doc.tokens.at[slots, pos].set(
            jnp.where(ok, jnp.asarray(tokens, jnp.int32), cur)),
        length=doc.length.at[slots].add(jnp.where(ok, 1, 0)),
    )


def valid_mask(doc: SlotDoc) -> jax.Array:
    idx = jnp.arange(doc.slot_capacity, dtype=jnp.int32)[None, :]
    return idx < doc.length[:, None]


def merge(a: SlotDoc, b: SlotDoc) -> SlotDoc:
    mine = valid_mask(a)
    return SlotDoc(
        tokens=jnp.where(mine, a.tokens, b.tokens),
        length=jnp.maximum(a.length, b.length),
        owner=jnp.maximum(a.owner, b.owner),
    )


def render(doc: SlotDoc) -> tuple[jax.Array, jax.Array]:
    """Flatten to (tokens i32[K*S], total_len): in-slot-order concatenation."""
    K, S = doc.tokens.shape
    mask = valid_mask(doc).reshape(-1)
    flat = doc.tokens.reshape(-1)
    total = jnp.sum(mask.astype(jnp.int32))
    # Stable left-pack: valid entries first, original order preserved.
    order = jnp.argsort(~mask, stable=True)
    out = jnp.where(jnp.arange(K * S) < total, flat[order], -1)
    return out, total


def digest(doc: SlotDoc) -> jax.Array:
    """Order-sensitive content hash — replicas must agree post-merge (RQ3)."""
    mask = valid_mask(doc)
    K, S = doc.tokens.shape
    idx = jnp.arange(K * S, dtype=jnp.uint32).reshape(K, S)
    h = jnp.where(mask, doc.tokens.astype(jnp.uint32), jnp.uint32(0))
    mixed = (h * jnp.uint32(2654435761) + idx * jnp.uint32(40503)) % jnp.uint32(2**31 - 1)
    return jnp.sum(jnp.where(mask, mixed, jnp.uint32(0)), dtype=jnp.uint32)
