"""TODO-claim protocol: optimistic write-verify (paper §3.5 / §A.5).

The paper's four steps — scan, claim, wait-for-sync, verify — become on TPU:

  1. scan   — ``todo.pick`` over the merged board (deterministic, rotated),
  2. claim  — LWW write with the agent's ticked Lamport clock,
  3. sync   — a collective (or pairwise) merge replaces the 50 ms wait; the
              merge is an exact join, so the verify read is exact,
  4. verify — claim succeeded iff the merged register names this agent.

Safety (at-most-one-winner) is the paper's theorem verbatim: concurrent
claims on key k resolve via the lexicographic (clock, client) total order,
and every replica converges to the same winner.  Property-tested in
tests/test_todo_protocol.py under random interleavings and merge orders.

``merge_fn`` is injected: agents running on a mesh pass a collective merge
(core.merge.collective_merge); host-side orchestration passes a fold over
replica states.  The protocol is agnostic — that is the substrate-
independence argument of paper §3.2.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import todo
from repro.core.clock import Lamport

MergeFn = Callable[[todo.TodoBoard], todo.TodoBoard]


class ClaimOutcome(NamedTuple):
    board: todo.TodoBoard    # post-merge board
    lamport: Lamport         # advanced clock
    todo_id: jax.Array       # i32 — the key this agent attempted
    attempted: jax.Array     # bool — a ready TODO existed
    won: jax.Array           # bool — verify read names this agent


def try_claim(board: todo.TodoBoard, lamport: Lamport, now: jax.Array,
              merge_fn: MergeFn) -> ClaimOutcome:
    """One scan→claim→sync→verify round for one agent."""
    # Lamport receive rule against everything observed so far.
    lam = lamport.observe(board.max_clock())
    k, found = todo.pick(board, lam.client)
    proposed = jax.tree.map(
        lambda new, old: jnp.where(found, new, old),
        todo.claim(board, k, lam.client, lam.time, now),
        board,
    )
    merged = merge_fn(proposed)
    won = found & (merged.status[k] == todo.CLAIMED) & (merged.assignee[k] == lam.client)
    return ClaimOutcome(board=merged, lamport=lam, todo_id=k,
                        attempted=found, won=won)


def complete(board: todo.TodoBoard, lamport: Lamport, k: jax.Array,
             merge_fn: MergeFn) -> tuple[todo.TodoBoard, Lamport]:
    lam = lamport.observe(board.max_clock())
    return merge_fn(todo.complete(board, k, lam.client, lam.time)), lam


def reclaim_stale(board: todo.TodoBoard, lamport: Lamport, now: jax.Array,
                  timeout: jax.Array, merge_fn: MergeFn
                  ) -> tuple[todo.TodoBoard, Lamport]:
    """Liveness sweep (paper's 120 s reclaim): any live agent may run it."""
    lam = lamport.observe(board.max_clock())
    return merge_fn(todo.reset_stale(board, now, timeout, lam.time, lam.client)), lam


# ---------------------------------------------------------------------------
# Vectorized N-agent round (used by the fused serving step): all agents claim
# concurrently against the same observed board; the merge arbitrates.
# ---------------------------------------------------------------------------

def concurrent_claims(board: todo.TodoBoard, clients: jax.Array,
                      clocks: jax.Array, now: jax.Array
                      ) -> tuple[todo.TodoBoard, jax.Array, jax.Array]:
    """N agents propose claims against one observed board snapshot.

    Returns (merged_board, todo_ids i32[N], won bool[N]).  Implemented as a
    fold of per-agent proposals through the join — equivalent to any delivery
    order by commutativity (that equivalence is property-tested).
    """
    n = clients.shape[0]

    def propose(i):
        k, found = todo.pick(board, clients[i])
        prop = todo.claim(board, k, clients[i], clocks[i], now)
        prop = jax.tree.map(lambda new, old: jnp.where(found, new, old), prop, board)
        return prop, k, found

    def body(i, carry):
        acc, ks, founds = carry
        prop, k, found = propose(i)
        from repro.core import merge as merge_mod
        acc = merge_mod.join(acc, prop)
        return acc, ks.at[i].set(k), founds.at[i].set(found)

    ks0 = jnp.zeros((n,), jnp.int32)
    f0 = jnp.zeros((n,), jnp.bool_)
    merged, ks, founds = jax.lax.fori_loop(0, n, body, (board, ks0, f0))
    won = founds & (merged.status[ks] == todo.CLAIMED) & (merged.assignee[ks] == clients)
    return merged, ks, won
