"""Observation-driven adaptation (paper §4.2).

An agent's "subscription" to CRDT events is, on TPU, a version-vector diff:
between decode steps the agent compares the merged state's per-slot versions
against its own snapshot.  Four behaviours from the paper map to:

  * completed-work detection — TODO status flips observed via the board,
  * context integration      — slot version advanced => new content to read,
  * naming alignment         — context re-read includes other slots' tokens,
  * conflict avoidance       — claim protocol (losers back off and re-pick).

``invalidations`` implements the context-invalidation signal that drives the
paper's coupled-task slowdown: if a dependency's content changed after the
agent snapshotted it, the agent must re-contextualize (re-prefill).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.doc import SlotDoc
from repro.core.rga import RGA


class Snapshot(NamedTuple):
    """What an agent last observed, per document slot."""

    versions: jax.Array    # i32[K]


def snapshot(doc: SlotDoc) -> Snapshot:
    return Snapshot(versions=doc.version)


def changed_mask(snap: Snapshot, doc: SlotDoc) -> jax.Array:
    """bool[K] — slots whose content advanced since the snapshot."""
    return doc.version > snap.versions


def invalidations(snap: Snapshot, doc: SlotDoc, deps_row: jax.Array) -> jax.Array:
    """True if any dependency slot changed since the snapshot (re-prefill)."""
    return jnp.any(changed_mask(snap, doc) & deps_row)


def observation_count(snap: Snapshot, doc: SlotDoc) -> jax.Array:
    """Number of update events this observation delivers (O(N×U) accounting)."""
    return jnp.sum((doc.version - snap.versions).clip(0))


class RGAFrontier(NamedTuple):
    """Version vector over an RGA replica (per-client op counts)."""

    counts: jax.Array    # i32[C]


def rga_frontier(state: RGA) -> RGAFrontier:
    return RGAFrontier(counts=state.count)


def rga_delta_mask(state: RGA, frontier: RGAFrontier) -> jax.Array:
    """bool[C, L] — ops not yet observed at ``frontier``."""
    idx = jnp.arange(state.capacity, dtype=jnp.int32)[None, :]
    return (idx >= frontier.counts[:, None]) & state.valid_mask()
