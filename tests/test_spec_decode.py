"""Speculative decoding through the mixed step.

Acceptance bar (mirrors PR-4's chunked-prefill equivalence sweep):

* the verify step's per-row accept counts match the crafted drafts
  exactly (full / none / partial acceptance) on MHA, MLA, hybrid-rglru
  and xLSTM archs, paged and dense;
* rejected-tail cache slots are restored BITWISE to their pre-verify
  bytes (gather-by-position compare against a pre-step snapshot — raw
  pool compares are invalid across engines because allocation order
  differs), including recurrent state snapshots + committed-span replay;
* post-rollback continuation streams equal the never-drafted greedy
  reference — the token-identity guarantee (argmax is robust to the
  last-ulp reduction-width differences PR-4 documented for width-1
  matvecs, which is why the *byte* guarantee is scoped to the restored
  tail, not cross-width cache equality);
* the scheduler end-to-end: speculative streams equal non-speculative
  greedy across drafters (ngram / doc / adversarial), chunk sizes, dense
  and paged modes, with zero page leaks — including COW prefix-shared
  rows (no double-free);
* the orchestrator: sequential agent trials are digest-identical off vs
  speculative, and uncoupled parallel trials too.

Everything runs in f32 interpret mode on CPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.kernels import ref as kref
from repro.models import attention, lm
from repro.models import cache as cache_mod
from repro.serving import draft as draft_mod
from repro.serving.scheduler import ContinuousBatchingEngine, Request

B, MAX_LEN, PS = 3, 32, 8
V = 128


def _f32(t):
    return jax.tree.map(lambda x: x.astype(jnp.float32), t)


def _mk(kind):
    if kind == "mha":
        cfg = configs.reduced(configs.get("olmo-1b"), d_model=32, vocab=V)
        return cfg.replace(num_layers=2)
    if kind == "mla":
        return configs.reduced(configs.get("deepseek-v2-lite-16b"),
                               d_model=32, vocab=V)
    if kind == "hybrid":
        cfg = configs.reduced(configs.get("olmo-1b"), d_model=32, vocab=V)
        return cfg.replace(block_pattern=("attn", "rglru"), num_layers=4)
    cfg = configs.reduced(configs.get("xlstm-125m"), d_model=32, vocab=V)
    return cfg.replace(block_pattern=("slstm", "mlstm", "attn"),
                       num_layers=3, d_ff=128)


@pytest.fixture(scope="module", params=["mha", "mla", "hybrid", "xlstm"])
def llm(request):
    cfg = _mk(request.param)
    return cfg, _f32(lm.init(jax.random.PRNGKey(0), cfg))


@pytest.fixture(scope="module")
def mha_llm():
    cfg = _mk("mha")
    return cfg, _f32(lm.init(jax.random.PRNGKey(0), cfg))


@pytest.fixture(scope="module")
def mla_llm():
    cfg = _mk("mla")
    return cfg, _f32(lm.init(jax.random.PRNGKey(0), cfg))


@pytest.fixture(scope="module")
def hybrid_llm():
    cfg = _mk("hybrid")
    return cfg, _f32(lm.init(jax.random.PRNGKey(0), cfg))


def _mk_cache(cfg, paged):
    cache = lm.init_cache(cfg, B, MAX_LEN, dtype=jnp.float32,
                          paged=paged, page_size=PS)
    if paged:
        cache = lm.set_block_tables(
            cache, attention.default_block_tables(B, MAX_LEN, PS))
    return cache


# ---------------------------------------------------------------------------
# Drafter units
# ---------------------------------------------------------------------------

def test_ngram_drafter_prompt_lookup():
    d = draft_mod.NgramDrafter(max_ngram=3)
    # [7 8 9] occurred earlier, followed by [4 5 6]; trailing context ends
    # in [7 8 9] -> propose the continuation.
    ctx = [1, 2, 7, 8, 9, 4, 5, 6, 7, 8, 9]
    assert d.propose(ctx, 3) == [4, 5, 6]
    assert d.propose(ctx, 2) == [4, 5]
    assert d.propose([1, 2, 3], 4) == []          # no earlier match
    assert d.propose([], 4) == []
    assert d.propose(ctx, 0) == []


def test_ngram_rightmost_longest_match_wins():
    d = draft_mod.NgramDrafter(max_ngram=3)
    # Trailing [5 1 2]: the trigram match (-> 9) beats bigram/unigram ones.
    ctx = [5, 1, 2, 9, 1, 2, 8, 5, 1, 2]
    assert d.propose(ctx, 1) == [9]


def test_doc_drafter_and_fallback():
    d = draft_mod.DocDrafter(max_ngram=3, min_ngram=2)
    d.set_docs([[1, 2, 3, 4, 5]])
    assert d.propose([9, 2, 3], 2) == [4, 5]      # doc continuation
    # No doc match, but own history repeats -> n-gram fallback kicks in.
    assert d.propose([7, 8, 6, 7, 8], 1) == [6]
    nofb = draft_mod.DocDrafter(fallback=False)
    nofb.set_docs([[1, 2, 3]])
    assert nofb.propose([7, 8, 6, 7, 8], 1) == []
    # Live lists: growing the doc after set_docs is visible.
    live = [1, 2, 3]
    d2 = draft_mod.DocDrafter()
    d2.set_docs([live])
    live.extend([4, 5])
    assert d2.propose([2, 3], 2) == [4, 5]


def test_make_drafter_factory():
    assert draft_mod.make_drafter("ngram").name == "ngram"
    assert draft_mod.make_drafter("doc").name == "doc"
    with pytest.raises(ValueError):
        draft_mod.make_drafter("nope")


def test_accept_tokens_semantics():
    preds = [10, 11, 12, 13, 14]
    # Full acceptance: all drafts + bonus.
    app, a = draft_mod.accept_tokens([10, 11, 12], 3, preds, 99, None)
    assert (app, a) == ([10, 11, 12, 13], 3)
    # Zero acceptance still commits the bonus (>= 1 token per step).
    app, a = draft_mod.accept_tokens([7, 7], 0, preds, 99, None)
    assert (app, a) == ([10], 0)
    # eos truncation is inclusive; budget cap applies after.
    app, a = draft_mod.accept_tokens([10, 11, 12], 3, preds, 99, 11)
    assert app == [10, 11]
    app, a = draft_mod.accept_tokens([10, 11, 12], 3, preds, 2, None)
    assert app == [10, 11]
    app, a = draft_mod.accept_tokens([10], 1, preds, 0, None)
    assert app == [10]                             # floor: 1 token


def test_speculative_accept_oracle():
    # preds[j] = greedy token after span position j; tokens[1:] are drafts.
    preds = jnp.asarray([[5, 6, 7, 8], [5, 6, 7, 8], [5, 6, 7, 8]])
    toks = jnp.asarray([[1, 5, 6, 7],      # full match -> 3
                        [1, 9, 6, 7],      # first draft wrong -> 0
                        [1, 5, 9, 7]])     # second wrong -> 1
    acc = kref.speculative_accept(preds, toks, jnp.asarray([4, 4, 4]))
    assert list(np.asarray(acc)) == [3, 0, 1]
    # span 1 (no drafts) -> 0 regardless of content.
    acc = kref.speculative_accept(preds, toks, jnp.asarray([1, 1, 1]))
    assert list(np.asarray(acc)) == [0, 0, 0]


def test_paged_span_gather_restore_roundtrip():
    rng = np.random.RandomState(0)
    pool = jnp.asarray(rng.randn(6, 2, PS, 4).astype(np.float32))
    bt = jnp.asarray([[0, 2, 4, 5], [1, 3, 4, 5]], jnp.int32)
    start = jnp.asarray([5, 13], jnp.int32)
    snap = kref.paged_span_gather(pool, bt, start, 4)
    assert snap.shape == (2, 4, 2, 4)
    scr = pool + 1.0                               # corrupt every slot
    back = kref.paged_span_restore(scr, snap, bt, start,
                                   jnp.asarray([5, 13], jnp.int32),
                                   jnp.asarray([9, 17], jnp.int32))
    again = kref.paged_span_gather(back, bt, start, 4)
    assert np.array_equal(np.asarray(again), np.asarray(snap))
    # Window [lo, hi) masks: restoring nothing leaves the pool untouched.
    noop = kref.paged_span_restore(scr, snap, bt, start,
                                   jnp.asarray([5, 13], jnp.int32),
                                   jnp.asarray([5, 13], jnp.int32))
    assert np.array_equal(np.asarray(noop), np.asarray(scr))


# ---------------------------------------------------------------------------
# Verify + bitwise rollback at the lm level (all archs, paged and dense)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("paged", [True, False])
def test_verify_rollback_bitwise_and_streams(llm, paged):
    cfg, params = llm
    cache = _mk_cache(cfg, paged)
    rng = np.random.RandomState(0)
    plen, k = 6, 4
    prompts = rng.randint(0, V, size=(B, plen)).astype(np.int32)
    lg, cache = lm.mixed_step(params, cfg, jnp.asarray(prompts), cache,
                              jnp.zeros(B, jnp.int32),
                              jnp.full(B, plen, jnp.int32))
    t0 = np.asarray(jnp.argmax(lg, -1)).astype(np.int32)

    # Never-drafted greedy reference (width-1 decode) for stream identity.
    cache_ref = jax.tree.map(jnp.copy, cache)
    toks_ref, cur, pos = [], t0.copy(), np.full(B, plen, np.int64)
    for _ in range(10):
        lg2, cache_ref = lm.mixed_step(
            params, cfg, jnp.asarray(cur[:, None]), cache_ref,
            jnp.asarray(pos, jnp.int32), jnp.ones(B, jnp.int32))
        cur = np.asarray(jnp.argmax(lg2, -1)).astype(np.int32)
        toks_ref.append(cur)
        pos += 1
    toks_ref = np.stack(toks_ref, 1)

    # Crafted drafts: row 0 fully right, row 1 fully wrong, row 2 wrong at
    # position 2 — acceptance must come out exactly [4, 0, 2].
    drafts = np.zeros((B, k), np.int32)
    drafts[0] = toks_ref[0, :k]
    drafts[1] = (toks_ref[1, :k] + 1) % V
    drafts[2] = toks_ref[2, :k]
    drafts[2, 2] = (drafts[2, 2] + 1) % V
    toks = np.concatenate([t0[:, None], drafts], 1)
    span = np.full(B, 1 + k, np.int32)
    start = np.full(B, plen, np.int32)

    pre = jax.tree.map(jnp.copy, cache)
    snap = cache_mod.snapshot_span(cache, jnp.asarray(start), 1 + k)
    has_state = any(cache_mod.layout_for(kd, cfg, paged=False) == "state"
                    for kd in tuple(cfg.block_pattern)
                    + tuple(cfg.tail_blocks))
    if has_state:
        st_snap = lm.snapshot_state_rows(cfg, cache)
    preds, acc, cache = lm.verify_step(params, cfg, jnp.asarray(toks),
                                       cache, jnp.asarray(start),
                                       jnp.asarray(span))
    preds, acc = np.asarray(preds), np.asarray(acc)
    assert list(acc) == [4, 0, 2]
    n_app = acc + 1
    for b in range(B):
        a = int(acc[b])
        committed = list(drafts[b, :a]) + [int(preds[b, a])]
        assert committed == list(toks_ref[b, :a + 1])

    # Roll the rejected tails back and compare the restored slots BITWISE
    # against the pre-verify bytes, gathered by position.
    cache = cache_mod.restore_span(
        cache, snap, jnp.asarray(start),
        jnp.asarray(start + n_app, jnp.int32),
        jnp.asarray(start + span, jnp.int32))
    if has_state:
        mask = n_app < span
        cache = lm.restore_state_rows(cfg, cache, st_snap,
                                      jnp.asarray(mask))
        spans2 = np.where(mask, n_app, 0).astype(np.int32)
        w2 = int(spans2.max())
        _, cache = lm.mixed_step(params, cfg, jnp.asarray(toks[:, :w2]),
                                 cache, jnp.asarray(start),
                                 jnp.asarray(spans2))
    post = cache_mod.snapshot_span(cache, jnp.asarray(start), 1 + k)
    want = cache_mod.snapshot_span(pre, jnp.asarray(start), 1 + k)
    for la, lp in zip(jax.tree.leaves(post), jax.tree.leaves(want)):
        a_np, p_np = np.asarray(la), np.asarray(lp)
        for b in range(B):
            # Snapshot leaf layout: [B, W, ...] except stacked dense_mla's
            # adjacent-index gather, which keeps the group axis leading.
            sl = (slice(None), b) if a_np.shape[0] != B else (b,)
            for w in range(int(n_app[b]), 1 + k):
                assert np.array_equal(a_np[sl + (w,)], p_np[sl + (w,)])

    # Post-rollback continuation equals the never-drafted stream.
    cur = np.array([toks_ref[b, acc[b]] for b in range(B)], np.int32)
    pos = plen + n_app.astype(np.int64)
    for i in range(4):
        lg3, cache = lm.mixed_step(params, cfg, jnp.asarray(cur[:, None]),
                                   cache, jnp.asarray(pos, jnp.int32),
                                   jnp.ones(B, jnp.int32))
        cur = np.asarray(jnp.argmax(lg3, -1)).astype(np.int32)
        for b in range(B):
            assert int(cur[b]) == int(toks_ref[b, int(n_app[b]) + i])
        pos += 1


# ---------------------------------------------------------------------------
# Scheduler end-to-end: stream identity, leaks, COW, guards
# ---------------------------------------------------------------------------

class _BadDrafter:
    """Adversarial: always proposes the same (almost surely wrong) run."""

    def __init__(self, tok=127):
        self.tok = tok

    def propose(self, ctx, k):
        return [self.tok] * k


def _spec_prompts(rng, n=5):
    pat = rng.randint(0, V, size=6).tolist()
    return [(pat * 4)[:12 + i] for i in range(n)]


def _run_sched(cfg, params, prompts, spec, *, drafter=None, paged=True,
               chunk=8, max_new=8):
    eng = ContinuousBatchingEngine(
        cfg, params, batch=3, max_len=64, paged=paged, page_size=PS,
        chunk_size=chunk, spec_decode=spec, spec_k=4, drafter=drafter)
    out = eng.run([Request(rid=i, prompt=list(p), max_new_tokens=max_new)
                   for i, p in enumerate(prompts)])
    return eng, [r.tokens for r in out]


@pytest.mark.parametrize("arch", ["mha", "mla", "hybrid"])
def test_scheduler_spec_streams_match_greedy(arch, request):
    cfg, params = request.getfixturevalue(f"{arch}_llm")
    prompts = _spec_prompts(np.random.RandomState(0))
    eng0, base = _run_sched(cfg, params, prompts, "off")
    eng1, got = _run_sched(cfg, params, prompts, "ngram")
    assert got == base
    assert eng1.stats["draft_tokens"] > 0
    assert eng1.stats["accepted_tokens"] > 0
    assert eng1.stats["steps"] < eng0.stats["steps"]
    assert eng1.allocator.available == eng1.allocator.num_pages


def test_scheduler_doc_drafter_beats_ngram_on_converged_docs(mha_llm):
    cfg, params = mha_llm
    prompts = _spec_prompts(np.random.RandomState(0))
    _, base = _run_sched(cfg, params, prompts, "off")
    doc = draft_mod.DocDrafter()
    doc.set_docs([list(p) + list(t) for p, t in zip(prompts, base)])
    eng, got = _run_sched(cfg, params, prompts, "doc", drafter=doc)
    assert got == base
    # Seeded with the converged streams, doc lookup accepts nearly all.
    assert eng.spec_accept_rate > 0.5


def test_scheduler_adversarial_drafter_rolls_back_cleanly(mha_llm):
    cfg, params = mha_llm
    prompts = _spec_prompts(np.random.RandomState(0))
    _, base = _run_sched(cfg, params, prompts, "off")
    eng, got = _run_sched(cfg, params, prompts, "ngram",
                          drafter=_BadDrafter())
    assert got == base                     # streams survive 100% rejection
    assert eng.stats["rollback_tokens"] > 0
    assert eng.stats["accepted_tokens"] == 0
    assert eng.allocator.available == eng.allocator.num_pages  # no leak


@pytest.mark.parametrize("chunk", [2, 4, 16])
def test_scheduler_spec_streams_across_chunk_sizes(mha_llm, chunk):
    cfg, params = mha_llm
    prompts = _spec_prompts(np.random.RandomState(0))
    _, base = _run_sched(cfg, params, prompts, "off", chunk=chunk)
    _, got = _run_sched(cfg, params, prompts, "ngram", chunk=chunk)
    assert got == base


def test_scheduler_spec_dense_mode(mha_llm):
    cfg, params = mha_llm
    prompts = _spec_prompts(np.random.RandomState(0))
    _, base = _run_sched(cfg, params, prompts, "off", paged=False)
    eng, got = _run_sched(cfg, params, prompts, "ngram", paged=False)
    assert got == base
    assert eng.stats["accepted_tokens"] > 0


def test_scheduler_cow_prefix_shared_rollback_no_double_free(mha_llm):
    cfg, params = mha_llm
    prompt = np.random.RandomState(1).randint(0, V, size=17).tolist()

    def run(spec, drafter=None):
        eng = ContinuousBatchingEngine(
            cfg, params, batch=4, max_len=64, page_size=PS, chunk_size=8,
            prefix_sharing=True, spec_decode=spec, spec_k=4,
            drafter=drafter)
        rs = [Request(rid=i, prompt=list(prompt), max_new_tokens=8)
              for i in range(6)]
        eng.run(rs)
        return eng, [r.tokens for r in rs]

    _, base = run("off")
    eng, got = run("ngram", _BadDrafter(126))
    assert got == base
    assert eng.stats["rollback_tokens"] > 0
    assert eng.stats["shared_pages"] > 0          # sharing actually engaged
    assert eng.allocator.available == eng.allocator.num_pages


def test_scheduler_spec_guards(mha_llm):
    cfg, params = mha_llm
    with pytest.raises(ValueError, match="greedy"):
        ContinuousBatchingEngine(cfg, params, batch=2, max_len=64,
                                 temperature=0.5, spec_decode="ngram")
    with pytest.raises(ValueError, match="off/ngram/doc"):
        ContinuousBatchingEngine(cfg, params, batch=2, max_len=64,
                                 spec_decode="medusa")


# ---------------------------------------------------------------------------
# Orchestrator end-to-end
# ---------------------------------------------------------------------------

def test_orchestrator_spec_digest_identity():
    from repro.agents.orchestrator import make_sim_llm, run_task
    from repro.agents.tasks import TaskSpec

    cfg, params = make_sim_llm(0)
    small = TaskSpec(name="small", coupling="low", n_todos=3, deps={},
                     reads={}, base_tokens=16, par_inflation=1.0,
                     prompt_tokens=12, read_prompt_tokens=4)
    # Sequential: single writer, so the whole-trial document digest must
    # match the non-speculative run exactly.
    rs = {}
    for spec in ("off", "ngram"):
        rs[spec] = run_task(cfg, params, small, mode="sequential", seed=0,
                            max_len=128, kv="paged", prefill="chunked",
                            page_size=16, chunk_size=16, spec_decode=spec)
    assert rs["ngram"].digest == rs["off"].digest
    assert rs["ngram"].gen_tokens == rs["off"].gen_tokens
    assert rs["ngram"].draft_tokens > 0
    assert rs["ngram"].accepted_tokens > 0
    assert rs["ngram"].steps < rs["off"].steps
    assert 0.0 < rs["ngram"].accept_rate <= 1.0
    # Uncoupled parallel: no read edges, so slot content is prompt-pure
    # deterministic and digests must match despite step-clock compression.
    par = {}
    for spec in ("off", "doc"):
        par[spec] = run_task(cfg, params, small, mode="parallel",
                             n_agents=3, seed=0, max_len=128, kv="paged",
                             prefill="chunked", page_size=16,
                             chunk_size=16, spec_decode=spec)
    assert par["doc"].digest == par["off"].digest
    assert par["doc"].gen_tokens == par["off"].gen_tokens


def test_orchestrator_spec_requires_chunked():
    from repro.agents.orchestrator import make_sim_llm, run_task
    from repro.agents.tasks import TASKS

    cfg, params = make_sim_llm(0)
    with pytest.raises(ValueError, match="mixed serve step"):
        run_task(cfg, params, TASKS["tic_tac_toe"], mode="sequential",
                 prefill="replay", spec_decode="ngram")
