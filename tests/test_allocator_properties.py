"""Property tests for the single-engine page pool (serving/scheduler.py).

A model-based op machine drives ``PageAllocator`` (alloc / share / free /
reserve / take / release) against a reference refcount model and checks,
after EVERY op:

  * conservation: free + referenced == pool (a share never consumes a page,
    a reservation is already out of the free list),
  * the allocator's refcounts equal the model's exactly,
  * the free list is duplicate-free and disjoint from referenced pages,
  * double-free and share-after-free raise instead of corrupting.

Plus ``PrefixCache`` safety: a lookup never returns a freed or re-allocated
(generation-bumped) page.

Mirrors tests/test_delta_properties.py's optional-hypothesis pattern:
explicit seed parameters always run, and when ``hypothesis`` is installed
(the CI property job) the same machine is additionally driven by generated
op tapes.  Tier-1 collects and passes without the package.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.serving.scheduler import PageAllocator, PrefixCache

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

SEEDS = range(10)
POOL = 12


# ---------------------------------------------------------------------------
# Model-based op machine
# ---------------------------------------------------------------------------


class _Machine:
    """Interprets an op tape against PageAllocator + a reference model."""

    def __init__(self, num_pages: int = POOL):
        self.num_pages = num_pages
        self.alloc = PageAllocator(num_pages)
        self.refs: dict[int, int] = {}       # model: page -> refcount
        self.handles: list[int] = []         # one entry per outstanding ref
        self.reservations: list = []
        self.ever_allocated: set[int] = set()

    # ops ------------------------------------------------------------------

    def op_alloc(self, k: int) -> None:
        pages = self.alloc.alloc(k)
        if pages is None:
            assert self.alloc.available < k    # only refusal reason
            return
        assert len(pages) == k
        for p in pages:
            assert self.refs.get(p, 0) == 0, "handed out a live page"
            self.refs[p] = 1
            self.handles.append(p)
            self.ever_allocated.add(p)

    def op_share(self, pick: int) -> None:
        if not self.handles:
            return
        p = self.handles[pick % len(self.handles)]
        self.alloc.share([p])
        self.refs[p] += 1
        self.handles.append(p)

    def op_free(self, pick: int) -> None:
        if not self.handles:
            return
        p = self.handles.pop(pick % len(self.handles))
        self.alloc.free([p])
        self.refs[p] -= 1

    def op_reserve(self, k: int) -> None:
        res = self.alloc.reserve(k)
        if res is None:
            return
        self.reservations.append(res)
        for p in res._pages:
            self.refs[p] = 1
            self.ever_allocated.add(p)

    def op_take(self, pick: int) -> None:
        if not self.reservations:
            return
        res = self.reservations.pop(pick % len(self.reservations))
        for p in res.take():
            self.handles.append(p)             # ref already 1 from reserve

    def op_release(self, pick: int) -> None:
        if not self.reservations:
            return
        res = self.reservations.pop(pick % len(self.reservations))
        for p in list(res._pages):
            self.refs[p] -= 1
        res.release()

    OPS = ("alloc", "share", "free", "reserve", "take", "release")

    def apply(self, op: str, arg: int) -> None:
        if op in ("alloc", "reserve"):
            getattr(self, f"op_{op}")(arg % 4 + 1)
        else:
            getattr(self, f"op_{op}")(arg)
        self.check()

    # invariants -----------------------------------------------------------

    def check(self) -> None:
        referenced = {p for p, c in self.refs.items() if c > 0}
        # Conservation: free + referenced == pool, exactly once each.
        assert self.alloc.available + len(referenced) == self.num_pages
        free = self.alloc._free
        assert len(free) == len(set(free)), "duplicate page on free list"
        assert not (set(free) & referenced), "free page still referenced"
        for p in range(self.num_pages):
            assert self.alloc.refcount(p) == self.refs.get(p, 0), p
        # shared references never consume pool capacity
        assert len(self.handles) >= len(referenced) - sum(
            r.count for r in self.reservations)

    def run_tape(self, tape) -> None:
        for op, arg in tape:
            self.apply(op, arg)


def _random_tape(rng, length=120):
    weights = [0.3, 0.2, 0.3, 0.08, 0.06, 0.06]
    ops = rng.choice(_Machine.OPS, size=length, p=weights)
    args = rng.integers(0, 1000, size=length)
    return list(zip(ops.tolist(), args.tolist()))


# ---------------------------------------------------------------------------
# Always-on: explicit seed sweep
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", SEEDS)
def test_allocator_conservation_random_tape(seed):
    rng = np.random.default_rng(seed)
    m = _Machine()
    m.run_tape(_random_tape(rng))
    # Drain: every handle freed returns the pool to fully-available.
    for res in m.reservations:
        res.release()
    m.reservations.clear()
    for p in list(m.handles):
        m.alloc.free([p])
    m.handles.clear()
    assert m.alloc.available == m.num_pages


@pytest.mark.parametrize("seed", SEEDS)
def test_double_free_always_raises(seed):
    rng = np.random.default_rng(100 + seed)
    m = _Machine()
    m.run_tape(_random_tape(rng, length=60))
    dead = [p for p in m.ever_allocated if m.refs.get(p, 0) == 0]
    if not dead:
        pytest.skip("tape left no fully-freed page")
    with pytest.raises(ValueError, match="double free"):
        m.alloc.free([dead[0]])
    with pytest.raises(ValueError, match="unallocated"):
        m.alloc.share([dead[0]])
    m.check()                                  # the failed ops changed nothing


def test_free_is_all_or_nothing_on_double_free():
    """A batched free that hits a dead page must not half-apply silently —
    pages after the dead one are untouched (free iterates reversed)."""
    a = PageAllocator(4)
    pages = a.alloc(3)
    a.free([pages[2]])
    with pytest.raises(ValueError, match="double free"):
        a.free([pages[0], pages[2]])           # reversed: dead page first
    assert a.refcount(pages[0]) == 1           # untouched by the failed call


@pytest.mark.parametrize("seed", SEEDS)
def test_prefix_cache_never_returns_dead_pages(seed):
    """Interleave register/free/realloc churn: every page lookup() returns
    is live (refcount > 0) and generation-current."""
    rng = np.random.default_rng(200 + seed)
    ps = 4
    alloc = PageAllocator(POOL)
    cache = PrefixCache(alloc, ps)
    prompts = [[int(t) for t in rng.integers(2, 50, int(rng.integers(4, 13)))]
               for _ in range(6)]
    live: list[tuple[list, list]] = []         # (tokens, pages)
    for _ in range(80):
        u = rng.random()
        if u < 0.45 and prompts:
            toks = list(prompts[int(rng.integers(0, len(prompts)))])
            npages = -(-len(toks) // ps)
            shared = cache.lookup(toks)
            for p in shared:
                alloc.share([p])
            fresh = alloc.alloc(npages - len(shared))
            if fresh is None:
                alloc.free(shared)
                continue
            pages = shared + fresh
            cache.register(toks, pages)
            live.append((toks, pages))
        elif u < 0.8 and live:
            _, pages = live.pop(int(rng.integers(0, len(live))))
            alloc.free(pages)
        else:
            for toks in prompts:
                for p in cache.lookup(toks):
                    assert alloc.refcount(p) > 0, "lookup returned dead page"
    # Conservation held throughout; drain and verify total recovery.
    for _, pages in live:
        alloc.free(pages)
    assert alloc.available == POOL


def test_prefix_cache_generation_guard_rejects_reused_page():
    ps = 4
    alloc = PageAllocator(4)
    cache = PrefixCache(alloc, ps)
    toks = [1, 2, 3, 4]
    [page] = alloc.alloc(1)
    cache.register(toks, [page])
    assert cache.lookup(toks) == [page]
    alloc.free([page])
    # Same physical page, new life: the old prompt's entry must miss.
    assert alloc.alloc(1) == [page]
    assert cache.lookup(toks) == []


# ---------------------------------------------------------------------------
# Hypothesis-driven (optional: runs when the package is installed, e.g. in
# the CI property job; tier-1 collects without it)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    _op_tape = st.lists(
        st.tuples(st.sampled_from(_Machine.OPS), st.integers(0, 999)),
        max_size=150)

    @given(tape=_op_tape)
    @settings(max_examples=50)
    def test_allocator_conservation_hypothesis(tape):
        m = _Machine()
        m.run_tape(tape)

    @given(tape=_op_tape, pool=st.integers(1, 24))
    @settings(max_examples=50)
    def test_allocator_drain_recovers_pool_hypothesis(tape, pool):
        m = _Machine(pool)
        m.run_tape(tape)
        for res in m.reservations:
            res.release()
        for p in list(m.handles):
            m.alloc.free([p])
        assert m.alloc.available == pool
