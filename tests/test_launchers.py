"""Launcher CLI smoke tests (train/serve entry points)."""
from __future__ import annotations

import subprocess
import sys
from pathlib import Path

SRC = str(Path(__file__).resolve().parent.parent / "src")
ENV = {"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin", "HOME": "/root",
       "JAX_PLATFORMS": "cpu"}


def test_train_launcher_elastic_crash_recovery():
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "olmo-1b",
         "--steps", "8", "--workers", "2", "--fail-worker1-at", "3",
         "--seq-len", "32", "--batch", "2"],
        env=ENV, capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "worker1: crashed=True" in out.stdout
    assert "worker2: crashed=False step=8" in out.stdout


def test_serve_launcher_json():
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "olmo-1b",
         "--task", "tic_tac_toe", "--mode", "parallel", "--agents", "2",
         "--json"],
        env=ENV, capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    import json
    res = json.loads(out.stdout[out.stdout.index("{"):])
    assert res["parallel"]["converged"] is True
    assert res["parallel"]["tokens"] > 0
