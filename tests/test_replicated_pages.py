"""Replicated CRDT page table: simulator convergence matrix + engine path.

The deterministic fault-injecting simulator (serving/simulator.py) drives
the REAL protocol objects — ReplicatedPageStore / ReplicatedPageAllocator /
ReplicatedPrefixCache / AntiEntropyNode — for N ∈ {2, 4} replicas across
seeded fault schedules (drop+dup, reorder+delay, a partition that heals, a
crash with majority reclamation).  Each cell asserts, after quiescence:

  * bitwise page-table convergence across live replicas, equal to the
    ``merge.fold_join`` full-state oracle,
  * refcount conservation per single-writer lane (no leak, no double-free,
    ``dec <= inc`` cellwise) and free-list/refcount partition,
  * lease safety: no page was ever written by two live owners (checked
    online by the simulator's Monitor, not post-hoc).

Schedule-specific tests then pin the protocol's distinguishing behaviours:
fencing through a partition, majority retirement + page reclamation, and
the documented N=2 liveness gap (a crashed peer's pages stay pinned — safe,
never reclaimed).  Finally the engine path runs a real two-replica
``MultiEngineServer`` over a tiny model and checks cross-replica prefix
hits plus convergence of the replicated metadata.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.models import lm
from repro.serving.replicated import MultiEngineServer
from repro.serving.scheduler import Request
from repro.serving.simulator import SCHEDULES, Simulator

N_SWEEP = (2, 4)
STEPS = 40

_CACHE: dict = {}


def _run(n: int, schedule: str, seed: int = 0, steps: int = STEPS):
    """One simulator run per (n, schedule, seed), shared across tests."""
    key = (n, schedule, seed, steps)
    if key not in _CACHE:
        sim = Simulator(replicas=n, seed=seed, schedule=schedule,
                        steps=steps)
        _CACHE[key] = (sim.run(), sim)
    return _CACHE[key]


# ---------------------------------------------------------------------------
# Convergence matrix: every schedule, N in {2, 4}
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("schedule", sorted(SCHEDULES))
@pytest.mark.parametrize("n", N_SWEEP)
def test_sim_converges_with_invariants(n, schedule):
    result, sim = _run(n, schedule)
    assert result["ok"], result["failures"]
    assert result["counters"]["admitted"] > 0
    assert sim.monitor.violations == []
    # The schedule actually exercised the channel adversarially.
    assert sim.channel.dropped + sim.channel.duplicated > 0 \
        or sim.spec.delay_max > 0 or sim.spec.reorder > 0


@pytest.mark.parametrize("seed", [1, 2, 3])
def test_sim_converges_across_seeds(seed):
    result, _ = _run(4, "lossy", seed=seed)
    assert result["ok"], result["failures"]


def test_sim_cross_replica_adoption_exercised():
    """The fault matrix must cover real page adoption, not just disjoint
    working sets — otherwise the provisional-share protocol is untested."""
    total = 0
    for n in N_SWEEP:
        for schedule in sorted(SCHEDULES):
            result, _ = _run(n, schedule)
            total += result["counters"]["adopt_committed"]
            total += result["counters"]["adopt_aborted"]
    assert total > 0


# ---------------------------------------------------------------------------
# Schedule-specific protocol behaviours
# ---------------------------------------------------------------------------


def test_partition_fences_minority_then_heals():
    """N=2 partition: both sides fence (no majority possible), nobody is
    retired, and after the heal both replicas converge bitwise."""
    result, sim = _run(2, "partition_heal")
    assert result["ok"], result["failures"]
    assert result["fence_steps"] > 0
    assert result["retired"] == []
    assert result["live_replicas"] == [0, 1]


def test_partition_majority_retires_and_reclaims_minority():
    """N=4 partition longer than the retirement horizon: the 3-member side
    retires the minority replica, reclaims its home pages, and the retired
    replica halts itself on observing its own retirement — fencing at ttl
    (strictly before retirement at 2*ttl) is what makes this safe."""
    result, sim = _run(4, "partition_heal")
    assert result["ok"], result["failures"]
    assert result["retired"] == [0]
    assert result["live_replicas"] == [1, 2, 3]
    assert result["reclaimed_pages"] > 0
    assert sim.reps[0].allocator.halted


def test_crash_with_majority_retires_and_reclaims():
    result, sim = _run(4, "crash_reclaim")
    assert result["ok"], result["failures"]
    assert result["retired"] == [1]
    assert result["reclaimed_pages"] > 0
    # Reclaimed pages are usable: they ended on some survivor's free list.
    total_free = sum(len(sim.reps[r].allocator._free)
                     for r in result["live_replicas"])
    assert total_free > 0


def test_crash_without_majority_pins_pages():
    """N=2 crash: retirement needs a majority of 2, so the survivor can
    never retire the crashed peer — its pages stay pinned (the documented
    liveness gap), the survivor fences, and nothing unsafe happens."""
    result, sim = _run(2, "crash_reclaim")
    assert result["ok"], result["failures"]
    assert result["retired"] == []
    assert result["reclaimed_pages"] == 0
    assert result["fence_steps"] > 0
    assert result["live_replicas"] == [0]


# ---------------------------------------------------------------------------
# Determinism: same seed -> bitwise-identical everything
# ---------------------------------------------------------------------------


def test_sim_fully_deterministic():
    runs = []
    for _ in range(2):
        sim = Simulator(replicas=2, seed=7, schedule="lossy", steps=30)
        result = sim.run()
        assert result["ok"], result["failures"]
        runs.append((result["digest"], result["sync_bytes"],
                     result["channel"], result["counters"], sim.now))
    assert runs[0] == runs[1]


def test_sim_trace_is_json_serializable():
    import json
    result, sim = _run(2, "lossy")
    blob = json.dumps(sim.trace, default=str)
    assert "rounds" in blob and "events" in blob


# ---------------------------------------------------------------------------
# Engine path: MultiEngineServer over a real (tiny) model
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_llm():
    cfg = configs.reduced(configs.get("olmo-1b"), d_model=32, vocab=128)
    cfg = cfg.replace(num_layers=2)
    params = jax.tree.map(lambda x: x.astype(jnp.float32),
                          lm.init(jax.random.PRNGKey(0), cfg))
    return cfg, params


def _staggered_fanout(rng, count=12, prompt_len=16, new_tokens=4):
    """Two prompts interleaved AABB...: round-robin submission puts copies
    of each prompt on BOTH replicas, and later admissions land after gossip
    has shipped the earlier replica's prefix publications."""
    prompts = {c: [int(t) for t in rng.integers(2, 100, prompt_len)]
               for c in "AB"}
    pattern = ("AABB" * ((count + 3) // 4))[:count]
    return [Request(rid=i, prompt=list(prompts[c]),
                    max_new_tokens=new_tokens)
            for i, c in enumerate(pattern)]


def test_multi_engine_cross_replica_prefix_and_convergence(tiny_llm):
    cfg, params = tiny_llm
    server = MultiEngineServer(cfg, params, replicas=2, batch=3,
                               max_len=32, page_size=8, sync_every=1,
                               chunk_size=8)
    rng = np.random.default_rng(11)
    done = server.run(_staggered_fanout(rng), max_steps=400)
    stats = server.stats()
    assert stats["completed"] == 12
    assert all(len(r.tokens) == 4 for r in done)
    # Replicated metadata converged bitwise across both engines.
    assert server.converged()
    # Fan-out across replicas was visible through the CRDT prefix map.
    assert stats["cross_replica_hits"] > 0
    assert stats["published_prefix_pages"] > 0
    # Deterministic sync-bytes accounting (fixed-capacity delta packets).
    assert stats["sync_bytes"] > 0
    assert stats["sync_bytes_per_step"] > 0
    # All references returned: every lane drained to zero, no double-free.
    for store in server.stores:
        assert (store.refcounts() == 0).all()
        assert (store.dec <= store.inc).all()


def test_multi_engine_token_streams_match_single_engine(tiny_llm):
    """Distribution must not change tokens: each request's greedy stream
    equals a solo single-engine run of the same prompt."""
    from repro.serving.scheduler import ContinuousBatchingEngine
    cfg, params = tiny_llm
    rng = np.random.default_rng(13)
    reqs = _staggered_fanout(rng, count=4)
    server = MultiEngineServer(cfg, params, replicas=2, batch=2,
                               max_len=32, page_size=8, chunk_size=8)
    done = server.run(reqs)
    solos = {}
    for req in done:
        key = tuple(req.prompt)
        if key not in solos:
            solo = ContinuousBatchingEngine(cfg, params, batch=1,
                                            max_len=32, paged=True,
                                            page_size=8, chunk_size=8)
            want = solo.run([Request(0, list(req.prompt),
                                     req.max_new_tokens)])[0]
            solos[key] = tuple(want.tokens)
        assert tuple(req.tokens) == solos[key], req.rid


def test_multi_engine_deterministic_sync_bytes(tiny_llm):
    cfg, params = tiny_llm
    counts = []
    for _ in range(2):
        server = MultiEngineServer(cfg, params, replicas=2, batch=3,
                                   max_len=32, page_size=8, sync_every=1,
                                   chunk_size=8)
        rng = np.random.default_rng(11)
        server.run(_staggered_fanout(rng), max_steps=400)
        counts.append((server.sync_bytes, server.stats()["syncs"]))
    assert counts[0] == counts[1]
