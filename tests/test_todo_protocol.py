"""TODO-claim protocol: safety (at-most-one-winner), liveness, staleness.

Paper §A.5's safety theorem states that after convergence at most one agent's
verify read can succeed per TODO.  We check it under randomized concurrent
claim schedules, randomized merge (delivery) orders, and adversarial clock
collisions — plus the liveness rule (stale claims reclaimed) and idempotent
re-claims.
"""
from __future__ import annotations

import collections

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis optional; see conftest")
from hypothesis import given, strategies as st

from repro.core import merge, protocol, todo
from repro.core.clock import Lamport

K = 8


def _board_with(n_posted: int, deps: dict[int, list[int]] | None = None):
    b = todo.empty(K)
    lam = Lamport.create(client=1023)
    deps = deps or {}
    for k in range(n_posted):
        row = np.zeros((K,), bool)
        for d in deps.get(k, []):
            row[d] = True
        lam = lam.tick()
        b = todo.post(b, k, jnp.asarray(row), lam.time, lam.client)
    return b


@given(st.integers(1, 6), st.integers(2, 6), st.integers(0, 9999))
def test_at_most_one_winner(n_todos, n_agents, seed):
    rs = np.random.default_rng(seed)
    board = _board_with(n_todos)
    clients = jnp.asarray(rs.permutation(np.arange(1, 1 + n_agents)).astype(np.int32))
    # Adversarial: all agents use the SAME clock -> client-id tiebreak only.
    clocks = jnp.full((n_agents,), 100, jnp.int32)
    merged, ks, won = protocol.concurrent_claims(board, clients, clocks, jnp.int32(0))
    wins = collections.Counter(int(k) for k, w in zip(ks, won) if bool(w))
    assert all(v == 1 for v in wins.values()), wins
    # Verify read matches the merged register.
    for i in range(n_agents):
        if bool(won[i]):
            assert int(merged.assignee[int(ks[i])]) == int(clients[i])


@given(st.integers(0, 9999))
def test_winner_is_merge_order_independent(seed):
    """The arbitration outcome is a pure function of the claim set."""
    rs = np.random.default_rng(seed)
    board = _board_with(4)
    proposals = []
    for agent in range(1, 5):
        k, found = todo.pick(board, jnp.int32(agent))
        prop = todo.claim(board, k, jnp.int32(agent),
                          jnp.int32(rs.integers(50, 60)), jnp.int32(0))
        proposals.append(prop)
    perm = rs.permutation(4)
    m1 = merge.fold_join([proposals[i] for i in perm])
    m2 = merge.fold_join(list(reversed([proposals[i] for i in perm])))
    np.testing.assert_array_equal(np.asarray(m1.assignee), np.asarray(m2.assignee))
    np.testing.assert_array_equal(np.asarray(m1.status), np.asarray(m2.status))


def test_claim_verify_loser_retries_and_completes():
    """Liveness: with retries, all TODOs end up DONE; no lost work."""
    board = _board_with(5)
    lams = {a: Lamport.create(a) for a in (1, 2)}
    owned = {1: [], 2: []}
    merge_fn = lambda b: b    # single shared board (sequentialized interleave)
    for _ in range(30):
        for a in (1, 2):
            out = protocol.try_claim(board, lams[a], jnp.int32(0), merge_fn)
            board, lams[a] = out.board, out.lamport
            if bool(out.won):
                owned[a].append(int(out.todo_id))
                board, lams[a] = protocol.complete(
                    board, lams[a], out.todo_id, merge_fn)
        if bool(todo.all_done(board)):
            break
    assert bool(todo.all_done(board))
    assert sorted(owned[1] + owned[2]) == list(range(5))
    assert not (set(owned[1]) & set(owned[2]))


def test_dependency_gating():
    """A TODO is never claimable before its deps are DONE."""
    board = _board_with(3, deps={2: [0, 1]})
    ready = np.asarray(todo.ready_mask(board))
    assert ready[:2].all() and not ready[2]
    lam = Lamport.create(1)
    for k in (0, 1):
        board = todo.claim(board, jnp.int32(k), jnp.int32(1),
                           jnp.int32(100 + k), jnp.int32(0))
        board = todo.complete(board, jnp.int32(k), jnp.int32(1),
                              jnp.int32(200 + k))
    assert bool(todo.ready_mask(board)[2])


def test_stale_claim_reclaimed():
    """Paper's 120 s liveness rule: dead agent's claim reverts to PENDING."""
    board = _board_with(2)
    board = todo.claim(board, jnp.int32(0), jnp.int32(7), jnp.int32(100),
                       now=jnp.int32(10))
    lam = Lamport.create(2)
    # Too early: nothing reclaimed.
    b2, lam = protocol.reclaim_stale(board, lam, jnp.int32(50), jnp.int32(120),
                                     lambda b: b)
    assert int(b2.status[0]) == todo.CLAIMED
    # Past timeout: reverts, claimable by others.
    b3, lam = protocol.reclaim_stale(b2, lam, jnp.int32(200), jnp.int32(120),
                                     lambda b: b)
    assert int(b3.status[0]) == todo.PENDING and int(b3.assignee[0]) == 0
    out = protocol.try_claim(b3, Lamport.create(3), jnp.int32(201), lambda b: b)
    assert bool(out.won)


def test_done_not_reclaimed():
    board = _board_with(1)
    lam = Lamport.create(4)
    board = todo.claim(board, jnp.int32(0), jnp.int32(4), jnp.int32(10), jnp.int32(0))
    board = todo.complete(board, jnp.int32(0), jnp.int32(4), jnp.int32(11))
    b2, _ = protocol.reclaim_stale(board, Lamport.create(2), jnp.int32(10_000),
                                   jnp.int32(120), lambda b: b)
    assert int(b2.status[0]) == todo.DONE
