"""Prefill/decode disaggregation over the CRDT page table.

A two-replica MultiEngineServer with roles ["prefill", "decode"]: cold
prompts route to the prefill replica, which fills pages and publishes the
prefix chain through the replicated map once the bytes have landed
(publish-on-fill).  Warm prompts route to the decode replica, whose
admission hook adopts the published PHYSICAL pages — provisional share,
J_XFER_BEGIN, cross-pool transfer, commit iff the lease epoch is unchanged
— instead of recomputing the prefix.  The tests pin:

  * token streams identical to a solo single-engine run for MHA, MLA and
    int8-quantized pools (adoption is bitwise, so greedy decode cannot
    diverge),
  * the adoption counters actually fire (adopted pages, avoided prefill
    steps, transfer bytes) and ``cross_replica_hits`` counts only
    COMMITTED transfers,
  * ``adopt_pages=False`` keeps coordination (publication, role routing)
    but moves zero bytes — the local-prefill baseline, same streams,
  * an exporter crash mid-transfer (armed after J_XFER_BEGIN, before the
    commit check) rolls the adopter back: the provisional ref is returned,
    J_XFER_ABORT balances the journal, survivors converge bitwise and
    every request still completes with the correct stream.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.models import lm
from repro.serving.chaos import _xfer_balanced
from repro.serving.replicated import MultiEngineServer
from repro.serving.scheduler import ContinuousBatchingEngine, Request


def _f32(t):
    return jax.tree.map(lambda x: x.astype(jnp.float32), t)


@pytest.fixture(scope="module")
def mha_llm():
    cfg = configs.reduced(configs.get("olmo-1b"), d_model=32, vocab=128)
    cfg = cfg.replace(num_layers=2)
    return cfg, _f32(lm.init(jax.random.PRNGKey(0), cfg))


@pytest.fixture(scope="module")
def mla_llm():
    cfg = configs.reduced(configs.get("deepseek-v2-lite-16b"), d_model=32,
                          vocab=128)
    return cfg, _f32(lm.init(jax.random.PRNGKey(1), cfg))


def _requests(cfg, count=8, prompt_len=17, new_tokens=4, seed=11):
    """AABB... over two prompts: the first copy of each prompt is cold
    (prefill tier), later copies arrive after gossip has shipped the
    publication and should adopt on the decode tier."""
    rng = np.random.default_rng(seed)
    prompts = {c: [int(t) for t in rng.integers(2, cfg.vocab_size,
                                                prompt_len)]
               for c in "AB"}
    pattern = ("AABB" * ((count + 3) // 4))[:count]
    return [Request(rid=i, prompt=list(prompts[c]),
                    max_new_tokens=new_tokens)
            for i, c in enumerate(pattern)]


def _run_disagg(cfg, params, reqs, *, adopt=True, xfer_crash=False,
                replicas=2, **kw):
    """Staggered arrivals (first wave of 2, then one per step) so the
    decode tier admits AFTER the prefill tier's publications gossip."""
    roles = ["prefill"] + ["decode"] * (replicas - 1)
    server = MultiEngineServer(cfg, params, replicas=replicas, batch=2,
                               max_len=32, page_size=8, sync_every=1,
                               chunk_size=8, roles=roles,
                               adopt_pages=adopt, **kw)
    if xfer_crash:
        server.arm_transfer_crash(0)
    pending = list(reqs)
    for r in pending[:2]:
        server.submit(r)
    pending = pending[2:]
    while True:
        more = server.step()
        if pending:
            server.submit(pending.pop(0))
            more = True
        assert server.clock < 5_000
        if not more:
            break
    server.sync()
    return server


def _solo_streams(cfg, params, reqs, **kw):
    out = {}
    for req in reqs:
        key = tuple(req.prompt)
        if key not in out:
            solo = ContinuousBatchingEngine(cfg, params, batch=1,
                                            max_len=32, paged=True,
                                            page_size=8, chunk_size=8, **kw)
            done = solo.run([Request(0, list(req.prompt),
                                     req.max_new_tokens)])[0]
            out[key] = tuple(done.tokens)
    return out


@pytest.mark.parametrize("family", ["mha", "mla", "int8"])
def test_disagg_adoption_streams_match_local_prefill(family, mha_llm,
                                                     mla_llm):
    cfg, params = mla_llm if family == "mla" else mha_llm
    kw = {"kv_quant": "int8"} if family == "int8" else {}
    reqs = _requests(cfg)
    server = _run_disagg(cfg, params, reqs, adopt=True, **kw)
    stats = server.stats()
    assert stats["completed"] == len(reqs)
    assert server.converged()
    # The decode tier really adopted physical pages instead of re-running
    # the prefix through the model.
    assert stats["adopted_pages"] > 0
    assert stats["prefill_steps_avoided"] > 0
    assert stats["transferred_pages"] > 0
    assert stats["transfer_bytes"] > 0
    # Only committed transfers count as usable cross-replica hits.
    assert stats["cross_replica_hits"] == stats["transferred_pages"]
    # Adoption is bitwise, so greedy streams equal a solo engine's.
    solos = _solo_streams(cfg, params, reqs, **kw)
    for req in reqs:
        assert tuple(req.tokens) == solos[tuple(req.prompt)], req.rid
    # Every provisional ref was either committed or returned.
    for store in server.stores:
        assert (store.refcounts() == 0).all()
        assert (store.dec <= store.inc).all()


def test_disagg_baseline_never_moves_bytes(mha_llm):
    """adopt_pages=False keeps publication + role routing but the decode
    tier prefills locally: zero transfers, identical streams."""
    cfg, params = mha_llm
    reqs_on = _requests(cfg)
    reqs_off = _requests(cfg)
    server_on = _run_disagg(cfg, params, reqs_on, adopt=True)
    server_off = _run_disagg(cfg, params, reqs_off, adopt=False)
    s_on, s_off = server_on.stats(), server_off.stats()
    assert s_off["completed"] == len(reqs_off)
    assert s_off["transfer_bytes"] == 0
    assert s_off["transferred_pages"] == 0
    assert s_off["adopted_pages"] == 0
    assert s_off["cross_replica_hits"] == 0
    assert s_on["adopted_pages"] > 0
    assert {r.rid: list(r.tokens) for r in reqs_on} \
        == {r.rid: list(r.tokens) for r in reqs_off}


def test_disagg_exporter_crash_mid_transfer_rolls_back(mha_llm):
    """Crash the prefill exporter after J_XFER_BEGIN but before the commit
    check: the adopter must abort (return the provisional ref, journal
    J_XFER_ABORT), survivors converge, and recovery still completes every
    request with the correct stream.  Three replicas so the survivors form
    a majority that retires the crashed exporter (N=2 pins its pages — the
    documented liveness gap)."""
    cfg, params = mha_llm
    reqs = _requests(cfg)
    server = _run_disagg(cfg, params, reqs, adopt=True, xfer_crash=True,
                         replicas=3)
    assert server._xfer_crash is None          # the armed crash fired
    assert server.adopt_aborts >= 1
    # Aborted transfers are not usable hits.
    assert server.stats()["cross_replica_hits"] \
        == server.transferred_pages
    ok, detail = _xfer_balanced(server)
    assert ok, detail
    assert server.converged()
    stats = server.stats()
    assert stats["failed_requests"] == 0
    assert stats["lost_requests"] == 0
    # Recovery re-admits orphans as NEW Request objects, so stream identity
    # is checked against the replicated journal, not the submitted objects:
    # every rid reached a terminal DONE exactly once, and its journaled
    # generation equals the solo engine's greedy stream.
    store = next(s for r, s in enumerate(server.stores)
                 if not server.crashed[r])
    info = server._fold_journal(store)
    solos = _solo_streams(cfg, params, reqs)
    for req in reqs:
        d = info[req.rid]
        assert d["terminal"], req.rid
        gen = server._contiguous(d["gen"])
        assert tuple(gen) == solos[tuple(req.prompt)], req.rid


def test_disagg_role_validation(mha_llm):
    cfg, params = mha_llm
    with pytest.raises(ValueError, match="roles must name every replica"):
        MultiEngineServer(cfg, params, replicas=2, batch=2, max_len=32,
                          page_size=8, roles=["prefill"])
    with pytest.raises(ValueError, match="prefill/decode/mixed"):
        MultiEngineServer(cfg, params, replicas=2, batch=2, max_len=32,
                          page_size=8, roles=["prefill", "verifier"])


def test_disagg_deterministic_counters(mha_llm):
    """Same seed, same arrivals -> bit-identical adoption accounting (the
    property the regression gate's strict thresholds rely on)."""
    cfg, params = mha_llm
    runs = []
    for _ in range(2):
        server = _run_disagg(cfg, params, _requests(cfg), adopt=True)
        s = server.stats()
        runs.append((s["adopted_pages"], s["prefill_steps_avoided"],
                     s["transferred_pages"], s["transfer_bytes"],
                     s["adopt_aborts"], s["cross_replica_hits"],
                     s["steps"], s["sync_bytes"]))
    assert runs[0] == runs[1]
