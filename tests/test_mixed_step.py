"""Token-budget mixed serve step: chunked prefill fused with decode.

Acceptance sweep: chunked admission is equivalent to one-shot ragged
prefill across chunk sizes {1, ps/2, ps, 2·ps} on MHA, MLA, and hybrid
recurrent configs — caches bit-for-bit for every chunk size ≥ 2 (and for
MLA at every size), greedy tokens exactly equal everywhere.  Chunk width 1
reduces the query matmul to a matvec whose XLA reduction order rounds the
last bit differently, so width-1 logits are asserted at tight tolerance
plus exact argmax instead.

Everything runs in f32 + interpret mode (CPU) — the same bar the paged
decode kernels were verified at.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.kernels import ops, ref
from repro.models import attention, lm
from repro.serving import engine as engine_mod
from repro.serving.scheduler import ContinuousBatchingEngine, Request

B, MAX_LEN, PS = 3, 32, 8


def _f32(t):
    return jax.tree.map(lambda x: x.astype(jnp.float32), t)


@pytest.fixture(scope="module")
def mha_llm():
    cfg = configs.reduced(configs.get("olmo-1b"), d_model=32, vocab=128)
    cfg = cfg.replace(num_layers=2)
    return cfg, _f32(lm.init(jax.random.PRNGKey(0), cfg))


@pytest.fixture(scope="module")
def mla_llm():
    cfg = configs.reduced(configs.get("deepseek-v2-lite-16b"), d_model=32,
                          vocab=128)
    return cfg, _f32(lm.init(jax.random.PRNGKey(1), cfg))


@pytest.fixture(scope="module")
def hybrid_llm():
    """Paged full attention + RG-LRU recurrence in one pattern."""
    cfg = configs.reduced(configs.get("olmo-1b"), d_model=32, vocab=128)
    cfg = cfg.replace(block_pattern=("attn", "rglru"), num_layers=4)
    return cfg, _f32(lm.init(jax.random.PRNGKey(2), cfg))


@pytest.fixture(scope="module")
def xlstm_hybrid_llm():
    cfg = configs.reduced(configs.get("xlstm-125m"), d_model=32, vocab=128)
    cfg = cfg.replace(block_pattern=("slstm", "mlstm", "attn"),
                      num_layers=3, d_ff=128)
    return cfg, _f32(lm.init(jax.random.PRNGKey(3), cfg))


def _mk_cache(cfg, paged, batch=B, max_len=MAX_LEN, ps=PS):
    cache = lm.init_cache(cfg, batch, max_len, dtype=jnp.float32,
                          paged=paged, page_size=ps)
    if paged:
        cache = lm.set_block_tables(
            cache, attention.default_block_tables(batch, max_len, ps))
    return cache


def _chunked_admit(cfg, params, cache, prompts, lengths, chunk, impl="ref"):
    """Stream the ragged prompt batch in through mixed steps of ``chunk``."""
    filled = np.zeros(len(lengths), np.int64)
    logits = None
    while (filled < lengths).any():
        span = np.minimum(chunk, lengths - filled).clip(0)
        toks = np.zeros((len(lengths), chunk), np.int32)
        for b in range(len(lengths)):
            toks[b, :span[b]] = prompts[b, filled[b]:filled[b] + span[b]]
        lg, cache = lm.mixed_step(params, cfg, jnp.asarray(toks), cache,
                                  jnp.asarray(filled, jnp.int32),
                                  jnp.asarray(span, jnp.int32), impl=impl)
        if logits is None:
            logits = np.array(lg)
        else:
            logits[span > 0] = np.asarray(lg)[span > 0]
        filled += span
    return logits, cache


# ---------------------------------------------------------------------------
# Kernel <-> oracle sweeps (pallas interpret vs pure-jnp ref)
# ---------------------------------------------------------------------------

CHUNK_CASES = [
    # (B, Hq, Hkv, page_size, maxp, D, C, window)
    (1, 1, 1, 8, 3, 32, 4, None),
    (2, 4, 1, 16, 4, 64, 8, None),        # MQA
    (3, 4, 2, 10, 3, 16, 5, None),        # unaligned sizes (interpret)
    (2, 8, 2, 8, 4, 32, 16, 11),          # span > page, sliding window
    (2, 2, 2, 8, 4, 32, 1, None),         # span 1 == fused decode
]


def _chunk_setup(b, hq, hkv, ps, maxp, d, c, dtype, seed=0):
    r = np.random.default_rng(seed)
    pool = b * maxp + 2                       # spare pages stay untouched
    q = jnp.asarray(r.normal(size=(b, hq, c, d)), dtype)
    kp = jnp.asarray(r.normal(size=(pool, hkv, ps, d)), dtype)
    vp = jnp.asarray(r.normal(size=(pool, hkv, ps, d)), dtype)
    bt = jnp.asarray(r.permutation(pool)[:b * maxp].reshape(b, maxp)
                     .astype(np.int32))
    start = jnp.asarray(r.integers(0, maxp * ps - c, b), jnp.int32)
    span = jnp.asarray(r.integers(0, c + 1, b), jnp.int32)
    kn = jnp.asarray(r.normal(size=(b, hkv, c, d)), dtype)
    vn = jnp.asarray(r.normal(size=(b, hkv, c, d)), dtype)
    return q, kp, vp, bt, start, span, kn, vn


def _tol(dtype):
    return (dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16
            else dict(rtol=2e-5, atol=2e-5))


@pytest.mark.parametrize("case", CHUNK_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_chunk_attention_kernel_matches_oracle(case, dtype):
    b, hq, hkv, ps, maxp, d, c, window = case
    q, kp, vp, bt, start, span, kn, vn = _chunk_setup(
        b, hq, hkv, ps, maxp, d, c, dtype)
    o1, kp1, vp1 = ops.paged_chunk_attention(q, kp, vp, bt, start, span,
                                             kn, vn, window=window)
    o2, kp2, vp2 = ref.paged_chunk_attention(q, kp, vp, bt, start, span,
                                             kn, vn, window=window)
    # Output rows beyond each row's span are garbage on both paths.
    mask = (np.arange(c)[None, :] < np.asarray(span)[:, None])
    m4 = mask[:, None, :, None]
    np.testing.assert_allclose(
        np.where(m4, np.asarray(o1, np.float32), 0.0),
        np.where(m4, np.asarray(o2, np.float32), 0.0), **_tol(dtype))
    # The fused multi-slot write must be bit-identical to the oracle's
    # scatter — and touch only the written slots.
    np.testing.assert_array_equal(np.asarray(kp1), np.asarray(kp2))
    np.testing.assert_array_equal(np.asarray(vp1), np.asarray(vp2))


def test_paged_chunk_span1_matches_decode_kernel_write():
    """A span-1 chunk writes exactly what the fused decode kernel writes."""
    b, hq, hkv, ps, maxp, d = 2, 4, 2, 8, 4, 32
    q, kp, vp, bt, start, _, kn, vn = _chunk_setup(
        b, hq, hkv, ps, maxp, d, 1, jnp.float32)
    one = jnp.ones((b,), jnp.int32)
    _, kp1, vp1 = ops.paged_chunk_attention(q, kp, vp, bt, start, one,
                                            kn, vn)
    _, kp2, vp2 = ops.paged_decode_attention(q[:, :, 0], kp, vp, bt, start,
                                             kn[:, :, 0], vn[:, :, 0])
    np.testing.assert_array_equal(np.asarray(kp1), np.asarray(kp2))
    np.testing.assert_array_equal(np.asarray(vp1), np.asarray(vp2))


@pytest.mark.parametrize("c", [1, 4, 8])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_mla_chunk_kernel_matches_oracle(c, dtype):
    b, h, r, rd, ps, maxp = 2, 4, 16, 8, 8, 4
    dp = 128
    rng = np.random.default_rng(7)
    pool = b * maxp + 1
    q_abs = jnp.asarray(rng.normal(size=(b, h, c, r)), jnp.float32)
    q_rope = jnp.asarray(rng.normal(size=(b, h, c, rd)), jnp.float32)
    lp = jnp.asarray(rng.normal(size=(pool, ps, dp)), dtype)
    bt = jnp.asarray(rng.permutation(pool)[:b * maxp].reshape(b, maxp)
                     .astype(np.int32))
    start = jnp.asarray(rng.integers(0, maxp * ps - c, b), jnp.int32)
    span = jnp.asarray(rng.integers(0, c + 1, b), jnp.int32)
    ln = jnp.asarray(rng.normal(size=(b, c, dp)), dtype)
    ctx1, lp1 = ops.paged_mla_chunk(q_abs, q_rope, lp, bt, start, span, ln,
                                    scale=0.125)
    ctx2, lp2 = ref.paged_mla_chunk(q_abs, q_rope, lp, bt, start, span, ln,
                                    r=r, scale=0.125)
    mask = (np.arange(c)[None, :] < np.asarray(span)[:, None])[:, None, :,
                                                               None]
    np.testing.assert_allclose(
        np.where(mask, np.asarray(ctx1), 0.0),
        np.where(mask, np.asarray(ctx2), 0.0), **_tol(dtype))
    np.testing.assert_array_equal(np.asarray(lp1), np.asarray(lp2))


# ---------------------------------------------------------------------------
# Acceptance: chunked admission ≡ one-shot ragged prefill
# ---------------------------------------------------------------------------

CHUNK_SIZES = (1, PS // 2, PS, 2 * PS)


def _ragged_batch(seed=0):
    rng = np.random.default_rng(seed)
    lengths = np.asarray([8, 3, 5], np.int64)
    prompts = np.zeros((B, MAX_LEN), np.int32)
    for b in range(B):
        prompts[b, :lengths[b]] = rng.integers(2, 100, lengths[b])
    return prompts, lengths


@pytest.mark.parametrize("family", ["mha", "mla", "hybrid", "xlstm"])
def test_chunked_admission_equals_oneshot_prefill(family, mha_llm, mla_llm,
                                                  hybrid_llm,
                                                  xlstm_hybrid_llm):
    cfg, params = {"mha": mha_llm, "mla": mla_llm, "hybrid": hybrid_llm,
                   "xlstm": xlstm_hybrid_llm}[family]
    paged = family != "xlstm"                 # one dense-cache config too
    prompts, lengths = _ragged_batch()

    logits_a, cache_a = lm.prefill(params, cfg, jnp.asarray(prompts),
                                   _mk_cache(cfg, paged),
                                   lengths=jnp.asarray(lengths, jnp.int32))
    leaves_a = [np.asarray(x) for x in jax.tree.leaves(cache_a)]
    argmax_a = np.argmax(np.asarray(logits_a), -1)

    for chunk in CHUNK_SIZES:
        logits_b, cache_b = _chunked_admit(cfg, params,
                                           _mk_cache(cfg, paged), prompts,
                                           lengths, chunk)
        leaves_b = [np.asarray(x) for x in jax.tree.leaves(cache_b)]
        if chunk > 1:
            # Bit-for-bit: same cache bytes as the one-shot ragged prefill.
            for a, b_ in zip(leaves_a, leaves_b):
                np.testing.assert_array_equal(a, b_)
        else:
            for a, b_ in zip(leaves_a, leaves_b):
                np.testing.assert_allclose(a, b_, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(logits_a), logits_b,
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_array_equal(argmax_a, np.argmax(logits_b, -1))


def test_chunked_admission_bitwise_across_chunk_sizes(mha_llm):
    """Chunk partitioning cannot change the bits: every chunk size ≥ 2
    produces the identical cache AND identical last-position logits."""
    cfg, params = mha_llm
    prompts, lengths = _ragged_batch(seed=5)
    base = None
    for chunk in (PS // 2, PS, 2 * PS, MAX_LEN):
        logits, cache = _chunked_admit(cfg, params, _mk_cache(cfg, True),
                                       prompts, lengths, chunk)
        leaves = [np.asarray(x) for x in jax.tree.leaves(cache)]
        if base is None:
            base = (logits, leaves)
            continue
        np.testing.assert_array_equal(base[0], logits)
        for a, b_ in zip(base[1], leaves):
            np.testing.assert_array_equal(a, b_)


def test_mixed_step_span0_rows_keep_cache_bitwise(hybrid_llm):
    """Idle (span-0) rows — attention pool pages AND recurrent state — are
    untouched by other rows' spans."""
    from repro.models import cache as cache_mod
    cfg, params = hybrid_llm
    prompts, lengths = _ragged_batch(seed=9)
    cache = _mk_cache(cfg, True)
    # Row 0 prefills; rows 1, 2 idle.
    l0 = np.asarray([lengths[0], 0, 0], np.int64)
    _, cache = _chunked_admit(cfg, params, cache, prompts, l0, PS)
    bt = np.asarray(lm.get_block_tables(cache))
    row0_pages = sorted(set(bt[0].tolist()))
    before = {path: {k: np.asarray(v).copy() for k, v in layer.items()}
              for path, _, layer in cache_mod.iter_layers(cache)}
    # Now rows 1, 2 prefill; row 0 idle (span 0).
    l12 = np.asarray([0, lengths[1], lengths[2]], np.int64)
    _, cache = _chunked_admit(cfg, params, cache, prompts, l12, PS)
    for path, layout, layer in cache_mod.iter_layers(cache):
        if layout == "paged_mha":
            for name in cache_mod.pool_leaves(layer, layout):
                pool = np.asarray(layer[name])        # [G, P, Hkv, ps, D]
                np.testing.assert_array_equal(
                    pool[:, row0_pages], before[path][name][:, row0_pages])
        elif layout == "state":
            for name, v in layer.items():
                v = np.asarray(v)                     # [G, B, ...]
                np.testing.assert_array_equal(v[:, 0],
                                              before[path][name][:, 0])


# ---------------------------------------------------------------------------
# Recurrent ragged prefill (masked state carry-through)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", ["hybrid", "xlstm"])
def test_recurrent_ragged_prefill_isolates_rows(family, hybrid_llm,
                                                xlstm_hybrid_llm):
    """lm.prefill(lengths) on recurrent patterns: each row's state equals a
    solo prefill of that row alone, and zero-length rows keep state
    bit-for-bit (the ROADMAP recurrent-ragged item)."""
    cfg, params = {"hybrid": hybrid_llm, "xlstm": xlstm_hybrid_llm}[family]
    prompts, lengths = _ragged_batch(seed=11)
    paged = family == "hybrid"

    _, cache = lm.prefill(params, cfg, jnp.asarray(prompts),
                          _mk_cache(cfg, paged),
                          lengths=jnp.asarray(lengths, jnp.int32))

    for row in range(B):
        solo = lm.init_cache(cfg, 1, MAX_LEN, dtype=jnp.float32,
                             paged=paged, page_size=PS)
        if paged:
            solo = lm.set_block_tables(
                solo, attention.default_block_tables(1, MAX_LEN, PS))
        _, solo = lm.prefill(
            params, cfg, jnp.asarray(prompts[row:row + 1]), solo,
            lengths=jnp.asarray(lengths[row:row + 1], jnp.int32))
        # Compare recurrent-state leaves row-by-row (skip pools/tables,
        # whose page numbering differs between the batched and solo runs).
        from repro.models import cache as cache_mod
        batched_layers = dict(
            (path, layer) for path, layout, layer
            in cache_mod.iter_layers(cache) if layout == "state")
        for path, layout, s_layer in cache_mod.iter_layers(solo):
            if layout != "state":
                continue
            b_layer = batched_layers[path]
            for name in s_layer:
                sl, bl = np.asarray(s_layer[name]), np.asarray(b_layer[name])
                # Group layers stack [G, B, ...]; solo runs carry B == 1.
                np.testing.assert_allclose(bl[:, row], sl[:, 0],
                                           rtol=1e-6, atol=1e-6)


def test_recurrent_zero_length_rows_keep_state_bitwise(hybrid_llm):
    cfg, params = hybrid_llm
    prompts, lengths = _ragged_batch(seed=13)
    cache = _mk_cache(cfg, True)
    _, cache = lm.prefill(params, cfg, jnp.asarray(prompts), cache,
                          lengths=jnp.asarray([6, 0, 0], jnp.int32))
    from repro.models import cache as cache_mod
    before = {path: {k: np.asarray(v).copy() for k, v in layer.items()}
              for path, layout, layer in cache_mod.iter_layers(cache)
              if layout == "state"}
    _, cache = lm.prefill(params, cfg, jnp.asarray(prompts), cache,
                          lengths=jnp.asarray([0, 8, 0], jnp.int32))
    for path, layout, layer in cache_mod.iter_layers(cache):
        if layout != "state":
            continue
        for name, v in layer.items():
            v = np.asarray(v)
            old = before[path][name]
            # rows 0 and 2 were zero-length this prefill: bit-identical.
            for row in (0, 2):
                if v.ndim >= 2 and v.shape[1] == B:
                    np.testing.assert_array_equal(v[:, row], old[:, row])
                else:
                    np.testing.assert_array_equal(v[row], old[row])


# ---------------------------------------------------------------------------
# Scheduler: chunked admission end to end
# ---------------------------------------------------------------------------

def _mk_requests(rng, spec):
    return [Request(rid=i,
                    prompt=[int(t) for t in rng.integers(2, 100, n)],
                    max_new_tokens=m)
            for i, (n, m) in enumerate(spec)]


SPEC = [(5, 6), (9, 4), (13, 8), (7, 5), (4, 3), (11, 7)]


@pytest.mark.parametrize("family", ["mha", "hybrid"])
def test_scheduler_token_streams_equal_across_chunk_sizes(family, mha_llm,
                                                          hybrid_llm):
    cfg, params = {"mha": mha_llm, "hybrid": hybrid_llm}[family]
    outs = {}
    for chunk in CHUNK_SIZES:
        rng = np.random.default_rng(21)
        eng = ContinuousBatchingEngine(cfg, params, batch=2, max_len=32,
                                       paged=True, page_size=PS,
                                       chunk_size=chunk)
        outs[chunk] = [tuple(r.tokens)
                       for r in eng.run(_mk_requests(rng, SPEC))]
        assert eng.stats["completed"] == len(SPEC)
        assert eng.stats["decode_stall_steps"] == 0
    # Stalled whole-prompt admission (the old bucketed behaviour) emits the
    # same greedy streams — chunking changes scheduling, never tokens.
    rng = np.random.default_rng(21)
    eng = ContinuousBatchingEngine(cfg, params, batch=2, max_len=32,
                                   paged=True, page_size=PS,
                                   prefill_interleave=False)
    stalled = [tuple(r.tokens) for r in eng.run(_mk_requests(rng, SPEC))]
    assert eng.stats["decode_stall_steps"] > 0, \
        "stalled baseline must actually stall a decoding lane"
    for chunk in CHUNK_SIZES:
        assert outs[chunk] == outs[CHUNK_SIZES[0]], chunk
    assert stalled == outs[CHUNK_SIZES[0]]


def test_scheduler_serves_windowed_local_layers(mha_llm):
    """Sliding-window (local) layers over an unbounded dense cache ride the
    mixed step; only the ring layout is excluded (clear error)."""
    cfg, params = mha_llm
    wcfg = cfg.replace(block_pattern=("attn", "local"), num_layers=4,
                       window=8)
    wparams = _f32(lm.init(jax.random.PRNGKey(7), wcfg))
    rng = np.random.default_rng(61)
    eng = ContinuousBatchingEngine(wcfg, wparams, batch=2, max_len=32,
                                   paged=True, page_size=PS, chunk_size=4)
    reqs = eng.run(_mk_requests(rng, SPEC[:4]))
    assert eng.stats["completed"] == 4
    # Chunked == stalled greedy streams on the windowed pattern too.
    rng = np.random.default_rng(61)
    eng2 = ContinuousBatchingEngine(wcfg, wparams, batch=2, max_len=32,
                                    paged=True, page_size=PS,
                                    prefill_interleave=False)
    wants = eng2.run(_mk_requests(rng, SPEC[:4]))
    assert [r.tokens for r in reqs] == [w.tokens for w in wants]

    ring_cfg = wcfg.replace(ring_local_cache=True)
    ring_params = _f32(lm.init(jax.random.PRNGKey(7), ring_cfg))
    ring = ContinuousBatchingEngine(ring_cfg, ring_params, batch=2,
                                    max_len=32, paged=True, page_size=PS)
    ring.submit(Request(0, [3, 4, 5], 2))
    with pytest.raises(NotImplementedError, match="ring local cache"):
        ring.step()


def test_scheduler_dense_mode_agrees_with_paged(mha_llm):
    cfg, params = mha_llm
    outs = {}
    for paged in (True, False):
        rng = np.random.default_rng(23)
        eng = ContinuousBatchingEngine(cfg, params, batch=2, max_len=32,
                                       paged=paged, page_size=PS,
                                       chunk_size=PS)
        outs[paged] = [tuple(r.tokens)
                       for r in eng.run(_mk_requests(rng, SPEC))]
    assert outs[True] == outs[False]


def test_scheduler_recurrent_state_reset_on_row_reuse(hybrid_llm):
    """A freed row's recurrent state must not leak into the next request:
    back-to-back requests on one row match fresh-engine solo runs."""
    cfg, params = hybrid_llm
    rng = np.random.default_rng(31)
    reqs = _mk_requests(rng, [(6, 4), (9, 5), (5, 3)])
    eng = ContinuousBatchingEngine(cfg, params, batch=1, max_len=32,
                                   paged=True, page_size=PS, chunk_size=PS)
    eng.run(reqs)
    assert eng.stats["completed"] == 3
    rng = np.random.default_rng(31)
    for want in _mk_requests(rng, [(6, 4), (9, 5), (5, 3)]):
        solo = ContinuousBatchingEngine(cfg, params, batch=1, max_len=32,
                                        paged=True, page_size=PS,
                                        chunk_size=PS)
        solo.run([want])
        assert reqs[want.rid].tokens == want.tokens, want.rid


def test_token_budget_caps_spend_and_counts_stalls(mha_llm):
    """A starved token budget idles decode lanes — progress stays correct,
    and the starved lanes are counted."""
    cfg, params = mha_llm
    rng = np.random.default_rng(41)
    reqs = _mk_requests(rng, [(2, 12), (2, 12)])
    eng = ContinuousBatchingEngine(cfg, params, batch=2, max_len=32,
                                   paged=True, page_size=PS, chunk_size=PS,
                                   token_budget=2)
    for r in reqs:
        eng.submit(r)
    while eng.step():
        if all(r is not None and not r.admitting for r in eng.rows):
            break
    # Both rows decoding: shrink the budget below the decode demand (the
    # adaptive-controller hook) — one lane must stall per step now.
    eng.token_budget = 1
    while eng.step():
        pass
    assert eng.stats["completed"] == 2
    assert eng.stats["decode_stall_steps"] > 0
    assert eng.stats["stalled_lane_steps"] > 0
    rng = np.random.default_rng(41)
    free = ContinuousBatchingEngine(cfg, params, batch=2, max_len=32,
                                    paged=True, page_size=PS, chunk_size=PS)
    wants = free.run(_mk_requests(rng, [(2, 12), (2, 12)]))
    assert [r.tokens for r in reqs] == [w.tokens for w in wants]


def test_mid_admission_decode_does_not_stall(mha_llm):
    """While one row streams a long prompt in chunks, the other row emits a
    token EVERY step — the coordination stall the mixed step removes."""
    cfg, params = mha_llm
    rng = np.random.default_rng(43)
    long_p = [int(t) for t in rng.integers(2, 100, 24)]
    eng = ContinuousBatchingEngine(cfg, params, batch=2, max_len=64,
                                   paged=True, page_size=PS, chunk_size=4)
    a = Request(0, [int(t) for t in rng.integers(2, 100, 4)], 20)
    eng.submit(a)
    for _ in range(3):
        eng.step()                    # row 0 admitted and decoding
    n0 = len(a.tokens)
    b = Request(1, long_p, 2)
    eng.submit(b)
    admit_steps = -(-len(long_p) // 4)
    for _ in range(admit_steps):
        eng.step()
    # Row 0 gained one token per step throughout row 1's 6-step admission.
    assert len(a.tokens) == n0 + admit_steps
    assert eng.stats["decode_stall_steps"] == 0
    while eng.step():
        pass
    assert eng.stats["completed"] == 2


# ---------------------------------------------------------------------------
# Satellite: LRU preemption of COW/prefix-shared rows
# ---------------------------------------------------------------------------

def test_lru_preemption_of_prefix_shared_row(mha_llm):
    """Preempting a row whose pages are prefix-shared must drop only ITS
    references (no double-free), and its re-admission must re-share the
    pages still pinned by the surviving sharer."""
    cfg, params = mha_llm
    rng = np.random.default_rng(51)
    prompt = [int(t) for t in rng.integers(2, 100, 16)]   # 2 full pages
    # Two sharers + generation growth against a pool too small for both
    # full horizons: 2 shared prompt pages + 2×2 private generation pages
    # exceeds 5 pages, forcing a preemption mid-decode.
    reqs = [Request(rid=i, prompt=list(prompt), max_new_tokens=14)
            for i in range(2)]
    eng = ContinuousBatchingEngine(cfg, params, batch=2, max_len=32,
                                   paged=True, page_size=8, num_pages=5,
                                   prefix_sharing=True, chunk_size=8)
    eng.run(list(reqs))
    assert eng.stats["completed"] == 2
    assert eng.stats["preemptions"] >= 1
    assert eng.stats["shared_pages"] > 0
    assert all(len(r.tokens) == 14 for r in reqs)
    # No pages leaked, no double-frees raised along the way.
    assert eng.allocator.available == 5
    # Greedy streams match the unshared run bit-for-bit.
    rng = np.random.default_rng(51)
    plain = [Request(rid=i, prompt=list(prompt), max_new_tokens=14)
             for i in range(2)]
    eng2 = ContinuousBatchingEngine(cfg, params, batch=2, max_len=32,
                                    paged=True, page_size=8, num_pages=5,
                                    prefix_sharing=False, chunk_size=8)
    eng2.run(plain)
    assert [r.tokens for r in reqs] == [p.tokens for p in plain]


def test_preemption_victim_readmission_reshares(mha_llm):
    """After its eviction, the victim's re-admission lookup finds the
    sharer's still-resident prompt pages and re-shares them.  Admission is
    chunk-granular, so the clone shares the FIRST chunk's page at bind time
    and the second prompt page at growth time (growth-time re-share)."""
    cfg, params = mha_llm
    rng = np.random.default_rng(53)
    prompt = [int(t) for t in rng.integers(2, 100, 16)]
    reqs = [Request(rid=i, prompt=list(prompt), max_new_tokens=14)
            for i in range(2)]
    eng = ContinuousBatchingEngine(cfg, params, batch=2, max_len=32,
                                   paged=True, page_size=8, num_pages=5,
                                   prefix_sharing=True, chunk_size=8)
    for r in reqs:
        eng.submit(r)
    eng.admit()
    assert eng.stats["shared_pages"] >= 1  # first-chunk page shared at bind
    eng.step()                             # chunk 1 lands
    eng.step()                             # chunk 2: clone re-shares page 2
    shared_mid = eng.stats["shared_pages"]
    assert shared_mid >= 2
    assert reqs[1].pages[:2] == reqs[0].pages[:2]
    while eng.step():
        pass
    # The preempted request was re-admitted via the prefix cache: total
    # shared-page count grew beyond the in-flight clone share.
    assert eng.stats["preemptions"] >= 1
    assert eng.stats["shared_pages"] > shared_mid
    assert eng.allocator.available == 5


# ---------------------------------------------------------------------------
# Satellite: bucket_len clamps to max_len before raising
# ---------------------------------------------------------------------------

def test_bucket_len_clamps_to_max_len_before_raising():
    from repro.serving.engine import bucket_len
    # Boundary: longer than the largest bucket but within max_len — clamp.
    assert bucket_len(2000, max_len=4096) == 4096
    assert bucket_len(1025, max_len=2048) == 2048
    # Within a bucket: clamp the bucket, not the prompt.
    assert bucket_len(9, max_len=12) == 12
    assert bucket_len(9, max_len=64) == 16
    # Genuinely does not fit: still raises.
    with pytest.raises(ValueError, match="max_len"):
        bucket_len(2000, max_len=1500)
    with pytest.raises(ValueError, match="largest bucket"):
        bucket_len(2000)


def test_mixed_width_buckets():
    assert engine_mod.mixed_width_buckets(1) == (1,)
    assert engine_mod.mixed_width_buckets(8) == (1, 2, 4, 8)
    assert engine_mod.mixed_width_buckets(12) == (1, 2, 4, 8, 12)
    assert engine_mod.width_bucket(3, 8) == 4
    assert engine_mod.width_bucket(9, 8) == 8
    assert engine_mod.width_bucket(0, 8) == 1


# ---------------------------------------------------------------------------
# Satellite: cross-mode stats invariants (interleaved vs stalled admission)
# ---------------------------------------------------------------------------

def test_cross_mode_counter_invariants(mha_llm):
    """On an identical workload, the two admission modes must agree on every
    work-conservation counter — same tokens prefilled, same tokens
    generated, same completions — and differ exactly where they schedule:
    interleaved admission never stalls a decode lane
    (``decode_stall_steps == 0``) while the stalled baseline must, and its
    stalls are bounded by its own prefill-chunk count (a lane can only
    stall on steps that run a prompt chunk)."""
    cfg, params = mha_llm
    stats = {}
    toks = {}
    for interleave in (True, False):
        rng = np.random.default_rng(71)
        eng = ContinuousBatchingEngine(cfg, params, batch=2, max_len=32,
                                       paged=True, page_size=PS,
                                       chunk_size=PS,
                                       prefill_interleave=interleave)
        toks[interleave] = [tuple(r.tokens)
                            for r in eng.run(_mk_requests(rng, SPEC))]
        stats[interleave] = dict(eng.stats)
    inter, stall = stats[True], stats[False]
    # Work conservation: identical totals in both modes.
    for key in ("admitted", "completed", "prefill_tokens", "gen_tokens"):
        assert inter[key] == stall[key], key
    assert inter["prefill_tokens"] == sum(n for n, _ in SPEC)
    assert inter["gen_tokens"] == sum(m for _, m in SPEC)
    # Chunk accounting: every admission carries at least one chunk, and
    # interleaved (chunk_size-bounded) admission can only split prompts
    # more finely than the stalled whole-prompt baseline — never coarser.
    for s in (inter, stall):
        assert s["prefill_chunks"] >= s["admitted"]
        assert s["prefills"] <= s["prefill_chunks"]
    assert inter["prefill_chunks"] >= stall["prefill_chunks"]
    # Scheduling difference: interleaving is exactly the removal of stalls.
    assert inter["decode_stall_steps"] == 0
    assert inter["stalled_lane_steps"] == 0
    assert stall["decode_stall_steps"] > 0
    assert stall["stalled_lane_steps"] >= stall["decode_stall_steps"]
    # A lane only stalls on a step that carried someone else's chunk.
    assert stall["decode_stall_steps"] <= stall["prefills"]
    # Scheduling never changes tokens.
    assert toks[True] == toks[False]
