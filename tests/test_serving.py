"""Serving engine tests: generation, continuous batching, determinism."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.models import lm
from repro.serving.engine import Engine, make_serve_step, sample_token


@pytest.fixture(scope="module")
def setup():
    cfg = configs.reduced(configs.get("olmo-1b"), d_model=32, vocab=128)
    params = lm.init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_generate_shapes_and_determinism(setup):
    cfg, params = setup
    eng = Engine(cfg, params, batch=2, max_len=32)
    prompts = jnp.asarray([[5, 6, 7, 8], [9, 10, 11, 12]], jnp.int32)
    out1 = eng.generate(prompts, steps=6)
    eng.reset()
    out2 = eng.generate(prompts, steps=6)
    assert out1.shape == (2, 6)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


def test_greedy_matches_decode_loop(setup):
    """Engine output == manual prefill + decode_step loop."""
    cfg, params = setup
    prompts = jnp.asarray([[3, 4, 5, 6]], jnp.int32)
    eng = Engine(cfg, params, batch=1, max_len=32)
    got = np.asarray(eng.generate(prompts, steps=4))[0]

    cache = lm.init_cache(cfg, 1, 32)
    logits, cache = lm.prefill(params, cfg, prompts, cache)
    toks = [int(jnp.argmax(logits[0]))]
    pos = jnp.asarray([4], jnp.int32)
    for _ in range(3):
        logits, cache = lm.decode_step(
            params, cfg, jnp.asarray([toks[-1]], jnp.int32), cache, pos)
        toks.append(int(jnp.argmax(logits[0])))
        pos = pos + 1
    np.testing.assert_array_equal(got, np.asarray(toks))


def test_per_row_positions_reset(setup):
    """Continuous batching: one row restarts while the other continues."""
    cfg, params = setup
    eng = Engine(cfg, params, batch=2, max_len=64)
    prompts = jnp.asarray([[5, 6, 7, 8], [9, 10, 11, 12]], jnp.int32)
    eng.prefill(prompts)
    eng.step()
    eng.pos = eng.pos.at[1].set(0)         # row 1: new request
    eng.token = eng.token.at[1].set(21)
    eng.step()
    assert int(eng.pos[0]) == 6 and int(eng.pos[1]) == 1


def test_temperature_sampling_varies(setup):
    cfg, params = setup
    step = jax.jit(make_serve_step(cfg, temperature=1.0))
    cache = lm.init_cache(cfg, 4, 16)
    tok = jnp.asarray([3, 3, 3, 3], jnp.int32)
    pos = jnp.zeros((4,), jnp.int32)
    seen = set()
    key = jax.random.PRNGKey(0)
    for _ in range(5):
        key, sub = jax.random.split(key)
        tok, cache, pos = step(params, cache, tok, pos, sub)
        seen.update(np.asarray(tok).tolist())
    assert len(seen) > 1


def test_sample_token_greedy_vs_random():
    logits = jnp.asarray([[0.0, 5.0, 1.0]])
    assert int(sample_token(logits, jax.random.PRNGKey(0), 0.0)[0]) == 1
