"""Evaluator agent: conflict scan, auto-reconciliation, scoring."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.agents import evaluator
from repro.core import doc as doc_mod, merge


def _doc_with_dup(symbol_tok=5):
    d = doc_mod.empty(4, 32)
    # Slot 0 and slot 1 both declare symbol (tok % 64) via tok=5.
    d = doc_mod.append(d, 0, jnp.asarray([symbol_tok, 7, 0, 0]), 2)
    d = doc_mod.append(d, 1, jnp.asarray([symbol_tok, 9, 0, 0]), 2)
    return d


def test_scan_finds_duplicates():
    rep = evaluator.scan(_doc_with_dup())
    assert len(rep.conflicts) == 1
    c = rep.conflicts[0]
    assert (c.first_slot, c.dup_slot) == (0, 1)
    assert rep.total_declarations == 2


def test_reconcile_fixes_and_is_crdt_safe():
    d = _doc_with_dup()
    fixed, rep = evaluator.reconcile(d, patch_slot=3)
    assert rep.fixed == 1 and not rep.flagged
    # The patch is an ordinary append: merging the patched doc with the
    # original (any order) yields the patched doc (monotone fix).
    m1 = merge.join(fixed, d)
    m2 = merge.join(d, fixed)
    assert int(doc_mod.digest(m1)) == int(doc_mod.digest(m2)) \
        == int(doc_mod.digest(fixed))
    # Patch record: [old_token, dup_slot, fresh_token].
    toks = np.asarray(fixed.tokens)[3, :3]
    assert toks[0] == 5 and toks[1] == 1
    fresh = int(toks[2])
    assert fresh % 13 == 5 and fresh % 64 != 5 % 64


def test_scores_monotone_in_conflicts():
    clean = doc_mod.empty(2, 16)
    clean = doc_mod.append(clean, 0, jnp.asarray([5, 1, 0, 0]), 2)
    s_clean = evaluator.score(clean)
    s_dup = evaluator.score(_doc_with_dup())
    assert s_clean["code_quality"] >= s_dup["code_quality"]
    assert s_clean["conflicts_per_1k"] == 0.0
    assert s_dup["conflicts_per_1k"] > 0.0
